#!/usr/bin/env python
"""Docs/CLI drift gate (CI docs job): the docs tree must mention every
user-facing name the code registers, and every command the docs show must
parse against the real CLI.

Three greps, no imports of the package (the gate must run on a docs-only
checkout in seconds):

  * every ``--flag`` that ``src/repro/launch/train.py`` adds must appear
    somewhere in the docs tree (README.md, EXPERIMENTS.md, docs/*.md) —
    a flag nobody documents is a flag nobody finds;
  * every strategy the registry carries (``register_strategy("name")``)
    and every benchmark tag ``benchmarks/run.py`` accepts (``want("tag")``)
    must likewise be documented;
  * every ``python -m repro.launch.train ...`` invocation inside a fenced
    code block of README.md / EXPERIMENTS.md must use only flags the CLI
    actually defines — the stale-command direction of the same contract
    (docs showing ``--old-flag`` fail here the day the flag is renamed).

  python tools/check_docs_sync.py [--repo-root DIR]

Exit status 0 = docs and CLI agree; 1 = drift (each item printed).
"""
from __future__ import annotations

import argparse
import glob
import os
import re
import sys

_ADD_ARG_RE = re.compile(r"add_argument\(\s*\"(--[a-z][a-z0-9-]*)\"")
_REGISTER_RE = re.compile(r"@?register_strategy\(\"(\w+)\"\)")
_WANT_RE = re.compile(r"want\(\"(\w+)\"\)")
_DOC_FLAG_RE = re.compile(r"(--[a-z][a-z0-9-]*)")


def docs_files(root: str) -> list:
    files = [os.path.join(root, "README.md"),
             os.path.join(root, "EXPERIMENTS.md")]
    files += sorted(glob.glob(os.path.join(root, "docs", "*.md")))
    return [f for f in files if os.path.exists(f)]


def read(path: str) -> str:
    with open(path, encoding="utf-8") as f:
        return f.read()


def cli_flags(root: str) -> set:
    src = read(os.path.join(root, "src", "repro", "launch", "train.py"))
    return set(_ADD_ARG_RE.findall(src))


def registered_strategies(root: str) -> set:
    names = set()
    for path in glob.glob(os.path.join(root, "src", "repro", "**", "*.py"),
                          recursive=True):
        names.update(_REGISTER_RE.findall(read(path)))
    return names


def bench_tags(root: str) -> set:
    return set(_WANT_RE.findall(read(os.path.join(root, "benchmarks",
                                                  "run.py"))))


def documented_commands(path: str) -> list:
    """(lineno, command) for every `... repro.launch.train ...` invocation
    inside a fenced code block, with backslash continuations joined."""
    out = []
    in_fence = False
    pending, pending_line = None, 0
    for lineno, line in enumerate(read(path).splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if not in_fence:
            continue
        if pending is not None:
            pending += " " + line.strip().rstrip("\\")
            if not line.rstrip().endswith("\\"):
                out.append((pending_line, pending))
                pending = None
            continue
        if "repro.launch.train" in line:
            cmd = line.strip().rstrip("\\")
            if line.rstrip().endswith("\\"):
                pending, pending_line = cmd, lineno
            else:
                out.append((lineno, cmd))
    if pending is not None:
        out.append((pending_line, pending))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repo-root",
                    default=os.path.join(os.path.dirname(__file__), ".."))
    args = ap.parse_args(argv)
    root = os.path.abspath(args.repo_root)

    errors = []
    docs = docs_files(root)
    corpus = "\n".join(read(f) for f in docs)

    flags = cli_flags(root)
    if not flags:
        errors.append("no CLI flags parsed from launch/train.py "
                      "(regex drift? fix check_docs_sync, not the docs)")
    for flag in sorted(flags):
        if flag not in corpus:
            errors.append(f"undocumented CLI flag: {flag} "
                          "(launch/train.py defines it; no doc mentions it)")

    strategies = registered_strategies(root)
    if len(strategies) < 4:
        errors.append(f"only {sorted(strategies)} strategies parsed from "
                      "the registry (regex drift?)")
    for name in sorted(strategies):
        if not re.search(rf"\b{re.escape(name)}\b", corpus):
            errors.append(f"undocumented strategy: {name!r} is registered "
                          "but no doc mentions it")

    tags = bench_tags(root)
    for tag in sorted(tags):
        if not re.search(rf"\b{re.escape(tag)}\b", corpus):
            errors.append(f"undocumented benchmark tag: {tag!r} "
                          "(benchmarks/run.py --only accepts it)")

    # stale-command direction: flags used in documented train commands
    # must exist in the CLI
    for path in docs:
        for lineno, cmd in documented_commands(path):
            # only the segment after the module name is train's argv
            # (tools/launch_procs.py wrappers put launcher flags before it)
            argv_part = cmd.split("repro.launch.train", 1)[1]
            for flag in _DOC_FLAG_RE.findall(argv_part):
                if flag not in flags:
                    errors.append(
                        f"{os.path.relpath(path, root)}:{lineno}: stale "
                        f"flag {flag} in documented command (not defined "
                        "by launch/train.py)")

    for e in errors:
        print(e)
    print(f"checked {len(flags)} flags, {len(strategies)} strategies, "
          f"{len(tags)} bench tags against {len(docs)} docs: "
          f"{'OK' if not errors else f'{len(errors)} drift item(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
