#!/usr/bin/env python
"""Markdown link checker for the docs layer (CI docs job).

Scans the given markdown files for inline links/images and verifies every
*relative* target resolves: the file exists, and when the link carries a
``#fragment`` the target file contains a heading whose GitHub-style slug
matches. External schemes (http/https/mailto) are not fetched — this
checker guards the repo-internal cross-links (README <-> docs <->
EXPERIMENTS) that otherwise rot silently when files move or headings are
reworded.

  python tools/check_links.py README.md EXPERIMENTS.md docs/*.md

Exit status 0 = all links resolve; 1 = at least one broken link (each
printed as ``file:line: broken link``).
"""
from __future__ import annotations

import re
import sys

# inline markdown links/images: [text](target) — code spans are stripped
# first so `[x](y)` examples inside backticks don't count
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
_CODE_SPAN_RE = re.compile(r"`[^`]*`")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
_EXTERNAL = ("http://", "https://", "mailto:")


def slugify(heading: str) -> str:
    """GitHub-style heading slug: strip markdown emphasis/code markers,
    lowercase, drop punctuation, spaces -> dashes."""
    text = re.sub(r"[`*_]", "", heading.strip())
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # linked headings
    text = text.lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return re.sub(r" ", "-", text)


def heading_slugs(path) -> set:
    slugs = set()
    counts = {}
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for line in f:
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = _HEADING_RE.match(line)
            if not m:
                continue
            slug = slugify(m.group(1))
            n = counts.get(slug, 0)
            counts[slug] = n + 1
            slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def check_file(path) -> list:
    import os
    errors = []
    base = os.path.dirname(os.path.abspath(path))
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for m in _LINK_RE.finditer(_CODE_SPAN_RE.sub("", line)):
                target = m.group(1)
                if target.startswith(_EXTERNAL):
                    continue
                ref, _, frag = target.partition("#")
                dest = os.path.normpath(os.path.join(base, ref)) if ref \
                    else os.path.abspath(path)
                if not os.path.exists(dest):
                    errors.append(f"{path}:{lineno}: broken link "
                                  f"{target!r} -> {dest} (missing file)")
                    continue
                if frag and dest.endswith(".md"):
                    if frag not in heading_slugs(dest):
                        errors.append(f"{path}:{lineno}: broken anchor "
                                      f"{target!r} (no heading "
                                      f"#{frag} in {dest})")
    return errors


def main(argv) -> int:
    if not argv:
        print("usage: check_links.py FILE.md [FILE.md ...]",
              file=sys.stderr)
        return 2
    errors = []
    for path in argv:
        errors.extend(check_file(path))
    for e in errors:
        print(e)
    print(f"checked {len(argv)} files: "
          f"{'OK' if not errors else f'{len(errors)} broken'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
