#!/usr/bin/env python
"""Run-trace inspector: validate, export to Chrome/Perfetto, and print the
measured-vs-model drift table.

Input is a merged run trace written by ``--trace-out`` (or the un-merged
``PATH.e*p*.jsonl`` streams of a crashed/aborted run — they are merged in
memory). Three outputs:

  * **summary** — event counts and total span time per category
    (executor / schedule / resilience / checkpoint), plus the tracer's own
    self-accounted overhead.
  * **Chrome export** (``--chrome out.json``) — wraps the events in a
    ``{"traceEvents": [...]}`` document that chrome://tracing and
    https://ui.perfetto.dev load directly (Open trace file).
  * **drift table** (default) — regresses per-level sync costs out of the
    cycle spans and compares them against `benchmarks/comm_model.py`
    predictions for the run's topology. Each non-compile cycle span obeys

        dur ≈ n_steps * t_step + Σ_level n_syncs_level * t_level

    with (n_steps, n_syncs) carried in the span args, so a least-squares
    fit over all cycles yields the measured per-step compute time and the
    measured marginal cost of one sync at EVERY level — exactly the
    readings the ROADMAP's self-tuning controller needs, and the numbers
    the analytic model must be confronted with. Fresh-compile and
    fallback cycles are excluded (their duration is dominated by XLA).

Usage:

    python tools/trace_report.py runs/trace.jsonl
    python tools/trace_report.py runs/trace.jsonl --chrome trace_ui.json
    python tools/trace_report.py runs/trace.jsonl --validate
    python tools/trace_report.py runs/trace.jsonl --json report.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional

import numpy as np

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _REPO)                      # benchmarks.comm_model
sys.path.insert(0, os.path.join(_REPO, "src"))  # repro

from repro.obs.trace import (RUN_METADATA, load_events, to_chrome,  # noqa: E402
                             validate_event)


def validate(events: List[dict]) -> List[str]:
    """Schema errors over a whole trace (empty list = valid)."""
    errors = []
    for i, ev in enumerate(events):
        err = validate_event(ev)
        if err is not None:
            errors.append(f"event {i}: {err}")
    return errors


def run_metadata(events: List[dict]) -> Optional[dict]:
    """The run_metadata args (first occurrence — every process emits an
    identical copy)."""
    for ev in events:
        if ev.get("name") == RUN_METADATA:
            return ev.get("args") or {}
    return None


def summarize(events: List[dict]) -> Dict[str, dict]:
    """Per-category event counts and total span seconds, plus the
    tracer_self overhead under the "_tracer" key."""
    out: Dict[str, dict] = {}
    for ev in events:
        if ev.get("name") == "tracer_self":
            agg = out.setdefault("_tracer", {"events": 0, "overhead_s": 0.0})
            agg["events"] += int(ev["args"].get("events", 0))
            agg["overhead_s"] += ev["args"].get("overhead_us", 0.0) / 1e6
            continue
        cat = ev.get("cat", "?")
        agg = out.setdefault(cat, {"events": 0, "spans": 0, "span_s": 0.0})
        agg["events"] += 1
        if ev.get("ph") == "X":
            agg["spans"] += 1
            agg["span_s"] += ev.get("dur", 0) / 1e6
    return out


def fit_cycle_costs(events: List[dict]) -> Optional[dict]:
    """Least-squares decomposition of cycle durations into per-step and
    per-level-sync costs.

    Every clean cycle span (no fresh compile, no fallback) is one sample
    of ``dur = steps * t_step + Σ n_syncs_l * t_l``; samples from all
    processes pool into one fit (each process dispatches the same cycles,
    so they are repeated measurements of the same costs). Returns
    ``{"t_step_s", "levels": {name: t_sync_s}, "samples", "excluded",
    "residual_frac"}`` or None when no clean cycle carries sync args.
    Negative coefficients are clamped to 0 in the output (a level whose
    syncs are fully hidden by overlap can fit slightly negative) — the
    raw value is kept under "raw"."""
    rows = []
    for ev in events:
        if ev.get("name") != "cycle" or ev.get("ph") != "X":
            continue
        args = ev.get("args") or {}
        if "syncs" not in args or "steps" not in args:
            continue
        rows.append((args, ev.get("dur", 0) / 1e6,
                     args.get("fresh_compile") or args.get("fallback")))
    if not rows:
        return None
    levels = sorted({name for args, _, _ in rows
                     for name in args["syncs"]})
    clean = [(a, d) for a, d, excl in rows if not excl]
    excluded = len(rows) - len(clean)
    if len(clean) < 1 + len(levels):
        return {"t_step_s": None, "levels": {}, "samples": len(clean),
                "excluded": excluded, "residual_frac": None,
                "note": f"{len(clean)} clean cycle(s) cannot determine "
                        f"{1 + len(levels)} coefficients"}
    X = np.array([[a["steps"]] + [a["syncs"].get(n, 0) for n in levels]
                  for a, _ in clean], dtype=float)
    y = np.array([d for _, d in clean])
    coef, _, rank, _ = np.linalg.lstsq(X, y, rcond=None)
    resid = float(np.abs(X @ coef - y).sum() / max(y.sum(), 1e-12))
    fit = {"t_step_s": max(float(coef[0]), 0.0),
           "levels": {n: max(float(c), 0.0)
                      for n, c in zip(levels, coef[1:])},
           "raw": {"t_step_s": float(coef[0]),
                   **{n: float(c) for n, c in zip(levels, coef[1:])}},
           "samples": len(clean), "excluded": excluded,
           "residual_frac": resid, "rank": int(rank)}
    if rank < 1 + len(levels):
        fit["note"] = ("rank-deficient fit: some sync counts never vary "
                       "independently across cycles")
    return fit


def _spec_from_meta(meta: dict):
    """The run's TopologySpec: the explicit spec from metadata, or the
    implicit 2-level chip/pod shape of a --nodes run (default per-depth
    bandwidths — the same defaults the model would have used)."""
    from repro.topo import TopologySpec
    if meta.get("topology"):
        return TopologySpec.load(meta["topology"])
    return TopologySpec.load(
        f"chip:{meta.get('local_world', 1)} x pod:{meta.get('n_replicas', 2)}")


def drift_table(events: List[dict], *,
                fit: Optional[dict] = None) -> Optional[List[dict]]:
    """Measured-vs-model rows, one per sync level of the run's topology.

    Measured values come from `fit_cycle_costs`; model values from
    `benchmarks.comm_model.topology_level_costs` under the run's wire
    format and parameter bytes (run_metadata). Levels whose measured
    coefficient is unavailable (zero syncs recorded, or a rank-deficient
    fit) still get a row with ``measured_s=None`` — coverage over every
    sync level is the point. Level 0 (the intra-replica gradient
    all-reduce) is not a sync level: it rides inside t_step."""
    from benchmarks.comm_model import topology_level_costs

    meta = run_metadata(events)
    if meta is None or not meta.get("param_bytes"):
        return None
    if fit is None:
        fit = fit_cycle_costs(events)
    spec = _spec_from_meta(meta)
    wire = meta.get("wire_format") or "bf16"
    model_rows = topology_level_costs(spec, float(meta["param_bytes"]),
                                      b_max=meta.get("b_max", 4),
                                      wire_format=wire)
    measured = dict(fit["levels"]) if fit else {}
    # the fit keys sync levels by controller name: "_outer" for the
    # outermost, the level's own name for inner levels
    out = []
    for row in model_rows[1:]:  # skip level 0: per-step, not per-sync
        key = "_outer" if row["name"] == spec.outer.name else row["name"]
        m = measured.pop(key, None)
        out.append({"level": row["name"], "members": row["members"],
                    "wire": row["wire"], "period": row["period"],
                    "model_sync_s": row["sync_s"],
                    "measured_sync_s": m,
                    "drift_x": (m / row["sync_s"]
                                if m is not None and row["sync_s"] > 0
                                else None)})
    for key, m in measured.items():  # fit levels the spec no longer names
        out.append({"level": key, "members": None, "wire": None,
                    "period": None, "model_sync_s": None,
                    "measured_sync_s": m, "drift_x": None})
    return out


def build_report(events: List[dict]) -> dict:
    """Everything the CLI prints, as one JSON-serializable dict (the
    benchmarks and the CI trace-smoke lane consume this via --json)."""
    errors = validate(events)
    fit = fit_cycle_costs(events)
    drift = drift_table(events, fit=fit)
    return {"n_events": len(events),
            "schema_errors": errors,
            "metadata": run_metadata(events),
            "summary": summarize(events),
            "cycle_fit": fit,
            "drift": drift}


def _fmt_s(v) -> str:
    return "      --" if v is None else f"{v * 1e3:8.3f}"


def print_report(rep: dict, *, out=sys.stdout) -> None:
    p = lambda *a: print(*a, file=out)
    meta = rep["metadata"] or {}
    p(f"trace: {rep['n_events']} events, "
      f"{len(rep['schema_errors'])} schema error(s)")
    if meta:
        p(f"run: arch={meta.get('arch')} strategy={meta.get('strategy')} "
          f"steps={meta.get('steps')} procs={meta.get('procs')} "
          f"topology={meta.get('topology') or 'implicit'}")
    p("\nper-category:")
    for cat, agg in sorted(rep["summary"].items()):
        if cat == "_tracer":
            p(f"  tracer self-overhead: {agg['overhead_s'] * 1e3:.1f} ms "
              f"over {agg['events']} events")
        else:
            p(f"  {cat:<11} {agg['events']:>5} events  "
              f"{agg['spans']:>4} spans  {agg['span_s']:8.3f} s")
    fit = rep["cycle_fit"]
    if fit:
        p(f"\ncycle fit: {fit['samples']} clean cycles "
          f"({fit['excluded']} compile/fallback excluded), "
          f"t_step={_fmt_s(fit['t_step_s'])} ms, "
          f"residual={fit['residual_frac']:.1%}"
          if fit.get("residual_frac") is not None else
          f"\ncycle fit: {fit.get('note', 'unavailable')}")
        if fit.get("note") and fit.get("residual_frac") is not None:
            p(f"  note: {fit['note']}")
    if rep["drift"]:
        p("\ndrift table (per-level sync cost, measured vs comm_model):")
        p(f"  {'level':<10} {'members':>7} {'wire':>5} {'period':>6} "
          f"{'model ms':>9} {'meas ms':>9} {'drift':>7}")
        for row in rep["drift"]:
            drift = (f"{row['drift_x']:6.2f}x" if row["drift_x"] is not None
                     else "     --")
            p(f"  {row['level']:<10} {str(row['members']):>7} "
              f"{str(row['wire']):>5} {str(row['period']):>6} "
              f"{_fmt_s(row['model_sync_s'])} "
              f"{_fmt_s(row['measured_sync_s'])} {drift}")
        p("  (drift > 1: the wire is slower than modeled — recalibrate "
          "ClusterModel bandwidths; ~1: the model holds)")
    elif rep["metadata"] is None:
        p("\nno run_metadata event: drift table unavailable (trace written "
          "without --trace-out's entry-point metadata?)")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("trace", help="merged run trace (or the base path of "
                                  "un-merged .e*p*.jsonl streams)")
    ap.add_argument("--chrome", metavar="OUT",
                    help="export a chrome://tracing / Perfetto-loadable "
                         "trace-event JSON document")
    ap.add_argument("--json", metavar="OUT",
                    help="write the full report (summary+fit+drift) as "
                         "JSON")
    ap.add_argument("--validate", action="store_true",
                    help="exit non-zero if any event fails the schema")
    args = ap.parse_args()

    events = load_events(args.trace)
    rep = build_report(events)
    print_report(rep)
    if args.chrome:
        with open(args.chrome, "w") as f:
            json.dump(to_chrome(events), f)
        print(f"chrome trace -> {args.chrome} "
              f"(load in chrome://tracing or ui.perfetto.dev)")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rep, f, indent=1)
        print(f"report -> {args.json}")
    if args.validate and rep["schema_errors"]:
        for e in rep["schema_errors"][:20]:
            print(f"SCHEMA: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
