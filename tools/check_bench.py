#!/usr/bin/env python
"""Perf-regression gate over the committed BENCH_*.json records.

CI regenerates each record from scratch (bench smoke) and then runs this
gate against the version committed in the repo: instead of merely
uploading artifacts, the job FAILS when a fresh record regresses past
tolerance. Three kinds of checks per record, declared in POLICIES:

  * exact     — structural facts that must never move (collective counts,
                schedule shapes, zero resume deltas). Always hard.
  * bounds    — machine-independent absolute bounds (byte ratios, quality
                deltas): `(min, max)`, either side None.
  * baseline  — machine-RELATIVE comparison against the committed value:
                `("higher"|"lower", rel_tol)` — a fresh "higher is better"
                metric must be >= committed * (1 - rel_tol). Tolerances
                are wide because CI runners differ from the machines that
                produced the committed records; the gated metrics are
                same-machine ratios (fused-vs-per-leaf speedup, degraded
                exchange cost), which travel much better than wall-clock.

Usage (what .github/workflows/ci.yml runs):

    cp BENCH_exchange.json /tmp/baseline/          # before the bench rm
    python -m benchmarks.run --only exchange --quick
    python tools/check_bench.py --baseline-dir /tmp/baseline \
        --fresh-dir . --records BENCH_exchange.json

Exit status 0 = no regression; 1 = any check failed (each failure is
printed). To see the gate catch a regression, tamper with a fresh value:
`python tools/check_bench.py --self-test` does exactly that in-memory.
"""
from __future__ import annotations

import argparse
import copy
import json
import os
import sys

# key -> ("exact", value-from-baseline?) | ("bounds", (lo, hi))
#     | ("bounds_strict", (lo, hi)) | ("baseline", (direction, rel_tol))
#     | ("custom", check-name)
# "exact" with None compares against the BASELINE record's value. Step-count
# -dependent values must NOT use "exact"/None: CI regenerates records with
# --quick (shorter runs) while the committed baselines are full runs.
# "bounds" is inclusive; "bounds_strict" fails AT the bound too — for
# invariants like "recovery took measurable time" (> 0) and "the hierarchy
# still pays off" (< 1).
CUSTOM_CHECKS = {
    # every level of the 3-level schedule actually synced
    "sync_counts_positive": lambda v: (
        None if isinstance(v, dict) and v and all(c > 0 for c in v.values())
        else f"expected positive per-level sync counts, got {v!r}"),
}

POLICIES = {
    "BENCH_exchange.json": {
        "all_reduce_ops_fused": ("exact", 1),
        "all_reduce_ops_per_leaf": ("bounds", (2, None)),
        "int8_vs_bf16_bytes": ("bounds_strict", (None, 0.52)),
        # fused arena must stay a win over per-leaf on the same machine
        "fused_speedup_f32": ("baseline", ("higher", 0.5)),
        "fused_speedup_bf16": ("baseline", ("higher", 0.5)),
    },
    "BENCH_resilience.json": {
        "resume_param_delta": ("exact", 0.0),
        "resume_loss_delta": ("exact", 0.0),
        "invalidations_per_membership_event": ("exact", 1.0),
        "loss_delta_k1": ("bounds", (-0.5, 0.5)),
        "loss_delta_k2": ("bounds", (-0.5, 0.5)),
        "recovery_s_mean": ("bounds_strict", (0.0, None)),
        "degraded_exchange_cost_ratio": ("baseline", ("higher", 0.25)),
        # live fault plane (real SIGKILL + supervised regroup): recovered
        # params must equal the simulated oracle EXACTLY, detection must
        # land inside the watchdog budget, and each recovery phase must
        # have measurable (nonzero) cost
        "live_oracle_param_delta": ("exact", 0.0),
        "live_detect_within_budget": ("exact", 1.0),
        "live_detect_s": ("bounds_strict", (0.0, None)),
        "live_regroup_s": ("bounds_strict", (0.0, None)),
        "live_resume_s": ("bounds_strict", (0.0, None)),
    },
    "BENCH_overlap.json": {
        # at least one macro-cycle actually ran the overlap dispatch path
        "overlap_cycles": ("bounds_strict", (0, None)),
        # the headline claim: the overlap executor hides >= 30% of the
        # measured blocking exchange time on the real 2-process gloo
        # runtime (visible-after-compute vs blocked-before-compute legs)
        "overlap_hidden_fraction": ("bounds", (0.3, None)),
        # serial_exchange changes host waiting, never numerics
        "loss_delta_overlap_vs_serial": ("exact", 0.0),
        # one-cycle-stale merge may move the loss, but boundedly
        "loss_delta_overlap_vs_off": ("bounds", (-0.5, 0.5)),
        # analytic model: overlap never prices above the blocking schedule
        "model_step_ratio_overlap_vs_blocking": ("bounds_strict", (None, 1.0)),
    },
    "BENCH_obs.json": {
        # the ISSUE 8 headline: tracing costs <= 3% of the tracing-off
        # wall time (tracer self-accounted overhead vs the untraced leg)
        "trace_overhead_frac": ("bounds", (None, 0.03)),
        # the merged 2-process trace is schema-valid Chrome trace JSON
        "trace_valid": ("exact", 1.0),
        "trace_events": ("bounds_strict", (0, None)),
        # spans/events from every layer: executor, schedule, resilience,
        # checkpoint, comm meters, run metadata
        "trace_has_required_cats": ("exact", 1.0),
        # the drift table prices every sync level of the 3-level topology
        "drift_levels_covered": ("bounds", (2, None)),
    },
    "BENCH_strategies.json": {
        # the whole registered family ran, stayed finite, and trained
        "n_strategies": ("exact", 6.0),
        "registry_covers_all": ("exact", 1.0),
        "all_finite": ("exact", 1.0),
        "trains_all": ("exact", 1.0),
        # macro executor == per-step reference across every strategy
        "macro_vs_per_step_max_delta": ("bounds", (None, 1e-4)),
        # gossip's single partner copy must strictly undercut the sync
        # ring, in wire bytes AND modeled step time; the periodic family
        # amortizes its ring over B, so it must undercut sync too
        "bytes_per_step_gossip_vs_sync": ("bounds_strict", (None, 1.0)),
        "bytes_per_step_easgd_vs_sync": ("bounds_strict", (None, 1.0)),
        "bytes_per_step_downpour_vs_sync": ("bounds_strict", (None, 1.0)),
        "model_step_ratio_gossip_vs_sync": ("bounds_strict", (None, 1.0)),
        "model_step_ratio_daso_vs_sync": ("bounds_strict", (None, 1.0)),
    },
    "BENCH_tuning.json": {
        # the self-tuning headline: a tuned run that DISCOVERS a DCN
        # degradation by probing must finish strictly cheaper on the
        # simulated clock than a static run that never learns of it
        "tuned_vs_static_sim_time_ratio": ("bounds_strict", (None, 1.0)),
        # ...and discover it within K <= 3 probe cycles of the event
        "adapt_cycles": ("bounds", (None, 3)),
        "retune_events": ("bounds_strict", (0, None)),
        # autotune on a healthy cluster (measured == nominal) is a
        # bit-exact no-op: the probe never perturbs numerics
        "noop_retune_param_delta": ("exact", 0.0),
        "noop_retune_loss_delta": ("exact", 0.0),
        # skew-sorted groups waste strictly less inner-barrier wait
        "reshuffle_wait_ratio": ("bounds_strict", (None, 1.0)),
    },
    "BENCH_topology.json": {
        "two_level_param_delta": ("exact", 0.0),
        "two_level_loss_delta": ("exact", 0.0),
        "three_level_inner_periods": ("exact", None),
        "three_level_sync_counts": ("custom", "sync_counts_positive"),
        # hierarchy must keep paying off when the DCN degrades
        "analytic_step_ratio_3v2_degraded_dcn": ("bounds_strict", (None, 1.0)),
        "analytic_step_ratio_3v2": ("baseline", ("lower", 0.25)),
    },
}


def check_record(name: str, fresh: dict, baseline: dict, *,
                 expect_quick: bool = False) -> list:
    failures = []
    if expect_quick and fresh.get("config", {}).get("quick") is not True:
        failures.append(f"{name}: fresh record was not generated with "
                        "--quick (a crashed quick bench must not be "
                        "papered over by a stale full-mode record)")
    fd, bd = fresh.get("derived", {}), baseline.get("derived", {})
    for key, (kind, arg) in POLICIES[name].items():
        if key not in fd:
            failures.append(f"{name}: fresh record lacks {key!r}")
            continue
        v = fd[key]
        if kind == "exact":
            want = bd.get(key) if arg is None else arg
            if v != want:
                failures.append(f"{name}: {key} = {v!r}, expected {want!r}")
        elif kind in ("bounds", "bounds_strict"):
            lo, hi = arg
            strict = kind == "bounds_strict"
            if lo is not None and (v <= lo if strict else v < lo):
                failures.append(f"{name}: {key} = {v} "
                                f"{'<=' if strict else '<'} floor {lo}")
            if hi is not None and (v >= hi if strict else v > hi):
                failures.append(f"{name}: {key} = {v} "
                                f"{'>=' if strict else '>'} ceiling {hi}")
        elif kind == "custom":
            err = CUSTOM_CHECKS[arg](v)
            if err is not None:
                failures.append(f"{name}: {key}: {err}")
        elif kind == "baseline":
            if key not in bd:
                failures.append(f"{name}: baseline lacks {key!r}")
                continue
            direction, tol = arg
            ref = bd[key]
            if direction == "higher" and v < ref * (1 - tol):
                failures.append(
                    f"{name}: {key} regressed: {v:.4g} < committed "
                    f"{ref:.4g} * (1 - {tol}) — perf regression")
            if direction == "lower" and v > ref * (1 + tol):
                failures.append(
                    f"{name}: {key} regressed: {v:.4g} > committed "
                    f"{ref:.4g} * (1 + {tol}) — perf regression")
    return failures


def self_test() -> int:
    """Prove the gate fails on an injected regression (run locally and in
    CI once per change to this file)."""
    base = {"derived": {
        "all_reduce_ops_fused": 1, "all_reduce_ops_per_leaf": 112,
        "int8_vs_bf16_bytes": 0.51, "fused_speedup_f32": 1.79,
        "fused_speedup_bf16": 1.70}}
    ok = check_record("BENCH_exchange.json", copy.deepcopy(base), base)
    if ok:
        print("self-test: clean record unexpectedly failed:", ok)
        return 1
    bad = copy.deepcopy(base)
    bad["derived"]["fused_speedup_f32"] = 0.6   # injected perf regression
    bad["derived"]["all_reduce_ops_fused"] = 3  # injected structural break
    fails = check_record("BENCH_exchange.json", bad, base)
    if len(fails) != 2:
        print("self-test: injected regression not caught:", fails)
        return 1
    print("self-test OK: injected regression caught:")
    for f in fails:
        print("  ", f)
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", default=None,
                    help="directory holding the committed records")
    ap.add_argument("--fresh-dir", default=".",
                    help="directory holding the regenerated records")
    ap.add_argument("--records", nargs="+", default=sorted(POLICIES),
                    help="which BENCH_*.json files to gate")
    ap.add_argument("--expect-quick", action="store_true",
                    help="require fresh records to carry config.quick == "
                         "true (CI regenerates with --quick; this catches "
                         "a stale full-mode record standing in for a "
                         "crashed bench)")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the gate catches an injected regression")
    args = ap.parse_args()
    if args.self_test:
        sys.exit(self_test())
    if args.baseline_dir is None:
        ap.error("--baseline-dir is required (or use --self-test)")

    failures = []
    for name in args.records:
        if name not in POLICIES:
            failures.append(f"no gate policy for {name!r} "
                            f"(known: {sorted(POLICIES)})")
            continue
        fresh_p = os.path.join(args.fresh_dir, name)
        base_p = os.path.join(args.baseline_dir, name)
        try:
            with open(fresh_p) as f:
                fresh = json.load(f)
        except OSError as e:
            failures.append(f"{name}: fresh record unreadable: {e}")
            continue
        try:
            with open(base_p) as f:
                baseline = json.load(f)
        except OSError as e:
            failures.append(f"{name}: committed baseline unreadable: {e}")
            continue
        fails = check_record(name, fresh, baseline,
                             expect_quick=args.expect_quick)
        status = "FAIL" if fails else "ok"
        print(f"[check_bench] {name}: {status} "
              f"({len(POLICIES[name])} checks)")
        failures.extend(fails)
    for f in failures:
        print("  REGRESSION:", f)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
