#!/usr/bin/env python
"""Spawn N local coordinator-connected `jax.distributed` processes.

The development/CI harness for the multi-process runtime
(src/repro/launch/distributed.py): each child is one "host" of the
topology, pinned to ``world / N`` CPU devices via
``--xla_force_host_platform_device_count``, joined through a coordinator
on a free localhost port. Children inherit a *explicitly constructed*
environment — ``JAX_PLATFORMS`` and ``XLA_FLAGS`` are always set (CPU by
default) so local runs match CI, and the ``DASO_COORDINATOR`` /
``DASO_NUM_PROCS`` / ``DASO_PROC_ID`` variables carry the process-group
identity that `repro.launch.distributed.DistributedConfig.from_env`
reads.

Everything after ``--`` goes to the target module verbatim
(``repro.launch.train`` by default); ``--distributed`` is appended for
the default module if missing. The per-process device count is derived
from a ``--topology`` spec in the child args when present (world / N),
or set with ``--local-devices``.

  # 2-process distributed quickstart (matches the CI multiprocess-smoke job)
  python tools/launch_procs.py --procs 2 -- \
      --arch llama3.2-1b --topology "chip:1 x host:2 x pod:2" \
      --steps 40 --per-node-batch 2 --seq-len 16 --metrics-out /tmp/mp.json

  # same run, single process: the SPMD oracle the 2-process run is
  # bit-exact with (tests/test_multiprocess.py)
  python tools/launch_procs.py --procs 1 -- ...same args...

Exit status: 0 iff every child exited 0. The first failure terminates the
rest of the group (a hung coordinator peer would otherwise block forever).

Supervisor mode (``--supervise``, implied by ``--kill``) adds the live
fault-tolerance plane (src/repro/resilience/runtime.py): children write
heartbeats into a shared run directory, ``--kill proc:step`` SIGKILLs one
child once its heartbeat reaches the given training step, and a detected
death triggers a *regroup* instead of a group failure — survivors are torn
down and relaunched under a fresh coordinator epoch (new port), resuming
from the newest intact checkpoint with the death replayed as a PR-3
membership-mask crash event. ``--elastic-rejoin`` restarts the full process
count instead, the reborn ranks rejoining via the reseed path. ``--report``
writes detection/regroup/resume timings as JSON.

  # kill proc 2 at step 6; survivors regroup and finish
  python tools/launch_procs.py --procs 4 --kill 2:6 --report /tmp/r.json -- \
      --arch llama3.2-1b --tiny --topology "chip:1 x host:2 x pod:2" \
      --steps 16 --ckpt /tmp/ck --ckpt-every 1 --metrics-out /tmp/m.json
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import socket
import subprocess
import sys
import tempfile
import threading
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def free_port() -> int:
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def child_flag_value(child_args, flag: str):
    """Value of `--flag SPEC` / `--flag=SPEC` in the child args, or None.
    Last occurrence wins, matching argparse."""
    val = None
    for i, a in enumerate(child_args):
        if a == flag:
            if i + 1 >= len(child_args):
                raise SystemExit(f"{flag} given without a value")
            val = child_args[i + 1]
        elif a.startswith(flag + "="):
            val = a.split("=", 1)[1]
    return val


def topology_spec(child_args):
    """The parsed TopologySpec of the child run, or None."""
    spec_arg = child_flag_value(child_args, "--topology")
    if spec_arg is None:
        return None
    sys.path.insert(0, SRC)
    from repro.topo import TopologySpec
    return TopologySpec.load(spec_arg)


def merge_trace(child_args) -> None:
    """Merge the per-process trace streams of a --trace-out run into the
    single run trace at that path. Called after the group exits — the only
    point where no worker can still be appending; crashed workers' partial
    streams merge fine (every event line is self-contained JSONL)."""
    base = child_flag_value(child_args, "--trace-out")
    if base is None:
        return
    sys.path.insert(0, SRC)
    from repro.obs.trace import merge_streams
    say = lambda m: print(f"[launch_procs] {m}", file=sys.stderr)
    try:
        if merge_streams(base, log=say) is None:
            say(f"no trace streams found at {base}.e*p*.jsonl")
    except (OSError, ValueError) as e:
        say(f"trace merge failed: {e}")


def derive_local_devices(child_args, procs: int) -> int:
    """world/procs from a --topology spec in the child args, else 1.
    Handles both the two-token form (``--topology SPEC``) and the
    ``--topology=SPEC`` spelling."""
    spec = topology_spec(child_args)
    if spec is None:
        return 1
    if spec.world % procs:
        raise SystemExit(f"topology world {spec.world} does not divide "
                         f"over {procs} processes")
    return spec.world // procs


def viable_procs(spec, max_procs: int) -> int:
    """Largest process count <= max_procs the topology can regroup onto:
    world must divide evenly AND every process must own a whole replica
    subtree (launch.mesh.validate_process_topology). Survivor counts that
    straddle a replica are skipped — the regrouped epoch re-spans the FULL
    world with fewer, fatter processes."""
    sys.path.insert(0, SRC)
    from repro.launch.mesh import validate_process_topology
    for k in range(max_procs, 0, -1):
        if spec.world % k:
            continue
        try:
            validate_process_topology(spec, k)
            return k
        except ValueError:
            continue
    raise SystemExit(f"topology {spec.to_str()} has no viable process "
                     f"count <= {max_procs}")


def child_env(procs: int, pid: int, port: int, devices: int,
              extra: dict | None = None) -> dict:
    """Explicit child environment: the JAX-relevant variables are always
    set (never silently inherited; `forced_cpu_env` is the one shared
    definition), plus the DASO_* process-group identity. `extra` carries
    the supervision variables (DASO_RUN_DIR & co) in supervisor mode."""
    sys.path.insert(0, SRC)
    from repro.launch.distributed import forced_cpu_env

    env = forced_cpu_env(devices)
    env["DASO_COORDINATOR"] = f"127.0.0.1:{port}"
    env["DASO_NUM_PROCS"] = str(procs)
    env["DASO_PROC_ID"] = str(pid)
    env["PYTHONUNBUFFERED"] = "1"
    if extra:
        env.update(extra)
    return env


def _pump(proc: subprocess.Popen, tag: str, sink) -> None:
    for line in proc.stdout:
        sink.write(f"[{tag}] {line}")
        sink.flush()


def launch(procs: int, child_args, *, module: str = "repro.launch.train",
           local_devices: int | None = None, port: int | None = None,
           timeout: float = 1800.0, quiet: bool = False) -> int:
    """Run the process group to completion; returns the worst exit code."""
    child_args = list(child_args)
    if module == "repro.launch.train" and "--distributed" not in child_args:
        child_args.append("--distributed")
    devices = (local_devices if local_devices is not None
               else derive_local_devices(child_args, procs))
    port = port or free_port()
    cmd = [sys.executable, "-m", module] + child_args
    children, pumps = [], []
    sink = open(os.devnull, "w") if quiet else sys.stderr
    for pid in range(procs):
        p = subprocess.Popen(cmd, env=child_env(procs, pid, port, devices),
                             stdout=subprocess.PIPE,
                             stderr=subprocess.STDOUT, text=True)
        t = threading.Thread(target=_pump, args=(p, f"p{pid}", sink),
                             daemon=True)
        t.start()
        children.append(p)
        pumps.append(t)

    deadline = time.monotonic() + timeout
    codes = [None] * procs
    try:
        while any(c is None for c in codes):
            for i, p in enumerate(children):
                if codes[i] is None:
                    codes[i] = p.poll()
            bad = [i for i, c in enumerate(codes) if c not in (None, 0)]
            if bad or time.monotonic() > deadline:
                if time.monotonic() > deadline:
                    print(f"[launch_procs] timeout after {timeout:.0f}s",
                          file=sys.stderr)
                    codes = [c if c is not None else 124 for c in codes]
                else:
                    print(f"[launch_procs] process {bad[0]} exited "
                          f"{codes[bad[0]]}; terminating the group",
                          file=sys.stderr)
                for p in children:
                    if p.poll() is None:
                        p.send_signal(signal.SIGTERM)
                break
            time.sleep(0.05)
        for p in children:
            try:
                p.wait(timeout=10)
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait()
    finally:
        for t in pumps:
            t.join(timeout=5)
        if quiet:
            sink.close()
    # a child that was still running at the deadline keeps its timeout
    # marker (124) even if SIGTERM let it exit 0 — a timed-out group must
    # never report success
    codes = [c if c == 124 else p.returncode
             for c, p in zip(codes, children)]
    merge_trace(child_args)
    return max(abs(c) for c in codes)


# -- supervisor mode: live fault injection + regroup --------------------------

def parse_kill(s: str):
    """--kill "proc:step" -> (proc, step)."""
    try:
        proc, step = s.split(":")
        return int(proc), int(step)
    except ValueError:
        raise SystemExit(f"--kill expects PROC:STEP (e.g. 2:6), got {s!r}")


def _spawn_group(procs, child_args, module, devices, port, extra_env,
                 sink):
    cmd = [sys.executable, "-m", module] + list(child_args)
    children, pumps = [], []
    for pid in range(procs):
        p = subprocess.Popen(
            cmd, env=child_env(procs, pid, port, devices, extra_env(pid)),
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
        t = threading.Thread(target=_pump, args=(p, f"p{pid}", sink),
                             daemon=True)
        t.start()
        children.append(p)
        pumps.append(t)
    return children, pumps


def _teardown(children, *, grace: float = 10.0) -> None:
    for p in children:
        if p.poll() is None:
            p.send_signal(signal.SIGTERM)
    deadline = time.monotonic() + grace
    for p in children:
        try:
            p.wait(timeout=max(0.1, deadline - time.monotonic()))
        except subprocess.TimeoutExpired:
            p.kill()
            p.wait()


def _monitor_epoch(children, *, run_dir, epoch, deadline, kill,
                   watchdog_s, exit_peer_lost, read_hb):
    """Poll one epoch's group to completion or first failure.

    Returns a dict: outcome "ok" | "failed" | "timeout", per-child codes,
    the root failure (proc id + mechanism + time), the kill record if the
    injection fired, and t_train (first heartbeat with phase=="train" —
    what recovery timing is measured to)."""
    n = len(children)
    codes = [None] * n
    out = {"outcome": None, "codes": codes, "root": None,
           "t_kill": None, "t_train": None}
    kill_pending = kill is not None
    # a worker stalled this long past the watchdog has a wedged watchdog
    # too (the in-process exit at watchdog_s is the first line of defense)
    stall_s = watchdog_s + 60.0
    spawn_t = time.monotonic()
    last_beat = [spawn_t] * n       # when we last saw a FRESH beat
    seen_t = [None] * n             # the beat's own wall-clock stamp
    last_step = [-1] * n

    def fail(root, mechanism, code=None):
        out["outcome"] = "failed"
        out["root"] = {"proc": root, "mechanism": mechanism, "code": code,
                       "t": time.monotonic(), "step": last_step[root]}

    while True:
        for i, p in enumerate(children):
            if codes[i] is None:
                codes[i] = p.poll()
        for i in range(n):
            hb = read_hb(run_dir, epoch, i)
            if hb is not None:
                if hb.get("t") != seen_t[i]:  # a beat we haven't seen yet
                    seen_t[i] = hb.get("t")
                    last_beat[i] = time.monotonic()
                last_step[i] = int(hb.get("step", -1))
                if out["t_train"] is None and hb.get("phase") == "train":
                    out["t_train"] = time.monotonic()
        if kill_pending and codes[kill[0]] is None \
                and last_step[kill[0]] >= kill[1]:
            children[kill[0]].send_signal(signal.SIGKILL)
            out["t_kill"] = time.monotonic()
            kill_pending = False
        bad = [i for i, c in enumerate(codes) if c not in (None, 0)]
        if bad:
            root = bad[0]
            if codes[root] == exit_peer_lost and len(children) > 1:
                # that child *detected* a peer loss (its watchdog fired);
                # the root cause is whoever stopped making progress first
                others = [i for i in range(n) if i != root]
                root = min(others, key=lambda i: last_beat[i])
                fail(root, "watchdog", codes[bad[0]])
            else:
                fail(root, "exit", codes[bad[0]])
            return out
        alive = [i for i, c in enumerate(codes) if c is None]
        if not alive:
            out["outcome"] = "ok"
            return out
        now = time.monotonic()
        for i in alive:
            if now - last_beat[i] > stall_s:
                children[i].kill()
                fail(i, "stall")
                return out
        if now > deadline:
            out["outcome"] = "timeout"
            return out
        time.sleep(0.05)


def supervise(procs: int, child_args, *,
              module: str = "repro.launch.train",
              timeout: float = 1800.0, quiet: bool = False,
              kill: tuple | None = None,
              watchdog_s: float | None = None,
              hb_interval: float = 0.25,
              max_regroups: int = 2,
              elastic: bool = False,
              run_dir: str | None = None,
              report_path: str | None = None) -> int:
    """Run the group under live-fault supervision: heartbeat-triggered
    SIGKILL injection (`kill=(proc, step)`), bounded failure detection,
    and regroup-restart of the survivors under fresh coordinator epochs
    (resuming from the newest intact checkpoint, the death replayed as a
    membership-mask crash event — src/repro/resilience/runtime.py has the
    full protocol). Returns 0 iff the final epoch completed cleanly."""
    sys.path.insert(0, SRC)
    from repro.launch.mesh import process_replica_slice
    from repro.resilience import runtime as rt

    child_args = list(child_args)
    if module == "repro.launch.train" and "--distributed" not in child_args:
        child_args.append("--distributed")
    spec = topology_spec(child_args)
    if spec is None:
        raise SystemExit("supervisor mode needs --topology in the child "
                         "args (replica ownership of a dead process is "
                         "derived from the topology)")
    if child_flag_value(child_args, "--ckpt") is None or \
            child_flag_value(child_args, "--ckpt-every") is None:
        raise SystemExit("supervisor mode needs --ckpt DIR --ckpt-every N "
                         "in the child args: a regrouped epoch resumes "
                         "from the newest intact checkpoint")
    if child_flag_value(child_args, "--overlap") not in (None, "off"):
        raise SystemExit("supervisor mode needs --overlap off: recovery "
                         "replays membership-mask fault events, which the "
                         "overlap schedule rejects")
    watchdog_s = (watchdog_s if watchdog_s is not None
                  else rt.DEFAULT_WATCHDOG_S)
    run_dir = run_dir or tempfile.mkdtemp(prefix="daso-live-")
    os.makedirs(run_dir, exist_ok=True)
    sink = open(os.devnull, "w") if quiet else sys.stderr
    deadline = time.monotonic() + timeout

    report = {"ok": False, "exit_code": 1, "procs": procs,
              "watchdog_s": watchdog_s, "run_dir": run_dir,
              "elastic": elastic, "kill": None, "epochs": [],
              "dead_replicas": [], "timings": {}}
    if kill is not None:
        report["kill"] = {"proc": kill[0], "step": kill[1]}

    def finish(code: int) -> int:
        # a regrouped run leaves one stream per (epoch, proc); the merge
        # interleaves them all into one timeline
        merge_trace(child_args)
        report["exit_code"] = code
        report["ok"] = code == 0
        if report_path:
            with open(report_path, "w") as f:
                json.dump(report, f, indent=1)
        if quiet:
            sink.close()
        return code

    epoch, regroups = 0, 0
    dead: list[int] = []
    n = procs
    t0 = time.monotonic()
    t_detect = t_kill = None
    children = []
    try:
        while True:
            devices = spec.world // n
            port = free_port()
            extra = {rt.ENV_RUN_DIR: run_dir,
                     rt.ENV_EPOCH: str(epoch),
                     rt.ENV_WATCHDOG_S: str(watchdog_s),
                     rt.ENV_HB_INTERVAL: str(hb_interval)}
            if epoch > 0:
                rg_path = os.path.join(run_dir, f"regroup_{epoch}.json")
                rt.save_regroup(rg_path, rt.RegroupPlan(
                    epoch=epoch, dead_replicas=tuple(dead),
                    rejoin=elastic))
                extra[rt.ENV_REGROUP_FILE] = rg_path
            t_spawn = time.monotonic()
            children, pumps = _spawn_group(
                n, child_args, module, devices, port, lambda pid: extra,
                sink)
            mon = _monitor_epoch(
                children, run_dir=run_dir, epoch=epoch, deadline=deadline,
                kill=kill if epoch == 0 else None, watchdog_s=watchdog_s,
                exit_peer_lost=rt.EXIT_PEER_LOST, read_hb=rt.read_heartbeat)
            _teardown(children)
            for t in pumps:
                t.join(timeout=5)
            codes = [p.returncode for p in children]
            rec = {"epoch": epoch, "procs": n, "codes": codes,
                   "outcome": mon["outcome"]}
            if mon["t_kill"] is not None:
                t_kill = mon["t_kill"]
                report["kill"]["t_after_start_s"] = t_kill - t0
            if epoch > 0:
                rec["regroup_s"] = t_spawn - t_detect
                if mon["t_train"] is not None:
                    rec["resume_s"] = mon["t_train"] - t_spawn
            report["epochs"].append(rec)

            if mon["outcome"] == "ok":
                if epoch > 0:
                    report["timings"] = {
                        "detect_s": (t_detect - t_kill
                                     if t_kill is not None else None),
                        "regroup_s": report["epochs"][-1].get("regroup_s"),
                        "resume_s": report["epochs"][-1].get("resume_s"),
                        "total_s": time.monotonic() - t0}
                return finish(0)
            if mon["outcome"] == "timeout":
                print(f"[launch_procs] supervised run timed out after "
                      f"{timeout:.0f}s (epoch {epoch})", file=sys.stderr)
                return finish(124)
            root = mon["root"]
            t_detect = root["t"]
            rec["detect"] = {"proc": root["proc"],
                             "mechanism": root["mechanism"],
                             "code": root["code"],
                             "detect_s": (t_detect - t_kill
                                          if t_kill is not None else None)}
            lost = list(process_replica_slice(spec, n, root["proc"]))
            print(f"[launch_procs] epoch {epoch}: process {root['proc']} "
                  f"lost ({root['mechanism']}, code={root['code']}) -> "
                  f"replicas {lost} dead"
                  + (f", detected {rec['detect']['detect_s']:.2f}s after "
                     f"kill" if rec["detect"]["detect_s"] is not None
                     else ""), file=sys.stderr)
            if regroups >= max_regroups:
                print(f"[launch_procs] giving up after {regroups} "
                      f"regroups", file=sys.stderr)
                return finish(max(abs(c or 1) for c in codes))
            # elastic epochs rejoin their dead at the resume step, so each
            # failure stands alone; plain regroups accumulate the dead set
            # (the worker drops crashes already reflected in the resumed
            # checkpoint's membership, so replay stays idempotent)
            dead = sorted(set(lost) if elastic else set(dead) | set(lost))
            report["dead_replicas"] = dead
            n = procs if elastic else viable_procs(spec, n - 1)
            regroups += 1
            epoch += 1
            print(f"[launch_procs] regroup {regroups}: epoch {epoch} with "
                  f"{n} proc(s) over the full world "
                  f"({spec.world // n} devices each)"
                  + (", elastic rejoin" if elastic else ""),
                  file=sys.stderr)
    finally:
        _teardown(children, grace=2.0)  # no child outlives the supervisor


def main() -> None:
    ap = argparse.ArgumentParser(
        description="spawn N local jax.distributed processes "
                    "(args after -- go to the target module)")
    ap.add_argument("--procs", type=int, required=True)
    ap.add_argument("--local-devices", type=int, default=None,
                    help="CPU devices per process (default: topology "
                         "world / procs when the child args carry "
                         "--topology, else 1)")
    ap.add_argument("--module", default="repro.launch.train",
                    help="python module to run in every process")
    ap.add_argument("--port", type=int, default=None,
                    help="coordinator port (default: pick a free one)")
    ap.add_argument("--timeout", type=float, default=1800.0,
                    help="seconds before the whole group is killed")
    ap.add_argument("--quiet", action="store_true",
                    help="drop child output (exit status still propagates)")
    ap.add_argument("--supervise", action="store_true",
                    help="run under the live fault-tolerance supervisor: "
                         "heartbeats, watchdog-bounded detection, and "
                         "regroup-restart of survivors on a process death "
                         "(implied by --kill)")
    ap.add_argument("--kill", default=None, metavar="PROC:STEP",
                    help="SIGKILL child PROC once its heartbeat reaches "
                         "training step STEP (fault injection; implies "
                         "--supervise)")
    ap.add_argument("--watchdog", type=float, default=None,
                    help="per-worker progress watchdog seconds (default "
                         "from resilience.runtime; must exceed the worst "
                         "single compile+cycle)")
    ap.add_argument("--max-regroups", type=int, default=2,
                    help="give up after this many regroup-restarts")
    ap.add_argument("--elastic-rejoin", action="store_true",
                    help="regroup with the ORIGINAL process count — the "
                         "restarted ranks rejoin and are re-seeded from "
                         "the survivors' mean state")
    ap.add_argument("--run-dir", default=None,
                    help="shared heartbeat/regroup directory (default: a "
                         "fresh temp dir)")
    ap.add_argument("--report", default=None, metavar="JSON",
                    help="write supervision report (detect/regroup/resume "
                         "timings, per-epoch outcomes) to this path")
    ap.add_argument("child_args", nargs=argparse.REMAINDER,
                    help="-- then the target module's arguments")
    args = ap.parse_args()
    rest = args.child_args
    if rest and rest[0] == "--":
        rest = rest[1:]
    if args.supervise or args.kill is not None:
        code = supervise(args.procs, rest, module=args.module,
                         timeout=args.timeout, quiet=args.quiet,
                         kill=(parse_kill(args.kill)
                               if args.kill else None),
                         watchdog_s=args.watchdog,
                         max_regroups=args.max_regroups,
                         elastic=args.elastic_rejoin,
                         run_dir=args.run_dir,
                         report_path=args.report)
    else:
        code = launch(args.procs, rest, module=args.module,
                      local_devices=args.local_devices, port=args.port,
                      timeout=args.timeout, quiet=args.quiet)
    sys.exit(code)


if __name__ == "__main__":
    main()
