#!/usr/bin/env python
"""Spawn N local coordinator-connected `jax.distributed` processes.

The development/CI harness for the multi-process runtime
(src/repro/launch/distributed.py): each child is one "host" of the
topology, pinned to ``world / N`` CPU devices via
``--xla_force_host_platform_device_count``, joined through a coordinator
on a free localhost port. Children inherit a *explicitly constructed*
environment — ``JAX_PLATFORMS`` and ``XLA_FLAGS`` are always set (CPU by
default) so local runs match CI, and the ``DASO_COORDINATOR`` /
``DASO_NUM_PROCS`` / ``DASO_PROC_ID`` variables carry the process-group
identity that `repro.launch.distributed.DistributedConfig.from_env`
reads.

Everything after ``--`` goes to the target module verbatim
(``repro.launch.train`` by default); ``--distributed`` is appended for
the default module if missing. The per-process device count is derived
from a ``--topology`` spec in the child args when present (world / N),
or set with ``--local-devices``.

  # 2-process distributed quickstart (matches the CI multiprocess-smoke job)
  python tools/launch_procs.py --procs 2 -- \
      --arch llama3.2-1b --topology "chip:1 x host:2 x pod:2" \
      --steps 40 --per-node-batch 2 --seq-len 16 --metrics-out /tmp/mp.json

  # same run, single process: the SPMD oracle the 2-process run is
  # bit-exact with (tests/test_multiprocess.py)
  python tools/launch_procs.py --procs 1 -- ...same args...

Exit status: 0 iff every child exited 0. The first failure terminates the
rest of the group (a hung coordinator peer would otherwise block forever).
"""
from __future__ import annotations

import argparse
import os
import signal
import socket
import subprocess
import sys
import threading
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def free_port() -> int:
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def derive_local_devices(child_args, procs: int) -> int:
    """world/procs from a --topology spec in the child args, else 1.
    Handles both the two-token form (``--topology SPEC``) and the
    ``--topology=SPEC`` spelling."""
    spec_arg = None
    for i, a in enumerate(child_args):
        if a == "--topology":
            if i + 1 >= len(child_args):
                raise SystemExit("--topology given without a spec")
            spec_arg = child_args[i + 1]
        elif a.startswith("--topology="):
            spec_arg = a.split("=", 1)[1]
    if spec_arg is None:
        return 1
    sys.path.insert(0, SRC)
    from repro.topo import TopologySpec
    world = TopologySpec.load(spec_arg).world
    if world % procs:
        raise SystemExit(f"topology world {world} does not divide over "
                         f"{procs} processes")
    return world // procs


def child_env(procs: int, pid: int, port: int, devices: int) -> dict:
    """Explicit child environment: the JAX-relevant variables are always
    set (never silently inherited; `forced_cpu_env` is the one shared
    definition), plus the DASO_* process-group identity."""
    sys.path.insert(0, SRC)
    from repro.launch.distributed import forced_cpu_env

    env = forced_cpu_env(devices)
    env["DASO_COORDINATOR"] = f"127.0.0.1:{port}"
    env["DASO_NUM_PROCS"] = str(procs)
    env["DASO_PROC_ID"] = str(pid)
    env["PYTHONUNBUFFERED"] = "1"
    return env


def _pump(proc: subprocess.Popen, tag: str, sink) -> None:
    for line in proc.stdout:
        sink.write(f"[{tag}] {line}")
        sink.flush()


def launch(procs: int, child_args, *, module: str = "repro.launch.train",
           local_devices: int | None = None, port: int | None = None,
           timeout: float = 1800.0, quiet: bool = False) -> int:
    """Run the process group to completion; returns the worst exit code."""
    child_args = list(child_args)
    if module == "repro.launch.train" and "--distributed" not in child_args:
        child_args.append("--distributed")
    devices = (local_devices if local_devices is not None
               else derive_local_devices(child_args, procs))
    port = port or free_port()
    cmd = [sys.executable, "-m", module] + child_args
    children, pumps = [], []
    sink = open(os.devnull, "w") if quiet else sys.stderr
    for pid in range(procs):
        p = subprocess.Popen(cmd, env=child_env(procs, pid, port, devices),
                             stdout=subprocess.PIPE,
                             stderr=subprocess.STDOUT, text=True)
        t = threading.Thread(target=_pump, args=(p, f"p{pid}", sink),
                             daemon=True)
        t.start()
        children.append(p)
        pumps.append(t)

    deadline = time.monotonic() + timeout
    codes = [None] * procs
    try:
        while any(c is None for c in codes):
            for i, p in enumerate(children):
                if codes[i] is None:
                    codes[i] = p.poll()
            bad = [i for i, c in enumerate(codes) if c not in (None, 0)]
            if bad or time.monotonic() > deadline:
                if time.monotonic() > deadline:
                    print(f"[launch_procs] timeout after {timeout:.0f}s",
                          file=sys.stderr)
                    codes = [c if c is not None else 124 for c in codes]
                else:
                    print(f"[launch_procs] process {bad[0]} exited "
                          f"{codes[bad[0]]}; terminating the group",
                          file=sys.stderr)
                for p in children:
                    if p.poll() is None:
                        p.send_signal(signal.SIGTERM)
                break
            time.sleep(0.05)
        for p in children:
            try:
                p.wait(timeout=10)
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait()
    finally:
        for t in pumps:
            t.join(timeout=5)
        if quiet:
            sink.close()
    # a child that was still running at the deadline keeps its timeout
    # marker (124) even if SIGTERM let it exit 0 — a timed-out group must
    # never report success
    codes = [c if c == 124 else p.returncode
             for c, p in zip(codes, children)]
    return max(abs(c) for c in codes)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="spawn N local jax.distributed processes "
                    "(args after -- go to the target module)")
    ap.add_argument("--procs", type=int, required=True)
    ap.add_argument("--local-devices", type=int, default=None,
                    help="CPU devices per process (default: topology "
                         "world / procs when the child args carry "
                         "--topology, else 1)")
    ap.add_argument("--module", default="repro.launch.train",
                    help="python module to run in every process")
    ap.add_argument("--port", type=int, default=None,
                    help="coordinator port (default: pick a free one)")
    ap.add_argument("--timeout", type=float, default=1800.0,
                    help="seconds before the whole group is killed")
    ap.add_argument("--quiet", action="store_true",
                    help="drop child output (exit status still propagates)")
    ap.add_argument("child_args", nargs=argparse.REMAINDER,
                    help="-- then the target module's arguments")
    args = ap.parse_args()
    rest = args.child_args
    if rest and rest[0] == "--":
        rest = rest[1:]
    code = launch(args.procs, rest, module=args.module,
                  local_devices=args.local_devices, port=args.port,
                  timeout=args.timeout, quiet=args.quiet)
    sys.exit(code)


if __name__ == "__main__":
    main()
