"""Pallas kernel validation (interpret=True on CPU): shape/dtype sweeps +
hypothesis properties, assert_allclose vs the pure-jnp oracles in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import flash_attention, rglru_scan, ssm_scan
from repro.kernels.ref import attention_ref, rglru_scan_ref, ssm_scan_ref


# ------------------------------------------------------ flash attention ----

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hk,Sq,Sk,D", [
    (2, 4, 4, 128, 128, 64),     # MHA square
    (1, 8, 2, 128, 128, 32),     # GQA 4:1
    (2, 4, 1, 64, 256, 64),      # MQA, q suffix of longer kv
    (1, 2, 2, 256, 256, 128),    # MXU-aligned head dim
])
def test_flash_attention_sweep(B, Hq, Hk, Sq, Sk, D, dtype):
    key = jax.random.PRNGKey(B * Sq + D)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hk, Sk, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hk, Sk, D)).astype(dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=True)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_attention_sliding_window(window):
    key = jax.random.PRNGKey(window)
    B, H, S, D = 1, 2, 256, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@given(bq=st.sampled_from([32, 64, 128]), bk=st.sampled_from([32, 64, 128]))
@settings(max_examples=6, deadline=None)
def test_flash_attention_block_size_invariance(bq, bk):
    key = jax.random.PRNGKey(42)
    B, H, S, D = 1, 2, 128, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ------------------------------------------------------------- ssm scan ----

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,Di,N,bd", [
    (2, 64, 128, 16, 64),
    (1, 128, 64, 8, 64),
    (3, 32, 96, 4, 32),   # Di not a multiple of the preferred block
])
def test_ssm_scan_sweep(B, S, Di, N, bd, dtype):
    key = jax.random.PRNGKey(S + Di)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, Di)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di))).astype(
        jnp.float32)
    A = -jnp.exp(0.5 * jax.random.normal(ks[2], (Di, N)))
    Bm = jax.random.normal(ks[3], (B, S, N)).astype(dtype)
    Cm = jax.random.normal(ks[4], (B, S, N)).astype(dtype)
    h0 = jnp.zeros((B, Di, N), jnp.float32)
    y, h = ssm_scan(x, dt, A, Bm, Cm, h0, block_d=bd)
    yr, hr = ssm_scan_ref(x, dt, A, Bm, Cm, h0)
    atol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=atol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=atol)


def test_ssm_scan_nonzero_initial_state():
    key = jax.random.PRNGKey(5)
    B, S, Di, N = 1, 16, 32, 4
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (B, S, Di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di)))
    A = -jnp.exp(0.5 * jax.random.normal(ks[2], (Di, N)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    h0 = jax.random.normal(ks[5], (B, Di, N))
    y, h = ssm_scan(x, dt, A, Bm, Cm, h0, block_d=16)
    yr, hr = ssm_scan_ref(x, dt, A, Bm, Cm, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)


# ----------------------------------------------------------- rglru scan ----

@pytest.mark.parametrize("B,S,W,bw", [(2, 64, 128, 64), (1, 32, 48, 16)])
def test_rglru_scan_sweep(B, S, W, bw):
    key = jax.random.PRNGKey(W)
    ks = jax.random.split(key, 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W)))
    gx = jax.random.normal(ks[1], (B, S, W))
    h0 = jax.random.normal(ks[2], (B, W))
    hs, h = rglru_scan(a, gx, h0, block_w=bw)
    hsr, hr = rglru_scan_ref(a, gx, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hsr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-5)


@given(st.integers(0, 10))
@settings(max_examples=8, deadline=None)
def test_rglru_decay_bound_property(seed):
    """With |a|<1 and bounded input, the state stays bounded (stability)."""
    key = jax.random.PRNGKey(seed)
    B, S, W = 1, 64, 16
    a = jax.nn.sigmoid(jax.random.normal(key, (B, S, W))) * 0.99
    gx = jnp.clip(jax.random.normal(jax.random.fold_in(key, 1), (B, S, W)),
                  -1, 1)
    h0 = jnp.zeros((B, W))
    hs, _ = rglru_scan(a, gx, h0, block_w=16)
    bound = 1.0 / (1.0 - 0.99) + 1.0
    assert float(jnp.max(jnp.abs(hs))) < bound
