"""Minimal deterministic stand-in for `hypothesis`, installed by conftest.py
only when the real package is missing (the repo's property tests must not be
silently skipped on minimal containers). Not a fuzzer: it draws a fixed,
seeded sample of `max_examples` inputs per test, which keeps the properties
exercised and the suite deterministic. Install the real thing with
``pip install -e .[test]`` to get actual shrinking/coverage.

Covers exactly the API surface the test-suite uses: ``given`` (positional and
keyword strategies), ``settings(max_examples=, deadline=)``, and the
strategies ``integers``, ``floats``, ``booleans``, ``sampled_from``,
``lists``, plus ``.filter`` / ``.map``.
"""
from __future__ import annotations

import functools
import inspect
import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)

    def filter(self, pred):
        def draw(rng):
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate too restrictive for the "
                             "hypothesis fallback shim")
        return _Strategy(draw)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value, max_value):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(items):
    seq = list(items)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))])


def lists(elements, min_size=0, max_size=10):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]
    return _Strategy(draw)


class settings:
    """Decorator recording max_examples on the function (deadline etc. are
    accepted and ignored). Works above or below @given."""

    def __init__(self, max_examples=20, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_max_examples = self.max_examples
        return fn


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples", 20))
            rng = random.Random(0)  # deterministic across runs
            for _ in range(n):
                drawn = [s.example(rng) for s in arg_strategies]
                drawn_kw = {k: s.example(rng)
                            for k, s in kw_strategies.items()}
                fn(*args, *drawn, **kwargs, **drawn_kw)

        # all drawn parameters are provided by the shim; hide them from
        # pytest's fixture resolution (every @given in this suite draws the
        # test's full argument list)
        wrapper.__signature__ = inspect.Signature([])
        return wrapper
    return deco
