"""Multi-device distributed semantics, run in subprocesses with
--xla_force_host_platform_device_count (so the main pytest process keeps its
single real CPU device, per the dry-run contract). The true multi-PROCESS
runtime (jax.distributed) is exercised by tests/test_multiprocess.py."""
import pytest

from conftest import run_subprocess as _run


def test_daso_mesh_step_matches_single_device_simulator():
    """The same DASO cycle on a (pod,data,model) mesh and on a single device
    (simulator layout) must produce identical parameters — proving the mesh
    execution implements exactly the paper's algorithm."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.daso import (DasoConfig, daso_train_step,
                                     replicate_params)
        from repro.optim.optimizers import sgd

        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"]
            return jnp.mean((pred - batch["y"]) ** 2), {}

        R, per, d = 2, 8, 16
        key = jax.random.PRNGKey(0)
        params0 = {"w": jax.random.normal(key, (d, 4)) * 0.1}
        opt = sgd(momentum=0.9, weight_decay=1e-4)
        cfg = DasoConfig(n_replicas=R, global_world=8, b_max=4)
        modes = ["send", "receive", "local", "local"] * 2
        steps = [daso_train_step(loss_fn, opt, cfg, mode=m, staleness=1)
                 for m in modes]

        def data(step):
            k = jax.random.fold_in(key, step)
            x = jax.random.normal(k, (R, per, d))
            y = jax.random.normal(jax.random.fold_in(k, 1), (R, per, 4))
            return {"x": x, "y": y}

        def run(device_put_fn):
            p = device_put_fn(replicate_params(params0, R))
            o = device_put_fn(replicate_params(opt.init(params0), R))
            infl = jax.tree.map(lambda x: x, p)
            for t, s in enumerate(steps):
                p, o, infl, m = jax.jit(s)(p, o, infl, data(t), 0.05)
            return jax.device_get(p["w"])

        # single-device (simulator) run
        ref = run(lambda t: t)
        # mesh run: replica axis sharded over pod, batch over data
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        sh_p = NamedSharding(mesh, P("pod"))
        put = lambda t: jax.tree.map(
            lambda x: jax.device_put(x, sh_p), t)
        got = run(put)
        np.testing.assert_allclose(got, ref, rtol=2e-5, atol=1e-6)
        print("MESH==SIM OK")
    """)
    assert "MESH==SIM OK" in out


def test_daso_cycle_collectives_touch_pod_axis_only_on_sync_steps():
    """HLO audit: the 'local' step variant must have NO cross-pod collective;
    the 'send' variant must have one. This is the paper's traffic pattern."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.daso import DasoConfig, daso_train_step
        from repro.launch.hlo_stats import collective_stats
        from repro.optim.optimizers import sgd

        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"]
            return jnp.mean((pred - batch["y"]) ** 2), {}

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
        R, per, d = 2, 4, 128  # w is 128x4 f32 = 2 KiB > the 1 KiB threshold
        opt = sgd(momentum=0.0, weight_decay=0.0)
        cfg = DasoConfig(n_replicas=R, global_world=4, b_max=4)
        SDS = jax.ShapeDtypeStruct
        params = {"w": SDS((R, d, 4), jnp.float32)}
        opt_state = {}
        infl = params
        batch = {"x": SDS((R, per, d), jnp.float32),
                 "y": SDS((R, per, 4), jnp.float32)}
        shp = NamedSharding(mesh, P("pod"))
        shb = NamedSharding(mesh, P("pod", "data"))
        sc = NamedSharding(mesh, P())

        for mode, expect_pod in [("local", False), ("send", True),
                                 ("receive", False), ("blocking", True)]:
            step = daso_train_step(loss_fn, opt, cfg, mode=mode, staleness=1)
            lowered = jax.jit(step, in_shardings=(
                {"w": shp}, {}, {"w": shp},
                {"x": shb, "y": shb}, sc)).lower(
                params, opt_state, infl, batch, SDS((), jnp.float32))
            stats = collective_stats(lowered.compile().as_text(), mesh_shape)
            pod_bytes = sum(v["bytes"] for k, v in stats.items()
                            if isinstance(v, dict) and "@pod" in k)
            # scalar metrics (loss mean over replicas) may cross the pod
            # axis — only parameter-scale traffic counts
            assert (pod_bytes > 1024) == expect_pod, (mode, stats)
            print(mode, "pod_bytes", pod_bytes)
        print("COLLECTIVE AUDIT OK")
    """)
    assert "COLLECTIVE AUDIT OK" in out


def test_sharded_lm_forward_matches_single_device():
    """Full reduced-arch LM forward under the production sharding policy on
    an 8-device mesh == single-device forward."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_reduced
        from repro.models.lm import init_params, forward
        from repro.launch.specs import make_policy, make_param_shardings
        from repro.sharding import use_policy

        cfg = get_reduced("qwen3-8b").replace(vocab_size=512)
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key)
        toks = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
        ref = forward(params, toks, cfg)["logits"]

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        policy = make_policy(mesh, fsdp=True)
        p_sh = make_param_shardings(cfg, params, policy)
        params_s = jax.tree.map(jax.device_put, params, p_sh)
        tok_sh = NamedSharding(mesh, P(("pod", "data"), None))
        toks_s = jax.device_put(toks, tok_sh)
        with use_policy(policy):
            got = jax.jit(lambda p, t: forward(p, t, cfg)["logits"],
                          in_shardings=(p_sh, tok_sh))(params_s, toks_s)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-4)
        print("SHARDED==LOCAL OK")
    """)
    assert "SHARDED==LOCAL OK" in out


def test_production_mesh_shapes():
    out = _run("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert m1.axis_names == ("data", "model")
        assert m1.devices.shape == (16, 16)
        m2 = make_production_mesh(multi_pod=True)
        assert m2.axis_names == ("pod", "data", "model")
        assert m2.devices.shape == (2, 16, 16)
        print("MESH OK")
    """, devices=512)
    assert "MESH OK" in out


def test_dryrun_contract_end_to_end():
    """The deliverable-e contract: a full (arch x shape) dry-run record on the
    real 512-device multi-pod production mesh, lower + compile + memory/cost/
    collective stats, via the actual CLI entry point."""
    out = _run("""
        from repro.launch.dryrun import run_one
        rec = run_one("llama3.2-1b", "long_500k", multi_pod=True)
        assert rec["ok"]
        assert rec["memory"]["peak_estimate_per_device"] > 0
        assert rec["cost"]["flops"] > 0
        assert rec["collectives"]["_total_count"] >= 0
        assert rec["devices"] == 512
        print("DRYRUN CONTRACT OK")
    """, devices=512)
    assert "DRYRUN CONTRACT OK" in out
