import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.configs.base import ATTN, ATTN_LOCAL, ATTN_SWA, MAMBA, RGLRU

TRANSFORMER_ARCHS = [a for a in ARCH_IDS if a != "resnet50"]


@pytest.mark.parametrize("arch", TRANSFORMER_ARCHS)
def test_full_config_validates(arch):
    cfg = get_config(arch)
    cfg.validate()
    assert cfg.n_layers >= 16
    assert cfg.source


@pytest.mark.parametrize("arch", TRANSFORMER_ARCHS)
def test_reduced_config_constraints(arch):
    cfg = get_reduced(arch)
    cfg.validate()
    assert cfg.n_layers <= max(2, len(cfg.layer_pattern))
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4


def test_assigned_shapes_exact():
    """The exact published shapes from the assignment table."""
    c = get_config("qwen3-8b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (36, 4096, 32, 8, 12288, 151936)
    assert c.qk_norm
    c = get_config("falcon-mamba-7b")
    assert (c.n_layers, c.d_model, c.vocab_size) == (64, 4096, 65024)
    assert c.ssm.d_state == 16 and c.layer_pattern == (MAMBA,)
    c = get_config("mixtral-8x22b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.vocab_size) == (56, 6144, 48, 8, 32768)
    assert c.moe.n_experts == 8 and c.moe.top_k == 2
    assert c.layer_pattern == (ATTN_SWA,) and c.sliding_window > 0
    c = get_config("recurrentgemma-9b")
    assert c.n_layers == 38 and c.layer_pattern == (RGLRU, RGLRU, ATTN_LOCAL)
    assert c.n_kv_heads == 1
    c = get_config("moonshot-v1-16b-a3b")
    assert c.moe.n_experts == 64 and c.moe.top_k == 6 and c.moe.d_ff == 1408
    c = get_config("granite-moe-3b-a800m")
    assert c.moe.n_experts == 40 and c.moe.top_k == 8
    c = get_config("llama3.2-1b")
    assert c.tie_embeddings and c.vocab_size == 128256
    c = get_config("qwen2-vl-2b")
    assert c.rope_type == "mrope" and c.prefix_embed_len > 0
    c = get_config("musicgen-large")
    assert c.family == "audio" and c.vocab_size == 2048
    c = get_config("minitron-8b")
    assert c.d_ff == 16384 and c.vocab_size == 256000


def test_long_context_policy():
    from repro.launch.specs import needs_window_override
    for arch in TRANSFORMER_ARCHS:
        cfg = get_config(arch)
        wo = needs_window_override(cfg, "long_500k")
        if cfg.is_subquadratic():
            assert wo == 0, arch
        else:
            assert wo > 0, arch  # dense archs run the windowed variant
