"""Process <-> topology partition contract (launch/mesh.py): under process
sharding each process owns exactly its subtree of the topology, and
mismatched fanout/process-count combinations raise precise errors. Pure
host-side functions — no devices, no subprocesses."""
import pytest

from repro.launch.mesh import (device_node_path, process_node_paths,
                               process_replica_slice, replica_unit_sizes,
                               validate_process_topology)
from repro.topo import TopologySpec


def spec(s):
    return TopologySpec.load(s)


class TestValidate:
    def test_one_process_always_fits(self):
        assert validate_process_topology(spec("chip:4 x pod:2"), 1) == 8

    def test_process_per_outer_unit(self):
        # 2 procs x one pod each, 4 devices per proc
        assert validate_process_topology(spec("chip:2 x host:2 x pod:2"),
                                         2) == 4

    def test_process_per_finest_unit(self):
        # 4 procs x one host each
        assert validate_process_topology(spec("chip:2 x host:2 x pod:2"),
                                         4) == 2

    def test_world_not_divisible(self):
        with pytest.raises(ValueError, match="does not divide"):
            validate_process_topology(spec("chip:4 x pod:3"), 5)

    def test_replica_straddles_processes(self):
        # world 8 / 8 procs = 1 device each, but a replica spans 4 chips
        with pytest.raises(ValueError, match="split a replica"):
            validate_process_topology(spec("chip:4 x pod:2"), 8)

    def test_block_cuts_through_level_units(self):
        # R=6 (host:3 x pod:2), 3 procs -> blocks of 2 cut pods of 3
        with pytest.raises(ValueError, match="cut through"):
            validate_process_topology(spec("chip:1 x host:3 x pod:2"), 3)

    def test_bad_process_count(self):
        with pytest.raises(ValueError, match=">= 1"):
            validate_process_topology(spec("chip:1 x pod:2"), 0)


class TestOwnership:
    def test_unit_sizes(self):
        s = spec("chip:1 x host:2 x pod:3")
        assert replica_unit_sizes(s) == {"host": 1, "pod": 2}

    def test_each_process_owns_one_pod(self):
        s = spec("chip:1 x host:2 x pod:2")
        assert process_node_paths(s, 2, 0) == ("pod0",)
        assert process_node_paths(s, 2, 1) == ("pod1",)

    def test_each_process_owns_one_host_subtree(self):
        s = spec("chip:1 x host:2 x pod:2")
        assert process_node_paths(s, 4, 0) == ("pod0/host0",)
        assert process_node_paths(s, 4, 3) == ("pod1/host1",)

    def test_coarse_split_owns_sibling_subtrees(self):
        s = spec("chip:1 x host:2 x pod:4")
        assert process_node_paths(s, 2, 1) == ("pod2", "pod3")

    def test_paths_round_trip_through_replicas_of(self):
        s = spec("chip:2 x host:2 x pod:2")
        for n_procs in (1, 2, 4):
            for pid in range(n_procs):
                rng = process_replica_slice(s, n_procs, pid)
                got = []
                for path in process_node_paths(s, n_procs, pid):
                    got.extend(s.replicas_of(path))
                assert sorted(got) == list(rng), (n_procs, pid)

    def test_slices_partition_the_replica_axis(self):
        s = spec("chip:1 x host:3 x pod:2")
        covered = []
        for pid in range(2):
            covered.extend(process_replica_slice(s, 2, pid))
        assert covered == list(range(s.n_replicas))

    def test_process_id_out_of_range(self):
        with pytest.raises(ValueError, match="process_id"):
            process_replica_slice(spec("chip:1 x pod:2"), 2, 2)


class TestDevicePaths:
    def test_device_to_path(self):
        s = spec("chip:2 x host:2 x pod:2")
        assert device_node_path(s, 0) == "pod0/host0:chip0"
        assert device_node_path(s, 3) == "pod0/host1:chip1"
        assert device_node_path(s, 7) == "pod1/host1:chip1"

    def test_two_level_paths(self):
        s = spec("chip:2 x pod:2")
        assert device_node_path(s, 2) == "pod1:chip0"

    def test_out_of_range(self):
        with pytest.raises(ValueError, match="outside"):
            device_node_path(spec("chip:2 x pod:2"), 4)

    def test_process_block_is_contiguous_devices(self):
        """The mesh lowers devices process-major: process p's replica block
        maps exactly onto its contiguous device block."""
        s = spec("chip:2 x host:2 x pod:2")
        local = validate_process_topology(s, 2)
        for pid in range(2):
            replicas = set(process_replica_slice(s, 2, pid))
            devs = range(pid * local, (pid + 1) * local)
            assert {d // s.local_world for d in devs} == replicas
