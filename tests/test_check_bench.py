"""The perf-regression gate (tools/check_bench.py) must pass the committed
records against themselves and fail on injected regressions. No JAX — pure
JSON plumbing, so this runs in milliseconds."""
import importlib.util
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TOOL = os.path.join(REPO, "tools", "check_bench.py")

spec = importlib.util.spec_from_file_location("check_bench", TOOL)
check_bench = importlib.util.module_from_spec(spec)
spec.loader.exec_module(check_bench)


def _committed(name):
    with open(os.path.join(REPO, name)) as f:
        return json.load(f)


@pytest.mark.parametrize("name", sorted(check_bench.POLICIES))
def test_committed_records_pass_their_own_gate(name):
    rec = _committed(name)
    assert check_bench.check_record(name, rec, rec) == []


def test_gate_catches_structural_break():
    rec = _committed("BENCH_exchange.json")
    bad = json.loads(json.dumps(rec))
    bad["derived"]["all_reduce_ops_fused"] = 112  # fusion fell apart
    fails = check_bench.check_record("BENCH_exchange.json", bad, rec)
    assert any("all_reduce_ops_fused" in f for f in fails)


def test_gate_catches_perf_regression():
    rec = _committed("BENCH_exchange.json")
    bad = json.loads(json.dumps(rec))
    bad["derived"]["fused_speedup_f32"] = 0.1
    fails = check_bench.check_record("BENCH_exchange.json", bad, rec)
    assert any("perf regression" in f for f in fails)


def test_gate_tolerates_machine_variance():
    """A 30% slower runner is noise, not a regression."""
    rec = _committed("BENCH_exchange.json")
    ok = json.loads(json.dumps(rec))
    ok["derived"]["fused_speedup_f32"] *= 0.7
    assert check_bench.check_record("BENCH_exchange.json", ok, rec) == []


def test_missing_fresh_key_fails():
    rec = _committed("BENCH_topology.json")
    bad = json.loads(json.dumps(rec))
    del bad["derived"]["two_level_param_delta"]
    fails = check_bench.check_record("BENCH_topology.json", bad, rec)
    assert any("lacks" in f for f in fails)


def test_cli_self_test_exits_zero():
    r = subprocess.run([sys.executable, TOOL, "--self-test"],
                       capture_output=True, text=True, timeout=60)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "injected regression caught" in r.stdout