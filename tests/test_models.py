"""Model-component unit + property tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ref import attention_ref, rglru_scan_ref, ssm_scan_ref
from repro.models.attention import multihead_attention
from repro.models.common import cross_entropy_loss
from repro.models.mamba import linear_recurrence, selective_scan
from repro.models.rope import apply_mrope, apply_rope


def _bhsd_to_bshd(x):
    return x.swapaxes(1, 2)


@pytest.mark.parametrize("Hq,Hk", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("window", [0, 16])
def test_chunked_attention_matches_ref(Hq, Hk, window):
    key = jax.random.PRNGKey(0)
    B, S, D = 2, 64, 32
    q = jax.random.normal(key, (B, Hq, S, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hk, S, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hk, S, D))
    ref = attention_ref(q, k, v, causal=True, window=window)
    out = multihead_attention(_bhsd_to_bshd(q), _bhsd_to_bshd(k),
                              _bhsd_to_bshd(v), causal=True, window=window,
                              q_chunk=16)
    np.testing.assert_allclose(np.asarray(_bhsd_to_bshd(out)),
                               np.asarray(ref), atol=2e-5)


def test_attention_chunk_size_invariance():
    key = jax.random.PRNGKey(3)
    B, H, S, D = 1, 2, 128, 16
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, D))
    outs = [multihead_attention(q, k, v, q_chunk=c) for c in (16, 32, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=1e-5)


@given(st.integers(8, 64).filter(lambda s: s % 8 == 0),
       st.sampled_from([4, 8, 16]))
@settings(max_examples=10, deadline=None)
def test_selective_scan_chunk_invariance(S, chunk):
    key = jax.random.PRNGKey(S)
    B, Di, N = 2, 16, 4
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, Di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di)))
    A = -jnp.exp(0.5 * jax.random.normal(ks[2], (Di, N)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    h0 = jnp.zeros((B, Di, N))
    y, h = selective_scan(x, dt, A, Bm, Cm, h0, chunk=chunk)
    yr, hr = ssm_scan_ref(x, dt, A, Bm, Cm, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr.astype(y.dtype)),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-4)


def test_selective_scan_state_carry():
    """Scanning two halves with carried state == scanning the whole."""
    key = jax.random.PRNGKey(7)
    B, S, Di, N = 1, 32, 8, 4
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, Di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di)))
    A = -jnp.exp(0.5 * jax.random.normal(ks[2], (Di, N)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    h0 = jnp.zeros((B, Di, N))
    y_full, h_full = selective_scan(x, dt, A, Bm, Cm, h0, chunk=8)
    y1, h1 = selective_scan(x[:, :16], dt[:, :16], A, Bm[:, :16],
                            Cm[:, :16], h0, chunk=8)
    y2, h2 = selective_scan(x[:, 16:], dt[:, 16:], A, Bm[:, 16:],
                            Cm[:, 16:], h1, chunk=8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), atol=1e-5)


def test_linear_recurrence_matches_ref():
    key = jax.random.PRNGKey(9)
    B, S, W = 2, 48, 8
    a = jax.nn.sigmoid(jax.random.normal(key, (B, S, W)))
    gx = jax.random.normal(jax.random.fold_in(key, 1), (B, S, W))
    h0 = jnp.zeros((B, W))
    hs, h = linear_recurrence(a, gx, h0, chunk=16)
    hsr, hr = rglru_scan_ref(a, gx, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hsr), atol=1e-5)


def test_rope_preserves_norm_and_relative_property():
    key = jax.random.PRNGKey(11)
    B, S, H, D = 1, 16, 2, 32
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    qr, kr = apply_rope(q, k, pos, 10000.0)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(qr, axis=-1)),
                               np.asarray(jnp.linalg.norm(q, axis=-1)),
                               rtol=1e-5)
    # relative property: q_i . k_j depends only on i - j
    d1 = float(jnp.einsum("d,d->", qr[0, 5, 0], kr[0, 3, 0]))
    qr2, kr2 = apply_rope(q, k, pos + 7, 10000.0)
    d2 = float(jnp.einsum("d,d->", qr2[0, 5, 0], kr2[0, 3, 0]))
    assert abs(d1 - d2) < 1e-3


def test_mrope_equals_rope_when_positions_equal():
    """With t==h==w positions, M-RoPE must reduce to standard RoPE."""
    key = jax.random.PRNGKey(13)
    B, S, H, D = 1, 8, 2, 32
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    pos1 = jnp.broadcast_to(jnp.arange(S), (B, S))
    pos3 = jnp.tile(pos1[..., None], (1, 1, 3))
    q1, k1 = apply_rope(q, k, pos1, 10000.0)
    q3, k3 = apply_mrope(q, k, pos3, 10000.0)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q3), atol=1e-5)
    np.testing.assert_allclose(np.asarray(k1), np.asarray(k3), atol=1e-5)


def test_cross_entropy_matches_naive_and_chunked():
    key = jax.random.PRNGKey(17)
    B, S, V = 2, 8, 64
    logits = jax.random.normal(key, (B, S, V))
    labels = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0, V)
    labels = labels.at[0, 0].set(-1)  # ignored position
    naive = -jnp.take_along_axis(
        jax.nn.log_softmax(logits, -1),
        jnp.clip(labels, 0)[..., None], axis=-1)[..., 0]
    naive = jnp.where(labels >= 0, naive, 0.0).sum() / (labels >= 0).sum()
    got = cross_entropy_loss(logits, labels)
    np.testing.assert_allclose(float(got), float(naive), rtol=1e-5)
    chunked = cross_entropy_loss(logits, labels, vocab_chunk=16)
    np.testing.assert_allclose(float(chunked), float(naive), rtol=1e-5)


def test_moe_router_aux_losses_behave():
    """Uniform router -> minimal load-balance loss; skewed -> larger."""
    from repro.configs import get_reduced
    from repro.models.moe import init_moe, moe_apply
    cfg = get_reduced("mixtral-8x22b")
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    _, aux = moe_apply(p, x, cfg)
    assert float(aux["moe_drop_frac"]) >= 0.0
    # router pushed to always pick expert 0 -> lb loss rises
    p_skew = dict(p)
    p_skew["router"] = p["router"].at[:, 0].set(50.0)
    _, aux_skew = moe_apply(p_skew, x, cfg)
    assert float(aux_skew["moe_lb_loss"]) > float(aux["moe_lb_loss"])


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mixtral-8x22b"])
def test_pallas_attention_impl_matches_jnp(arch):
    """Full model forward with attn_impl='pallas' (flash kernel,
    interpret=True on CPU) == the jnp chunked path."""
    from repro.configs import get_reduced
    from repro.models.lm import forward, init_params
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (2, 128), 0, cfg.vocab_size)
    ref = forward(params, toks, cfg, attn_impl="jnp")["logits"]
    got = forward(params, toks, cfg, attn_impl="pallas")["logits"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=5e-3)
