"""Per-architecture smoke tests (deliverable f): reduced same-family variant,
one forward + one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.models.lm import forward, init_params
from repro.optim.optimizers import sgd
from repro.train.step import make_lm_loss

TRANSFORMER_ARCHS = [a for a in ARCH_IDS if a != "resnet50"]


def _batch(cfg, key, B=2, S=16):
    toks = jax.random.randint(key, (B, S - cfg.prefix_embed_len), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks,
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.prefix_embed_len:
        batch["prefix_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.prefix_embed_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", TRANSFORMER_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    out = forward(params, batch["tokens"], cfg,
                  prefix_embeds=batch.get("prefix_embeds"))
    assert out["logits"].shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(out["logits"]).all())


@pytest.mark.parametrize("arch", TRANSFORMER_ARCHS)
def test_one_train_step(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    loss_fn = make_lm_loss(cfg)
    opt = sgd(momentum=0.9)
    opt_state = opt.init(params)
    batch = _batch(cfg, key)
    (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                   batch)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gnorm > 0.0
    new_params, _ = opt.update(grads, opt_state, params, 0.01)
    # params actually moved
    moved = any(float(jnp.max(jnp.abs(a - b))) > 0
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert moved
    out = forward(new_params, batch["tokens"], cfg,
                  prefix_embeds=batch.get("prefix_embeds"))
    assert bool(jnp.isfinite(out["logits"]).all())


def test_resnet_smoke():
    from repro.configs.resnet50 import reduced
    from repro.models.cnn import init_resnet, resnet_apply
    cfg = reduced()
    params, state = init_resnet(cfg, jax.random.PRNGKey(0))
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    logits, new_state = resnet_apply(params, state, imgs, cfg, train=True)
    assert logits.shape == (2, cfg.n_classes)
    assert bool(jnp.isfinite(logits).all())
    # batch-norm running stats moved
    old = state["stem"]["bn"]["mean"]
    new = new_state["stem"]["bn"]["mean"]
    assert float(jnp.max(jnp.abs(old - new))) > 0
