"""Checkpoint IO: bit-exact round-trip properties over mixed-dtype pytrees
(bf16 leaves, list/tuple containers, optimizer state), sharded restore
placement, and the versioned TrainState layer that backs deterministic
resume (controller schedule state, membership, loss trace)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.io import (TRAIN_STATE_VERSION, TrainState,
                                 load_checkpoint, load_train_state,
                                 save_checkpoint, save_train_state)
from repro.core.daso import DasoConfig
from repro.core.schedule import DasoController
from repro.optim.optimizers import adamw, sgd

_LEAF_SPECS = [
    ("float32", (3, 4)), ("float32", (7,)), ("bfloat16", (5, 3)),
    ("bfloat16", (2,)), ("float16", (4,)), ("int32", (6,)),
    ("int8", (3, 3)), ("uint32", (2, 2)),
]


def _leaf(rng, dt, shape):
    if dt.startswith(("int", "uint")):
        x = rng.randint(0 if dt.startswith("u") else -100, 100, size=shape)
    else:
        x = rng.randn(*shape) * 3
    return jnp.asarray(x).astype(dt)


def _assert_trees_identical(a, b):
    """Same treedef (tuple vs list distinguished), same dtypes, same bits."""
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb, (ta, tb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32)
                                      if x.dtype == jnp.bfloat16
                                      else np.asarray(x),
                                      np.asarray(y, np.float32)
                                      if y.dtype == jnp.bfloat16
                                      else np.asarray(y))


# -------------------------------------------------------- round-trips --

@given(st.lists(st.sampled_from(_LEAF_SPECS), min_size=1, max_size=6),
       st.sampled_from(["dict", "list", "tuple", "nested"]))
@settings(max_examples=20, deadline=None)
def test_roundtrip_mixed_dtype_property(specs, container):
    """save -> load is bit-identical (bf16 via the exact f32 widening) and
    structure-exact: lists come back lists, tuples come back tuples."""
    import tempfile

    rng = np.random.RandomState(len(specs) + len(container))
    leaves = [_leaf(rng, dt, shape) for dt, shape in specs]
    if container == "dict":
        tree = {f"k{i}": x for i, x in enumerate(leaves)}
    elif container == "list":
        tree = list(leaves)
    elif container == "tuple":
        tree = tuple(leaves)
    else:
        tree = {"a": (leaves[0], list(leaves)), "b": {"c": tuple(leaves)}}
    with tempfile.TemporaryDirectory() as path:
        save_checkpoint(path, tree, step=3)
        loaded, manifest = load_checkpoint(path)
    assert manifest["step"] == 3
    _assert_trees_identical(tree, loaded)


@pytest.mark.parametrize("opt_factory", [lambda: sgd(momentum=0.9),
                                         lambda: adamw()])
def test_optimizer_state_roundtrip(opt_factory, tmp_path):
    """Optimizer states (momentum trees, adamw's scalar step counter)
    survive the checkpoint layer exactly."""
    opt = opt_factory()
    params = {"w": jnp.ones((3, 2)), "b": jnp.zeros((2,), jnp.bfloat16)}
    state = opt.init(params)
    # advance once so the state is non-trivial
    grads = jax.tree.map(jnp.ones_like, params)
    _, state = opt.update(grads, state, params, 0.1)
    save_checkpoint(str(tmp_path), {"opt": state})
    loaded, _ = load_checkpoint(str(tmp_path))
    _assert_trees_identical(state, loaded["opt"])


def test_sharded_restore_placement(tmp_path):
    """Restore with a shardings pytree places every leaf with the
    requested NamedSharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("pod",))
    tree = {"w": jnp.arange(8, dtype=jnp.float32).reshape(2, 4),
            "b": jnp.ones((4,))}
    save_checkpoint(str(tmp_path), tree)
    sh = {"w": NamedSharding(mesh, P("pod")),
          "b": NamedSharding(mesh, P())}
    loaded, _ = load_checkpoint(str(tmp_path), shardings=sh)
    for k in tree:
        assert loaded[k].sharding.is_equivalent_to(sh[k], loaded[k].ndim)
        np.testing.assert_array_equal(np.asarray(loaded[k]),
                                      np.asarray(tree[k]))


# --------------------------------------------------------- TrainState --

def _controller_with_history():
    cfg = DasoConfig(n_replicas=2, global_world=8, b_max=4,
                     warmup_steps=2, cooldown_steps=2, total_steps=30)
    c = DasoController(cfg, loss_window=5)
    for t in range(12):
        c.mode_for_step(t)
        c.observe_loss(1.0 / (t + 1))
    c.notify_membership_change(12, 1)
    c.notify_dcn_scale(0.5, step=12)
    return cfg, c


def test_train_state_roundtrip(tmp_path):
    """Full TrainState: carry (tuple of trees incl. bf16), controller
    schedule state (window, history, events), membership, losses."""
    cfg, c = _controller_with_history()
    carry = ({"w": jnp.ones((2, 3, 3)), "b": jnp.zeros((2, 4), jnp.bfloat16)},
             {"mu": {"w": jnp.full((2, 3, 3), 0.5)}},
             {"w": jnp.ones((2, 3, 3)) * 2})
    state = TrainState(step=12, carry=carry, controller=c.state_dict(),
                       membership=[1.0, 0.0],
                       rng=jax.random.PRNGKey(7), strategy="daso",
                       losses=[1.0, 0.5, 0.25])
    save_train_state(str(tmp_path), state)
    loaded = load_train_state(str(tmp_path))
    assert loaded.version == TRAIN_STATE_VERSION
    assert loaded.step == 12
    assert loaded.strategy == "daso"
    assert loaded.membership == [1.0, 0.0]
    assert loaded.losses == [1.0, 0.5, 0.25]
    _assert_trees_identical(carry, loaded.carry)
    np.testing.assert_array_equal(np.asarray(loaded.rng),
                                  np.asarray(jax.random.PRNGKey(7)))
    # a controller restored from the loaded dict behaves identically
    c2 = DasoController(cfg, loss_window=5)
    c2.load_state_dict(loaded.controller)
    assert c2.state_dict() == c.state_dict()
    assert c2.history == c.history and c2.events == c.events
    assert (c2.b, c2.w) == (c.b, c.w)
    for t in range(12, 20):
        assert c2.mode_for_step(t) == c.mode_for_step(t)


def test_train_state_version_guard(tmp_path):
    """A checkpoint from a newer TrainState version is refused, and a bare
    parameter checkpoint is not mistaken for a TrainState."""
    state = TrainState(step=1, carry=({"w": jnp.ones(2)},),
                       version=TRAIN_STATE_VERSION + 1)
    save_train_state(str(tmp_path / "new"), state)
    with pytest.raises(ValueError, match="newer"):
        load_train_state(str(tmp_path / "new"))
    save_checkpoint(str(tmp_path / "bare"), {"w": jnp.ones(2)})
    with pytest.raises(ValueError, match="not a TrainState"):
        load_train_state(str(tmp_path / "bare"))
