"""Substrate: data pipeline, checkpointing, optimizers, schedules, HLO parse."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.io import load_checkpoint, save_checkpoint
from repro.data.synthetic import (SyntheticImages, SyntheticLM,
                                  make_noniid_class_partition)
from repro.optim.optimizers import adamw, clip_by_global_norm, sgd
from repro.optim.schedules import (plateau_decay_init, plateau_decay_update,
                                   warmup_cosine)


def test_synthetic_lm_deterministic_and_learnable():
    src = SyntheticLM(vocab_size=128, seq_len=32, seed=7)
    b1 = src.batch(4, step=3)
    b2 = src.batch(4, step=3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = src.batch(4, step=4)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))
    assert int(b1["tokens"].max()) < 128


def test_synthetic_images_class_structure():
    src = SyntheticImages(n_classes=4, image_size=16, seed=0)
    b = src.batch(64, step=0)
    assert b["images"].shape == (64, 16, 16, 3)
    # same-class images are closer to each other than cross-class (signal!)
    imgs, labels = np.asarray(b["images"]), np.asarray(b["labels"])
    c0 = imgs[labels == labels[0]]
    c_other = imgs[labels != labels[0]]
    if len(c0) > 1 and len(c_other) > 0:
        d_in = np.linalg.norm(c0[0] - c0[1])
        d_out = np.linalg.norm(c0[0] - c_other[0])
        assert d_in < d_out


def test_noniid_partition_rows_are_distributions():
    w = make_noniid_class_partition(10, 4, alpha=0.3, seed=1)
    assert w.shape == (4, 10)
    np.testing.assert_allclose(w.sum(axis=1), 1.0, rtol=1e-6)
    # skew: max class prob well above uniform
    assert w.max() > 0.3


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "c": [jnp.ones((4,), jnp.bfloat16), jnp.zeros((2,), jnp.int32)]}
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, tree, step=42, extra={"note": "x"})
    loaded, manifest = load_checkpoint(path)
    assert manifest["step"] == 42
    assert loaded["c"][0].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(loaded["a"]["b"]),
                                  np.asarray(tree["a"]["b"]))


def test_sgd_momentum_matches_closed_form():
    """One param, constant grad g: after k steps with momentum m,
    velocity = g*(1-m^k)/(1-m)."""
    opt = sgd(momentum=0.5, weight_decay=0.0)
    p = {"w": jnp.zeros(())}
    s = opt.init(p)
    g = {"w": jnp.ones(())}
    for k in range(1, 5):
        p, s = opt.update(g, s, p, lr=1.0)
    # sum_{k=1..4} velocity_k, velocity_k = (1-0.5^k)/(1-0.5)
    expect = -sum((1 - 0.5 ** k) / 0.5 for k in range(1, 5))
    np.testing.assert_allclose(float(p["w"]), expect, rtol=1e-6)


def test_adamw_decays_and_steps():
    opt = adamw(weight_decay=0.1)
    p = {"w": jnp.ones((3,))}
    s = opt.init(p)
    g = {"w": jnp.zeros((3,))}
    p2, s2 = opt.update(g, s, p, lr=0.1)
    assert float(p2["w"][0]) < 1.0  # pure weight decay moved it
    assert int(s2["t"]) == 1


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, n = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(n), 10.0, rtol=1e-6)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)


def test_warmup_cosine_shape():
    fn = warmup_cosine(1.0, 10, 100)
    assert float(fn(0)) == 0.0
    np.testing.assert_allclose(float(fn(10)), 1.0, rtol=1e-5)
    assert float(fn(55)) < 1.0
    assert float(fn(100)) <= float(fn(55))


@given(st.lists(st.floats(0.1, 10.0), min_size=1, max_size=30))
@settings(max_examples=20, deadline=None)
def test_plateau_scale_never_increases(losses):
    s = plateau_decay_init()
    for l in losses:
        s, _ = plateau_decay_update(s, l, patience=2)
    assert s.scale <= 1.0


def test_hlo_collective_classifier():
    from repro.launch.hlo_stats import classify_axis
    mesh = {"pod": 2, "data": 4, "model": 2}
    # strides: model=1, data=2, pod=8
    assert classify_axis([0, 1], mesh) == "model"
    assert classify_axis([0, 2, 4, 6], mesh) == "data"
    assert classify_axis([0, 8], mesh) == "pod"
    assert classify_axis([0, 1, 2, 3, 4, 5, 6, 7], mesh) == "pod+data"
    assert classify_axis(None, mesh) == "none"


def test_hlo_iota_replica_groups_parse():
    from repro.launch.hlo_stats import _first_group
    assert _first_group("{{0,1},{2,3}}") == [0, 1]
    assert _first_group("[2,4]<=[8]") == [0, 1, 2, 3]
    g = _first_group("[4,2]<=[2,4]T(1,0)")
    assert g == [0, 4]
