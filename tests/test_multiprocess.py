"""The SPMD-equivalence contract of the multi-process runtime
(launch/distributed.py): an N-process `jax.distributed` run of the same
TopologySpec, seed, and fault plan is bit-exact with the single-process
SPMD run — on both executors. Spawns REAL process groups through
tools/launch_procs.py (each child pinned to world/N forced CPU devices,
joined via a localhost coordinator), then compares the metrics JSON and
final checkpoint bit-for-bit.

These tests use the --tiny arch: at that scale per-device compute sits
below XLA CPU's intra-op partitioning thresholds, so the only layout-
dependent code paths are the collectives — which the runtime pins with
DasoConfig.deterministic_reduce (docs/architecture.md, "Multi-process
runtime")."""
import glob
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from conftest import subprocess_env

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
LAUNCHER = os.path.join(REPO, "tools", "launch_procs.py")
TOPOLOGY = "chip:1 x host:2 x pod:2"  # world 4: R=4 replicas, 3 levels

BASE_ARGS = ["--arch", "llama3.2-1b", "--tiny", "--topology", TOPOLOGY,
             "--per-node-batch", "2", "--seq-len", "16", "--b-max", "4",
             "--seed", "0"]


def launch(procs: int, train_args, timeout: int = 600) -> None:
    """Run one process group to completion via the real harness. The
    harness constructs each child's JAX env explicitly; wiping the
    variables here proves nothing leaks in from the pytest process."""
    cmd = [sys.executable, LAUNCHER, "--procs", str(procs),
           "--timeout", str(timeout), "--"] + BASE_ARGS + train_args
    env = subprocess_env(devices=1)
    env.pop("XLA_FLAGS")  # the harness sets the per-child device count
    r = subprocess.run(cmd, capture_output=True, text=True,
                       timeout=timeout + 60, env=env, cwd=REPO)
    assert r.returncode == 0, (f"launch_procs --procs {procs} failed "
                               f"({r.returncode}):\n{r.stdout}\n{r.stderr}")


def load_metrics(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def assert_same_params(dir_a: str, dir_b: str) -> None:
    files_a = sorted(glob.glob(os.path.join(dir_a, "*.npz")))
    files_b = sorted(glob.glob(os.path.join(dir_b, "*.npz")))
    assert files_a and len(files_a) == len(files_b)
    for fa, fb in zip(files_a, files_b):
        a, b = np.load(fa), np.load(fb)
        assert sorted(a.files) == sorted(b.files)
        for k in a.files:
            if k == "__save_id__":
                continue  # unique per save by design
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def _equivalence(tmp_path, procs: int, extra, *, steps: int = 16,
                 ckpt: bool = True):
    """N-process vs 1-process: bit-identical loss trace (and final params
    when `ckpt`)."""
    out = {}
    for n in (1, procs):
        m = str(tmp_path / f"metrics_{n}.json")
        args = extra + ["--steps", str(steps), "--metrics-out", m]
        if ckpt:
            args += ["--ckpt", str(tmp_path / f"ckpt_{n}")]
        launch(n, args)
        out[n] = load_metrics(m)
    assert out[1]["losses"] == out[procs]["losses"], (
        "per-step loss traces diverge between process layouts")
    assert out[1]["final_loss"] == out[procs]["final_loss"]
    assert out[1]["sync_fraction"] == out[procs]["sync_fraction"]
    if ckpt:
        assert_same_params(str(tmp_path / "ckpt_1"),
                           str(tmp_path / f"ckpt_{procs}"))
    return out


def test_two_process_macro_bit_exact(tmp_path):
    """Flagship contract: 2 processes, compiled macro-cycle executor."""
    out = _equivalence(tmp_path, 2, [])
    # the schedule actually exercised async + hierarchy, not just warmup
    assert 0.0 < out[1]["sync_fraction"] < 1.0
    stats = out[1]["executor_stats"]
    assert stats["dispatches"] < 16  # macro-cycles, not per-step


@pytest.mark.slow
def test_two_process_per_step_bit_exact(tmp_path):
    """Same contract on the per-step reference executor. @slow: tier-1
    keeps the macro flagship only; the CI multiprocess-smoke matrix and
    the nightly job run this on every PR / night."""
    _equivalence(tmp_path, 2, ["--executor", "per_step"], steps=10,
                 ckpt=False)


@pytest.mark.slow
def test_two_process_fault_plan_bit_exact(tmp_path):
    """Crash + rejoin replayed identically on every process: membership
    masks, cache invalidations, and rejoin re-seeding are deterministic,
    so the faulty run is bit-exact across layouts too. @slow: see
    test_two_process_per_step_bit_exact."""
    plan = tmp_path / "plan.json"
    plan.write_text(json.dumps({"events": [
        {"step": 4, "kind": "crash", "replica": 3},
        {"step": 8, "kind": "rejoin", "replica": 3}]}))
    out = _equivalence(tmp_path, 2, ["--fault-plan", str(plan)], steps=12,
                       ckpt=False)
    for n in (1, 2):
        r = out[n]["resilience"]
        assert r["invalidations"] == 2
        assert [e["kind"] for e in r["events"]] == ["crash", "rejoin"]


@pytest.mark.slow
def test_four_process_bit_exact(tmp_path):
    """One process per finest subtree (pod/host), one device each — the CI
    multiprocess-smoke matrix's 4-process cell."""
    _equivalence(tmp_path, 4, [], steps=10, ckpt=False)


def test_dispatch_overlap_without_overlap_mode_fails_fast(tmp_path):
    """Regression guard for the PR-5 gloo interleaving failure: async
    dispatch with the BLOCKING schedule would put two collective-bearing
    programs in flight on the shared gloo TCP pairs. The launcher must
    reject --dispatch overlap + --overlap off BEFORE jax.distributed even
    initializes, with the fix named — not hang or abort mid-run."""
    cmd = [sys.executable, LAUNCHER, "--procs", "2",
           "--timeout", "120", "--"] + BASE_ARGS + [
           "--steps", "2", "--dispatch", "overlap", "--overlap", "off"]
    env = subprocess_env(devices=1)
    env.pop("XLA_FLAGS")
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=180,
                       env=env, cwd=REPO)
    assert r.returncode != 0
    assert "requires --overlap one_cycle" in r.stdout + r.stderr


@pytest.mark.slow
def test_two_process_overlap_hides_exchange(tmp_path):
    """The overlap-smoke lane: on the real 2-process gloo runtime the
    overlap dispatch leg and the serial-exchange baseline leg are
    bit-identical in numerics, the exchange visibly overlaps (visible
    wait < blocking wait), and overlap cycles actually ran."""
    out = {}
    for name, extra in [("overlap", ["--dispatch", "overlap"]),
                        ("serial", ["--overlap-serial-exchange"])]:
        m = str(tmp_path / f"{name}.json")
        launch(2, ["--overlap", "one_cycle", "--steps", "12",
                   "--metrics-out", m] + extra)
        out[name] = load_metrics(m)
    assert out["overlap"]["losses"] == out["serial"]["losses"]
    s_ov = out["overlap"]["executor_stats"]
    s_se = out["serial"]["executor_stats"]
    assert s_ov["overlap_cycles"] > 0
    assert s_se["overlap_exchange_blocking_s"] > 0.0
    # measured overlap fraction > 0: some of the blocking wait disappeared
    assert (s_ov["overlap_exchange_visible_s"]
            < s_se["overlap_exchange_blocking_s"])


@pytest.mark.slow
def test_two_process_overlap_spmd_bit_exact(tmp_path):
    """The SPMD-equivalence contract holds under overlap dispatch too: a
    2-process overlap run is bit-exact with the 1-process SPMD oracle."""
    _equivalence(tmp_path, 2, ["--overlap", "one_cycle",
                               "--dispatch", "overlap"],
                 steps=12, ckpt=False)


def test_mismatched_process_count_fails_fast(tmp_path):
    """A topology that cannot be carved into per-process subtrees must be
    rejected at placement time, before any training step."""
    cmd = [sys.executable, LAUNCHER, "--procs", "3",
           "--timeout", "120", "--", "--arch", "llama3.2-1b", "--tiny",
           "--topology", "chip:1 x host:3 x pod:2", "--steps", "2",
           "--per-node-batch", "2", "--seq-len", "16"]
    env = subprocess_env(devices=1)
    env.pop("XLA_FLAGS")
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=240,
                       env=env, cwd=REPO)
    assert r.returncode != 0
    assert "cut through" in r.stdout + r.stderr