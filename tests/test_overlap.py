"""The double-buffered compute/communication overlap tier (ISSUE 6):

  * Eq. (1) with `extra_staleness` — 0 is bit-exact with the pre-overlap
    merge; kernel/ref/per-leaf implementations agree for every extra age
    (property tests, hypothesis or the conftest fallback shim).
  * The overlap controller schedule: ov_start / ov_sync~E tokens, the
    cut-after-ov-step cycle planning, and checkpoint state round-trips
    (including pre-overlap state dicts without `_ov_last`).
  * Executor equivalence: the overlap-dispatched macro path is bit-exact
    with the per-step reference path, and `serial_exchange` (the
    benchmark baseline leg) changes host waiting only, never numerics.
  * Convergence: the one-cycle-stale merge stays within tolerance of the
    blocking schedule on both executors.
  * Checkpointing: mid-run resume of the 4-slot overlap carry is
    bit-exact; carry-layout mismatches are rejected with the fix named.
  * `check_overlap_topology` and the `overlap_step_s` analytic algebra.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import make_mlp_problem

from repro.core.daso import (DasoConfig, daso_train_step,
                             global_receive, global_receive_per_leaf)
from repro.core.executor import (OVERLAP_COMPUTE_PREFIX, MacroCycleExecutor,
                                 make_strategy, run_compiled_training)
from repro.core.schedule import DasoController, Mode, is_ov_mode, split_ov
from repro.kernels.ref import eq1_merge_ref
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant_lr
from repro.train.loop import TrainLoopConfig, run_training

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# -- Eq. (1) with extra staleness: properties ---------------------------------

def _old_eq1(local, stale, s, p):
    """The pre-overlap Eq. (1) merge, written out independently."""
    s2 = jnp.float32(2.0 * s)
    pf = jnp.float32(float(p))
    out = (s2 * local.astype(jnp.float32)
           + pf * stale.astype(jnp.float32)) / (s2 + pf)
    return out.astype(local.dtype)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 8), st.integers(2, 64),
       st.sampled_from(["float32", "bfloat16"]))
def test_extra_staleness_zero_is_pre_overlap_merge(staleness, world, dtype):
    """extra_staleness=0 must be BIT-exact with the pre-overlap kernel:
    2.0 * (S + 0) is the same float as 2.0 * S, so the whole multiply-add
    chain is unchanged."""
    k = jax.random.PRNGKey(staleness * 1000 + world)
    local = jax.random.normal(k, (2, 33)).astype(dtype)
    stale = jax.random.normal(jax.random.fold_in(k, 1), (2, 33)).astype(dtype)
    got = eq1_merge_ref(local, stale, staleness=staleness,
                        global_world=world, extra_staleness=0)
    want = _old_eq1(local, stale, staleness, world)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 6), st.integers(0, 5), st.integers(2, 32))
def test_extra_staleness_equals_shifted_staleness(staleness, extra, world):
    """The merge depends only on the EFFECTIVE age S + E: (s, e) and
    (s + e, 0) produce bit-identical outputs."""
    k = jax.random.PRNGKey(7 * staleness + extra)
    local = jax.random.normal(k, (3, 17))
    stale = jax.random.normal(jax.random.fold_in(k, 1), (3, 17))
    a = eq1_merge_ref(local, stale, staleness=staleness,
                      global_world=world, extra_staleness=extra)
    b = eq1_merge_ref(local, stale, staleness=staleness + extra,
                      global_world=world, extra_staleness=0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(0, 4),
       st.sampled_from(["float32", "bfloat16"]))
def test_global_receive_impls_agree_with_extra(staleness, extra, dtype):
    """per_leaf / fused-ref / Pallas-kernel merges agree for every extra
    age and dtype (the kernel runs interpret=True on CPU)."""
    k = jax.random.PRNGKey(staleness + 10 * extra)
    tree = {"a": jax.random.normal(k, (2, 5, 3)).astype(dtype),
            "b": jax.random.normal(jax.random.fold_in(k, 1), (2, 7))}
    stale = jax.tree.map(lambda x: x + 0.25, tree)
    kw = dict(staleness=staleness, global_world=8, extra_staleness=extra)
    out = {name: global_receive(tree, stale, impl=impl,
                                use_kernels=kern, **kw)
           for name, impl, kern in [("per_leaf", "per_leaf", False),
                                    ("ref", "fused", False),
                                    ("kernel", "fused", True)]}
    for name in ("ref", "kernel"):
        for la, lb in zip(jax.tree.leaves(out["per_leaf"]),
                          jax.tree.leaves(out[name])):
            np.testing.assert_allclose(np.asarray(la, np.float32),
                                       np.asarray(lb, np.float32),
                                       atol=2e-6, err_msg=name)


def test_overlap_flag_does_not_leak_into_blocking_graphs():
    """The off-mode bit-exactness contract at the HLO level: the compiled
    program of every NON-overlap mode is identical whether cfg.overlap is
    "off" or "one_cycle" — the flag changes which programs run, never what
    a given program computes."""
    cfg_off = DasoConfig(n_replicas=2, global_world=4, b_max=4,
                         warmup_steps=2, cooldown_steps=2, total_steps=12)
    cfg_ov = dataclasses.replace(cfg_off, overlap="one_cycle")
    params = {"w": jnp.ones((2, 4, 3))}
    opt = sgd(momentum=0.9)
    opt_state = jax.vmap(opt.init)(params)
    inflight = jax.tree.map(jnp.zeros_like, params)
    batch = {"x": jnp.ones((2, 8, 4)), "y": jnp.ones((2, 8, 3))}

    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2), {}

    for mode in ("local", "blocking", "send", "receive"):
        texts = []
        for cfg in (cfg_off, cfg_ov):
            step = daso_train_step(loss_fn, opt, cfg, mode=mode, staleness=1)
            texts.append(jax.jit(step).lower(
                params, opt_state, inflight, batch, 0.1).as_text())
        assert texts[0] == texts[1], f"mode {mode!r} HLO differs"


# -- controller schedule -------------------------------------------------------

def _cfg(overlap="one_cycle", **kw):
    base = dict(n_replicas=2, global_world=4, b_max=4, warmup_steps=3,
                cooldown_steps=2, total_steps=16, overlap=overlap)
    base.update(kw)
    return DasoConfig(**base)


def test_split_ov_tokens():
    assert split_ov("ov_sync~2") == (Mode.OV_SYNC, 2)
    assert split_ov("ov_sync") == (Mode.OV_SYNC, 0)
    assert split_ov("local") == ("local", 0)
    assert is_ov_mode("ov_sync~1+host")
    assert is_ov_mode("ov_start")
    assert not is_ov_mode("send+host")


def test_overlap_schedule_tokens():
    """Warm-up blocking, then ov_start, B-1 locals, and ov_sync~E where
    E = age - min(W, age); cool-down blocking resets the snapshot."""
    c = DasoController(_cfg(), loss_window=50)
    modes = [c.mode_for_step(s) for s in range(16)]
    assert [m for m, _ in modes[:3]] == [Mode.BLOCKING] * 3
    assert modes[3] == (Mode.OV_START, 1)
    assert [m for m, _ in modes[4:7]] == [Mode.LOCAL] * 3
    # age 4, W = max(1, 4 // 4) = 1 -> S = 1, extra = 3
    assert modes[7] == ("ov_sync~3", 1)
    assert [m for m, _ in modes[8:11]] == [Mode.LOCAL] * 3
    assert modes[11] == ("ov_sync~3", 1)
    assert [m for m, _ in modes[14:]] == [Mode.BLOCKING] * 2
    assert c._ov_last is None  # cooldown superseded the snapshot


def test_overlap_plan_cycle_cuts_after_ov_step():
    c = DasoController(_cfg(), loss_window=50)
    assert [m for m, _ in c.plan_cycle(0)] == [Mode.BLOCKING] * 3
    assert [m for m, _ in c.plan_cycle(3)] == [Mode.OV_START]
    assert [m for m, _ in c.plan_cycle(4)] == [Mode.LOCAL] * 3 + ["ov_sync~3"]
    assert [m for m, _ in c.plan_cycle(8)] == [Mode.LOCAL] * 3 + ["ov_sync~3"]


def test_overlap_controller_state_roundtrip():
    a = DasoController(_cfg(total_steps=40, cooldown_steps=0),
                       loss_window=50)
    for s in range(9):
        a.mode_for_step(s)
    sd = a.state_dict()
    assert sd["_ov_last"] == 7
    b = DasoController(_cfg(total_steps=40, cooldown_steps=0),
                       loss_window=50)
    b.load_state_dict(sd)
    for s in range(9, 20):
        assert a.mode_for_step(s) == b.mode_for_step(s)


def test_pre_overlap_state_dict_loads():
    """A checkpoint written before the overlap tier has no _ov_last key;
    loading it must keep the fresh default (re-snapshot via ov_start)."""
    a = DasoController(_cfg(), loss_window=50)
    for s in range(6):
        a.mode_for_step(s)
    sd = a.state_dict()
    del sd["_ov_last"]
    b = DasoController(_cfg(), loss_window=50)
    b.load_state_dict(sd)
    assert b._ov_last is None
    # next cycling step re-snapshots instead of merging a lost buffer
    assert b.mode_for_step(6) == (Mode.OV_START, 1)


def test_overlap_sync_fraction_counts_ov_sync():
    c = DasoController(_cfg(), loss_window=50)
    for s in range(16):
        c.mode_for_step(s)
    # 3 warmup + 2 ov_sync + 2 cooldown of 16 steps
    assert c.global_sync_fraction() == pytest.approx(7 / 16)
    assert c.level_sync_counts()["_outer"] == 7


# -- executor: overlap cycle recognition and carry layout ---------------------

def _strategy(overlap):
    cfg = _cfg(overlap=overlap)
    _, loss_fn, _, _ = make_mlp_problem(jax.random.PRNGKey(0))
    return make_strategy("daso", loss_fn, sgd(momentum=0.9), cfg,
                         controller=DasoController(cfg, loss_window=50))


def test_overlap_carry_is_four_slot():
    params0, _, _, _ = make_mlp_problem(jax.random.PRNGKey(0))
    assert len(_strategy("one_cycle").init_carry(params0)) == 4
    assert len(_strategy("off").init_carry(params0)) == 3
    assert _strategy("off").overlap_cycle((("local", 1),)) is None


def test_overlap_cycle_recognition():
    s = _strategy("one_cycle")
    ov = s.overlap_cycle((("local", 1), ("local", 1), ("ov_sync~2", 1)))
    assert ov is not None
    assert (ov.staleness, ov.extra_staleness) == (1, 2)
    assert all(m.startswith(OVERLAP_COMPUTE_PREFIX)
               for m, _ in ov.compute_shape)
    # ov_start ends a cycle without an exchange to dispatch
    assert s.overlap_cycle((("ov_start", 1),)) is None
    # a blocking step inside the cycle forbids the async dispatch
    assert s.overlap_cycle((("blocking", 1), ("ov_sync", 1))) is None
    assert s.overlap_cycle(()) is None


# -- executor equivalence and convergence -------------------------------------

def _run(overlap, executor, *, serial_exchange=False, n_steps=24,
         ckpt_every=0, ckpt_dir=None, resume_from=None):
    key = jax.random.PRNGKey(3)
    params0, loss_fn, daso_data, _ = make_mlp_problem(key)
    cfg = TrainLoopConfig(strategy="daso", n_steps=n_steps, n_replicas=2,
                          b_max=4, loss_window=50, executor=executor,
                          overlap=overlap,
                          overlap_serial_exchange=serial_exchange,
                          ckpt_every=ckpt_every, ckpt_dir=ckpt_dir,
                          resume_from=resume_from)
    return run_training(loss_fn, params0, daso_data, cfg,
                        optimizer=sgd(momentum=0.9),
                        lr_fn=constant_lr(0.05), log=None)


def test_overlap_macro_matches_per_step():
    """The overlap-dispatched macro path is bit-exact with the per-step
    reference path — the dispatch structure changes, the math does not."""
    macro = _run("one_cycle", "macro")
    ref = _run("one_cycle", "per_step")
    assert macro.losses == ref.losses
    for a, b in zip(jax.tree.leaves(macro.params),
                    jax.tree.leaves(ref.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert macro.executor_stats.overlap_cycles > 0


@pytest.mark.parametrize("serial", [False, True])
def test_overlap_stats_legs_partition_wall(serial):
    """The overlap timing legs are an EXACT partition of the overlap wall
    time: every leg ends on block_until_ready at a boundary timestamp that
    is also where the next leg starts (core/executor.py::_run_overlap), so
    compute + visible (or blocking) + merge == wall to float addition."""
    r = _run("one_cycle", "macro", serial_exchange=serial)
    st = r.executor_stats
    assert st.overlap_cycles > 0
    legs = (st.overlap_compute_s + st.overlap_exchange_visible_s
            + st.overlap_exchange_blocking_s + st.overlap_merge_s)
    assert st.overlap_wall_s > 0.0
    assert legs == pytest.approx(st.overlap_wall_s, rel=1e-9, abs=1e-9)
    # the mode under test fills its leg, the other stays zero
    if serial:
        assert st.overlap_exchange_blocking_s > 0.0
        assert st.overlap_exchange_visible_s == 0.0
    else:
        assert st.overlap_exchange_visible_s > 0.0
        assert st.overlap_exchange_blocking_s == 0.0


def test_serial_exchange_identical_numerics():
    """serial_exchange (the benchmark's blocking baseline leg) changes
    only WHEN the host waits — losses and params must be bit-identical."""
    a = _run("one_cycle", "macro", serial_exchange=False)
    b = _run("one_cycle", "macro", serial_exchange=True)
    assert a.losses == b.losses
    for la, lb in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    assert b.executor_stats.overlap_exchange_blocking_s >= 0.0
    assert b.executor_stats.overlap_cycles == a.executor_stats.overlap_cycles


@pytest.mark.parametrize("executor", ["macro", "per_step"])
def test_overlap_convergence_close_to_blocking(executor):
    """One-cycle-stale merges may move the loss, but on the tiny 2-level
    problem the gap to the blocking schedule stays small — the paper's
    claim that selective/asynchronous sync does not hurt convergence."""
    ov = _run("one_cycle", executor, n_steps=32)
    off = _run("off", executor, n_steps=32)
    assert ov.losses[-1] < ov.losses[0]  # it actually trains
    assert abs(ov.final_loss - off.final_loss) < 0.25


def test_overlap_off_losses_unchanged_by_serial_flag():
    """overlap=off runs have no overlap cycles for serial_exchange to
    touch; the flag must be inert."""
    a = _run("off", "macro", serial_exchange=True)
    b = _run("off", "macro", serial_exchange=False)
    assert a.losses == b.losses
    assert a.executor_stats.overlap_cycles == 0


# -- checkpointing of the 4-slot overlap carry --------------------------------

def test_overlap_checkpoint_resume_bit_exact(tmp_path):
    """Resume mid-overlap: the pending arena and the controller's
    _ov_last survive the round-trip, so the resumed run is bit-exact."""
    ckpt = str(tmp_path / "ck")
    fresh = _run("one_cycle", "macro", n_steps=24)
    _run("one_cycle", "macro", n_steps=24, ckpt_every=8, ckpt_dir=ckpt)
    dirs = sorted(os.listdir(ckpt))
    assert dirs
    resumed = _run("one_cycle", "macro", n_steps=24,
                   resume_from=os.path.join(ckpt, dirs[0]))
    assert resumed.losses == fresh.losses
    for a, b in zip(jax.tree.leaves(resumed.params),
                    jax.tree.leaves(fresh.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_overlap_layout_mismatch_rejected(tmp_path):
    from repro.checkpoint.io import TrainState, load_train_state, \
        save_train_state
    path = str(tmp_path / "st")
    carry = ({"w": jnp.ones((2, 3))}, {"m": jnp.zeros((2, 3))},
             {"w": jnp.zeros((2, 3))})
    save_train_state(path, TrainState(step=4, carry=carry, overlap="off"))
    with pytest.raises(ValueError, match="--overlap off"):
        load_train_state(path, expect_overlap="one_cycle")
    assert load_train_state(path, expect_overlap="off").overlap == "off"


def test_v1_checkpoint_defaults_to_off(tmp_path):
    """A TrainState written before the overlap tier (v1, no overlap key)
    must load as overlap="off" — and be rejected by an overlap run."""
    from repro.checkpoint.io import TrainState, load_train_state, \
        save_train_state
    path = str(tmp_path / "st")
    save_train_state(path, TrainState(step=2, carry={"w": jnp.ones((2,))}))
    mf = os.path.join(path, "manifest.json")
    with open(mf) as f:
        manifest = json.load(f)
    host = manifest["extra"]["train_state"]
    host["version"] = 1
    del host["overlap"]
    with open(mf, "w") as f:
        json.dump(manifest, f)
    ts = load_train_state(path, expect_overlap="off")
    assert ts.overlap == "off" and ts.version == 1
    with pytest.raises(ValueError, match="TrainState v1"):
        load_train_state(path, expect_overlap="one_cycle")


# -- multi-process guardrails --------------------------------------------------

def test_check_overlap_topology():
    from repro.launch.distributed import check_overlap_topology
    from repro.topo import TopologySpec
    spec = TopologySpec.load("chip:1 x host:2 x pod:2")  # R=4, host groups 2
    check_overlap_topology(spec, 1)   # single process: nothing to race
    check_overlap_topology(spec, 2)   # 2 rows/proc, host group 2: local
    with pytest.raises(ValueError, match="process-local"):
        check_overlap_topology(spec, 4)  # host groups span processes


def test_sync_strategy_rejects_overlap():
    _, loss_fn, _, sync_data = make_mlp_problem(jax.random.PRNGKey(0))
    cfg = TrainLoopConfig(strategy="sync", n_steps=4, overlap="one_cycle")
    with pytest.raises(ValueError, match="sync"):
        run_training(loss_fn, {"w": jnp.ones((8, 1))}, sync_data, cfg,
                     log=None)


# -- analytic model: overlap_step_s algebra -----------------------------------

def _comm():
    import sys
    sys.path.insert(0, REPO)
    from benchmarks import comm_model
    return comm_model


def test_overlap_step_free_exchange_is_pure_compute():
    """Zero-cost DCN: the cycle costs exactly one compute + local
    all-reduce per step — overlap adds nothing."""
    cm = _comm()
    c = cm.ClusterModel(ib_bw=1e30, step_latency_s=0.0)
    t_local = cm.ring_allreduce_s(1e8, c.gpus_per_node, c.nvlink_bw,
                                  latency=3e-6)
    got = cm.overlap_step_s(1e8, 16, c, b=4, blocking_frac=0.0)
    assert got == pytest.approx(c.t_compute_s + t_local, rel=1e-12)


def test_overlap_step_exchange_dominated():
    """No compute, no local members: the step degenerates to the exchange
    amortized over the cycle — t_exchange / B exactly."""
    cm = _comm()
    c = cm.ClusterModel(gpus_per_node=1, t_compute_s=0.0)
    t_ex = cm.degraded_exchange_s(1e9, 16, c)
    got = cm.overlap_step_s(1e9, 16, c, b=4, blocking_frac=0.0)
    assert got == pytest.approx(t_ex / 4, rel=1e-12)


def test_overlap_step_compute_dominated():
    cm = _comm()
    c = cm.ClusterModel(t_compute_s=100.0)
    t_local = cm.ring_allreduce_s(1e8, c.gpus_per_node, c.nvlink_bw,
                                  latency=3e-6)
    got = cm.overlap_step_s(1e8, 4, c, b=4, blocking_frac=0.0)
    assert got == pytest.approx(c.t_compute_s + t_local, rel=1e-12)


def test_overlap_step_blocking_frac_blend():
    """blocking_frac=1 is the fully blocking schedule for both models."""
    cm = _comm()
    c = cm.ClusterModel()
    assert cm.overlap_step_s(1e8, 16, c, blocking_frac=1.0) == \
        pytest.approx(cm.daso_step_s(1e8, 16, c, blocking_frac=1.0,
                                     nonblocking_hidden=0.0), rel=1e-12)


def test_overlap_step_rejects_bad_cycle():
    cm = _comm()
    with pytest.raises(ValueError, match="b must be >= 1"):
        cm.overlap_step_s(1e8, 16, cm.ClusterModel(), b=0)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 16), st.integers(2, 64),
       st.floats(0.0, 1.0))
def test_overlap_never_worse_than_unhidden(b, n_nodes, blocking_frac):
    """The measured-dispatch model never prices a step above the same
    schedule with zero hiding."""
    cm = _comm()
    c = cm.ClusterModel()
    ov = cm.overlap_step_s(1e8, n_nodes, c, b=b,
                           blocking_frac=blocking_frac)
    blk = cm.daso_step_s(1e8, n_nodes, c, b=b, blocking_frac=blocking_frac,
                         nonblocking_hidden=0.0)
    assert ov <= blk + 1e-15
