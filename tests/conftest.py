import os
import sys

# Make `repro` importable regardless of how pytest is invoked.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# The property tests import `hypothesis`. On minimal containers without it
# (and without network for `pip install -e .[test]`), register the
# deterministic fallback shim so the tier-1 suite still collects and runs.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback as _shim  # noqa: F401

    _module = type(sys)("hypothesis")
    _module.given = _shim.given
    _module.settings = _shim.settings
    _module.strategies = _shim
    sys.modules["hypothesis"] = _module
    sys.modules["hypothesis.strategies"] = _shim

def make_mlp_problem(key, R=2, per=16, d=8):
    """Shared tiny-MLP training problem for the loop/executor tests.
    Returns (params0, loss_fn, daso_data, sync_data); daso batches carry the
    leading replica axis R. Random init: all-zeros would zero every gradient
    (tanh(0) kills the w2 grad and, through w2=0, the w1 grad) and nothing
    would train."""
    import jax
    import jax.numpy as jnp

    w1 = jax.random.normal(key, (d, 16)) * 0.5
    k1, k2 = jax.random.split(jax.random.fold_in(key, 7))
    params0 = {"w1": jax.random.normal(k1, (d, 16)) * 0.3,
               "w2": jax.random.normal(k2, (16, 1)) * 0.3}

    def loss_fn(params, batch):
        h = jnp.tanh(batch["x"] @ params["w1"])
        pred = h @ params["w2"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    def daso_data(step):
        k = jax.random.fold_in(key, step)
        x = jax.random.normal(k, (R, per, d))
        y = jnp.tanh(x @ w1).sum(-1, keepdims=True) * 0.3
        return {"x": x, "y": y}

    def sync_data(step):
        b = daso_data(step)
        return {k2_: v.reshape((-1,) + v.shape[2:]) for k2_, v in b.items()}

    return params0, loss_fn, daso_data, sync_data


# NOTE: XLA_FLAGS / device-count overrides are intentionally NOT set here —
# smoke tests must see the real single CPU device. Multi-device distributed
# tests spawn subprocesses through the helpers below, which build the JAX
# environment EXPLICITLY (platform + device count are always set, never
# silently inherited) so a local `pytest` run behaves exactly like CI.



def subprocess_env(devices: int = 1, extra: dict = None) -> dict:
    """Environment for a spawned JAX subprocess: JAX_PLATFORMS is pinned
    to cpu and XLA_FLAGS to the forced host device count — never
    inherited from the developer's shell — so a local `pytest` run
    behaves exactly like CI. One definition, shared with the process
    launcher (launch.distributed.forced_cpu_env)."""
    from repro.launch.distributed import forced_cpu_env

    env = forced_cpu_env(devices)
    if extra:
        env.update(extra)
    return env


def run_subprocess(script: str, devices: int = 8, timeout: int = 900,
                   extra_env: dict = None) -> str:
    """Run an inline python script in a fresh process on `devices` forced
    CPU devices; assert success and return stdout. Coordinator port races
    are handled at the source — `launch.distributed.initialize` retries
    transient connect/bind failures with backoff — so any failure here is
    real and surfaces immediately."""
    import subprocess
    import sys
    import textwrap

    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=subprocess_env(devices, extra_env))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout
