import os
import sys

# Make `repro` importable regardless of how pytest is invoked.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: XLA_FLAGS / device-count overrides are intentionally NOT set here —
# smoke tests must see the real single CPU device. Multi-device distributed
# tests spawn subprocesses that set --xla_force_host_platform_device_count
# themselves (see test_distributed.py).
