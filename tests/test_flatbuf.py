"""Fused flat-buffer exchange: pack/unpack roundtrip properties over
mixed-dtype/mixed-shape pytrees, wire-codec tiers (bf16 / int8 error
bounds), Pallas comm kernels vs the jnp oracles, dtype/wire-aware
transfer_bytes, and the HLO-level guarantee that one global exchange is
exactly ONE cross-replica all-reduce independent of leaf count."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import flatbuf
from repro.core.compression import (compress_bf16_roundtrip, transfer_bytes,
                                    wire_itemsize)
from repro.kernels import ops, ref

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# (dtype, shape) menu for the mixed-tree property; the shim's sampled_from
# handles arbitrary items
_LEAF_SPECS = [
    ("float32", (3, 4)), ("float32", (7,)), ("float32", (2, 2, 2)),
    ("bfloat16", (5, 3)), ("bfloat16", (8,)),
    ("float16", (4, 4)), ("int32", (6,)), ("int8", (3, 3)),
]


def _make_tree(specs, batch_shape=()):
    rng = np.random.RandomState(len(specs))
    tree = {}
    for i, (dt, shape) in enumerate(specs):
        full = batch_shape + shape
        if dt.startswith("int"):
            x = rng.randint(-100, 100, size=full)
        else:
            x = rng.randn(*full) * 3
        tree[f"leaf{i}"] = jnp.asarray(x).astype(dt)
    return tree


# ------------------------------------------------------- pack/unpack ----

@given(st.lists(st.sampled_from(_LEAF_SPECS), min_size=1, max_size=8),
       st.sampled_from([0, 1]))
@settings(max_examples=25, deadline=None)
def test_pack_unpack_roundtrip_property(specs, batch_dims):
    """pack -> unpack is bit-identical for every dtype (no casts ever
    happen during packing), for flat and replica-batched trees."""
    tree = _make_tree(specs, batch_shape=(3,) * batch_dims)
    layout = flatbuf.build_layout(tree, batch_dims=batch_dims)
    arenas = flatbuf.pack(tree, layout)
    # one arena per distinct dtype, each 1-D past the batch dims
    assert set(arenas) == {jnp.dtype(dt).name for dt, _ in specs}
    for key, arena in arenas.items():
        assert arena.shape == (3,) * batch_dims + (layout.arena_sizes[key],)
    out = flatbuf.unpack(arenas, layout)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_layout_static_offsets():
    tree = {"a": jnp.zeros((2, 3)), "b": jnp.zeros((5,)),
            "c": jnp.zeros((4,), jnp.int32)}
    layout = flatbuf.build_layout(tree)
    assert layout.n_leaves == 3
    assert layout.arena_sizes == {"float32": 11, "int32": 4}
    slots = {s.offset: s.size for s in layout.slots if s.arena == "float32"}
    assert slots == {0: 6, 6: 5}


def test_layout_rejects_mismatched_batch_dims():
    tree = {"a": jnp.zeros((2, 3)), "b": jnp.zeros((4, 3))}
    with pytest.raises(ValueError):
        flatbuf.build_layout(tree, batch_dims=1)


# ------------------------------------------------------- wire codecs ----

def test_bf16_wire_roundtrip_matches_per_leaf_cast():
    tree = _make_tree([("float32", (9, 5)), ("float32", (17,)),
                       ("int32", (4,))])
    out = flatbuf.tree_wire_roundtrip(tree, "bf16")
    for k in ("leaf0", "leaf1"):
        expect = tree[k].astype(jnp.bfloat16).astype(jnp.float32)
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(expect))
    # non-floating leaves pass through untouched
    np.testing.assert_array_equal(np.asarray(out["leaf2"]),
                                  np.asarray(tree["leaf2"]))
    # compression.py back-compat wrapper rides the same codec
    out2 = compress_bf16_roundtrip(tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(out2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.sampled_from([64, 128, 256]), st.integers(1, 2000),
       st.booleans())
@settings(max_examples=20, deadline=None)
def test_int8_quantize_error_bounds_property(block, n, stochastic):
    """Per-block absmax scaling: |x - deq(q(x))| <= scale/2 per block for
    round-to-nearest, < scale for stochastic rounding."""
    key = jax.random.PRNGKey(block + n)
    x = jax.random.normal(key, (n,)) * (1.0 + n % 7)
    bits = (jax.random.bits(jax.random.fold_in(key, 1), x.shape, jnp.uint32)
            if stochastic else None)
    v, s = ops.quantize_int8(x, bits, block=block)
    d = ops.dequantize_int8(v, s, block=block)
    # expand per-block scales to elementwise bounds
    nb = s.shape[-1]
    bound = np.repeat(np.asarray(s), block)[:n]
    err = np.abs(np.asarray(d) - np.asarray(x))
    tol = 1e-6
    if stochastic:
        assert np.all(err <= bound + tol)
    else:
        assert np.all(err <= bound / 2 + tol)
    assert nb == -(-n // block)


def test_int8_stochastic_rounding_is_unbiased():
    """Mean of many stochastic draws converges to x (round-to-nearest has
    a deterministic bias of up to scale/2; stochastic is unbiased)."""
    key = jax.random.PRNGKey(0)
    x = np.full(256, 0.325, np.float32)
    x[0] = 12.7  # pins the block scale to 12.7/127 = 0.1 exactly
    x = jnp.asarray(x)
    # deterministic: 0.325/0.1 = 3.25 rounds to 3 -> constant 0.025 bias
    vd, sd = ops.quantize_int8(x, block=256)
    det = np.asarray(ops.dequantize_int8(vd, sd, block=256))[1:]
    assert abs(det.mean() - 0.325) > 0.02
    acc = 0.0
    draws = 200
    for i in range(draws):
        bits = jax.random.bits(jax.random.fold_in(key, i),
                               x.shape, jnp.uint32)
        vv, ss = ops.quantize_int8(x, bits, block=256)
        acc += np.asarray(ops.dequantize_int8(vv, ss, block=256))[1:].mean()
    assert abs(acc / draws - 0.325) < 0.005


# --------------------------------------------------- kernels vs refs ----

def test_eq1_merge_kernel_matches_ref():
    key = jax.random.PRNGKey(3)
    local = jax.random.normal(key, (2, 999))
    stale = jax.random.normal(jax.random.fold_in(key, 1), (2, 999))
    out = ops.eq1_merge(local, stale, staleness=3, global_world=16,
                        block=256)
    expect = ref.eq1_merge_ref(local, stale, staleness=3, global_world=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-6)


def test_bf16_pack_unpack_kernels():
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (3, 500))
    b = ops.bf16_pack(x, block=128)
    assert b.dtype == jnp.bfloat16 and b.shape == x.shape
    u = ops.bf16_unpack(b, block=128)
    np.testing.assert_array_equal(
        np.asarray(u), np.asarray(x.astype(jnp.bfloat16)
                                  .astype(jnp.float32)))


def test_quantize_kernel_matches_ref():
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (2, 777)) * 4
    for bits in (None, jax.random.bits(key, x.shape, jnp.uint32)):
        v, s = ops.quantize_int8(x, bits, block=128)
        vr, sr = ref.quantize_int8_block_ref(x, block=128, bits=bits)
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                                   rtol=1e-6)
        # a 1-ULP scale difference may flip a rounding boundary
        assert np.max(np.abs(np.asarray(v, np.int32)
                             - np.asarray(vr, np.int32))) <= 1
        d = ops.dequantize_int8(v, s, block=128)
        dr = ref.dequantize_int8_block_ref(vr, sr, block=128)
        np.testing.assert_allclose(np.asarray(d), np.asarray(dr),
                                   atol=1e-4)


# ------------------------------------------------------ byte account ----

def test_transfer_bytes_dtype_and_wire_aware():
    tree = {"w": jnp.zeros((100,), jnp.float32),
            "b": jnp.zeros((10,), jnp.bfloat16),
            "step": jnp.zeros((3,), jnp.int32)}
    # floating leaves charged at the wire tier; int32 at its own 4 bytes.
    # "f32" is identity — the bf16 leaf still crosses at 2 bytes/elem
    assert transfer_bytes(tree, wire_format="f32") == \
        100 * 4 + 10 * 2 + 12
    assert transfer_bytes(tree, wire_format="bf16") == 110 * 2 + 12
    # int8: 1 byte/elem + one f32 scale per (ceil) block per dtype arena
    assert transfer_bytes(tree, wire_format="int8", int8_block=64) == \
        (100 + 4 * 2) + (10 + 4 * 1) + 12
    # blocks span leaf boundaries inside an arena (matching the fused
    # codec, which quantizes the packed arena): two 10-elem f32 leaves
    # share one 64-elem block, not one block each
    pair = {"a": jnp.zeros((10,)), "b": jnp.zeros((10,))}
    assert transfer_bytes(pair, wire_format="int8", int8_block=64) == \
        20 + 4 * 1
    with pytest.raises(ValueError):
        transfer_bytes(tree, wire_format="f8")


def test_int8_wire_halves_bf16_bytes():
    """Acceptance: int8 wire format halves transfer_bytes vs bf16 (up to
    the per-block scale overhead)."""
    tree = {f"w{i}": jnp.zeros((4096,), jnp.float32) for i in range(8)}
    b16 = transfer_bytes(tree, wire_format="bf16")
    i8 = transfer_bytes(tree, wire_format="int8", int8_block=256)
    assert i8 <= b16 * 0.51
    assert wire_itemsize("int8", int8_block=256) == pytest.approx(
        1.0 + 4.0 / 256)


# ------------------------------------------------------ HLO contract ----

def test_one_exchange_is_one_all_reduce_any_leaf_count():
    """The fused exchange lowers to exactly ONE cross-replica all-reduce
    independent of the number of parameter leaves; the legacy per-leaf
    path lowers to one per leaf. Runs on a 2-virtual-device pod mesh in a
    subprocess (the main pytest process keeps its single real device)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    script = """
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.daso import blocking_sync, replica_mean_per_leaf
        from repro.launch.hlo_stats import collective_stats

        mesh = jax.make_mesh((2,), ("pod",))
        sh = NamedSharding(mesh, P("pod"))

        def n_all_reduce(fn, tree):
            shard = {k: sh for k in tree}
            hlo = jax.jit(fn, in_shardings=(shard,)).lower(
                tree).compile().as_text()
            stats = collective_stats(hlo, {"pod": 2})
            return sum(v["count"] for k, v in stats.items()
                       if isinstance(v, dict) and k.startswith("all-reduce"))

        for n_leaves in (2, 7):
            tree = {f"w{i}": jax.ShapeDtypeStruct((2, 32, 3 + i),
                                                  jnp.float32)
                    for i in range(n_leaves)}
            for wf in ("f32", "bf16", "int8"):
                n = n_all_reduce(
                    lambda t, wf=wf: blocking_sync(t, wire_format=wf), tree)
                assert n == 1, (wf, n_leaves, n)
            n = n_all_reduce(
                lambda t: replica_mean_per_leaf(t, jnp.bfloat16), tree)
            assert n == n_leaves, (n_leaves, n)
        print("ONE COLLECTIVE OK")
    """
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "ONE COLLECTIVE OK" in r.stdout
