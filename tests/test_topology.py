"""Topology subsystem (src/repro/topo/): spec round-trip properties,
lowering structure, the 2-level bit-exactness acceptance contract (a
2-level spec must reproduce legacy training losses/params EXACTLY, both
executors), 3-level end-to-end training, per-level group-mean semantics,
topology-node fault addressing, and the per-level one-collective HLO
contract (subprocess, forced multi-device mesh)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_mlp_problem
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import flatbuf
from repro.core.daso import DasoConfig, level_group_mean
from repro.core.executor import make_strategy, run_compiled_training
from repro.core.schedule import (DasoController, HierDasoController,
                                 join_mode, split_mode)
from repro.core.simulator import run_per_step_training
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant_lr
from repro.resilience.faults import FaultEvent, FaultPlan
from repro.topo import (Level, TopologySpec, build_topology_strategy,
                        daso_config_from, derive_inner_periods,
                        make_controller)
from repro.topo.strategy import HierDasoStrategy

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ------------------------------------------------------------ spec parsing --

@settings(max_examples=30)
@given(n_levels=st.integers(2, 5),
       seed=st.integers(0, 10 ** 6))
def test_spec_roundtrips_str_and_json(n_levels, seed):
    """Property: any spec survives to_str -> parse and to_json -> from_json
    exactly (== on the frozen dataclasses, floats included)."""
    import random
    rng = random.Random(seed)
    pool = ["chip", "gpu", "host", "rack", "pod", "dc", "zone", "l8"]
    names = rng.sample(pool, n_levels)
    levels = tuple(
        Level(name=names[i], fanout=rng.randint(1, 8),
              bandwidth=rng.choice([1e9, 25e9, 50e9, 600e9, 1.5e10]),
              latency=rng.choice([0.0, 1e-6, 3e-5]),
              period=rng.choice([None, 1, 2, 4, 8]))
        for i in range(n_levels))
    spec = TopologySpec(levels)
    assert TopologySpec.parse(spec.to_str()) == spec
    assert TopologySpec.from_json(spec.to_json()) == spec
    assert TopologySpec.load(spec.to_str()) == spec
    assert TopologySpec.load(spec.to_json()) == spec


def test_spec_grammar_defaults_and_errors():
    spec = TopologySpec.parse("chip:4 × host:2@5e10/1e-5%3, pod:2")
    assert [lvl.name for lvl in spec.levels] == ["chip", "host", "pod"]
    assert spec.level("host").period == 3
    assert spec.level("host").bandwidth == 5e10
    # omitted fields take per-depth defaults
    assert spec.level("chip").bandwidth == 600e9
    assert spec.level("pod").bandwidth == 25e9
    with pytest.raises(ValueError):
        TopologySpec.parse("chip:4")              # one level
    with pytest.raises(ValueError):
        TopologySpec.parse("chip:4 x chip:2")     # duplicate names
    with pytest.raises(ValueError):
        TopologySpec.parse("chip:0 x pod:2")      # bad fanout
    with pytest.raises(ValueError):
        TopologySpec.parse("Chip:4 x pod:2")      # bad name
    with pytest.raises(ValueError):
        Level("pod", 2, -1.0, 0.0)                # bad bandwidth


def test_spec_structure_and_groups():
    spec = TopologySpec.parse("chip:4 x host:2 x pod:3")
    assert spec.local_world == 4
    assert spec.n_replicas == 6
    assert spec.world == 24
    assert spec.group_size("host") == 2
    assert spec.group_size("pod") == 6
    assert spec.inner_names() == ("host",)
    assert spec.mesh_axis_names() == ("pod", "host", "chip")
    assert spec.mesh_shape() == (3, 2, 4)
    with pytest.raises(ValueError):
        spec.group_size("chip")  # level 0 is not a replica group


def test_spec_names_containing_x_and_digits():
    """Separator/addressing edge cases: 'x' inside a level name must not
    split the spec, and a level name ending in a digit stays addressable
    in node paths."""
    spec = TopologySpec.parse("proxy:4 x box:2 x pod:2")
    assert [lvl.name for lvl in spec.levels] == ["proxy", "box", "pod"]
    assert TopologySpec.parse(spec.to_str()) == spec
    spec2 = TopologySpec.parse("chip:2 × tier2:2 × pod:2")
    assert spec2.replicas_of("pod1/tier21") == (3,)
    assert TopologySpec.parse(spec2.to_str()) == spec2


def test_fanout_one_intermediate_level_is_elided():
    """A degenerate (group-size-1) intermediate level is legal but its
    sync is a no-op: the schedule elides it and training runs clean."""
    spec = TopologySpec.parse("chip:4 x host:1 x pod:2")
    assert derive_inner_periods(spec, b_max=4) == {}
    key = jax.random.PRNGKey(5)
    params0, loss_fn, daso_data, _ = make_mlp_problem(key, R=2)
    cfg = daso_config_from(spec, warmup_steps=2, cooldown_steps=2,
                           total_steps=16)
    strat = build_topology_strategy(loss_fn, sgd(momentum=0.9), spec, cfg,
                                    loss_window=10 ** 9)
    res = run_compiled_training(strat, params0, daso_data,
                                constant_lr(0.1), 16)
    assert np.all(np.isfinite(res.losses))
    assert all("host" not in h[1] for h in res.controller.history)
    # the analytic model elides the same level instead of crashing
    from benchmarks.comm_model import topology_level_costs
    rows = topology_level_costs(spec, 1e8)
    assert [r["name"] for r in rows] == ["chip", "pod"]


def test_replicas_of_node_paths():
    spec = TopologySpec.parse("chip:2 x host:2 x pod:3")
    assert spec.replicas_of("pod0") == (0, 1)
    assert spec.replicas_of("pod2") == (4, 5)
    assert spec.replicas_of("pod1/host1") == (3,)
    with pytest.raises(ValueError):
        spec.replicas_of("host0")          # must start outermost
    with pytest.raises(ValueError):
        spec.replicas_of("pod3")           # index out of range
    with pytest.raises(ValueError):
        spec.replicas_of("pod0/chip1")     # level 0 not addressable
    with pytest.raises(ValueError):
        spec.replicas_of("pod0/banana1")   # unknown level


# --------------------------------------------------------------- schedule --

def test_derived_inner_periods_track_bandwidth_ratio():
    spec = TopologySpec.parse("chip:4 x host:2@50e9 x pod:2@25e9")
    assert derive_inner_periods(spec, b_max=4) == {"host": 2}
    # explicit %period wins over the derived value
    spec2 = TopologySpec.parse("chip:4 x host:2@50e9%1 x pod:2@25e9")
    assert derive_inner_periods(spec2, b_max=4) == {"host": 1}
    # a level as slow as the outermost syncs at b_max
    spec3 = TopologySpec.parse("chip:4 x host:2@25e9 x pod:2@25e9")
    assert derive_inner_periods(spec3, b_max=4) == {"host": 4}


def test_hier_controller_mode_tokens():
    spec = TopologySpec.parse("chip:4 x host:2 x pod:2")
    cfg = daso_config_from(spec, warmup_steps=2, cooldown_steps=2,
                           total_steps=20)
    c = make_controller(spec, cfg, loss_window=10 ** 9)
    assert isinstance(c, HierDasoController)
    modes = [c.mode_for_step(t)[0] for t in range(12)]
    # warm-up blocking steps elide inner syncs (already a full-world sync)
    assert modes[0] == modes[1] == "blocking"
    # cycling: host (B_l = 2) ticks on every second step
    for t, m in enumerate(modes[2:], start=2):
        outer, inner = split_mode(m)
        assert inner == (("host",) if (t + 1) % 2 == 0 else ())
    # history records the joined tokens and both tallies see them
    counts = c.level_sync_counts()
    assert counts["host"] == sum(1 for m in modes if "host" in m)
    assert join_mode("send", ("host",)) == "send+host"
    assert split_mode("send+host,rack") == ("send", ("host", "rack"))
    assert split_mode("local") == ("local", ())


def test_two_level_controller_is_plain_daso_controller():
    """Lowering a 2-level spec must give the unmodified legacy controller,
    so its histories are byte-identical to pre-topology runs."""
    spec = TopologySpec.two_level(local_world=4, n_replicas=4)
    cfg = daso_config_from(spec)
    c = make_controller(spec, cfg)
    assert type(c) is DasoController


# ---------------------------------------------------------- group mean ------

def _tree(key, R):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"a": jax.random.normal(k1, (R, 3, 2)),
            "b": {"w": jax.random.normal(k2, (R, 5)),
                  "n": jnp.arange(R * 4, dtype=jnp.int32).reshape(R, 4)}}


@settings(max_examples=15)
@given(groups=st.integers(2, 4), per=st.integers(1, 3),
       seed=st.integers(0, 100))
def test_level_group_mean_matches_per_group_oracle(groups, per, seed):
    """Property: the fused arena group mean equals an explicit per-group
    jnp mean for every leaf, any group structure."""
    R = groups * per
    tree = _tree(jax.random.PRNGKey(seed), R)
    got = level_group_mean(tree, per)

    def oracle(x):
        xr = x.reshape((groups, per) + x.shape[1:])
        if jnp.issubdtype(x.dtype, jnp.floating):
            m = xr.astype(jnp.float32).mean(axis=1, keepdims=True)
            m = m.astype(x.dtype)
        else:
            m = jnp.round(
                xr.astype(jnp.float32).mean(axis=1, keepdims=True)
            ).astype(x.dtype)
        return jnp.broadcast_to(m, xr.shape).reshape(x.shape)

    want = jax.tree.map(oracle, tree)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-6, atol=1e-6)


def test_level_group_mean_membership_mask():
    """Masked group mean averages only each group's active rows; a fully
    dead group contributes zeros (its rows are frozen ghosts upstream)."""
    R, g = 4, 2
    x = {"w": jnp.arange(R * 2, dtype=jnp.float32).reshape(R, 2)}
    mask = flatbuf.normalize_membership((1.0, 0.0, 1.0, 1.0), R)
    got = level_group_mean(x, g, mask=mask)["w"]
    # group 0 = rows {0,1}, only row 0 active -> mean = row0
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(x["w"][0]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(x["w"][0]))
    # group 1 = rows {2,3}, both active -> plain mean
    want = np.asarray((x["w"][2] + x["w"][3]) / 2)
    np.testing.assert_allclose(np.asarray(got[2]), want)
    np.testing.assert_allclose(np.asarray(got[3]), want)
    # group size == R degenerates to the full replica mean
    full = level_group_mean(x, R)["w"]
    np.testing.assert_allclose(np.asarray(full[0]),
                               np.asarray(x["w"].mean(0)))
    with pytest.raises(ValueError):
        level_group_mean(x, 3)  # R=4 not divisible
    with pytest.raises(ValueError):
        level_group_mean(x, 2, wire_format="int8")


# ----------------------------------------------- 2-level bit-exactness ------

@pytest.mark.parametrize("executor", ["macro", "per_step"])
def test_two_level_spec_bit_exact_with_legacy(executor):
    """ACCEPTANCE: a 2-level topology spec reproduces current training
    losses BIT-exactly (== on floats, array_equal on params) on both
    executors — via the lowered stock strategy AND via the hier_daso
    machinery forced onto the 2-level spec."""
    key = jax.random.PRNGKey(0)
    params0, loss_fn, daso_data, _ = make_mlp_problem(key, R=4)
    n_steps = 40
    opt = sgd(momentum=0.9, weight_decay=1e-4)
    spec = TopologySpec.parse("chip:4 x pod:4")
    legacy_cfg = DasoConfig(n_replicas=4, global_world=16, b_max=4,
                            warmup_steps=4, cooldown_steps=4,
                            total_steps=n_steps)
    assert daso_config_from(spec, warmup_steps=4, cooldown_steps=4,
                            total_steps=n_steps) == legacy_cfg

    def run(strategy):
        runner = (run_compiled_training if executor == "macro"
                  else run_per_step_training)
        return runner(strategy, params0, daso_data, constant_lr(0.1),
                      n_steps)

    legacy = run(make_strategy(
        "daso", loss_fn, opt, legacy_cfg,
        controller=DasoController(legacy_cfg, loss_window=10)))
    lowered = run(build_topology_strategy(
        loss_fn, opt, spec,
        daso_config_from(spec, warmup_steps=4, cooldown_steps=4,
                         total_steps=n_steps), loss_window=10))
    forced_hier = run(HierDasoStrategy(
        loss_fn, opt, legacy_cfg, topo=spec,
        controller=DasoController(legacy_cfg, loss_window=10)))

    for got in (lowered, forced_hier):
        assert got.losses == legacy.losses
        for a, b in zip(jax.tree.leaves(got.params),
                        jax.tree.leaves(legacy.params)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        assert [h[1] for h in got.controller.history] == \
               [h[1] for h in legacy.controller.history]


# --------------------------------------------------- 3-level end-to-end -----

def test_three_level_trains_on_both_executors():
    """A 3-level spec trains end-to-end, the macro path matches the
    per-step reference, and the schedule actually exercised the
    intermediate level."""
    key = jax.random.PRNGKey(1)
    params0, loss_fn, daso_data, _ = make_mlp_problem(key, R=4)
    n_steps = 40
    opt = sgd(momentum=0.9, weight_decay=1e-4)
    spec = TopologySpec.parse("chip:4 x host:2 x pod:2")

    def mk():
        cfg = daso_config_from(spec, warmup_steps=4, cooldown_steps=4,
                               total_steps=n_steps)
        return build_topology_strategy(loss_fn, opt, spec, cfg,
                                       loss_window=10)

    macro = run_compiled_training(mk(), params0, daso_data,
                                  constant_lr(0.1), n_steps)
    ref = run_per_step_training(mk(), params0, daso_data,
                                constant_lr(0.1), n_steps)
    assert np.all(np.isfinite(macro.losses))
    assert macro.final_loss < macro.losses[0]
    np.testing.assert_allclose(np.asarray(macro.losses, np.float32),
                               np.asarray(ref.losses, np.float32),
                               rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(macro.params),
                    jax.tree.leaves(ref.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)
    counts = macro.controller.level_sync_counts()
    assert counts.get("host", 0) > 0
    assert [h[1] for h in macro.controller.history] == \
           [h[1] for h in ref.controller.history]


def test_topology_via_train_loop_config():
    """TrainLoopConfig.topology threads a spec end-to-end (the launcher
    surface), deriving R/world from the fanouts."""
    from repro.train.loop import TrainLoopConfig, build_strategy, run_training

    key = jax.random.PRNGKey(2)
    params0, loss_fn, daso_data, _ = make_mlp_problem(key, R=4)
    cfg = TrainLoopConfig(strategy="daso", n_steps=24,
                          topology="chip:2 x host:2 x pod:2",
                          loss_window=10, log_every=1000)
    strat = build_strategy(loss_fn, cfg, sgd())
    assert isinstance(strat, HierDasoStrategy)
    assert strat.cfg.n_replicas == 4 and strat.cfg.global_world == 8
    res = run_training(loss_fn, params0, daso_data, cfg, log=None)
    assert np.all(np.isfinite(res.losses))
    with pytest.raises(ValueError):
        build_strategy(loss_fn, TrainLoopConfig(
            strategy="sync", topology="chip:2 x pod:2"), sgd())
    with pytest.raises(ValueError):
        build_strategy(loss_fn, TrainLoopConfig(strategy="hier_daso"),
                       sgd())


# ------------------------------------------------------- faults on nodes ----

def test_fault_plan_topology_node_resolution():
    spec = TopologySpec.parse("chip:2 x host:2 x pod:2")
    plan = FaultPlan((FaultEvent(step=6, kind="crash", node="pod1"),
                      FaultEvent(step=9, kind="straggle", node="pod0/host1",
                                 factor=2.0),
                      FaultEvent(step=12, kind="rejoin", node="pod1")))
    with pytest.raises(ValueError):
        plan.validate(4)  # unresolved node events must be rejected
    concrete = plan.resolve(spec)
    concrete.validate(4)
    assert [(e.step, e.kind, e.replica) for e in concrete.events] == \
        [(6, "crash", 2), (6, "crash", 3), (9, "straggle", 1),
         (12, "rejoin", 2), (12, "rejoin", 3)]
    # wire format round-trips the node field
    assert FaultPlan.from_json(plan.to_json()) == plan
    with pytest.raises(ValueError):
        FaultEvent(step=0, kind="crash")  # neither replica nor node
    with pytest.raises(ValueError):
        FaultEvent(step=0, kind="crash", replica=1, node="pod0")  # both
    with pytest.raises(ValueError):
        FaultEvent(step=0, kind="degrade_dcn", node="pod0", factor=0.5)


def test_supervisor_resolves_node_faults_on_two_level_lowered_strategy():
    """A 2-level spec lowers to the stock DasoStrategy, but the lowering
    stamps the spec on it so the supervisor still auto-resolves
    node-addressed fault plans (the docs/topologies.md promise)."""
    from repro.core.executor import DasoStrategy
    from repro.resilience.supervisor import run_with_faults

    key = jax.random.PRNGKey(6)
    params0, loss_fn, daso_data, _ = make_mlp_problem(key, R=4)
    spec = TopologySpec.parse("chip:4 x pod:4")
    cfg = daso_config_from(spec, total_steps=16)
    strat = build_topology_strategy(loss_fn, sgd(momentum=0.9), spec, cfg,
                                    loss_window=10 ** 9)
    assert type(strat) is DasoStrategy and strat.topo == spec
    plan = FaultPlan((FaultEvent(step=4, kind="crash", node="pod3"),))
    report = run_with_faults(strat, params0, daso_data, constant_lr(0.1),
                             16, plan)
    assert np.all(np.isfinite(report.result.losses))
    assert dict(report.membership_timeline)[4] == (1.0, 1.0, 1.0, 0.0)


def test_supervisor_replays_node_fault_on_three_level_topology():
    """Crash a whole pod (2 of 4 replicas) mid-run through the supervisor;
    training survives, membership timeline shows the subtree drop, and the
    run stays finite."""
    from repro.resilience.supervisor import run_with_faults

    key = jax.random.PRNGKey(3)
    params0, loss_fn, daso_data, _ = make_mlp_problem(key, R=4)
    spec = TopologySpec.parse("chip:2 x host:2 x pod:2")
    cfg = daso_config_from(spec, warmup_steps=2, cooldown_steps=2,
                           total_steps=30)
    strat = build_topology_strategy(loss_fn, sgd(momentum=0.9), spec, cfg,
                                    loss_window=10 ** 9)
    plan = FaultPlan((FaultEvent(step=8, kind="crash", node="pod1"),
                      FaultEvent(step=20, kind="rejoin", node="pod1")))
    report = run_with_faults(strat, params0, daso_data, constant_lr(0.1),
                             30, plan)
    assert np.all(np.isfinite(report.result.losses))
    masks = dict(report.membership_timeline)  # last mask per step wins
    assert masks[8] == (1.0, 1.0, 0.0, 0.0)
    assert masks[20] == (1.0, 1.0, 1.0, 1.0)
    # one invalidation per expanded per-replica event (2 crash + 2 rejoin);
    # recompiles still only happen at the next dispatched cycle
    assert report.invalidations == 4


# --------------------------------------------------- comm-model lowering ----

def test_topology_comm_model_levels():
    from benchmarks.comm_model import topology_level_costs, topology_step_s

    spec = TopologySpec.parse("chip:4 x host:2@50e9 x pod:2@25e9")
    rows = topology_level_costs(spec, 4e8, b_max=4, ib_eff=0.1)
    assert [r["name"] for r in rows] == ["chip", "host", "pod"]
    assert rows[0]["period"] == 1 and rows[0]["wire"] == "f32"
    assert rows[1]["period"] == 2
    assert rows[2]["period"] == 4 and rows[2]["wire"] == "bf16"
    # bf16 outermost carries half the bytes of the f32 inner tiers
    assert rows[2]["bytes_per_sync"] == rows[1]["bytes_per_sync"] / 2
    # per-step amortization divides by the period
    assert rows[1]["step_s"] == pytest.approx(rows[1]["sync_s"] / 2)
    t = topology_step_s(spec, 4e8, t_compute_s=0.1, ib_eff=0.1)
    assert t > 0.1  # compute plus strictly positive comm terms
    # an outer %period pin changes the derived inner periods exactly as
    # the executed schedule does (lower.daso_config_from's override)
    pinned = TopologySpec.parse("chip:4 x host:2@50e9 x pod:2@25e9%8")
    rows_p = topology_level_costs(pinned, 4e8, b_max=4, ib_eff=0.1)
    assert rows_p[1]["period"] == 4 and rows_p[2]["period"] == 8


# ----------------------------------------------------- HLO contract ---------

def test_hlo_exactly_one_collective_per_syncing_level():
    """ACCEPTANCE (per-level one-collective contract): on a topology-lowered
    mesh with one axis per level, each step variant emits exactly one
    parameter-scale collective per level it syncs — none for `local`, one
    spanning the host axis for `local+host`, and for `send+host` one @host
    plus one spanning the full replica (pod+host) group."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    script = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.daso import DasoConfig, daso_train_step
        from repro.launch.hlo_stats import collective_stats
        from repro.launch.mesh import make_topology_mesh
        from repro.optim.optimizers import sgd
        from repro.topo import TopologySpec

        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"]
            return jnp.mean((pred - batch["y"]) ** 2), {}

        spec = TopologySpec.parse("chip:2 x host:2 x pod:2")
        mesh = make_topology_mesh(spec, model=1)
        assert mesh.axis_names == ("pod", "host", "chip", "model")
        mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
        R, per, d = spec.n_replicas, 4, 128   # w: 128x4 f32 = 2 KiB
        opt = sgd(momentum=0.0, weight_decay=0.0)
        cfg = DasoConfig(n_replicas=R, global_world=spec.world, b_max=4)
        SDS = jax.ShapeDtypeStruct
        params = {"w": SDS((R, d, 4), jnp.float32)}
        infl = params
        batch = {"x": SDS((R, per, d), jnp.float32),
                 "y": SDS((R, per, 4), jnp.float32)}
        # replica axis sharded over BOTH replica levels, batch over chip
        shp = NamedSharding(mesh, P(("pod", "host")))
        shb = NamedSharding(mesh, P(("pod", "host"), "chip"))
        sc = NamedSharding(mesh, P())
        host_g = spec.group_size("host")

        def audit(mode, inner):
            step = daso_train_step(
                loss_fn, opt, cfg, mode=mode, staleness=1,
                inner_syncs=tuple((n, spec.group_size(n)) for n in inner))
            lowered = jax.jit(step, in_shardings=(
                {"w": shp}, {}, {"w": shp},
                {"x": shb, "y": shb}, sc)).lower(
                params, {}, infl, batch, SDS((), jnp.float32))
            # parameter-scale (>= 1 KiB) collectives only: scalar metric
            # reductions (loss means) are filtered per-op by min_bytes
            stats = collective_stats(lowered.compile().as_text(),
                                     mesh_shape, min_bytes=1024)
            return {k: v["count"] for k, v in stats.items()
                    if isinstance(v, dict)}

        def span(counts, axis):
            return sum(c for k, c in counts.items() if axis in k)

        def replica_spans(counts):
            # collectives spanning replica levels; the level-0 ("chip")
            # gradient all-reduce is expected on EVERY variant and is
            # asserted separately below
            return {k: c for k, c in counts.items()
                    if "host" in k or "pod" in k}

        c_local = audit("local", ())
        assert span(c_local, "chip") >= 1, c_local  # level-0 grad sync
        assert not replica_spans(c_local), \
            f"local must not touch replica levels: {c_local}"

        c_inner = replica_spans(audit("local", ("host",)))
        assert span(c_inner, "@host") == 1, c_inner
        assert span(c_inner, "pod") == 0, c_inner

        c_send = replica_spans(audit("send", ()))
        assert span(c_send, "@pod+host") == 1, c_send
        assert span(c_send, "@host") == 0, c_send

        c_both = replica_spans(audit("send", ("host",)))
        assert c_both.get("all-reduce@host") == 1, c_both
        # after the host-level sync GSPMD knows host groups are replicated,
        # so the outer exchange decomposes to a pod-only all-reduce (the
        # hierarchical decomposition falling out of the lowering); a full
        # pod+host span is equally contract-conforming
        outer = (c_both.get("all-reduce@pod", 0)
                 + c_both.get("all-reduce@pod+host", 0))
        assert outer == 1, c_both
        assert sum(c_both.values()) == 2, c_both
        print("PER-LEVEL HLO CONTRACT OK")
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "PER-LEVEL HLO CONTRACT OK" in r.stdout
