"""End-to-end training-loop integration: strategies converge, the controller
drives the schedule, checkpoint + restore reproduces the model."""
import jax
import numpy as np
from conftest import make_mlp_problem as _mlp_problem

from repro.checkpoint.io import load_checkpoint, save_checkpoint
from repro.core.schedule import Mode
from repro.train.loop import TrainLoopConfig, run_training


def test_all_strategies_learn():
    key = jax.random.PRNGKey(0)
    params0, loss_fn, daso_data, sync_data = _mlp_problem(key)
    finals = {}
    for strat in ("sync", "daso", "local_sgd"):
        data = sync_data if strat == "sync" else daso_data
        res = run_training(loss_fn, params0, data, TrainLoopConfig(
            strategy=strat, n_steps=80, n_replicas=2, local_world=2,
            b_max=4, lr=0.1, loss_window=10), log=None)
        finals[strat] = res.final_loss
        assert res.final_loss < res.losses[0] * 0.9, strat
    # daso close to sync
    assert abs(finals["daso"] - finals["sync"]) < 0.5 * finals["sync"] + 0.05


def test_daso_loop_schedule_is_recorded():
    key = jax.random.PRNGKey(1)
    params0, loss_fn, daso_data, _ = _mlp_problem(key)
    res = run_training(loss_fn, params0, daso_data, TrainLoopConfig(
        strategy="daso", n_steps=60, n_replicas=2, local_world=2, b_max=4,
        warmup_frac=0.2, cooldown_frac=0.2, lr=0.1), log=None)
    modes = [m for _, m, _, _ in res.controller.history]
    assert modes[0] == Mode.BLOCKING and modes[-1] == Mode.BLOCKING
    assert Mode.SEND in modes and Mode.RECEIVE in modes
    assert 0.0 < res.sync_fraction < 1.0


def test_checkpoint_roundtrip_through_loop(tmp_path):
    key = jax.random.PRNGKey(2)
    params0, loss_fn, daso_data, _ = _mlp_problem(key)
    res = run_training(loss_fn, params0, daso_data, TrainLoopConfig(
        strategy="daso", n_steps=20, n_replicas=2, local_world=2, lr=0.1),
        log=None)
    path = str(tmp_path / "ck")
    save_checkpoint(path, res.params, step=20)
    loaded, manifest = load_checkpoint(path)
    assert manifest["step"] == 20
    for a, b in zip(jax.tree.leaves(res.params), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # restored params give identical loss
    batch = jax.tree.map(lambda x: x[0], daso_data(99))
    l1 = loss_fn(res.params, batch)[0]
    l2 = loss_fn(loaded, batch)[0]
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
