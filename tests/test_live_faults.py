"""Live fault tolerance: real process death on the multi-process runtime.

The acceptance contract of the health plane (resilience/runtime.py) and the
launcher's supervisor mode (tools/launch_procs.py --kill): a process group
with one rank SIGKILLed mid-run detects the death within the watchdog
budget, regroups under a fresh coordinator epoch, resumes from the newest
intact checkpoint, and finishes with final params BIT-EXACT with the PR-3
simulated fault-plan oracle for the same crash. Plus the crash-safe
checkpoint layer (torn/truncated snapshots detected and skipped), the
regroup-event translation, the worker watchdog, and the resume surface of
the resilience supervisor.
"""
import copy
import glob
import json
import os
import subprocess
import sys
import time

import jax
import numpy as np
import pytest
from conftest import make_mlp_problem, subprocess_env

from repro.checkpoint.io import (CheckpointCorruptError, TrainState,
                                 list_train_state_dirs,
                                 load_latest_train_state, load_train_state,
                                 save_train_state)
from repro.core.daso import DasoConfig
from repro.core.executor import make_strategy
from repro.core.schedule import DasoController
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant_lr
from repro.resilience.faults import FaultEvent, FaultPlan
from repro.resilience.runtime import (EXIT_PEER_LOST, HealthConfig,
                                      HealthMonitor, RegroupPlan,
                                      load_regroup, read_heartbeat,
                                      regroup_fault_events, save_regroup)
from repro.resilience.supervisor import run_with_faults
from repro.train.loop import ckpt_step_dir

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
LAUNCHER = os.path.join(REPO, "tools", "launch_procs.py")
TOPOLOGY = "chip:1 x host:2 x pod:2"  # world 4: R=4 replicas, 3 levels
WATCHDOG_S = 120.0

BASE_ARGS = ["--arch", "llama3.2-1b", "--tiny", "--topology", TOPOLOGY,
             "--per-node-batch", "2", "--seq-len", "16", "--b-max", "4",
             "--seed", "0"]


def _launcher_env():
    env = subprocess_env(devices=1)
    env.pop("XLA_FLAGS")  # the harness sets the per-child device count
    return env


def supervised(tmp_path, procs, train_args, *, kill=None, elastic=False,
               timeout=900):
    """Run one supervised group through the real launcher; return
    (exit_code, report dict, combined output)."""
    report = str(tmp_path / "report.json")
    cmd = [sys.executable, LAUNCHER, "--procs", str(procs),
           "--timeout", str(timeout), "--watchdog", str(WATCHDOG_S),
           "--run-dir", str(tmp_path / "live"), "--report", report,
           "--supervise"]
    if kill is not None:
        cmd += ["--kill", kill]
    if elastic:
        cmd += ["--elastic-rejoin"]
    cmd += ["--"] + BASE_ARGS + train_args
    r = subprocess.run(cmd, capture_output=True, text=True,
                       timeout=timeout + 60, env=_launcher_env(), cwd=REPO)
    rep = {}
    if os.path.exists(report):
        with open(report) as f:
            rep = json.load(f)
    return r.returncode, rep, r.stdout + r.stderr


def launch_plain(procs, train_args, timeout=600):
    cmd = [sys.executable, LAUNCHER, "--procs", str(procs),
           "--timeout", str(timeout), "--"] + BASE_ARGS + train_args
    r = subprocess.run(cmd, capture_output=True, text=True,
                       timeout=timeout + 60, env=_launcher_env(), cwd=REPO)
    assert r.returncode == 0, (f"oracle launch failed ({r.returncode}):\n"
                               f"{r.stdout}\n{r.stderr}")


def assert_same_params(dir_a, dir_b):
    files_a = sorted(glob.glob(os.path.join(str(dir_a), "*.npz")))
    files_b = sorted(glob.glob(os.path.join(str(dir_b), "*.npz")))
    assert files_a and len(files_a) == len(files_b)
    for fa, fb in zip(files_a, files_b):
        a, b = np.load(fa), np.load(fb)
        assert sorted(a.files) == sorted(b.files)
        for k in a.files:
            if k == "__save_id__":
                continue  # unique per save by design
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)


# ------------------------------------------------ live kill e2e ----------

def test_live_kill_regroup_matches_simulated_oracle(tmp_path):
    """Flagship acceptance: 2 processes, rank 1 SIGKILLed at step 6. The
    supervisor must detect within the watchdog budget, regroup onto 1
    process spanning the full world, resume from the newest intact
    checkpoint, and produce final params bit-exact with the simulated
    fault-plan oracle crashing the same replicas at the same step."""
    steps = 14
    live_ckpt = tmp_path / "ckpt_live"
    live_metrics = tmp_path / "metrics_live.json"
    code, rep, out = supervised(
        tmp_path, 2,
        ["--steps", str(steps), "--ckpt", str(live_ckpt),
         "--ckpt-every", "1", "--metrics-out", str(live_metrics)],
        kill="1:6")
    assert code == 0, f"supervised run failed ({code}):\n{out}"
    assert rep["ok"] and rep["kill"]["proc"] == 1
    # detection: bounded by the watchdog budget (in practice the launcher
    # sees the SIGKILL exit within one poll interval)
    assert rep["timings"]["detect_s"] is not None
    assert 0.0 <= rep["timings"]["detect_s"] < WATCHDOG_S
    assert rep["timings"]["regroup_s"] > 0.0
    assert rep["timings"]["resume_s"] > 0.0
    # epoch 0 failed, epoch 1 regrouped onto fewer procs over the full world
    assert [e["outcome"] for e in rep["epochs"]] == ["failed", "ok"]
    assert rep["epochs"][0]["procs"] == 2
    assert rep["epochs"][1]["procs"] == 1
    # proc 1 of 2 owns the second pod subtree -> replicas 2, 3
    assert rep["dead_replicas"] == [2, 3]

    with open(live_metrics) as f:
        live = json.load(f)
    meta = live["resilience"]["live"]
    assert meta["epoch"] == 1 and meta["dead_replicas"] == [2, 3]
    crash_step = meta["crash_step"]
    assert 0 < crash_step <= 6 + 4  # within a cycle of the kill step

    # simulated oracle: same run, no supervisor, the death scripted as
    # crash events at the crash-equivalent step
    plan = tmp_path / "oracle_plan.json"
    plan.write_text(json.dumps({"events": [
        {"step": crash_step, "kind": "crash", "replica": r}
        for r in meta["dead_replicas"]]}))
    oracle_ckpt = tmp_path / "ckpt_oracle"
    oracle_metrics = tmp_path / "metrics_oracle.json"
    launch_plain(1, ["--steps", str(steps), "--fault-plan", str(plan),
                     "--ckpt", str(oracle_ckpt), "--ckpt-every", "1",
                     "--metrics-out", str(oracle_metrics)])
    assert_same_params(live_ckpt, oracle_ckpt)
    with open(oracle_metrics) as f:
        oracle = json.load(f)
    # the stitched loss trace (pre-crash checkpoint + resumed epoch) is
    # bit-identical to the oracle's uninterrupted one
    assert live["losses"] == oracle["losses"]
    assert live["final_loss"] == oracle["final_loss"]


@pytest.mark.slow
def test_live_kill_four_procs_matches_oracle(tmp_path):
    """4-process variant of the acceptance criterion: rank 2 SIGKILLed at
    step 6. World 4 cannot regroup onto 3 procs (4 % 3), so the survivors
    re-span the full world on 2 — and the result still matches the
    simulated oracle bit-exactly. @slow: 4 concurrent jax processes
    contend hard on CI cores; the live-fault-smoke lane and the nightly
    run it."""
    steps = 12
    live_ckpt = tmp_path / "ck"
    metrics = tmp_path / "m.json"
    code, rep, out = supervised(
        tmp_path, 4,
        ["--steps", str(steps), "--ckpt", str(live_ckpt),
         "--ckpt-every", "1", "--metrics-out", str(metrics)],
        kill="2:6")
    assert code == 0, f"supervised run failed ({code}):\n{out}"
    assert [e["procs"] for e in rep["epochs"]] == [4, 2]
    assert rep["dead_replicas"] == [2]  # proc 2 of 4 owns replica 2 only
    assert 0.0 <= rep["timings"]["detect_s"] < WATCHDOG_S

    with open(metrics) as f:
        meta = json.load(f)["resilience"]["live"]
    plan = tmp_path / "oracle_plan.json"
    plan.write_text(json.dumps({"events": [
        {"step": meta["crash_step"], "kind": "crash", "replica": 2}]}))
    oracle_ckpt = tmp_path / "ck_oracle"
    launch_plain(1, ["--steps", str(steps), "--fault-plan", str(plan),
                     "--ckpt", str(oracle_ckpt), "--ckpt-every", "1"])
    assert_same_params(live_ckpt, oracle_ckpt)


@pytest.mark.slow
def test_live_elastic_rejoin(tmp_path):
    """Elastic mode: the regrouped epoch restarts the ORIGINAL process
    count; the reborn rank's replicas rejoin at the resume step and are
    re-seeded from the survivors' mean."""
    metrics = tmp_path / "m.json"
    code, rep, out = supervised(
        tmp_path, 2,
        ["--steps", "14", "--ckpt", str(tmp_path / "ck"),
         "--ckpt-every", "1", "--metrics-out", str(metrics)],
        kill="1:6", elastic=True)
    assert code == 0, f"elastic supervised run failed ({code}):\n{out}"
    assert [e["procs"] for e in rep["epochs"]] == [2, 2]
    with open(metrics) as f:
        live = json.load(f)
    meta = live["resilience"]["live"]
    assert meta["rejoin"] is True
    kinds = [e["kind"] for e in live["resilience"]["events"]]
    assert kinds == ["crash", "crash", "rejoin", "rejoin"]
    assert np.all(np.isfinite(live["losses"]))


# --------------------------------- crash-safe checkpoint property --------

def _tiny_state(step, membership=None):
    carry = ({"w": np.arange(12.0, dtype=np.float32).reshape(3, 4) + step},
             {"m": np.full((3, 4), 0.5, np.float32)})
    return TrainState(step=step, carry=carry,
                      controller={"b": 4, "w": 1},
                      membership=membership, strategy="daso",
                      losses=[0.1 * i for i in range(step)])


def _corrupt(path, how):
    """Simulate a crash mid-save / torn pair in snapshot dir `path`."""
    npz = os.path.join(path, "arrays.npz")
    man = os.path.join(path, "manifest.json")
    if how == "truncate_arrays":
        with open(npz, "r+b") as f:
            f.truncate(os.path.getsize(npz) // 2)
    elif how == "truncate_manifest":
        with open(man, "r+b") as f:
            f.truncate(max(1, os.path.getsize(man) // 2))
    elif how == "missing_manifest":
        os.remove(man)
    elif how == "missing_arrays":
        os.remove(npz)
    elif how == "torn_pair":
        # arrays renamed in, then crash, then a later save's manifest:
        # both files individually valid but from different saves
        with open(man) as f:
            doc = json.load(f)
        doc["save_id"] = "9999-0-deadbeef"
        with open(man, "w") as f:
            json.dump(doc, f)
    else:
        raise AssertionError(how)


@pytest.mark.parametrize("how", ["truncate_arrays", "truncate_manifest",
                                 "missing_manifest", "missing_arrays",
                                 "torn_pair"])
def test_corrupt_checkpoint_detected_and_fallback(tmp_path, how):
    """A snapshot torn by a crash mid-write must be DETECTED (never
    silently half-loaded) and the loader must fall back to the newest
    intact sibling."""
    ckpt = str(tmp_path / "ck")
    for step in (4, 8):
        save_train_state(ckpt_step_dir(ckpt, step), _tiny_state(step))
    newest = ckpt_step_dir(ckpt, 8)
    _corrupt(newest, how)

    with pytest.raises(CheckpointCorruptError):
        load_train_state(newest)
    # explicit-path fallback scans the step_XXXXXXXX siblings
    st = load_train_state(newest, fallback=True)
    assert st.step == 4
    np.testing.assert_array_equal(np.asarray(st.carry[0]["w"]),
                                  np.arange(12.0).reshape(3, 4) + 4)
    # the latest-snapshot scan skips the corrupt one
    path, st2 = load_latest_train_state(ckpt)
    assert st2.step == 4 and path == ckpt_step_dir(ckpt, 4)


def test_load_latest_with_no_intact_snapshot(tmp_path):
    ckpt = str(tmp_path / "ck")
    save_train_state(ckpt_step_dir(ckpt, 4), _tiny_state(4))
    _corrupt(ckpt_step_dir(ckpt, 4), "truncate_arrays")
    with pytest.raises(CheckpointCorruptError):
        load_latest_train_state(ckpt)
    with pytest.raises(CheckpointCorruptError):
        load_latest_train_state(str(tmp_path / "nonexistent"))


def test_list_train_state_dirs_orders_newest_first(tmp_path):
    ckpt = str(tmp_path / "ck")
    for step in (3, 12, 7):
        save_train_state(ckpt_step_dir(ckpt, step), _tiny_state(step))
    (tmp_path / "ck" / "not_a_step").mkdir()
    dirs = list_train_state_dirs(ckpt)
    assert dirs == [ckpt_step_dir(ckpt, s) for s in (12, 7, 3)]


def test_atomic_save_keeps_old_snapshot_on_rewrite(tmp_path):
    """Re-saving into the same dir replaces atomically: a reader always
    sees a consistent (arrays, manifest) pair."""
    d = str(tmp_path / "snap")
    save_train_state(d, _tiny_state(4))
    save_train_state(d, _tiny_state(9))
    st = load_train_state(d)
    assert st.step == 9
    assert not [p for p in os.listdir(d) if ".tmp." in p]  # no debris


# --------------------------------------- regroup-event translation -------

def test_regroup_fault_events_translation():
    # fresh membership: every dead replica crashes at the resume step
    evs = regroup_fault_events(10, None, [2, 3])
    assert [(e.step, e.kind, e.replica) for e in evs] == \
        [(10, "crash", 2), (10, "crash", 3)]
    # a checkpoint written AFTER the deaths already has them masked:
    # replay must be idempotent (re-crashing a dead replica is invalid)
    evs = regroup_fault_events(10, [1.0, 1.0, 0.0, 1.0], [2, 3])
    assert [(e.kind, e.replica) for e in evs] == [("crash", 3)]
    FaultPlan(tuple(evs)).validate(4, alive0=[True, True, False, True])
    # elastic: dead replicas rejoin at the same step; FaultPlan orders
    # crash before rejoin so the reseed happens from the survivors
    evs = regroup_fault_events(10, [1.0, 1.0, 0.0, 1.0], [2, 3],
                               rejoin=True)
    plan = FaultPlan(tuple(evs))
    assert [(e.kind, e.replica) for e in plan.events] == \
        [("crash", 3), ("rejoin", 2), ("rejoin", 3)]
    plan.validate(4, alive0=[True, True, False, True])


def test_regroup_plan_roundtrip(tmp_path):
    p = str(tmp_path / "regroup.json")
    save_regroup(p, RegroupPlan(epoch=2, dead_replicas=(1, 3),
                                rejoin=True))
    got = load_regroup(p)
    assert got == RegroupPlan(epoch=2, dead_replicas=(1, 3), rejoin=True)


def test_viable_procs_respects_replica_subtrees():
    sys.path.insert(0, os.path.join(REPO, "tools"))
    import launch_procs as lp

    from repro.topo import TopologySpec
    spec = TopologySpec.load(TOPOLOGY)  # world 4
    assert lp.viable_procs(spec, 4) == 4
    assert lp.viable_procs(spec, 3) == 2  # 4 % 3 != 0 -> drop to 2
    assert lp.viable_procs(spec, 1) == 1


# ------------------------------------------------- health plane ----------

def test_heartbeat_roundtrip(tmp_path):
    cfg = HealthConfig(run_dir=str(tmp_path), epoch=3, watchdog_s=60.0,
                       hb_interval=0.05)
    mon = HealthMonitor(cfg, proc_id=1).start()
    try:
        mon.phase("train")
        mon.cycle_done(7)
        deadline = time.time() + 5.0
        hb = None
        while time.time() < deadline:
            hb = read_heartbeat(str(tmp_path), 3, 1)
            if hb and hb["step"] == 7:
                break
            time.sleep(0.05)
        assert hb is not None
        assert hb["proc"] == 1 and hb["epoch"] == 3
        assert hb["phase"] == "train" and hb["step"] == 7
    finally:
        mon.close()
    assert read_heartbeat(str(tmp_path), 3, 1)["phase"] == "done"
    # other (epoch, proc) slots are untouched
    assert read_heartbeat(str(tmp_path), 3, 0) is None
    assert read_heartbeat(str(tmp_path), 2, 1) is None


def test_watchdog_hard_exits_wedged_process(tmp_path):
    """A worker that stops making progress (parked in a dead collective)
    must hard-exit with EXIT_PEER_LOST within the watchdog budget — an
    exception could never unwind a thread stuck in gloo."""
    script = f"""
import time
from repro.resilience.runtime import HealthConfig, HealthMonitor
cfg = HealthConfig(run_dir={str(tmp_path)!r}, watchdog_s=0.6,
                   hb_interval=0.1)
mon = HealthMonitor(cfg, proc_id=0).start()
mon.phase("train")
time.sleep(30)   # never reports progress again -> watchdog must fire
"""
    t0 = time.monotonic()
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=25, env=subprocess_env(1))
    assert r.returncode == EXIT_PEER_LOST, (r.returncode, r.stderr)
    assert time.monotonic() - t0 < 20.0
    status = json.load(open(tmp_path / "status_0_0.json"))
    assert status["reason"] == "watchdog" and status["phase"] == "train"


def test_health_config_from_env(monkeypatch):
    monkeypatch.delenv("DASO_RUN_DIR", raising=False)
    assert HealthConfig.from_env() is None
    monkeypatch.setenv("DASO_RUN_DIR", "/tmp/run")
    monkeypatch.setenv("DASO_EPOCH", "2")
    monkeypatch.setenv("DASO_WATCHDOG_S", "45")
    cfg = HealthConfig.from_env()
    assert cfg.run_dir == "/tmp/run" and cfg.epoch == 2
    assert cfg.watchdog_s == 45.0 and cfg.regroup_file is None


# ------------------------------------- coordinator connect retry ---------

def test_initialize_retries_transient_connect_race(monkeypatch):
    """The PR-5 conftest retry-once wrapper is gone; the port race is now
    absorbed at the source with backoff inside launch.distributed
    .initialize."""
    from repro.launch import distributed as dmod

    calls, sleeps = [], []
    monkeypatch.setattr(dmod, "_initialized", False)

    def fake_init(**kw):
        calls.append(kw)
        if len(calls) < 3:
            raise RuntimeError("Failed to bind the port: "
                               "Address already in use")

    monkeypatch.setattr(jax.distributed, "initialize", fake_init)
    monkeypatch.setattr(jax.distributed, "shutdown", lambda: None)
    monkeypatch.setattr(dmod.time, "sleep", sleeps.append)
    cfg = dmod.DistributedConfig(coordinator="127.0.0.1:1", num_processes=2,
                                 process_id=0)
    dmod.initialize(cfg, backoff_s=0.5)
    assert len(calls) == 3
    assert sleeps == [0.5, 1.0]  # exponential backoff
    assert dmod._initialized

    # non-transient errors surface on the FIRST attempt
    monkeypatch.setattr(dmod, "_initialized", False)
    calls.clear()

    def fake_boom(**kw):
        calls.append(kw)
        raise RuntimeError("invalid coordinator address")

    monkeypatch.setattr(jax.distributed, "initialize", fake_boom)
    with pytest.raises(RuntimeError, match="invalid coordinator"):
        dmod.initialize(cfg, backoff_s=0.5)
    assert len(calls) == 1


# ------------------------------- supervisor resume surface (in-proc) -----

def _daso_strategy(loss_fn, n_steps, R=4):
    cfg = DasoConfig(n_replicas=R, global_world=4 * R, b_max=4,
                     warmup_steps=n_steps // 10,
                     cooldown_steps=n_steps // 10, total_steps=n_steps)
    return make_strategy("daso", loss_fn, sgd(momentum=0.9), cfg,
                         controller=DasoController(cfg, loss_window=10))


def test_run_with_faults_resume_is_bit_exact():
    """The regroup path in miniature: a fault run snapshotted every 4
    steps, then resumed from a pre-crash AND a post-crash snapshot, must
    reproduce the uninterrupted fault run's final params bit-exactly.
    This is the in-process half of the live-kill oracle equivalence."""
    key = jax.random.PRNGKey(11)
    params0, loss_fn, daso_data, _ = make_mlp_problem(key, R=4)
    n_steps = 24
    plan = FaultPlan.from_dicts([{"step": 10, "kind": "crash",
                                  "replica": 3}])

    snaps = {}
    strat = _daso_strategy(loss_fn, n_steps)

    def snap_cb(step, carry, seg_losses):
        snaps[step] = {
            "carry": jax.tree.map(np.array, carry),
            "controller": copy.deepcopy(strat.controller.state_dict()),
            "membership": (list(strat.membership)
                           if strat.membership is not None else None)}

    full = run_with_faults(strat, params0, daso_data, constant_lr(0.1),
                           n_steps, plan, ckpt_every=4, ckpt_cb=snap_cb)
    pre = [s for s in snaps if s <= 10]
    post = [s for s in snaps if s > 10]
    assert pre and post  # both sides of the crash are covered

    for step0 in (max(pre), min(post)):
        s = snaps[step0]
        strat2 = _daso_strategy(loss_fn, n_steps)
        strat2.controller.load_state_dict(s["controller"])
        # exactly what launch/train.py replays on resume: the scripted
        # events still ahead, from the snapshot's own membership
        remaining = FaultPlan(tuple(e for e in plan.events
                                    if e.step >= step0))
        rep = run_with_faults(strat2, params0, daso_data, constant_lr(0.1),
                              n_steps, remaining, start_step=step0,
                              carry=s["carry"], membership=s["membership"])
        for a, b in zip(jax.tree.leaves(full.result.params),
                        jax.tree.leaves(rep.result.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"resume@{step0}")


def test_run_with_faults_rejects_events_in_the_past():
    key = jax.random.PRNGKey(12)
    params0, loss_fn, daso_data, _ = make_mlp_problem(key, R=4)
    strat = _daso_strategy(loss_fn, 20)
    plan = FaultPlan.from_dicts([{"step": 5, "kind": "crash",
                                  "replica": 1}])
    carry = strat.init_carry(params0)
    with pytest.raises(ValueError, match="before resume step"):
        run_with_faults(strat, params0, daso_data, constant_lr(0.1), 20,
                        plan, start_step=8, carry=carry)


def test_regroup_events_replay_against_masked_checkpoint():
    """Second-failure idempotence: a checkpoint already carrying a masked
    membership only replays the NEW death."""
    key = jax.random.PRNGKey(13)
    params0, loss_fn, daso_data, _ = make_mlp_problem(key, R=4)
    strat = _daso_strategy(loss_fn, 24)
    membership = [1.0, 1.0, 0.0, 1.0]  # replica 2 died in a prior epoch
    events = regroup_fault_events(8, membership, [2, 3])
    plan = FaultPlan(tuple(events))
    carry = strat.init_carry(params0)
    rep = run_with_faults(strat, params0, daso_data, constant_lr(0.1), 24,
                          plan, start_step=8, carry=carry,
                          membership=membership)
    assert [(e["step"], e["kind"], e["replica"]) for e in rep.applied] == \
        [(8, "crash", 3)]
    assert rep.membership_timeline[0] == (8, (1.0, 1.0, 0.0, 1.0))
    assert rep.membership_timeline[-1] == (8, (1.0, 1.0, 0.0, 0.0))
    assert np.all(np.isfinite(rep.result.losses))
