"""DASO semantics: Eq.(1) staleness merge, phase machine, B/W schedule,
blocking-sync == flat-sync equivalence, replica divergence behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.daso import (DasoConfig, blocking_sync, daso_train_step,
                             dereplicate_params, global_receive, global_send,
                             replica_divergence, replica_mean,
                             replicate_params, sync_train_step)
from repro.core.schedule import DasoController, Mode
from repro.optim.optimizers import sgd


def _quadratic_loss(params, batch):
    # simple convex problem: ||W x - y||^2
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2), {}


def _make_problem(key, R=4, per=8, d=16):
    wtrue = jax.random.normal(key, (d, 1))
    def data_fn(step):
        k = jax.random.fold_in(key, step)
        x = jax.random.normal(k, (R, per, d))
        y = x @ wtrue + 0.01 * jax.random.normal(k, (R, per, 1))
        return {"x": x, "y": y}
    return wtrue, data_fn


# ---------------------------------------------------------------- Eq. (1) --

@given(st.integers(1, 64), st.integers(2, 1024))
@settings(max_examples=30, deadline=None)
def test_eq1_is_convex_combination(S, P):
    """Eq (1) weights: 2S/(2S+P) on local, P/(2S+P) on global — sum to 1."""
    local = {"w": jnp.full((2, 3), 2.0)}
    glob = {"w": jnp.full((2, 3), -1.0)}
    merged = global_receive(local, glob, staleness=S, global_world=P)
    expect = (2 * S * 2.0 + P * (-1.0)) / (2 * S + P)
    np.testing.assert_allclose(np.asarray(merged["w"]), expect, rtol=1e-6)
    # convexity: merged between min and max
    assert -1.0 <= float(merged["w"][0, 0]) <= 2.0


def test_eq1_staleness_monotonicity():
    """More staleness -> more weight on local params (paper's rationale)."""
    local = {"w": jnp.ones((1,))}
    glob = {"w": jnp.zeros((1,))}
    vals = [float(global_receive(local, glob, staleness=s,
                                 global_world=16)["w"][0])
            for s in (1, 2, 4, 8)]
    assert all(b > a for a, b in zip(vals, vals[1:]))


def test_send_is_replica_mean():
    params = replicate_params({"w": jnp.zeros((2,))}, 4)
    params = {"w": params["w"].at[:, 0].set(jnp.arange(4.0))}
    inflight = global_send(params)
    np.testing.assert_allclose(np.asarray(inflight["w"][:, 0]), 1.5)
    # every replica holds the same buffer
    assert float(jnp.max(jnp.abs(inflight["w"] - inflight["w"][0]))) == 0.0


def test_blocking_sync_bf16_compression_roundtrip():
    params = replicate_params({"w": jnp.array([1.0 + 1e-5, 2.0])}, 2)
    out = blocking_sync(params, compress=True)
    # values pass through bf16: small perturbations are quantized away
    assert out["w"].dtype == params["w"].dtype
    assert abs(float(out["w"][0, 0]) - 1.0) < 1e-2


# ------------------------------------------------- step-variant semantics --

def test_blocking_daso_equals_sync():
    """With blocking sync every step (and no compression), DASO on R replicas
    of batch b == flat sync on the R*b batch (iid split), bitwise-close."""
    key = jax.random.PRNGKey(0)
    _, data_fn = _make_problem(key)
    params0 = {"w": jnp.zeros((16, 1))}
    opt = sgd(momentum=0.9, weight_decay=0.0)
    cfg = DasoConfig(n_replicas=4, global_world=16, compress_blocking=False)
    step = jax.jit(daso_train_step(_quadratic_loss, opt, cfg,
                                   mode="blocking"))
    sstep = jax.jit(sync_train_step(_quadratic_loss, opt))

    p = replicate_params(params0, 4)
    o = replicate_params(opt.init(params0), 4)
    infl = p
    ps, os_ = params0, opt.init(params0)
    for t in range(5):
        batch = data_fn(t)
        p, o, infl, _ = step(p, o, infl, batch, 0.05)
        flat = {k: v.reshape((-1,) + v.shape[2:]) for k, v in batch.items()}
        ps, os_, _ = sstep(ps, os_, flat, 0.05)
    np.testing.assert_allclose(np.asarray(dereplicate_params(p)["w"]),
                               np.asarray(ps["w"]), rtol=2e-5, atol=1e-6)


def test_local_steps_diverge_and_sync_restores():
    key = jax.random.PRNGKey(1)
    _, data_fn = _make_problem(key)
    params0 = {"w": jnp.zeros((16, 1))}
    opt = sgd(momentum=0.0, weight_decay=0.0)
    cfg = DasoConfig(n_replicas=4, global_world=16)
    local = jax.jit(daso_train_step(_quadratic_loss, opt, cfg, mode="local"))
    hard = jax.jit(daso_train_step(_quadratic_loss, opt, cfg,
                                   mode="hard_avg"))
    p = replicate_params(params0, 4)
    o = replicate_params(opt.init(params0), 4)
    infl = p
    p, o, infl, _ = local(p, o, infl, data_fn(0), 0.05)
    assert float(replica_divergence(p)) > 0.0  # replicas saw different data
    p, o, infl, _ = hard(p, o, infl, data_fn(1), 0.05)
    assert float(replica_divergence(p)) < 1e-7


def test_receive_applies_weighted_merge():
    key = jax.random.PRNGKey(2)
    _, data_fn = _make_problem(key)
    params0 = {"w": jnp.zeros((16, 1))}
    opt = sgd(momentum=0.0, weight_decay=0.0)
    cfg = DasoConfig(n_replicas=4, global_world=16)
    send = jax.jit(daso_train_step(_quadratic_loss, opt, cfg, mode="send"))
    recv = jax.jit(daso_train_step(_quadratic_loss, opt, cfg, mode="receive",
                                   staleness=2))
    p = replicate_params(params0, 4)
    o = replicate_params(opt.init(params0), 4)
    infl = jax.tree.map(jnp.zeros_like, p)
    p, o, infl, _ = send(p, o, infl, data_fn(0), 0.05)
    assert float(jnp.max(jnp.abs(infl["w"]))) > 0  # buffer captured
    p_before = p
    p, o, infl, _ = recv(p, o, infl, data_fn(1), 0.05)
    # after receive+local the replicas were pulled toward the global mean
    assert float(replica_divergence(p)) < float(
        replica_divergence(p_before)) + 1e-6


# ----------------------------------------------------------- controller ----

def _cfg(b_max=4, warm=10, cool=10, total=100):
    return DasoConfig(n_replicas=4, global_world=16, b_max=b_max,
                      warmup_steps=warm, cooldown_steps=cool,
                      total_steps=total, plateau_patience=2)


def test_controller_phases():
    c = DasoController(_cfg(), loss_window=1000)
    modes = [c.mode_for_step(t)[0] for t in range(100)]
    assert all(m == Mode.BLOCKING for m in modes[:10])
    assert all(m == Mode.BLOCKING for m in modes[90:])
    assert any(m in (Mode.SEND, Mode.SEND_RECEIVE) for m in modes[10:90])
    assert any(m == Mode.LOCAL for m in modes[10:90])


def test_controller_send_receive_spacing():
    c = DasoController(_cfg(warm=0, cool=0, total=0), loss_window=10**9)
    events = [(t,) + c.mode_for_step(t) for t in range(40)]
    sends = [t for t, m, _ in events if m in (Mode.SEND, Mode.SEND_RECEIVE)]
    recvs = [(t, s) for t, m, s in events if m in (Mode.RECEIVE,
                                                   Mode.SEND_RECEIVE)]
    assert sends, "no sends happened"
    # B=4 spacing between sends
    assert all(b - a == 4 for a, b in zip(sends, sends[1:]))
    # every receive waits exactly W=1 steps and reports that staleness
    for t, s in recvs:
        assert s == 1


def test_controller_plateau_halves_and_resets():
    c = DasoController(_cfg(b_max=4, warm=0, cool=0, total=0), loss_window=1)
    assert (c.b, c.w) == (4, 1)
    c.observe_loss(1.0)  # first window sets the best-loss reference
    # constant loss -> plateau every `patience` windows
    for _ in range(2):
        c.observe_loss(1.0)
    assert (c.b, c.w) == (2, 1)
    for _ in range(2):
        c.observe_loss(1.0)
    assert (c.b, c.w) == (1, 1)
    for _ in range(2):
        c.observe_loss(1.0)
    assert (c.b, c.w) == (4, 1)  # paper: reset once both reach 1


def test_controller_improvement_keeps_b():
    c = DasoController(_cfg(warm=0, cool=0, total=0), loss_window=1)
    for i in range(20):
        c.observe_loss(1.0 / (i + 1))
    assert c.b == 4


def test_microbatched_grads_match_full_batch():
    """Gradient accumulation (beyond-paper memory optimization) must be
    numerically equivalent to the full-batch step."""
    import numpy as np
    from repro.core.daso import sync_train_step
    key = jax.random.PRNGKey(0)
    _, data_fn = _make_problem(key, R=1, per=16)
    params0 = {"w": jnp.zeros((16, 1))}
    opt = sgd(momentum=0.9, weight_decay=1e-4)
    batch = {k: v[0] for k, v in data_fn(0).items()}  # flat (16, d)
    outs = {}
    for n in (1, 2, 4):
        step = jax.jit(sync_train_step(_quadratic_loss, opt, n_micro=n))
        p, _, m = step(params0, opt.init(params0), batch, 0.05)
        outs[n] = p["w"]
    for n in (2, 4):
        np.testing.assert_allclose(np.asarray(outs[n]),
                                   np.asarray(outs[1]), atol=1e-6)
