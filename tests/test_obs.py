"""The observability tier (ISSUE 8):

  * `obs.trace` unit contract: span/instant/counter events are valid
    JSONL trace events, the null tracer is free, per-process streams
    merge timestamp-sorted, Chrome export wraps without loss.
  * Executor integration: every dispatched cycle gets a span carrying
    (steps, per-level sync counts, fresh_compile/fallback flags);
    checkpoint saves get spans; the overlap legs get their own spans.
  * Controller decision events: plateau-driven B/W changes, membership
    flushes, and DCN rescales land in the trace with a `reason` —
    and the tracer never leaks into controller checkpoints.
  * `obs.meters`: per-level bytes-on-the-wire from level_sync_counts +
    the flat-buffer wire pricing, split by outer phase wire tier, and
    cross-checked against compiled-program collective stats.
  * Heartbeat wire format: the schema round-trips what HealthMonitor
    writes, and tolerates extra keys in both planes.
  * `tools/trace_report.py`: the cycle-cost regression recovers known
    coefficients exactly, and the drift table covers every sync level
    of the run's topology.
"""
import json
import os
import sys

import jax
import jax.numpy as jnp
import pytest

from conftest import make_mlp_problem

from repro.core.daso import DasoConfig
from repro.core.schedule import DasoController
from repro.obs.trace import (NULL_TRACER, RUN_METADATA, Tracer, load_events,
                             merge_streams, stream_path, to_chrome,
                             validate_event)
from repro.obs import meters
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant_lr
from repro.train.loop import TrainLoopConfig, run_training

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _report_mod():
    sys.path.insert(0, os.path.join(REPO, "tools"))
    import trace_report
    return trace_report


def _events(path):
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


# -- tracer unit contract ------------------------------------------------------

def test_tracer_events_are_valid_jsonl(tmp_path):
    p = str(tmp_path / "t.e0p0.jsonl")
    tr = Tracer(p, proc_id=0, flush_every=4)
    with tr.span("cycle", cat="executor", steps=3):
        pass
    tr.instant("compile", cat="executor", shape_len=2)
    tr.counter("comm_meters", {"_outer.syncs": 4.0})
    tr.metadata(arch="mlp", param_bytes=123)
    tr.close()
    evs = _events(p)
    # process_name + 4 events + tracer_self
    assert len(evs) == 6
    for ev in evs:
        assert validate_event(ev) is None, ev
    names = [ev["name"] for ev in evs]
    assert names[0] == "process_name" and names[-1] == "tracer_self"
    assert RUN_METADATA in names
    span = next(ev for ev in evs if ev["name"] == "cycle")
    assert span["ph"] == "X" and span["dur"] >= 0
    assert span["args"]["steps"] == 3
    self_acct = evs[-1]["args"]
    # the self-accounting counter snapshots the count before itself
    assert self_acct["events"] == tr.n_events - 1
    assert tr.overhead_s > 0.0


def test_tracer_close_is_idempotent_and_final(tmp_path):
    p = str(tmp_path / "t.e0p0.jsonl")
    tr = Tracer(p)
    tr.instant("x")
    tr.close()
    n = len(_events(p))
    tr.close()
    tr.instant("after_close")  # dropped, not an error
    assert len(_events(p)) == n


def test_null_tracer_is_api_complete_noop():
    with NULL_TRACER.span("cycle", steps=1) as sp:
        assert sp is NULL_TRACER.span("again")  # shared instance
    NULL_TRACER.instant("x")
    NULL_TRACER.counter("c", {"v": 1.0})
    NULL_TRACER.metadata(a=1)
    NULL_TRACER.close()
    assert NULL_TRACER.enabled is False
    assert NULL_TRACER.n_events == 0


@pytest.mark.parametrize("ev,frag", [
    ("nope", "not an object"),
    ({"ph": "X", "ts": 0, "pid": 0}, "missing required key 'name'"),
    ({"name": "", "ph": "i", "ts": 0, "pid": 0}, "non-empty"),
    ({"name": "a", "ph": "Z", "ts": 0, "pid": 0}, "unknown phase"),
    ({"name": "a", "ph": "i", "ts": -1, "pid": 0}, "non-negative"),
    ({"name": "a", "ph": "X", "ts": 0, "pid": 0}, "dur"),
    ({"name": "a", "ph": "X", "ts": 0, "pid": 0, "dur": -5}, "dur"),
    ({"name": "a", "ph": "i", "ts": 0, "pid": 0, "args": [1]}, "args"),
])
def test_validate_event_rejects(ev, frag):
    err = validate_event(ev)
    assert err is not None and frag in err


def test_validate_event_tolerates_extra_keys():
    ev = {"name": "a", "ph": "i", "ts": 1, "pid": 0,
          "future_field": {"anything": True}}
    assert validate_event(ev) is None


def test_merge_streams_sorts_across_processes(tmp_path):
    base = str(tmp_path / "trace.jsonl")
    for proc in (0, 1):
        tr = Tracer(stream_path(base, proc), proc_id=proc)
        for i in range(3):
            tr.instant(f"p{proc}e{i}")
        tr.close()
    assert merge_streams(base) == base
    evs = _events(base)
    assert [ev["ts"] for ev in evs] == sorted(ev["ts"] for ev in evs)
    assert {ev["pid"] for ev in evs} == {0, 1}
    # load_events reads the merged file; in-memory merge when base absent
    assert load_events(base) == evs
    os.remove(base)
    assert load_events(base) == evs
    assert merge_streams(str(tmp_path / "other.jsonl")) is None


def test_stream_path_is_epoch_and_proc_tagged():
    assert stream_path("/r/t.jsonl", 3) == "/r/t.jsonl.e0p3.jsonl"
    assert stream_path("/r/t.jsonl", 1, epoch=2) == "/r/t.jsonl.e2p1.jsonl"


def test_chrome_export_wraps_all_events(tmp_path):
    p = str(tmp_path / "t.e0p0.jsonl")
    tr = Tracer(p)
    tr.instant("x")
    tr.close()
    evs = _events(p)
    doc = to_chrome(evs)
    assert doc["traceEvents"] == evs
    json.dumps(doc)  # must be serializable as a chrome trace document


# -- executor + controller integration ----------------------------------------

def _traced_run(tmp_path, **kw):
    base = str(tmp_path / "trace.jsonl")
    tr = Tracer(stream_path(base, 0), proc_id=0)
    key = jax.random.PRNGKey(3)
    params0, loss_fn, daso_data, _ = make_mlp_problem(key)
    cfg = TrainLoopConfig(strategy="daso", n_steps=kw.pop("n_steps", 24),
                          n_replicas=2, b_max=4, loss_window=50,
                          executor="macro", **kw)
    result = run_training(loss_fn, params0, daso_data, cfg,
                          optimizer=sgd(momentum=0.9),
                          lr_fn=constant_lr(0.05), log=None, tracer=tr)
    tr.close()
    merge_streams(base)
    return result, _events(base)


def test_executor_cycle_spans_carry_sync_counts(tmp_path):
    result, evs = _traced_run(tmp_path)
    cycles = [ev for ev in evs
              if ev["name"] == "cycle" and ev["ph"] == "X"]
    assert cycles
    assert sum(ev["args"]["steps"] for ev in cycles) == 24
    # the span args carry the per-level sync counts the drift fit needs
    outer = sum(ev["args"]["syncs"].get("_outer", 0) for ev in cycles)
    assert outer == result.controller.level_sync_counts()["_outer"]
    # lazy jit: compile cost lands inside the first cycle span of a shape
    assert cycles[0]["args"]["fresh_compile"] is True
    fresh = sum(ev["args"]["fresh_compile"] for ev in cycles)
    compiles = [ev for ev in evs if ev["name"] == "compile"]
    assert len(compiles) == result.executor_stats.compiles
    assert 1 <= fresh <= len(compiles)
    for ev in evs:
        assert validate_event(ev) is None, ev


def test_overlap_run_emits_exchange_leg_spans(tmp_path):
    _, evs = _traced_run(tmp_path, overlap="one_cycle")
    names = {ev["name"] for ev in evs if ev["ph"] == "X"}
    assert {"ov_compute", "ov_exchange_visible", "ov_merge"} <= names


def test_checkpoint_save_span(tmp_path):
    _, evs = _traced_run(tmp_path, ckpt_every=8,
                         ckpt_dir=str(tmp_path / "ck"))
    saves = [ev for ev in evs if ev["name"] == "checkpoint_save"]
    assert saves and all(ev["cat"] == "checkpoint" for ev in saves)
    assert saves[0]["args"]["step"] >= 0


def _plateau_controller(tracer):
    cfg = DasoConfig(n_replicas=2, global_world=4, b_max=4, warmup_steps=0,
                     cooldown_steps=0, total_steps=10_000,
                     plateau_patience=1)
    c = DasoController(cfg, loss_window=2)
    c.tracer = tracer
    return c


def test_controller_plateau_events_have_reasons(tmp_path):
    p = str(tmp_path / "t.e0p0.jsonl")
    tr = Tracer(p)
    c = _plateau_controller(tr)
    for _ in range(40):  # constant loss: every window is a plateau
        c.observe_loss(1.0)
    c.notify_membership_change(step=80, n_active=3)
    c.notify_dcn_scale(0.25, step=81)
    c.notify_dcn_scale(1.0, step=82)
    tr.close()
    evs = _events(p)
    bw = [ev for ev in evs if ev["name"] == "bw_change"]
    reasons = {ev["args"]["reason"] for ev in bw}
    # B halves 4->2->1 then resets: both reason codes appear
    assert reasons == {"plateau_halve", "plateau_reset"}
    halve = next(ev for ev in bw if ev["args"]["reason"] == "plateau_halve")
    assert halve["args"]["b_to"] == halve["args"]["b_from"] // 2
    assert all(ev["cat"] == "schedule" for ev in bw)
    mem = next(ev for ev in evs if ev["name"] == "membership_change")
    assert mem["args"] == {"reason": "plateau_stats_flushed", "step": 80,
                           "n_active": 3}
    dcn = [ev["args"]["reason"] for ev in evs if ev["name"] == "dcn_scale"]
    assert dcn == ["dcn_degraded", "dcn_recovered"]


def test_controller_tracer_never_enters_checkpoints(tmp_path):
    tr = Tracer(str(tmp_path / "t.e0p0.jsonl"))
    c = _plateau_controller(tr)
    for _ in range(6):
        c.observe_loss(1.0)
    sd = c.state_dict()
    assert "tracer" not in sd
    json.dumps(sd)  # checkpoint payload must stay JSON-serializable
    c2 = _plateau_controller(None)
    c2.tracer = None
    c2.load_state_dict(sd)  # and load never expects one
    tr.close()


# -- meters: per-level communication accounting -------------------------------

def _history(modes):
    return [(i, m, 4, 1) for i, m in enumerate(modes)]


def test_outer_sync_split():
    h = _history(["blocking", "local", "send", "send_receive+host",
                  "ov_sync~2", "hard_avg", "local"])
    assert meters.outer_sync_split(h) == {"blocking": 2, "nonblocking": 3}
    assert meters.outer_sync_split([]) == {"blocking": 0, "nonblocking": 0}


@pytest.mark.parametrize("name", ["gossip", "downpour"])
def test_meters_account_baseline_strategy_traffic(name):
    """Every exchange the gossip/downpour controllers emit lands in the
    outer meter row — exchange tokens price at the nonblocking tier,
    warm-up/cool-down at the blocking tier, and the row's sync count
    equals the history's non-local step count (no orphan bytes)."""
    n_steps = 20
    key = jax.random.PRNGKey(11)
    params0, loss_fn, daso_data, _ = make_mlp_problem(key)
    cfg = TrainLoopConfig(strategy=name, n_steps=n_steps, n_replicas=2,
                          local_world=2, b_max=4, lr=0.1, loss_window=10)
    res = run_training(loss_fn, params0, daso_data, cfg, log=None)
    ctl = res.controller
    n_exchanges = sum(1 for (_, m, _, _) in ctl.history if m != "local")
    assert n_exchanges > 0
    split = meters.outer_sync_split(ctl.history)
    # the strategy's own exchange token (gossip~s / push) is classified
    # nonblocking; the warm-up/cool-down averages blocking; nothing falls
    # through unpriced
    assert split["nonblocking"] > 0 and split["blocking"] > 0
    assert split["blocking"] + split["nonblocking"] == n_exchanges
    counts = ctl.level_sync_counts()
    assert counts == {"_outer": n_exchanges}
    rows = meters.level_bytes_report(res.params, counts, ctl.cfg,
                                     outer_split=split)
    assert sum(r.syncs for r in rows) == n_exchanges
    assert all(r.bytes_per_sync > 0 for r in rows)
    flat = meters.rows_as_counter(rows)
    priced = sum(v for k, v in flat.items() if k.endswith(".syncs"))
    assert priced == n_exchanges


def test_level_bytes_report_splits_outer_by_wire_tier():
    from repro.core.compression import transfer_bytes
    from repro.topo import TopologySpec
    params = {"w": jnp.ones((8, 4)), "b": jnp.ones((4,))}
    cfg = DasoConfig(n_replicas=4, global_world=4)  # bf16 blocking, f32 async
    spec = TopologySpec.load("chip:1 x host:2 x pod:2")
    counts = {"_outer": 5, "host": 9}
    rows = meters.level_bytes_report(
        params, counts, cfg, topo=spec,
        outer_split={"blocking": 2, "nonblocking": 3})
    by = {(r.level, r.variant): r for r in rows}
    blk = by[("_outer", "blocking")]
    nb = by[("_outer", "nonblocking")]
    assert (blk.syncs, nb.syncs) == (2, 3)
    assert blk.wire_format == "bf16" and nb.wire_format == "f32"
    assert blk.bytes_per_sync == transfer_bytes(params, wire_format="bf16")
    assert nb.bytes_per_sync == 2 * blk.bytes_per_sync
    assert blk.total_bytes == 2 * blk.bytes_per_sync
    inner = by[("host", "")]
    assert (inner.syncs, inner.group_size) == (9, 2)
    # forced wire format: one outer row, no split
    import dataclasses
    forced = dataclasses.replace(cfg, wire_format="f32")
    rows2 = meters.level_bytes_report(params, counts, forced, topo=spec,
                                      outer_split={"blocking": 2,
                                                   "nonblocking": 3})
    assert [r for r in rows2 if r.level == "_outer"][0].syncs == 5


def test_level_bytes_report_keeps_orphan_levels():
    cfg = DasoConfig(n_replicas=2, global_world=2)
    rows = meters.level_bytes_report({"w": jnp.ones((4,))},
                                     {"_outer": 3, "host": 7}, cfg)
    orphan = [r for r in rows if r.level == "host"][0]
    assert (orphan.syncs, orphan.group_size) == (7, 0)


def test_rows_as_counter_flattens():
    r = meters.LevelMeter("_outer", 3, "bf16", 4, 100, variant="blocking")
    flat = meters.rows_as_counter([r])
    assert flat == {"_outer.blocking.syncs": 3.0,
                    "_outer.blocking.bytes_per_sync": 100.0,
                    "_outer.blocking.total_bytes": 300.0}


def test_crosscheck_hlo_picks_matching_variant():
    rows = [meters.LevelMeter("_outer", 2, "bf16", 4, 544,
                              variant="blocking"),
            meters.LevelMeter("_outer", 3, "f32", 4, 1088,
                              variant="nonblocking"),
            meters.LevelMeter("chip", 9, "f32", 2, 1088)]
    hlo = {"all-reduce@pod": {"bytes": 2176, "count": 2},   # 1088/op
           "all-reduce@chip": {"bytes": 9792, "count": 9},  # 1088/op
           "_total": {"bytes": 0, "count": 0}}              # ignored
    verdicts = {v["level"]: v for v in meters.crosscheck_hlo(rows, hlo)}
    # auto axis map: chip -> chip, _outer -> the unclaimed axis (pod)
    assert verdicts["_outer"]["axis"] == "pod"
    assert verdicts["_outer"]["variant"] == "nonblocking"
    assert verdicts["_outer"]["ok"] is True
    assert verdicts["chip"]["ok"] is True
    # a mispriced meter fails the check
    bad = [meters.LevelMeter("chip", 9, "f32", 2, 2000)]
    v = meters.crosscheck_hlo(bad, hlo)[0]
    assert v["ok"] is False and v["rel_err"] > 0.05


def test_crosscheck_hlo_reports_unmatched_levels():
    rows = [meters.LevelMeter("_outer", 0, "f32", 2, 100)]
    v = meters.crosscheck_hlo(rows, {})[0]
    assert v["ok"] is None and v["hlo_bytes"] is None


# -- heartbeat wire-format schema ---------------------------------------------

def test_heartbeat_schema_roundtrip(tmp_path):
    from repro.resilience.runtime import (HealthConfig, HealthMonitor,
                                          read_heartbeat,
                                          validate_heartbeat)
    cfg = HealthConfig(run_dir=str(tmp_path), epoch=2, watchdog_s=60.0)
    mon = HealthMonitor(cfg, proc_id=1).start()
    mon.phase("train")
    mon.cycle_done(12)
    mon.close()
    doc = read_heartbeat(str(tmp_path), 2, 1)
    assert doc is not None
    assert validate_heartbeat(doc) is None
    assert doc["phase"] == "done" and doc["step"] == 12
    assert (doc["proc"], doc["epoch"]) == (1, 2)
    # extra keys are tolerated in BOTH directions: a newer writer's beat
    # still validates under this reader's schema
    doc["future_key"] = {"x": 1}
    assert validate_heartbeat(doc) is None


@pytest.mark.parametrize("mutate,frag", [
    (lambda d: d.pop("phase"), "missing required key 'phase'"),
    (lambda d: d.update(phase=""), "bad value for 'phase'"),
    (lambda d: d.update(proc=-1), "bad value for 'proc'"),
    (lambda d: d.update(step="4"), "bad value for 'step'"),
    (lambda d: d.update(t=-1.0), "bad value for 't'"),
])
def test_heartbeat_schema_rejects(mutate, frag):
    from repro.resilience.runtime import validate_heartbeat
    doc = {"proc": 0, "epoch": 0, "phase": "train", "step": 3, "t": 1.5}
    assert validate_heartbeat(doc) is None
    mutate(doc)
    err = validate_heartbeat(doc)
    assert err is not None and frag in err
    assert "not an object" in validate_heartbeat([doc])


def test_health_monitor_phase_events_reach_trace(tmp_path):
    from repro.resilience.runtime import HealthConfig, HealthMonitor
    tr = Tracer(str(tmp_path / "t.e0p0.jsonl"))
    cfg = HealthConfig(run_dir=str(tmp_path / "hb"), watchdog_s=60.0)
    mon = HealthMonitor(cfg, proc_id=0, tracer=tr).start()
    mon.phase("train")
    mon.close()
    tr.close()
    phases = [ev["args"]["phase"] for ev in _events(tr.path)
              if ev["name"] == "phase"]
    assert phases == ["train", "done"]


# -- trace_report: cycle-cost fit and drift table -----------------------------

def _cycle_span(steps, syncs, dur_s, **flags):
    return {"name": "cycle", "cat": "executor", "ph": "X", "ts": 0,
            "dur": int(dur_s * 1e6), "pid": 0, "tid": 0,
            "args": {"start_step": 0, "steps": steps, "syncs": syncs,
                     "fresh_compile": False, "fallback": False, **flags}}


def _synthetic_trace(t_step=0.010, t_outer=0.040, t_chip=0.005):
    """Cycle spans whose durations obey the fit model EXACTLY, with
    enough sync-count variation to determine every coefficient."""
    def dur(steps, syncs):
        return (steps * t_step + syncs.get("_outer", 0) * t_outer
                + syncs.get("chip", 0) * t_chip)
    cycles = [(4, {"_outer": 1, "chip": 4}), (4, {"_outer": 0, "chip": 4}),
              (2, {"_outer": 1, "chip": 0}), (8, {"_outer": 2, "chip": 8}),
              (1, {"_outer": 0, "chip": 1})]
    evs = [{"name": RUN_METADATA, "cat": "meta", "ph": "i", "s": "p",
            "ts": 0, "pid": 0, "tid": 0,
            "args": {"arch": "mlp", "topology": "chip:2 x pod:2",
                     "param_bytes": 4 * 1024 ** 2, "b_max": 4,
                     "wire_format": "bf16", "n_replicas": 2,
                     "local_world": 2}}]
    # a compile cycle with an absurd duration: must be excluded, not fit
    evs.append(_cycle_span(4, {"_outer": 1, "chip": 4}, 60.0,
                           fresh_compile=True))
    evs.extend(_cycle_span(s, sy, dur(s, sy)) for s, sy in cycles)
    return evs


def test_fit_cycle_costs_recovers_exact_coefficients():
    tr = _report_mod()
    fit = tr.fit_cycle_costs(_synthetic_trace())
    assert fit["samples"] == 5 and fit["excluded"] == 1
    assert fit["t_step_s"] == pytest.approx(0.010, rel=1e-6)
    assert fit["levels"]["_outer"] == pytest.approx(0.040, rel=1e-6)
    assert fit["levels"]["chip"] == pytest.approx(0.005, rel=1e-6)
    assert fit["residual_frac"] == pytest.approx(0.0, abs=1e-9)
    assert "note" not in fit


def test_fit_cycle_costs_underdetermined_is_flagged():
    tr = _report_mod()
    evs = [_cycle_span(4, {"_outer": 1}, 0.05)]
    fit = tr.fit_cycle_costs(evs)
    assert fit["t_step_s"] is None and "note" in fit
    assert tr.fit_cycle_costs([]) is None


def test_drift_table_covers_every_sync_level():
    tr = _report_mod()
    evs = _synthetic_trace()
    drift = tr.drift_table(evs)
    assert drift is not None
    # "chip:2 x pod:2" has exactly one sync level above the gradient
    # all-reduce: the pod exchange, keyed "_outer" in the fit
    levels = {row["level"]: row for row in drift}
    assert "pod" in levels
    pod = levels["pod"]
    assert pod["model_sync_s"] > 0
    assert pod["measured_sync_s"] == pytest.approx(0.040, rel=1e-6)
    assert pod["drift_x"] == pytest.approx(
        pod["measured_sync_s"] / pod["model_sync_s"], rel=1e-9)
    # every topology sync level appears even if unmeasured, and fit
    # levels the spec does not name are appended rather than dropped
    assert all(row["measured_sync_s"] is not None or row["period"]
               for row in drift)
    assert any(row["level"] == "chip" for row in drift)


def test_drift_table_requires_metadata():
    tr = _report_mod()
    assert tr.drift_table([_cycle_span(4, {"_outer": 1}, 0.05)]) is None


def test_build_report_end_to_end(tmp_path):
    tr = _report_mod()
    _, evs = _traced_run(tmp_path)
    rep = tr.build_report(evs)
    assert rep["schema_errors"] == []
    assert rep["summary"]["executor"]["spans"] > 0
    assert rep["summary"]["_tracer"]["events"] > 0
    assert rep["cycle_fit"]["samples"] >= 0
    json.dumps(rep)  # --json output contract
