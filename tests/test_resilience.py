"""Resilience subsystem: elastic-membership exchange equivalence against a
survivors-only oracle (plus the one-collective HLO contract under a mask),
frozen ghost rows, rejoin re-seeding, controller fault adaptation, the
fault-plan DSL, supervisor end-to-end crash/rejoin runs, and the
acceptance-criterion deterministic resume (macro AND per_step executors)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_mlp_problem as _mlp_problem

from repro.core import flatbuf
from repro.core.daso import (DasoConfig, daso_train_step, freeze_inactive,
                             global_receive, replica_mean,
                             replica_mean_per_leaf)
from repro.core.executor import MacroCycleExecutor, make_strategy
from repro.core.schedule import DasoController
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant_lr
from repro.resilience.faults import FaultEvent, FaultPlan
from repro.resilience.membership import reseed_carry
from repro.resilience.supervisor import run_with_faults
from repro.train.loop import TrainLoopConfig, run_training

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _tree(key, R=4):
    k = jax.random.split(key, 3)
    return {"w": jax.random.normal(k[0], (R, 5, 3)),
            "nested": {"b": jax.random.normal(k[1], (R, 7)),
                       "s": jax.random.normal(k[2], (R, 1))}}


# ------------------------------------------------ elastic-merge oracle --

@pytest.mark.parametrize("wire_format", ["f32", "bf16"])
@pytest.mark.parametrize("mask", [(1.0, 1.0, 0.0, 1.0),
                                  (0.0, 1.0, 0.0, 1.0),
                                  (1.0, 0.0, 0.0, 0.0)])
def test_masked_fused_mean_matches_survivor_oracle(wire_format, mask):
    """Acceptance: the membership-weighted fused exchange equals a pure-jnp
    mean computed over the surviving replicas only, broadcast to every row."""
    tree = _tree(jax.random.PRNGKey(0))
    got = replica_mean(tree, wire_format=wire_format, mask=mask)
    alive = [i for i, m in enumerate(mask) if m]

    def oracle(x):
        wd = jnp.bfloat16 if wire_format == "bf16" else x.dtype
        sub = x[jnp.asarray(alive)].astype(wd)
        # reciprocal-multiply like the arena path (x/n and x*(1/n) differ
        # at the ULP in f32; the contract is the weighting, not the op)
        m = (jnp.sum(sub, axis=0, dtype=wd)
             * jnp.asarray(1.0 / len(alive), wd)).astype(x.dtype)
        return jnp.broadcast_to(m[None], x.shape)

    want = jax.tree.map(oracle, tree)
    tol = dict(rtol=1e-7, atol=1e-7) if wire_format == "f32" \
        else dict(rtol=1e-2, atol=1e-2)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **tol)


def test_masked_per_leaf_matches_fused():
    """The legacy per-leaf path applies the identical membership weighting."""
    tree = _tree(jax.random.PRNGKey(1))
    mask = (1.0, 0.0, 1.0, 1.0)
    fused = replica_mean(tree, wire_format="f32", mask=mask)
    per_leaf = replica_mean_per_leaf(tree, None, mask=mask)
    for a, b in zip(jax.tree.leaves(fused), jax.tree.leaves(per_leaf)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_masked_int8_mean_close_to_survivor_oracle():
    """The int8 tier stays within quantization distance of the survivor
    oracle under a mask."""
    tree = _tree(jax.random.PRNGKey(2))
    mask = (1.0, 1.0, 0.0, 1.0)
    got = replica_mean(tree, wire_format="int8", mask=mask)
    want = replica_mean(tree, wire_format="f32", mask=mask)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=0.05)


def test_dynamic_p_receive_matches_survivor_oracle():
    """Eq. (1) under elastic membership runs with the effective world size
    P_eff = P * n_active / R, and dropped rows stay frozen."""
    key = jax.random.PRNGKey(3)
    params = _tree(key)
    inflight = jax.tree.map(lambda x: x * 0.5, params)
    mask, R, P = (1.0, 0.0, 1.0, 1.0), 4, 16
    p_eff = P * 3 / R
    got = global_receive(params, inflight, staleness=2, global_world=p_eff,
                         mask=mask)

    def oracle(x, s):
        merged = (4.0 * x + p_eff * s) / (4.0 + p_eff)
        col = jnp.asarray(mask).reshape((R,) + (1,) * (x.ndim - 1))
        return jnp.where(col > 0, merged, x)

    want = jax.tree.map(oracle, params, inflight)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_one_collective_holds_under_membership_mask():
    """Acceptance: the PR-2 one-collective-per-sync HLO contract survives
    elastic membership — the mask multiply fuses, it must not add or split
    collectives. 2-virtual-device pod mesh in a subprocess."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    script = """
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.daso import blocking_sync
        from repro.launch.hlo_stats import collective_stats

        mesh = jax.make_mesh((2,), ("pod",))
        sh = NamedSharding(mesh, P("pod"))
        tree = {f"w{i}": jax.ShapeDtypeStruct((2, 32, 3 + i), jnp.float32)
                for i in range(6)}
        mask = (1.0, 0.0)
        for wf in ("f32", "bf16", "int8"):
            fn = lambda t, wf=wf: blocking_sync(t, wire_format=wf,
                                                mask=mask)
            hlo = jax.jit(fn, in_shardings=({k: sh for k in tree},)).lower(
                tree).compile().as_text()
            stats = collective_stats(hlo, {"pod": 2})
            n = sum(v["count"] for k, v in stats.items()
                    if isinstance(v, dict) and k.startswith("all-reduce"))
            assert n == 1, (wf, n)
        print("MASKED ONE COLLECTIVE OK")
    """
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "MASKED ONE COLLECTIVE OK" in r.stdout


# ----------------------------------------------------- frozen ghosts --

def test_elastic_step_freezes_dead_rows():
    """A dropped replica's params/opt rows are ghosts: every step variant
    leaves them bit-identical while active rows train."""
    key = jax.random.PRNGKey(4)
    params0, loss_fn, daso_data, _ = _mlp_problem(key, R=4)
    cfg = DasoConfig(n_replicas=4, global_world=16, b_max=4)
    opt = sgd(momentum=0.9)
    mask = (1.0, 1.0, 0.0, 1.0)
    from repro.core.daso import replicate_params
    params = replicate_params(params0, 4)
    opt_state = replicate_params(opt.init(params0), 4)
    inflight = jax.tree.map(jnp.array, params)
    batch = daso_data(0)
    for mode in ("local", "send", "receive", "blocking", "hard_avg"):
        step = jax.jit(daso_train_step(loss_fn, opt, cfg, mode=mode,
                                       staleness=1, membership=mask))
        p2, o2, _, m = step(params, opt_state, inflight, batch, 0.1)
        for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a[2]), np.asarray(b[2]))
            assert not np.allclose(np.asarray(a[0]), np.asarray(b[0]))
        for a, b in zip(jax.tree.leaves(o2), jax.tree.leaves(opt_state)):
            np.testing.assert_array_equal(np.asarray(a[2]), np.asarray(b[2]))
        # reported loss averages active replicas only
        lr_ = np.asarray(m["loss_per_replica"])
        np.testing.assert_allclose(
            float(m["loss"]), float((lr_[0] + lr_[1] + lr_[3]) / 3),
            rtol=1e-6)


def test_freeze_inactive_identity_without_mask():
    new = {"w": jnp.ones((2, 3))}
    assert freeze_inactive(new, {"w": jnp.zeros((2, 3))}, None) is new


def test_reseed_carry_bootstraps_joiner_from_donor_mean():
    key = jax.random.PRNGKey(5)
    carry = (_tree(key), {"mu": _tree(jax.random.fold_in(key, 1))})
    donor_mask = (1.0, 1.0, 0.0, 1.0)
    out = reseed_carry(carry, donor_mask, [2])
    for x, y in zip(jax.tree.leaves(carry), jax.tree.leaves(out)):
        x, y = np.asarray(x), np.asarray(y)
        want = (x[0] + x[1] + x[3]) / 3
        np.testing.assert_allclose(y[2], want, rtol=1e-6, atol=1e-7)
        np.testing.assert_array_equal(y[[0, 1, 3]], x[[0, 1, 3]])
    with pytest.raises(ValueError, match="donor and joiner"):
        reseed_carry(carry, (1.0,) * 4, [2])


# ------------------------------------------------- membership guards --

def test_normalize_membership_validation():
    assert flatbuf.normalize_membership(None, 4) is None
    assert flatbuf.normalize_membership((1, 1, 1, 1), 4) is None
    assert flatbuf.normalize_membership([1, 0, 1, 1], 4) == (1.0, 0.0, 1.0,
                                                            1.0)
    with pytest.raises(ValueError, match="entries"):
        flatbuf.normalize_membership((1.0, 0.0), 4)
    with pytest.raises(ValueError, match="no active"):
        flatbuf.normalize_membership((0.0,) * 4, 4)
    with pytest.raises(ValueError, match="0/1"):
        flatbuf.normalize_membership((0.5, 1.0), 2)


# --------------------------------------------- controller adaptation --

def test_controller_membership_change_flushes_plateau_stats():
    cfg = DasoConfig(n_replicas=4, global_world=16, b_max=4)
    c = DasoController(cfg, loss_window=5)
    for _ in range(3):
        c.observe_loss(1.0)
    assert c.window_remaining() == 2
    c.notify_membership_change(3, 3)
    assert c.window_remaining() == 5  # window discarded
    assert c.events == [(3, "membership", 3.0)]
    # a post-fault loss bump must not immediately count toward the
    # plateau patience (baseline restarted)
    b0 = c.b
    for _ in range(5):
        c.observe_loss(10.0)
    assert c.b == b0


def test_controller_dcn_scale_stretches_b():
    cfg = DasoConfig(n_replicas=4, global_world=16, b_max=4)
    c = DasoController(cfg, loss_window=5)
    c.notify_dcn_scale(0.25, step=7)
    assert c.b == 16 and c.w == 4       # b_max/scale, W = B/4
    c.notify_dcn_scale(0.001, step=8)
    assert c.b == 16                    # capped at 4*b_max
    c.notify_dcn_scale(1.0, step=9)
    assert c.b == 4 and c.w == 1        # clamped back to b_max
    with pytest.raises(ValueError):
        c.notify_dcn_scale(0.0)


# ---------------------------------------------------- fault-plan DSL --

def test_fault_plan_json_roundtrip_and_queries():
    plan = FaultPlan.from_dicts([
        {"step": 20, "kind": "rejoin", "replica": 1},
        {"step": 5, "kind": "crash", "replica": 1},
        {"step": 8, "kind": "straggle", "replica": 0, "factor": 3.0},
        {"step": 10, "kind": "degrade_dcn", "factor": 0.5},
        {"step": 15, "kind": "restore_dcn"},
    ])
    plan.validate(4)
    assert [e.step for e in plan.events] == [5, 8, 10, 15, 20]  # sorted
    assert FaultPlan.from_json(plan.to_json()) == plan
    assert plan.boundaries() == [5, 8, 10, 15, 20]
    assert plan.next_boundary_after(8) == 10
    assert plan.next_boundary_after(20) is None
    assert plan.membership_at(4, 4) == (1.0,) * 4
    assert plan.membership_at(5, 4) == (1.0, 0.0, 1.0, 1.0)
    assert plan.membership_at(20, 4) == (1.0,) * 4
    assert plan.dcn_scale_at(12) == 0.5 and plan.dcn_scale_at(15) == 1.0
    assert plan.slowdowns_at(9, 4) == (3.0, 1.0, 1.0, 1.0)


def test_fault_plan_validation_rejects_incoherent_scripts():
    with pytest.raises(ValueError, match="already down"):
        FaultPlan.from_dicts([{"step": 1, "kind": "crash", "replica": 0},
                              {"step": 2, "kind": "crash",
                               "replica": 0}]).validate(2)
    with pytest.raises(ValueError, match="already active"):
        FaultPlan.from_dicts([{"step": 1, "kind": "rejoin",
                               "replica": 0}]).validate(2)
    with pytest.raises(ValueError, match="no active"):
        FaultPlan.from_dicts([{"step": 1, "kind": "crash", "replica": 0},
                              {"step": 2, "kind": "crash",
                               "replica": 1}]).validate(2)
    with pytest.raises(ValueError, match="outside"):
        FaultPlan.from_dicts([{"step": 1, "kind": "crash",
                               "replica": 9}]).validate(2)
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(step=1, kind="meteor")
    with pytest.raises(ValueError, match="bandwidth fraction"):
        FaultEvent(step=1, kind="degrade_dcn", factor=2.0)


# ------------------------------------------------- supervisor e2e -----

def _daso_strategy(loss_fn, n_steps, R=4, loss_window=10):
    cfg = DasoConfig(n_replicas=R, global_world=4 * R, b_max=4,
                     warmup_steps=n_steps // 10,
                     cooldown_steps=n_steps // 10, total_steps=n_steps)
    return make_strategy("daso", loss_fn, sgd(momentum=0.9), cfg,
                         controller=DasoController(cfg,
                                                   loss_window=loss_window))


def test_supervisor_crash_rejoin_end_to_end():
    key = jax.random.PRNGKey(6)
    params0, loss_fn, daso_data, _ = _mlp_problem(key, R=4)
    n_steps = 40
    plan = FaultPlan.from_dicts([
        {"step": 10, "kind": "crash", "replica": 3},
        {"step": 14, "kind": "degrade_dcn", "factor": 0.25},
        {"step": 22, "kind": "restore_dcn"},
        {"step": 26, "kind": "rejoin", "replica": 3},
    ])
    strat = _daso_strategy(loss_fn, n_steps)
    ex = MacroCycleExecutor(strat)
    report = run_with_faults(strat, params0, daso_data, constant_lr(0.1),
                             n_steps, plan, executor=ex, t_compute_s=0.1,
                             exchange_cost_fn=lambda n, s: 0.05 / s)
    res = report.result
    assert len(res.losses) == n_steps
    assert np.all(np.isfinite(res.losses))
    assert res.final_loss < res.losses[0]          # it still trains
    # every membership event invalidated the compiled-cycle cache
    assert report.invalidations == 2
    assert ex.stats.invalidations == 2
    assert [mask for _, mask in report.membership_timeline] == \
        [(1.0,) * 4, (1.0, 1.0, 1.0, 0.0), (1.0,) * 4]
    assert [(e["step"], e["kind"]) for e in report.applied] == \
        [(10, "crash"), (14, "degrade_dcn"), (22, "restore_dcn"),
         (26, "rejoin")]
    # recovery cost recorded for both membership events
    assert len(report.recovery_s()) == 2
    assert all(t > 0 for t in report.recovery_s())
    # simulated clock: 40 steps of compute + degraded exchanges > fault-free
    assert report.simulated_time_s > 40 * 0.1
    # fault-free comparison run: losses should end in the same regime
    strat2 = _daso_strategy(loss_fn, n_steps)
    clean = run_with_faults(strat2, params0, daso_data, constant_lr(0.1),
                            n_steps, FaultPlan())
    assert abs(clean.result.final_loss - res.final_loss) < 0.5


def test_finalize_params_skips_dead_replica_rows():
    """Regression: with replica 0 crashed (and never rejoined), the final
    params must come from an ACTIVE replica, not row 0's frozen ghost."""
    key = jax.random.PRNGKey(8)
    params0, loss_fn, daso_data, _ = _mlp_problem(key, R=4)
    strat = _daso_strategy(loss_fn, 20)
    strat.set_membership([0.0, 1.0, 1.0, 1.0])
    carry = strat.init_carry(params0)
    # make every row distinct so the selected row is identifiable
    carry = (jax.tree.map(
        lambda x: x + jnp.arange(4.0).reshape((4,) + (1,) * (x.ndim - 1)),
        carry[0]),) + carry[1:]
    out = strat.finalize_params(carry)
    for leaf, src in zip(jax.tree.leaves(out), jax.tree.leaves(carry[0])):
        np.testing.assert_array_equal(np.asarray(leaf), np.asarray(src[1]))
    # end-to-end: crash replica 0 mid-run, no rejoin — reported params are
    # the survivors' trained state (they keep improving), not the ghost
    plan = FaultPlan.from_dicts([{"step": 8, "kind": "crash", "replica": 0}])
    strat2 = _daso_strategy(loss_fn, 40)
    rep = run_with_faults(strat2, params0, daso_data, constant_lr(0.1), 40,
                          plan)
    eval_batch = daso_data(999)
    flat = {k: v.reshape((-1,) + v.shape[2:]) for k, v in eval_batch.items()}
    final_loss = float(loss_fn(rep.result.params, flat)[0])
    init_loss = float(loss_fn(params0, flat)[0])
    assert final_loss < 0.5 * init_loss  # trained well past the early ghost


def test_supervisor_matches_plain_executor_without_faults():
    """An empty fault plan must be a no-op wrapper: identical losses and
    params to run_compiled_training."""
    from repro.core.executor import run_compiled_training

    key = jax.random.PRNGKey(7)
    params0, loss_fn, daso_data, _ = _mlp_problem(key, R=2)
    n_steps = 24
    a = _daso_strategy(loss_fn, n_steps, R=2)
    b = _daso_strategy(loss_fn, n_steps, R=2)
    rep = run_with_faults(a, params0, daso_data, constant_lr(0.1), n_steps,
                          FaultPlan())
    ref = run_compiled_training(b, params0, daso_data, constant_lr(0.1),
                                n_steps)
    np.testing.assert_allclose(np.asarray(rep.result.losses, np.float32),
                               np.asarray(ref.losses, np.float32),
                               rtol=1e-6, atol=1e-7)
    for x, y in zip(jax.tree.leaves(rep.result.params),
                    jax.tree.leaves(ref.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-6, atol=1e-7)


# ------------------------------------------- deterministic resume -----

@pytest.mark.parametrize("executor", ["macro", "per_step"])
def test_deterministic_resume_matches_uninterrupted(executor, tmp_path):
    """Acceptance: a run interrupted at step k and resumed from the
    TrainState checkpoint reproduces the uninterrupted run's losses and
    final params allclose at f32 — for both executors. (On this setup the
    match is in fact bit-exact.)"""
    key = jax.random.PRNGKey(0)
    params0, loss_fn, daso_data, _ = _mlp_problem(key)
    n_steps = 40
    base = TrainLoopConfig(strategy="daso", n_steps=n_steps, n_replicas=2,
                           loss_window=10, executor=executor)
    fresh = run_training(loss_fn, params0, daso_data, base, log=None)

    ckpt = TrainLoopConfig(**{**base.__dict__, "ckpt_every": 10,
                              "ckpt_dir": str(tmp_path)})
    run_training(loss_fn, params0, daso_data, ckpt, log=None)
    states = sorted(os.listdir(tmp_path))
    assert states, "no TrainState checkpoints written"
    mid = states[min(1, len(states) - 1)]
    k = int(mid.split("_")[1])
    assert 0 < k < n_steps

    resume = TrainLoopConfig(**{**base.__dict__,
                                "resume_from": str(tmp_path / mid)})
    resumed = run_training(loss_fn, params0, daso_data, resume, log=None)
    # full loss trace (prefix stitched from the checkpoint) matches
    np.testing.assert_allclose(np.asarray(resumed.losses, np.float32),
                               np.asarray(fresh.losses, np.float32),
                               rtol=1e-6, atol=1e-7)
    for a, b in zip(jax.tree.leaves(resumed.params),
                    jax.tree.leaves(fresh.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-6, atol=1e-7)
    # schedule-identical, not just numerically close
    assert [h[1] for h in resumed.controller.history] == \
        [h[1] for h in fresh.controller.history]
