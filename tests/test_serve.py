"""Serving-path integration: prefill + decode must match teacher-forced full
forward; ring-window caches must equal windowed full attention; the Engine
must generate deterministically."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.lm import forward, init_params
from repro.serve.engine import Engine, make_decode_fn, make_prefill_fn


def _no_drop(cfg):
    if cfg.moe is not None:
        cf = float(cfg.moe.n_experts) / cfg.moe.top_k
        return cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                   capacity_factor=cf))
    return cfg


@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen3-8b",
                                  "falcon-mamba-7b", "recurrentgemma-9b",
                                  "mixtral-8x22b", "qwen2-vl-2b",
                                  "moonshot-v1-16b-a3b", "musicgen-large"])
def test_decode_matches_teacher_forcing(arch):
    cfg = _no_drop(get_reduced(arch))
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S = 2, 32
    pref = cfg.prefix_embed_len
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    pe = (0.1 * jax.random.normal(key, (B, pref, cfg.d_model))
          if pref else None)
    full = forward(params, toks, cfg, prefix_embeds=pe)["logits"]
    S0 = S - 6
    prefill = make_prefill_fn(cfg, cache_len=S + pref)
    decode = make_decode_fn(cfg)
    st = prefill(params, toks[:, :S0], prefix_embeds=pe)
    cache, logits = st["cache"], [st["logits_last"]]
    for i in range(6):
        out = decode(params, cache, toks[:, S0 + i:S0 + i + 1],
                     jnp.asarray(pref + S0 + i, jnp.int32))
        logits.append(out["logits"])
        cache = out["cache"]
    errs = [float(jnp.max(jnp.abs(full[:, pref + S0 - 1 + i] - logits[i])))
            for i in range(6)]
    assert max(errs) < 2e-3, (arch, errs)


def test_ring_window_decode_past_window():
    """Decode far beyond the window: ring cache must equal a windowed full
    forward (positions > window wrap and evict)."""
    cfg = _no_drop(get_reduced("mixtral-8x22b")).replace(sliding_window=16)
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    B, S = 1, 48  # 3x the window
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full = forward(params, toks, cfg)["logits"]
    prefill = make_prefill_fn(cfg, cache_len=S)
    decode = make_decode_fn(cfg)
    S0 = 8  # prefill shorter than window, then decode across the boundary
    st = prefill(params, toks[:, :S0])
    cache, logits = st["cache"], [st["logits_last"]]
    for i in range(S - S0):
        out = decode(params, cache, toks[:, S0 + i:S0 + i + 1],
                     jnp.asarray(S0 + i, jnp.int32))
        logits.append(out["logits"])
        cache = out["cache"]
    errs = [float(jnp.max(jnp.abs(full[:, S0 - 1 + i] - logits[i])))
            for i in range(S - S0)]
    assert max(errs) < 2e-3, errs


def test_window_override_long_context_variant():
    """Dense arch with long_context window override: decode must equal a
    model whose attention is windowed."""
    cfg = _no_drop(get_reduced("llama3.2-1b"))
    wo = 16
    key = jax.random.PRNGKey(5)
    params = init_params(cfg, key)
    B, S = 1, 40
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full_windowed = forward(params, toks, cfg, window_override=wo)["logits"]
    prefill = make_prefill_fn(cfg, cache_len=S, window_override=wo)
    decode = make_decode_fn(cfg, window_override=wo)
    S0 = 20
    st = prefill(params, toks[:, :S0])
    cache, logits = st["cache"], [st["logits_last"]]
    for i in range(S - S0):
        out = decode(params, cache, toks[:, S0 + i:S0 + i + 1],
                     jnp.asarray(S0 + i, jnp.int32))
        logits.append(out["logits"])
        cache = out["cache"]
    errs = [float(jnp.max(jnp.abs(full_windowed[:, S0 - 1 + i] - logits[i])))
            for i in range(S - S0)]
    assert max(errs) < 2e-3, errs


def test_engine_generate_deterministic():
    cfg = _no_drop(get_reduced("llama3.2-1b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=64)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (3, 8), 0,
                                 cfg.vocab_size)
    out1 = eng.generate(prompts, max_new_tokens=8)
    out2 = eng.generate(prompts, max_new_tokens=8)
    assert out1.shape == (3, 8)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert int(out1.min()) >= 0 and int(out1.max()) < cfg.vocab_size
