"""Macro-cycle executor: numerics must match the per-step reference path
(allclose at f32), one compilation per distinct cycle shape, host dispatches
per cycling-phase cycle reduced to 1, strategy registry surface."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_mlp_problem as _mlp_problem

from repro.core.daso import DasoConfig
from repro.core.executor import (CyclePlan, MacroCycleExecutor, _group_runs,
                                 get_strategy, list_strategies, make_strategy,
                                 run_compiled_training)
from repro.core.schedule import DasoController, Mode
from repro.core.simulator import run_per_step_training
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant_lr


def _daso_cfg(n_steps, R=2, b_max=4):
    return DasoConfig(n_replicas=R, global_world=4 * R, b_max=b_max,
                      warmup_steps=n_steps // 10,
                      cooldown_steps=n_steps // 10, total_steps=n_steps)


def _make(strategy_name, loss_fn, n_steps, *, loss_window=10, R=2):
    opt = sgd(momentum=0.9, weight_decay=1e-4)
    if strategy_name == "sync":
        return make_strategy("sync", loss_fn, opt)
    dcfg = _daso_cfg(n_steps, R=R)
    return make_strategy(strategy_name, loss_fn, opt, dcfg,
                         controller=DasoController(dcfg,
                                                   loss_window=loss_window))


# ------------------------------------------------------------- equivalence --

@pytest.mark.parametrize("strategy", ["daso", "sync", "local_sgd"])
def test_executor_matches_per_step_path(strategy):
    """Same seed -> allclose params and loss trace, macro vs per-step."""
    key = jax.random.PRNGKey(0)
    params0, loss_fn, daso_data, sync_data = _mlp_problem(key)
    data = sync_data if strategy == "sync" else daso_data
    lr_fn = constant_lr(0.1)
    n_steps = 60

    macro = run_compiled_training(_make(strategy, loss_fn, n_steps),
                                  params0, data, lr_fn, n_steps)
    ref = run_per_step_training(_make(strategy, loss_fn, n_steps),
                                params0, data, lr_fn, n_steps)

    np.testing.assert_allclose(np.asarray(macro.losses, np.float32),
                               np.asarray(ref.losses, np.float32),
                               rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(macro.params),
                    jax.tree.leaves(ref.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)
    # the schedules must be literally identical, not just numerically close
    if macro.controller is not None:
        assert [h[1] for h in macro.controller.history] == \
               [h[1] for h in ref.controller.history]


def _multi_leaf_problem(key, R=2, per=8, d=6):
    """Like the shared MLP problem but with 5 parameter leaves across 2
    nested dicts, so the fused arena genuinely coalesces leaves."""
    k = jax.random.split(key, 6)
    params0 = {"emb": jax.random.normal(k[0], (d, 12)) * 0.3,
               "mlp": {"w1": jax.random.normal(k[1], (12, 8)) * 0.3,
                       "b1": jax.random.normal(k[2], (8,)) * 0.1,
                       "w2": jax.random.normal(k[3], (8, 1)) * 0.3},
               "scale": jax.random.normal(k[4], (1,)) * 0.1}
    wtrue = jax.random.normal(k[5], (d, 1))

    def loss_fn(params, batch):
        h = jnp.tanh(batch["x"] @ params["emb"])
        h = jnp.tanh(h @ params["mlp"]["w1"] + params["mlp"]["b1"])
        pred = h @ params["mlp"]["w2"] * (1.0 + params["scale"])
        return jnp.mean((pred - batch["y"]) ** 2), {}

    def daso_data(step):
        kk = jax.random.fold_in(key, step)
        x = jax.random.normal(kk, (R, per, d))
        return {"x": x, "y": jnp.tanh(x @ wtrue) * 0.5}

    return params0, loss_fn, daso_data


@pytest.mark.parametrize("wire_format", [None, "f32", "bf16"])
def test_fused_arena_training_matches_per_leaf(wire_format):
    """Acceptance: fused flat-buffer DASO training == the legacy per-leaf
    exchange path, allclose at f32, on a multi-leaf model (the arena
    coalesces 5 leaves into one buffer; numerics must not move)."""
    key = jax.random.PRNGKey(7)
    params0, loss_fn, daso_data = _multi_leaf_problem(key)
    opt = sgd(momentum=0.9, weight_decay=1e-4)
    n_steps = 40

    def run(exchange_impl):
        dcfg = DasoConfig(n_replicas=2, global_world=8, b_max=4,
                          warmup_steps=4, cooldown_steps=4,
                          total_steps=n_steps, wire_format=wire_format,
                          exchange_impl=exchange_impl)
        strat = make_strategy("daso", loss_fn, opt, dcfg,
                              controller=DasoController(dcfg,
                                                        loss_window=10))
        return run_compiled_training(strat, params0, daso_data,
                                     constant_lr(0.1), n_steps)

    fused, per_leaf = run("fused"), run("per_leaf")
    np.testing.assert_allclose(np.asarray(fused.losses, np.float32),
                               np.asarray(per_leaf.losses, np.float32),
                               rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(fused.params),
                    jax.tree.leaves(per_leaf.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)


def test_int8_wire_training_converges():
    """The beyond-paper int8 tier trains: loss stays finite and params end
    within quantization distance of the f32-wire run."""
    key = jax.random.PRNGKey(8)
    params0, loss_fn, daso_data = _multi_leaf_problem(key)
    opt = sgd(momentum=0.9, weight_decay=1e-4)
    n_steps = 24

    def run(wire_format):
        dcfg = DasoConfig(n_replicas=2, global_world=8, b_max=4,
                          warmup_steps=4, cooldown_steps=4,
                          total_steps=n_steps, wire_format=wire_format)
        strat = make_strategy("daso", loss_fn, opt, dcfg,
                              controller=DasoController(dcfg,
                                                        loss_window=10**9))
        return run_compiled_training(strat, params0, daso_data,
                                     constant_lr(0.1), n_steps)

    i8, f32 = run("int8"), run("f32")
    assert np.all(np.isfinite(i8.losses))
    assert i8.final_loss < i8.losses[0]  # it actually trains
    gap = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(i8.params),
                              jax.tree.leaves(f32.params)))
    assert gap < 0.05  # small quantization drift, not divergence


def test_executor_params0_not_consumed():
    """Donation must never eat the caller's params0 (regression: the carry
    used to alias it)."""
    key = jax.random.PRNGKey(3)
    params0, loss_fn, _, sync_data = _mlp_problem(key)
    lr_fn = constant_lr(0.1)
    before = float(jnp.sum(jnp.abs(params0["w1"])))
    run_compiled_training(_make("sync", loss_fn, 20), params0, sync_data,
                          lr_fn, 20)
    # still alive, readable, and untouched by the donated training run
    assert float(jnp.sum(jnp.abs(params0["w1"]))) == before


# ------------------------------------------------------ dispatch reduction --

def test_cycling_phase_one_dispatch_per_cycle():
    """In the cycling phase a B=4 cycle (send, receive, local, local) is one
    host dispatch instead of B+1 step-wise launches."""
    key = jax.random.PRNGKey(1)
    params0, loss_fn, daso_data, _ = _mlp_problem(key)
    n_steps = 40
    opt = sgd(momentum=0.9, weight_decay=1e-4)
    # no warm-up/cool-down: pure cycling, huge window so B/W never move
    dcfg = DasoConfig(n_replicas=2, global_world=8, b_max=4)
    strat = make_strategy("daso", loss_fn, opt, dcfg,
                          controller=DasoController(dcfg, loss_window=10**9))
    ex = MacroCycleExecutor(strat)
    res = run_compiled_training(strat, params0, daso_data,
                                constant_lr(0.1), n_steps, executor=ex)
    assert ex.stats.steps + ex.stats.fallback_steps == n_steps
    # 40 steps of (send, receive, local, local) = 10 cycles -> 10 dispatches
    assert ex.stats.cycles == n_steps // 4
    assert ex.stats.dispatches == ex.stats.cycles
    assert res.executor_stats.dispatches_per_step() == pytest.approx(0.25)


def test_compile_cache_one_program_per_shape():
    """Distinct cycle shapes compile once each; repeats hit the cache."""
    key = jax.random.PRNGKey(2)
    params0, loss_fn, daso_data, _ = _mlp_problem(key)
    n_steps = 80
    strat = _make("daso", loss_fn, n_steps, loss_window=10)
    ex = MacroCycleExecutor(strat, tail_fallback=False)
    run_compiled_training(strat, params0, daso_data, constant_lr(0.1),
                          n_steps, executor=ex)
    shapes = set(ex.cached_shapes)
    assert ex.stats.compiles == len(shapes)
    # the schedule repeats cycles, so caching must actually dedupe
    assert ex.stats.cycles > len(shapes)


def test_tail_fallback_avoids_single_use_compile():
    """A final partial cycle with an unseen shape runs per-step instead of
    paying a compilation for one use."""
    key = jax.random.PRNGKey(4)
    params0, loss_fn, daso_data, _ = _mlp_problem(key)
    opt = sgd(momentum=0.9, weight_decay=1e-4)
    dcfg = DasoConfig(n_replicas=2, global_world=8, b_max=4)
    strat = make_strategy("daso", loss_fn, opt, dcfg,
                          controller=DasoController(dcfg, loss_window=10**9))
    ex = MacroCycleExecutor(strat)
    n_steps = 42  # 10 full cycles of 4 + irregular 2-step tail
    run_compiled_training(strat, params0, daso_data, constant_lr(0.1),
                          n_steps, executor=ex)
    assert ex.stats.fallback_steps == 2
    shapes = set(ex.cached_shapes)
    assert all(len(s) == 4 for s in shapes)


# ------------------------------------------------------------ plan/registry --

def test_controller_plan_matches_mode_for_step():
    """plan_cycle must consume exactly the sequence mode_for_step yields."""
    dcfg = DasoConfig(n_replicas=4, global_world=16, b_max=4,
                      warmup_steps=6, cooldown_steps=6, total_steps=60)
    a = DasoController(dcfg, loss_window=10**9)
    b = DasoController(dcfg, loss_window=10**9)
    planned = []
    step = 0
    while step < 60:
        shape = a.plan_cycle(step, max_len=min(32, 60 - step))
        assert shape, "empty plan"
        planned.extend(shape)
        step += len(shape)
    stepwise = [b.mode_for_step(t) for t in range(60)]
    assert planned == stepwise
    assert a.history == b.history


def test_plan_respects_loss_window_boundary():
    """Cycles never span a plateau-window edge, so observe_loss feedback
    lands between compiled cycles exactly as on the per-step path."""
    dcfg = DasoConfig(n_replicas=4, global_world=16, b_max=8)
    c = DasoController(dcfg, loss_window=5)
    c.observe_loss(1.0)
    c.observe_loss(1.0)  # 3 slots left in the window
    shape = c.plan_cycle(0, max_len=32)
    assert len(shape) <= 3


def test_group_runs():
    shape = (("send", 1), ("receive", 1), ("local", 1), ("local", 1))
    assert _group_runs(shape) == [("send", 1, 0, 1), ("receive", 1, 1, 1),
                                  ("local", 1, 2, 2)]


def test_registry_surface():
    assert set(list_strategies()) >= {"daso", "sync", "local_sgd"}
    assert get_strategy("daso").name == "daso"
    with pytest.raises(KeyError):
        get_strategy("nope")


def test_local_sgd_plan_shape():
    key = jax.random.PRNGKey(5)
    _, loss_fn, _, _ = _mlp_problem(key)
    strat = _make("local_sgd", loss_fn, 40)
    plan = strat.plan_cycle(0, 32)
    assert isinstance(plan, CyclePlan)
    assert plan.shape[0][0] == Mode.HARD_AVG
    assert all(m == Mode.LOCAL for m, _ in plan.shape[1:])
    assert len(plan) == 4
