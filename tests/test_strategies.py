"""Cross-strategy conformance suite (ISSUE 9 headline artifact).

One parameterized battery over EVERY registered strategy — the paper's
daso family plus the baseline expansion (core/baselines.py: gossip /
easgd / downpour) — so any future strategy inherits the full test
surface by registering:

  * macro-cycle executor == per-step reference path (losses, params,
    mode history);
  * checkpoint save/resume is bit-exact with the uninterrupted run
    (TrainState round-trips each strategy's carry layout + controller);
  * membership-mask fault plans run through the resilience supervisor
    (crash + rejoin; cache invalidations; membership timeline);
  * one-collective-or-zero HLO contract on a replica-sharded mesh:
    exchange steps lower to exactly one parameter-scale all-reduce over
    the replica axis — except gossip, whose pairwise exchange must
    contain NO all-reduce (data movement only);
  * 2-process SPMD runs are bit-exact with the 1-process oracle
    (gossip in tier-1; easgd/downpour on the nightly/slow tier).

Plus the satellite property tests (gossip mean preservation, EASGD
closed-form center) and the get_strategy error-path regression.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import make_mlp_problem, subprocess_env
from repro.core.baselines import gossip_mix
from repro.core.daso import DasoConfig
from repro.core.executor import (get_strategy, list_strategies,
                                 make_strategy, run_compiled_training)
from repro.core.simulator import run_per_step_training
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant_lr

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
LAUNCHER = os.path.join(REPO, "tools", "launch_procs.py")

ALL = ("sync", "daso", "local_sgd", "gossip", "easgd", "downpour")
REPLICA = tuple(s for s in ALL if s != "sync")
NEW = ("gossip", "easgd", "downpour")


def test_every_registered_strategy_is_covered():
    """The battery's strategy list IS the registry (minus hier_daso,
    which needs a topology spec and has its own suite in
    test_topology.py). A strategy registered without joining ALL fails
    here, so the conformance surface cannot silently shrink."""
    import repro.topo.strategy  # noqa: F401  (registers "hier_daso")
    assert set(list_strategies()) - {"hier_daso"} == set(ALL)


def _cfg(n_steps, R=2, b_max=4, **kw):
    return DasoConfig(n_replicas=R, global_world=4 * R, b_max=b_max,
                      warmup_steps=n_steps // 10,
                      cooldown_steps=n_steps // 10,
                      total_steps=n_steps, **kw)


def _make(name, loss_fn, n_steps, *, R=2, loss_window=10, **cfg_kw):
    opt = sgd(momentum=0.9, weight_decay=1e-4)
    if name == "sync":
        return make_strategy("sync", loss_fn, opt)
    cfg = _cfg(n_steps, R=R, **cfg_kw)
    cls = get_strategy(name)
    return make_strategy(name, loss_fn, opt, cfg,
                         controller=cls.make_controller(
                             cfg, loss_window=loss_window))


# ------------------------------------------------ macro == per-step ----------

@pytest.mark.parametrize("name", ALL)
def test_macro_matches_per_step(name):
    n_steps = 30
    key = jax.random.PRNGKey(0)
    params0, loss_fn, daso_data, sync_data = make_mlp_problem(key)
    data_fn = sync_data if name == "sync" else daso_data

    macro = _make(name, loss_fn, n_steps)
    ref = _make(name, loss_fn, n_steps)
    rm = run_compiled_training(macro, params0, data_fn, constant_lr(0.1),
                               n_steps)
    rp = run_per_step_training(ref, params0, data_fn, constant_lr(0.1),
                               n_steps)
    assert len(rm.losses) == len(rp.losses) == n_steps
    np.testing.assert_allclose(rm.losses, rp.losses, rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(rm.params), jax.tree.leaves(rp.params)):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=1e-6)
    if macro.controller is not None:
        assert ([h[1] for h in macro.controller.history]
                == [h[1] for h in ref.controller.history])


@pytest.mark.parametrize("name", NEW)
def test_new_strategies_schedule_shape(name):
    """The periodic schedule: blocking warm-up/cool-down, one exchange
    token every B cycling steps, locals between — and gossip's partner
    shift rotates between exchanges."""
    n_steps = 40
    key = jax.random.PRNGKey(1)
    params0, loss_fn, daso_data, _ = make_mlp_problem(key, R=4)
    strat = _make(name, loss_fn, n_steps, R=4)
    run_compiled_training(strat, params0, daso_data, constant_lr(0.05),
                          n_steps)
    modes = [h[1] for h in strat.controller.history]
    warm = n_steps // 10
    assert modes[:warm] == ["blocking"] * warm
    assert modes[-warm:] == ["blocking"] * warm
    cycling = modes[warm:-warm]
    token = {"gossip": "gossip~", "easgd": "elastic",
             "downpour": "push"}[name]
    exchanges = [m for m in cycling if m.startswith(token)]
    assert exchanges, cycling
    assert all(m.startswith(token) or m == "local" for m in cycling)
    # B=4 periodicity: exchange every 4th cycling step
    assert [m.startswith(token) for m in cycling[:8]] \
        == [True, False, False, False] * 2
    if name == "gossip":
        # R=4: shifts rotate 1,2,3,1,... so the ring mixes globally
        shifts = [int(m.split("~")[1]) for m in exchanges]
        assert shifts[:3] == [1, 2, 3]
    assert 0.0 < strat.sync_fraction() < 1.0


# ------------------------------------------------ checkpoint resume ----------

@pytest.mark.parametrize("name", ALL)
def test_checkpoint_resume_bit_exact(name, tmp_path):
    from repro.train.loop import TrainLoopConfig, run_training

    n_steps = 24
    key = jax.random.PRNGKey(2)
    params0, loss_fn, daso_data, sync_data = make_mlp_problem(key)
    data_fn = sync_data if name == "sync" else daso_data

    def loop_cfg(**kw):
        return TrainLoopConfig(strategy=name, n_steps=n_steps, n_replicas=2,
                               local_world=2, b_max=4, lr=0.1,
                               loss_window=10, **kw)

    full = run_training(loss_fn, params0, data_fn, loop_cfg(), log=None)
    ck = run_training(loss_fn, params0, data_fn,
                      loop_cfg(ckpt_every=8, ckpt_dir=str(tmp_path)),
                      log=None)
    assert full.losses == ck.losses
    saved = sorted(os.listdir(tmp_path))
    assert saved, "no checkpoint written"
    resumed = run_training(
        loss_fn, params0, data_fn,
        loop_cfg(resume_from=str(tmp_path / saved[0])), log=None)
    # bit-exact: the resumed run replays the identical schedule + numerics
    assert resumed.losses == full.losses
    for a, b in zip(jax.tree.leaves(resumed.params),
                    jax.tree.leaves(full.params)):
        np.testing.assert_array_equal(a, b)


def test_checkpoint_rejects_strategy_mismatch(tmp_path):
    from repro.train.loop import TrainLoopConfig, run_training

    key = jax.random.PRNGKey(3)
    params0, loss_fn, daso_data, _ = make_mlp_problem(key)
    cfg = TrainLoopConfig(strategy="gossip", n_steps=12, n_replicas=2,
                          local_world=2, ckpt_every=4,
                          ckpt_dir=str(tmp_path))
    run_training(loss_fn, params0, daso_data, cfg, log=None)
    saved = sorted(os.listdir(tmp_path))[0]
    bad = TrainLoopConfig(strategy="easgd", n_steps=12, n_replicas=2,
                          local_world=2, resume_from=str(tmp_path / saved))
    with pytest.raises(ValueError, match="gossip"):
        run_training(loss_fn, params0, daso_data, bad, log=None)


# ------------------------------------------------ fault plans ----------------

@pytest.mark.parametrize("name", REPLICA)
def test_fault_plan_crash_rejoin(name):
    from repro.resilience.faults import FaultPlan
    from repro.resilience.supervisor import run_with_faults

    n_steps = 32
    key = jax.random.PRNGKey(4)
    params0, loss_fn, daso_data, _ = make_mlp_problem(key, R=4)
    strat = _make(name, loss_fn, n_steps, R=4)
    plan = FaultPlan.from_dicts([
        {"step": 8, "kind": "crash", "replica": 3},
        {"step": 16, "kind": "rejoin", "replica": 3}])
    report = run_with_faults(strat, params0, daso_data, constant_lr(0.05),
                             n_steps, plan)
    assert len(report.result.losses) == n_steps
    assert np.all(np.isfinite(report.result.losses))
    assert report.invalidations == 2
    masks = [m for (_, m) in report.membership_timeline]
    assert masks == [(1.0, 1.0, 1.0, 1.0), (1.0, 1.0, 1.0, 0.0),
                     (1.0, 1.0, 1.0, 1.0)]
    # the final params come from an ACTIVE replica and are finite
    for leaf in jax.tree.leaves(report.result.params):
        assert np.all(np.isfinite(leaf))


def test_fault_plan_rejects_sync():
    from repro.resilience.faults import FaultPlan
    from repro.resilience.supervisor import run_with_faults

    key = jax.random.PRNGKey(5)
    params0, loss_fn, _, sync_data = make_mlp_problem(key)
    strat = _make("sync", loss_fn, 8)
    plan = FaultPlan()
    with pytest.raises(ValueError, match="replica-axis"):
        run_with_faults(strat, params0, sync_data, constant_lr(0.05), 8,
                        plan)


# ------------------------------------------------ HLO contract ---------------

_HLO_SCRIPT = """
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.daso import DasoConfig
from repro.core.executor import get_strategy, make_strategy
from repro.launch.hlo_stats import collective_stats
from repro.optim.optimizers import sgd

def loss_fn(params, batch):
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2), {}

mesh = jax.make_mesh((2,), ("pod",))
mesh_shape = {"pod": 2}
R, per, d = 2, 4, 256   # w: 256x4 f32 = 4 KiB >> the 1 KiB floor
cfg = DasoConfig(n_replicas=R, global_world=4 * R, b_max=4,
                 warmup_steps=2, cooldown_steps=2, total_steps=20)
opt = sgd(momentum=0.9, weight_decay=1e-4)
key = jax.random.PRNGKey(0)
params0 = {"w": jax.random.normal(key, (d, 4)) * 0.1}
shp = NamedSharding(mesh, P("pod"))
sc = NamedSharding(mesh, P())
batch = {"x": jax.device_put(jnp.ones((R, per, d)), shp),
         "y": jax.device_put(jnp.ones((R, per, 4)), shp)}
lr = jnp.asarray(0.1)

CASES = [("daso", "local", 0), ("daso", "send", 1), ("daso", "blocking", 1),
         ("local_sgd", "hard_avg", 1),
         ("gossip", "local", 0), ("gossip", "gossip~1", 0),
         ("gossip", "blocking", 1),
         ("easgd", "elastic", 1), ("easgd", "blocking", 1),
         ("downpour", "push", 1), ("downpour", "blocking", 1)]

out = []
for name, mode, want_ar in CASES:
    cls = get_strategy(name)
    strat = make_strategy(name, loss_fn, opt, cfg,
                          controller=cls.make_controller(cfg))
    carry = jax.device_put(strat.init_carry(params0),
                           jax.tree.map(lambda _: shp, strat.init_carry(
                               params0)))
    step = strat.step_fn(mode, 1)
    shardings = (jax.tree.map(lambda _: shp, carry),
                 {"x": shp, "y": shp}, sc)
    lowered = jax.jit(step, in_shardings=shardings).lower(carry, batch, lr)
    stats = collective_stats(lowered.compile().as_text(), mesh_shape,
                             min_bytes=1024)
    ar = sum(v["count"] for k, v in stats.items()
             if k.startswith("all-reduce@") and isinstance(v, dict))
    total = stats["_total_count"]
    out.append({"strategy": name, "mode": mode, "want_ar": want_ar,
                "all_reduce": ar, "total": total})
print("VERDICTS " + json.dumps(out))
"""


def test_hlo_one_collective_or_zero():
    """Every exchange step compiles to exactly ONE parameter-scale
    all-reduce over the replica axis; local steps to zero; gossip's
    pairwise exchange to zero all-reduces (its partner copy is data
    movement — permute/gather family — never a reduction)."""
    env = dict(os.environ)
    env.update(subprocess_env(devices=2))
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c",
                        textwrap.dedent(_HLO_SCRIPT)],
                       capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("VERDICTS ")][0]
    verdicts = json.loads(line[len("VERDICTS "):])
    assert len(verdicts) == 11
    for v in verdicts:
        assert v["all_reduce"] == v["want_ar"], v
        if v["mode"] == "gossip~1":
            # the exchange still moves parameter-scale data across the
            # replica axis — just not through a reduction
            assert v["total"] >= 1, v
        if v["mode"] == "local":
            assert v["total"] == 0, v


# ------------------------------------------------ 2-proc SPMD ----------------

def _launch_equivalence(tmp_path, name, steps=14):
    """N-process vs 1-process bit-exactness through the real launcher,
    2-level topology (R=2 replicas, one per process)."""
    base = ["--arch", "llama3.2-1b", "--tiny",
            "--topology", "chip:1 x host:2", "--per-node-batch", "2",
            "--seq-len", "16", "--b-max", "4", "--seed", "0",
            "--strategy", name, "--steps", str(steps)]
    out = {}
    for n in (1, 2):
        m = str(tmp_path / f"metrics_{name}_{n}.json")
        cmd = [sys.executable, LAUNCHER, "--procs", str(n),
               "--timeout", "600", "--"] + base + ["--metrics-out", m]
        env = subprocess_env(devices=1)
        env.pop("XLA_FLAGS")  # the harness sets per-child device counts
        r = subprocess.run(cmd, capture_output=True, text=True, timeout=660,
                           env=env, cwd=REPO)
        assert r.returncode == 0, (f"{name} procs={n} failed:\n"
                                   f"{r.stdout}\n{r.stderr}")
        with open(m) as f:
            out[n] = json.load(f)
    assert out[1]["losses"] == out[2]["losses"], (
        f"{name}: loss traces diverge between process layouts")
    assert out[1]["final_loss"] == out[2]["final_loss"]
    assert out[1]["sync_fraction"] == out[2]["sync_fraction"]


def test_two_process_gossip_bit_exact(tmp_path):
    """Gossip has no reduction at all, so layout invariance needs no
    deterministic-reduce fallback — the strongest SPMD check of the
    family, kept in tier-1."""
    _launch_equivalence(tmp_path, "gossip")


@pytest.mark.slow
@pytest.mark.parametrize("name", ["easgd", "downpour"])
def test_two_process_baseline_bit_exact(name, tmp_path):
    """EASGD / DOWNPOUR exchanges are masked all-reduces pinned by
    deterministic_reduce on distributed runs. @slow: tier-1 keeps the
    gossip flagship; CI's strategy-matrix and nightly lanes run these."""
    _launch_equivalence(tmp_path, name, steps=12)


# ------------------------------------------------ property tests -------------

@settings(max_examples=20, deadline=None)
@given(r=st.integers(2, 5), n_rounds=st.integers(1, 8), seed=st.integers(0, 99))
def test_gossip_preserves_global_mean(r, n_rounds, seed):
    """Satellite: pairwise gossip preserves the exact global parameter
    mean across ANY permutation (shift) schedule. Dyadic-rational inputs
    (eighths) keep every f32 add/halve exact, so the mean is compared
    bit-exactly in f64."""
    rng = np.random.default_rng(seed)
    shifts = rng.integers(1, r, size=n_rounds)
    tree = {"w": jnp.asarray(rng.integers(-64, 64, size=(r, 5, 3)),
                             jnp.float32) / 8.0,
            "b": jnp.asarray(rng.integers(-64, 64, size=(r, 7)),
                             jnp.float32) / 8.0}
    want = {k: np.mean(np.asarray(v, np.float64), axis=0)
            for k, v in tree.items()}
    for s in shifts:
        tree = gossip_mix(tree, shift=int(s), wire_format="f32")
    got = {k: np.mean(np.asarray(v, np.float64), axis=0)
           for k, v in tree.items()}
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])


@settings(max_examples=10, deadline=None)
@given(alpha=st.sampled_from([0.25, 0.125, 0.0625]),
       b_max=st.integers(1, 4),
       grad=st.sampled_from([0.5, -0.25, 1.5]))
def test_easgd_center_closed_form(alpha, b_max, grad):
    """Satellite: for a constant gradient, EASGD's center equals the
    closed-form moving-average recursion, bit-exactly. R=2 with identical
    replica rows makes the masked mean exact ((x+x)/2 == x), so a scalar
    np.float32 mirror of the step builder's arithmetic reproduces params
    and center to the last bit."""
    R, n_steps, lr = 2, 16, 0.25
    cfg = DasoConfig(n_replicas=R, global_world=4 * R, b_max=b_max,
                     warmup_steps=0, cooldown_steps=0, total_steps=n_steps,
                     wire_format="f32")

    def loss_fn(params, batch):
        # d(loss)/dw = grad, constant in w
        return jnp.sum(params["w"]) * grad, {}

    cls = get_strategy("easgd")
    strat = make_strategy("easgd", loss_fn,
                          sgd(momentum=0.0, weight_decay=0.0), cfg,
                          alpha=alpha, controller=cls.make_controller(cfg))
    params0 = {"w": jnp.asarray([1.0], jnp.float32)}
    carry = strat.init_carry(params0)
    batch = {"x": jnp.zeros((R, 1, 1))}
    for t in range(n_steps):
        mode, stale = strat.next_mode(t)
        carry, _ = strat.step_fn(mode, stale)(carry, batch,
                                              jnp.asarray(lr, jnp.float32))

    # scalar f32 mirror (rows are identical, so mean == row value)
    a32, beta32 = np.float32(alpha), np.float32(alpha * R)
    p = c = np.float32(1.0)
    g, lr32 = np.float32(grad), np.float32(lr)
    last_ex = -10 ** 9
    for t in range(n_steps):
        p = np.float32(p - lr32 * g)
        if t - last_ex >= b_max:  # PeriodicController's B-spacing rule
            last_ex = t
            m = p
            p = np.float32((np.float32(1.0) - a32) * p + a32 * c)
            c = np.float32((np.float32(1.0) - beta32) * c + beta32 * m)
    params_rows, _, center_rows = carry
    np.testing.assert_array_equal(
        np.asarray(params_rows["w"]), np.full((R, 1), p, np.float32))
    np.testing.assert_array_equal(
        np.asarray(center_rows["w"]), np.full((R, 1), c, np.float32))


# ------------------------------------------------ error path -----------------

def test_get_strategy_suggests_closest():
    """Satellite regression: the KeyError lists the registered names
    sorted and suggests the closest match."""
    with pytest.raises(KeyError) as ei:
        get_strategy("gosip")
    msg = str(ei.value)
    assert str(sorted(list_strategies())) in msg
    assert "did you mean 'gossip'?" in msg
    with pytest.raises(KeyError) as ei:
        get_strategy("qqqqqq")
    assert "did you mean" not in str(ei.value)
    # list_strategies stays the sorted registry view
    assert list_strategies() == sorted(list_strategies())


def test_new_strategies_reject_overlap_and_tiny_worlds():
    key = jax.random.PRNGKey(6)
    _, loss_fn, _, _ = make_mlp_problem(key)
    opt = sgd()
    cfg = DasoConfig(n_replicas=2, global_world=8, b_max=4, overlap="one_cycle")
    for name in NEW:
        with pytest.raises(ValueError, match="overlap"):
            make_strategy(name, loss_fn, opt, cfg)
    cfg1 = DasoConfig(n_replicas=1, global_world=4, b_max=4)
    for name in NEW:
        with pytest.raises(ValueError, match="n_replicas"):
            make_strategy(name, loss_fn, opt, cfg1)
    with pytest.raises(ValueError, match="alpha"):
        make_strategy("easgd", loss_fn, opt,
                      DasoConfig(n_replicas=4, global_world=16, b_max=4),
                      alpha=0.5)
