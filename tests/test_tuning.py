"""Self-tuning topology (src/repro/topo/probe.py + controller.retune +
group reshuffling): probe determinism under deterministic reduction, the
retune no-op contract (measured == annotated must change NOTHING, down to
bit-exact training), straggler-aware reshuffle invariants (exact global
mean under any permutation; skew-sorting never increases inner-barrier
wait), checkpoint persistence of tuned periods (TrainState v3, v2 loads
as static), and the supervisor end-to-end acceptance: an injected DCN
degradation is discovered by probing and retuned within K cycles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_mlp_problem
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint.io import (TRAIN_STATE_VERSION, TrainState,
                                 load_train_state, save_train_state)
from repro.core.daso import level_group_mean, normalize_group_perm
from repro.core.executor import MacroCycleExecutor
from repro.core.schedule import HierDasoController
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant_lr
from repro.resilience.faults import FaultPlan
from repro.resilience.runtime import heartbeat_skew
from repro.resilience.supervisor import run_with_faults
from repro.topo import (TopologySpec, build_topology_strategy,
                        daso_config_from, derive_inner_periods,
                        make_controller)
from repro.topo import probe
from repro.topo.strategy import HierDasoStrategy

SPEC3 = TopologySpec.parse("chip:2 x host:2@50e9 x pod:2@25e9")  # R = 4


# ------------------------------------------------------------- probe --

def test_active_probe_deterministic_checksums():
    """Two probe rounds under deterministic reduction produce bit-identical
    reduction checksums — the probe never perturbs numerics, only timing."""
    a = probe.active_probe(SPEC3, rounds=2, deterministic=True)
    b = probe.active_probe(SPEC3, rounds=2, deterministic=True)
    assert a.checksums == b.checksums
    assert set(a.costs) == set(b.costs)
    assert all(t > 0 for t in a.costs.values())
    # targets: every non-degenerate inner level plus the outer key
    assert set(a.costs) == {"host", probe.OUTER_KEY}


def test_annotated_costs_are_pure_bandwidth():
    costs = probe.annotated_level_costs(SPEC3, param_bytes=100e9)
    assert costs["host"] == pytest.approx(100e9 / 50e9)
    assert costs[probe.OUTER_KEY] == pytest.approx(100e9 / 25e9)


@pytest.mark.parametrize("topo_str", [
    "chip:4 x pod:2",
    "chip:2 x host:2@50e9 x pod:2@25e9",
    "chip:2 x host:2@600e9 x rack:2@50e9 x pod:2@25e9",
])
def test_retuned_periods_identity_on_annotated_costs(topo_str):
    """The no-op invariant: re-deriving periods from the spec's own
    annotated costs reproduces the static lowering exactly."""
    spec = TopologySpec.parse(topo_str)
    costs = probe.annotated_level_costs(spec)
    assert probe.derive_retuned_periods(spec, costs) == \
        derive_inner_periods(spec)


# ------------------------------------------------------------ retune --

def _hier_controller(spec=SPEC3, total_steps=64):
    cfg = daso_config_from(spec, total_steps=total_steps)
    ctl = make_controller(spec, cfg, loss_window=10 ** 9)
    assert isinstance(ctl, HierDasoController)
    return ctl


def test_retune_noop_when_measured_matches_annotated():
    """measured == annotated changes nothing: same b/w, same periods, no
    events, retune returns False."""
    ctl = _hier_controller()
    ann = probe.annotated_level_costs(SPEC3)
    before = (ctl.b, ctl.w, dict(ctl.inner_periods), list(ctl.events))
    assert ctl.retune(dict(ann), annotated=ann) is False
    assert (ctl.b, ctl.w, dict(ctl.inner_periods), list(ctl.events)) == before


def test_retune_slow_outer_stretches_b_and_logs_event():
    ctl = _hier_controller()
    b0 = ctl.b
    ann = probe.annotated_level_costs(SPEC3)
    meas = dict(ann)
    meas[probe.OUTER_KEY] = ann[probe.OUTER_KEY] * 4.0  # DCN 4x slower
    assert ctl.retune(meas, annotated=ann, step=8) is True
    assert ctl.b > b0
    kinds = [k for (_, k, _) in ctl.events]
    assert "retune" in kinds and "dcn_scale" in kinds


def test_retune_rederives_inner_periods_from_cost_ratio():
    """An inner level measured faster relative to the outer gets a longer
    period (it can afford to sync more often per outer exchange — B_l
    tracks b_max * t_l / t_outer)."""
    ctl = _hier_controller()
    assert ctl.inner_periods == {"host": 2}  # static lowering at 50/25 GB/s
    ann = probe.annotated_level_costs(SPEC3)
    meas = dict(ann)
    meas["host"] = ann["host"] / 2.0      # host link measured 2x faster
    meas[probe.OUTER_KEY] = ann[probe.OUTER_KEY] * 2.0  # outer 2x slower
    assert ctl.retune(meas, annotated=ann, step=4) is True
    assert ctl.inner_periods["host"] == 1  # t_l/t_outer shrank 4x -> B_l=1
    assert ("retune_periods" in [k for (_, k, _) in ctl.events])


def test_retune_respects_pinned_periods():
    """An explicit `@period` annotation in the spec is an operator override
    the tuner must not fight."""
    spec = TopologySpec.parse("chip:2 x host:2@50e9%2 x pod:2@25e9")
    cfg = daso_config_from(spec, total_steps=64)
    ctl = make_controller(spec, cfg, loss_window=10 ** 9)
    assert ctl.pinned_periods == ("host",)
    ann = probe.annotated_level_costs(spec)
    meas = dict(ann)
    meas["host"] = ann["host"] / 8.0
    meas[probe.OUTER_KEY] = ann[probe.OUTER_KEY] * 2.0
    ctl.retune(meas, annotated=ann, step=4)
    assert ctl.inner_periods["host"] == 2  # pinned, untouched


# -------------------------------------------------------- reshuffle --

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10 ** 6), group_size=st.sampled_from([2, 4]),
       masked=st.booleans())
def test_permuted_group_mean_preserves_global_mean(seed, group_size, masked):
    """Property: for ANY regrouping permutation, the per-group mean
    preserves the exact global (membership-weighted) mean — groups
    partition the rows, and each group mean preserves its own sum."""
    R = 8
    rng = np.random.default_rng(seed)
    perm = tuple(int(i) for i in rng.permutation(R))
    tree = {"w": jnp.asarray(rng.normal(size=(R, 5)), jnp.float32)}
    mask = tuple(1.0 if (not masked or i != 3) else 0.0 for i in range(R))
    out = level_group_mean(tree, group_size, mask=mask, deterministic=True,
                           perm=perm)
    ref = level_group_mean(tree, group_size, mask=mask, deterministic=True)
    w_in = np.asarray(tree["w"], np.float64)
    m = np.asarray(mask, np.float64)[:, None]
    want = (w_in * m).sum(0) / m.sum()
    for got in (out, ref):
        g = np.asarray(got["w"], np.float64)
        np.testing.assert_allclose((g * m).sum(0) / m.sum(), want,
                                   rtol=1e-6, atol=1e-6)


def test_permuted_group_mean_matches_permute_then_mean_oracle():
    """slot-order semantics: permute rows -> contiguous group mean ->
    inverse-permute equals the fused path bit-for-bit."""
    R, g = 8, 2
    rng = np.random.default_rng(0)
    perm = (3, 0, 6, 1, 7, 2, 5, 4)
    x = jnp.asarray(rng.normal(size=(R, 4, 3)), jnp.float32)
    out = level_group_mean({"w": x}, g, deterministic=True, perm=perm)["w"]
    xp = np.asarray(x)[list(perm)]
    mp = xp.reshape(R // g, g, 4, 3).mean(1, keepdims=True)
    mp = np.broadcast_to(mp, (R // g, g, 4, 3)).reshape(R, 4, 3)
    inv = np.argsort(perm)
    np.testing.assert_array_equal(np.asarray(out), mp[inv])


def test_identity_perm_normalizes_to_fast_path():
    assert normalize_group_perm((0, 1, 2, 3), 4) is None
    assert normalize_group_perm(None, 4) is None
    with pytest.raises(ValueError):
        normalize_group_perm((0, 0, 1, 2), 4)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_skew_permutation_never_increases_wasted_wait(seed):
    """Property: sorting replicas by slowdown into groups can only shrink
    the inner-barrier wait (like waits with like)."""
    rng = np.random.default_rng(seed)
    R, g = 8, 2
    slow = [float(s) for s in rng.uniform(1.0, 3.0, size=R)]
    mask = [1.0] * R
    perm = probe.skew_permutation(slow)
    before = probe.wasted_wait_s(slow, mask, g, None, 1.0)
    after = probe.wasted_wait_s(slow, mask, g, perm, 1.0)
    assert after <= before + 1e-9


def test_heartbeat_skew_normalizes_to_fastest():
    before = {0: {"step": 0, "t": 0.0}, 1: {"step": 0, "t": 0.0}}
    after = {0: {"step": 10, "t": 1.0}, 1: {"step": 5, "t": 1.0}}
    skew = heartbeat_skew(before, after)
    assert skew[0] == pytest.approx(1.0)   # fastest
    assert skew[1] == pytest.approx(2.0)   # half the rate -> 2x slowdown


# ------------------------------------------------------ persistence --

def test_controller_state_dict_persists_tuned_periods():
    ctl = _hier_controller()
    ann = probe.annotated_level_costs(SPEC3)
    meas = dict(ann)
    meas["host"] = ann["host"] / 2.0
    meas[probe.OUTER_KEY] = ann[probe.OUTER_KEY] * 2.0
    ctl.retune(meas, annotated=ann, step=4)
    tuned = dict(ctl.inner_periods)
    sd = ctl.state_dict()
    assert sd["inner_periods"] == tuned
    fresh = _hier_controller()
    fresh.load_state_dict(sd)
    assert fresh.inner_periods == tuned
    for t in range(4, 24):
        assert fresh.mode_for_step(t) == ctl.mode_for_step(t)
    # v2 dict (no inner_periods key) loads as static: lowered defaults stand
    sd_v2 = {k: v for k, v in sd.items() if k != "inner_periods"}
    legacy = _hier_controller()
    legacy.load_state_dict(sd_v2)
    assert legacy.inner_periods == {"host": 2}


def test_train_state_resume_restores_tuned_periods(tmp_path):
    """Satellite fix: load_train_state mid-retune must hand back the TUNED
    periods, not the static lowering — and the round-trip is exact."""
    ctl = _hier_controller()
    ann = probe.annotated_level_costs(SPEC3)
    meas = dict(ann)
    meas[probe.OUTER_KEY] = ann[probe.OUTER_KEY] * 4.0
    meas["host"] = ann["host"] / 2.0
    ctl.retune(meas, annotated=ann, step=8)
    carry = ({"w": jnp.ones((4, 3))},)
    state = TrainState(step=8, carry=carry, controller=ctl.state_dict(),
                       membership=[1.0] * 4, strategy="hier_daso")
    save_train_state(str(tmp_path), state)
    loaded = load_train_state(str(tmp_path))
    assert loaded.version == TRAIN_STATE_VERSION >= 3
    resumed = _hier_controller()
    resumed.load_state_dict(loaded.controller)
    assert resumed.inner_periods == ctl.inner_periods
    assert (resumed.b, resumed.w) == (ctl.b, ctl.w)
    assert resumed.state_dict() == ctl.state_dict()


# -------------------------------------------------- supervisor e2e --

def _hier_problem(key, n_steps, spec=SPEC3):
    params0, loss_fn, daso_data, _ = make_mlp_problem(key, R=spec.n_replicas)
    cfg = daso_config_from(spec, warmup_steps=2, cooldown_steps=2,
                           total_steps=n_steps)
    strat = build_topology_strategy(loss_fn, sgd(momentum=0.9), spec, cfg,
                                    loss_window=10 ** 9)
    assert isinstance(strat, HierDasoStrategy)
    return strat, params0, daso_data


def test_autotune_without_faults_is_bit_exact_noop():
    """Acceptance: autotune on a healthy cluster (measured == nominal by
    construction of the cost model) must not perturb training at all."""
    key = jax.random.PRNGKey(11)
    n_steps = 24
    cost = lambda n, s: 0.05 / s  # noqa: E731
    runs = []
    for autotune_every in (0, 1):
        strat, params0, data = _hier_problem(key, n_steps)
        rep = run_with_faults(strat, params0, data, constant_lr(0.1),
                              n_steps, FaultPlan(), t_compute_s=0.01,
                              exchange_cost_fn=cost,
                              autotune_every=autotune_every)
        runs.append(rep)
    assert runs[1].retunes == [] and runs[1].reshuffles == 0
    np.testing.assert_array_equal(
        np.asarray(runs[0].result.losses, np.float32),
        np.asarray(runs[1].result.losses, np.float32))
    for a, b in zip(jax.tree.leaves(runs[0].result.params),
                    jax.tree.leaves(runs[1].result.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_supervisor_discovers_dcn_degradation_within_k_cycles():
    """Acceptance: with oracle notification OFF (the autotune default), an
    injected DCN degradation is discovered by the probe and the schedule
    retuned within K <= 3 cycles of the event."""
    key = jax.random.PRNGKey(12)
    n_steps = 48
    degrade_step = 8
    plan = FaultPlan.from_dicts([
        {"step": degrade_step, "kind": "degrade_dcn", "factor": 0.25},
    ])
    strat, params0, data = _hier_problem(key, n_steps)
    ex = MacroCycleExecutor(strat)
    b0 = strat.controller.b
    rep = run_with_faults(strat, params0, data, constant_lr(0.1), n_steps,
                          plan, executor=ex, t_compute_s=0.01,
                          exchange_cost_fn=lambda n, s: 0.05 / s,
                          autotune_every=1)
    assert np.all(np.isfinite(rep.result.losses))
    sched = [r for r in rep.retunes if r["schedule_changed"]]
    assert sched, "probe never discovered the degradation"
    # adapt latency in cycles: first schedule-changing probe at or after
    # the degrade step, within K=3 cycle boundaries
    first = sched[0]
    degrade_cycle = min(r["cycle"] for r in rep.retunes
                        if r["step"] >= degrade_step) \
        if rep.retunes else None
    assert first["step"] >= degrade_step
    assert first["cycle"] - (degrade_cycle or first["cycle"]) <= 3
    assert strat.controller.b > b0          # schedule actually stretched
    assert ex.stats.invalidations >= 1      # retune recompiled the cycle
    kinds = [k for (_, k, _) in strat.controller.events]
    assert "retune" in kinds


def test_supervisor_reshuffles_on_straggler_skew():
    """A straggler inside one inner group triggers a probe-round reshuffle
    that pairs it with the other slow replica, shrinking wasted wait."""
    key = jax.random.PRNGKey(13)
    n_steps = 32
    plan = FaultPlan.from_dicts([
        {"step": 4, "kind": "straggle", "replica": 1, "factor": 3.0},
        {"step": 4, "kind": "straggle", "replica": 3, "factor": 3.0},
    ])
    strat, params0, data = _hier_problem(key, n_steps)
    rep = run_with_faults(strat, params0, data, constant_lr(0.1), n_steps,
                          plan, t_compute_s=0.01,
                          exchange_cost_fn=lambda n, s: 0.05 / s,
                          autotune_every=1)
    assert rep.reshuffles >= 1
    # slot order groups the two fast and the two slow replicas together
    perm = strat.group_perm
    assert perm is not None
    slow = {1, 3}
    groups = [set(perm[i:i + 2]) for i in range(0, 4, 2)]
    assert slow in groups
    # identical plan without reshuffling wastes strictly more wait
    strat2, params0b, data2 = _hier_problem(key, n_steps)
    rep2 = run_with_faults(strat2, params0b, data2, constant_lr(0.1),
                           n_steps, plan, t_compute_s=0.01,
                           exchange_cost_fn=lambda n, s: 0.05 / s,
                           autotune_every=1, reshuffle=False)
    assert rep2.reshuffles == 0
    assert rep.wasted_wait_s < rep2.wasted_wait_s


def test_reshuffled_training_stays_finite_and_trains():
    """End-to-end numerics under a live regrouping: losses finite and
    improving (the global mean is preserved, so training is unharmed)."""
    key = jax.random.PRNGKey(14)
    n_steps = 40
    plan = FaultPlan.from_dicts([
        {"step": 6, "kind": "straggle", "replica": 0, "factor": 2.5},
        {"step": 6, "kind": "straggle", "replica": 2, "factor": 2.5},
    ])
    strat, params0, data = _hier_problem(key, n_steps)
    rep = run_with_faults(strat, params0, data, constant_lr(0.1), n_steps,
                          plan, t_compute_s=0.01,
                          exchange_cost_fn=lambda n, s: 0.05 / s,
                          autotune_every=2)
    assert len(rep.result.losses) == n_steps
    assert np.all(np.isfinite(rep.result.losses))
    assert rep.result.final_loss < rep.result.losses[0]
