from repro.optim.optimizers import Optimizer, adamw, sgd  # noqa: F401
from repro.optim.schedules import (  # noqa: F401
    PlateauState,
    constant_lr,
    plateau_decay_init,
    plateau_decay_update,
    warmup_cosine,
    warmup_linear_scaled,
)
