"""Pure-function optimizers (optax-style init/update pairs, no dependency).

The paper's experiments use SGD with momentum 0.9 and weight decay 1e-4 as
the node-local optimizer; DASO wraps whatever local optimizer it is given.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, opt_state, params, lr) -> (new_params, new_state)


def sgd(momentum: float = 0.9, weight_decay: float = 1e-4,
        nesterov: bool = False) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {}
        return {"mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                   params)}

    def update(grads, state, params, lr):
        def leaf(g, p, mu):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            if momentum:
                mu = momentum * mu + g
                g = g + momentum * mu if nesterov else mu
            return (p.astype(jnp.float32) - lr * g).astype(p.dtype), mu

        if momentum == 0.0:
            new = jax.tree.map(
                lambda g, p: leaf(g, p, jnp.zeros_like(g, jnp.float32))[0],
                grads, params)
            return new, state
        out = jax.tree.map(leaf, grads, params, state["mu"])
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda o: isinstance(o, tuple))
        new_mu = jax.tree.map(lambda o: o[1], out,
                              is_leaf=lambda o: isinstance(o, tuple))
        return new_params, {"mu": new_mu}

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        c1 = 1.0 - b1 ** t.astype(jnp.float32)
        c2 = 1.0 - b2 ** t.astype(jnp.float32)

        def leaf(g, p, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
            p32 = p.astype(jnp.float32)
            p32 = p32 - lr * (upd + weight_decay * p32)
            return p32.astype(p.dtype), m, v

        out = jax.tree.map(leaf, grads, params, state["m"], state["v"])
        istuple = lambda o: isinstance(o, tuple)
        return (jax.tree.map(lambda o: o[0], out, is_leaf=istuple),
                {"m": jax.tree.map(lambda o: o[1], out, is_leaf=istuple),
                 "v": jax.tree.map(lambda o: o[2], out, is_leaf=istuple),
                 "t": t})

    return Optimizer(init, update)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), n
