"""LR schedules. The paper uses: linear warm-up over 5 epochs, max LR scaled
by the number of global processes, and plateau decay (x0.5 when the training
loss is stable for 5 epochs). Plateau detection runs host-side (it also drives
DASO's B/W schedule, see repro.core.schedule)."""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax.numpy as jnp


def constant_lr(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.0):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = floor + (peak_lr - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn


def warmup_linear_scaled(base_lr: float, n_processes: int, warmup_steps: int):
    """Paper setup: peak LR scaled with global process count, linear warmup."""
    peak = base_lr * n_processes
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        return jnp.where(step < warmup_steps,
                         peak * (step + 1) / warmup_steps, peak)
    return fn


# --- host-side plateau detection (paper: "loss stable for 5 epochs") -------

@dataclass(frozen=True)
class PlateauState:
    best: float = float("inf")
    since_improve: int = 0
    scale: float = 1.0
    n_decays: int = 0


def plateau_decay_init() -> PlateauState:
    return PlateauState()


def plateau_decay_update(state: PlateauState, loss: float, *,
                         patience: int = 5, factor: float = 0.5,
                         threshold: float = 1e-3):
    """Returns (new_state, plateaued: bool). `loss` is the epoch/window mean."""
    improved = loss < state.best * (1.0 - threshold)
    if improved:
        return replace(state, best=loss, since_improve=0), False
    since = state.since_improve + 1
    if since >= patience:
        return replace(state, since_improve=0, scale=state.scale * factor,
                       n_decays=state.n_decays + 1), True
    return replace(state, since_improve=since), False
