"""Relaxed-synchronization baseline strategies: gossip / EASGD / DOWNPOUR.

DASO (core/daso.py) is one point in the design space the paper positions
itself in; this module adds the three classic neighbors under the same
`register_strategy` registry so every executor surface — macro-cycle
compilation, per-step oracle, checkpoint TrainState, elastic membership,
the supervisor's fault plans — drives them through the identical Strategy
interface (and tests/test_strategies.py proves it with one shared
conformance battery):

  * **gossip** — pairwise parameter exchange over the replica axis: every
    B steps each replica averages with ONE partner, a ring shift whose
    offset rotates between exchanges so information percolates the whole
    ring. No global collective — the partner copy moves as a permutation
    of the packed flat-buffer arena (`jnp.roll` on the replica axis, which
    GSPMD lowers to collective-permute on a sharded mesh), wire-encoded at
    the non-blocking tier ("How to scale distributed deep learning?",
    Jin et al.).
  * **easgd** — Elastic Averaging SGD: replicas are pulled toward a
    tracked center variable by an elastic term `params ← (1-α)·params +
    α·center`, while the center tracks the replica mean as a moving
    average `center ← (1-β)·center + β·mean(params)` with β = α·n_active
    (Zhang et al., 2015). One global all-reduce per exchange step.
  * **downpour** — DOWNPOUR's parameter server modeled as SPMD state:
    each replica accumulates a local delta against the last server
    snapshot (the `anchor` carry slot); a push applies the sum of active
    deltas to the server copy and redistributes it. The masked replica
    mean times n_active IS the delta sum, so the whole push is one
    all-reduce — a designated-replica server would break the
    one-program-per-cycle SPMD contract for no modeling gain (Dean et
    al., 2012).

All three run the *periodic* schedule (`PeriodicController`): blocking
warm-up/cool-down phases exactly like DASO, and one exchange every B
steps in between — B inherits the paper's plateau halve/reset rule, so
the exchange period adapts to training progress just like DASO's send
period. None of them has a non-blocking in-flight exchange, so overlap
is rejected up front.

Carry layouts (the conformance suite's checkpoint leg round-trips each):

    gossip    (params_R, opt_R)             2 slots
    easgd     (params_R, opt_R, center_R)   3 slots
    downpour  (params_R, opt_R, anchor_R)   3 slots
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import flatbuf
from repro.core.daso import (_cross_replica_loss, blocking_sync,
                             freeze_inactive, local_step, replica_mean,
                             replicate_params)
from repro.core.executor import DasoStrategy, register_strategy
from repro.core.schedule import DasoController, Mode, split_mode, split_ov


# -- periodic controllers ------------------------------------------------------

@dataclass
class PeriodicController(DasoController):
    """DASO's phase structure with the send/wait pair collapsed to one
    periodic exchange token: blocking warm-up/cool-down, then one
    `exchange_token(step)` every B steps of the cycling phase. There is
    never an exchange in flight (`_inflight_since` stays None), so the
    base class's macro-cycle planner and plateau-driven B halving work
    unchanged — a plateau shortens the exchange period exactly like it
    shortens DASO's send period."""
    #: outer-mode token emitted every B cycling steps (subclasses override
    #: the class attr or `exchange_token` for per-exchange variation)
    exchange_base = Mode.HARD_AVG
    #: exchanges emitted so far (drives gossip's rotating partner offset;
    #: checkpointed so a resumed ring continues where it left off)
    _n_ex: int = field(init=False, default=0)

    _STATE_FIELDS = DasoController._STATE_FIELDS + ("_n_ex",)

    def exchange_token(self, step: int) -> str:
        return self.exchange_base

    def mode_for_step(self, step: int) -> Tuple[str, int]:
        ph = self.phase(step)
        if ph in ("warmup", "cooldown"):
            self._inflight_since = None
            self._ov_last = None
            mode = Mode.BLOCKING
        elif self._would_send(step):
            self._last_send = step
            mode = self.exchange_token(step)
            self._n_ex += 1
        else:
            mode = Mode.LOCAL
        self.history.append((step, mode, self._b, self._w))
        return mode, 1


@dataclass
class GossipController(PeriodicController):
    """Each exchange pairs replica i with replica (i + shift) mod R; the
    shift rotates 1..R-1 between exchanges so consecutive exchanges use
    different partners and the ring mixes globally (a fixed shift of 1
    would need R-1 exchanges to percolate; the rotation is the cheap
    deterministic stand-in for randomized gossip matching)."""
    exchange_base = Mode.GOSSIP

    def exchange_token(self, step: int) -> str:
        r = self.cfg.n_replicas
        shift = (self._n_ex % (r - 1)) + 1 if r > 1 else 1
        return f"{Mode.GOSSIP}~{shift}"


@dataclass
class EasgdController(PeriodicController):
    exchange_base = Mode.ELASTIC


@dataclass
class DownpourController(PeriodicController):
    exchange_base = Mode.PUSH


# -- gossip exchange primitive -------------------------------------------------

def gossip_mix(tree, *, shift: int, wire_format: str = "f32",
               int8_block: int = 256, use_kernels: bool = False, mask=None):
    """One pairwise gossip exchange over the leading replica axis:
    ``row_i ← (row_i + row_{(i+shift) mod R}) / 2``.

    Runs on the packed flat-buffer arenas (one permutation per dtype arena
    regardless of leaf count). Only the PARTNER copy is wire-encoded —
    the wire format models what crosses the network, and a replica's own
    row never leaves the chip. There is no reduction anywhere, so the
    result is bit-identical for any device layout (the 2-proc == 1-proc
    contract holds without a deterministic-reduce fallback), and on a
    replica-sharded mesh the ring shift lowers to data movement
    (collective-permute family), never an all-reduce.

    `mask`: rows mix only when BOTH endpoints are active; a pair with a
    dead endpoint keeps its own row (dead rows stay frozen ghosts). Under
    partial membership the exchange is therefore mass-preserving only
    pairwise, not globally — the property-test guarantee (mean
    preservation for any shift schedule) is stated for full membership."""
    layout = flatbuf.build_layout(tree, batch_dims=1)
    arenas = flatbuf.pack(tree, layout)
    r = layout.batch_shape[0]
    if not 1 <= shift < max(r, 2):
        raise ValueError(f"gossip shift {shift} outside 1..{r - 1}")

    col = None
    if mask is not None:
        m = jnp.asarray(mask, jnp.bool_)
        col = (m & jnp.roll(m, -shift))[:, None]  # both endpoints active

    def mix(arena):
        partner = jnp.roll(arena, -shift, axis=0)
        if not jnp.issubdtype(arena.dtype, jnp.floating):
            out = jnp.round(0.5 * (arena.astype(jnp.float32)
                                   + partner.astype(jnp.float32)))
        else:
            if wire_format == "int8":
                partner = flatbuf.wire_roundtrip(partner, "int8",
                                                 int8_block=int8_block,
                                                 use_kernels=use_kernels)
            elif wire_format == "bf16":
                partner = flatbuf.encode_wire(partner, "bf16",
                                              use_kernels=use_kernels)
            out = 0.5 * (arena.astype(jnp.float32)
                         + partner.astype(jnp.float32))
        out = out.astype(arena.dtype)
        return out if col is None else jnp.where(col, out, arena)

    return flatbuf.unpack({k: mix(a) for k, a in arenas.items()}, layout)


# -- assembled train steps -----------------------------------------------------

def _aux_metrics(metrics, aux_r, mask, n_replicas: int, n_active: int):
    """Masked aux-metric reduction, same contract as daso_train_step."""
    for k, v in aux_r.items():
        if isinstance(v, jnp.ndarray) and v.ndim <= 1:
            if (mask is not None and v.ndim == 1
                    and v.shape[0] == n_replicas):
                metrics[k] = jnp.sum(
                    v * jnp.asarray(mask, v.dtype)) / n_active
            else:
                metrics[k] = jnp.mean(v)
    return metrics


def gossip_train_step(loss_fn, optimizer, cfg, *, mode: str, shift: int = 1,
                      n_micro: int = 1, membership=None):
    """step(params_R, opt_R, batch_R, lr) -> (params_R, opt_R, metrics).
    `mode` is local | blocking | gossip (shift decoded by the caller)."""
    assert mode in (Mode.LOCAL, Mode.BLOCKING, Mode.GOSSIP), mode
    lstep = local_step(loss_fn, optimizer, n_micro=n_micro)
    impl, kern, blk = (cfg.exchange_impl, cfg.exchange_kernels,
                       cfg.int8_block)
    det = cfg.deterministic_reduce
    mask = flatbuf.normalize_membership(membership, cfg.n_replicas)
    n_active = cfg.n_replicas if mask is None else int(sum(mask))

    def step(params, opt_state, batch, lr):
        new_p, new_o, loss_r, aux_r = lstep(params, opt_state, batch, lr)
        if mask is not None:
            new_p = freeze_inactive(new_p, params, mask)
            new_o = freeze_inactive(new_o, opt_state, mask)
        params, opt_state = new_p, new_o
        if mode == Mode.GOSSIP:
            params = gossip_mix(
                params, shift=shift,
                wire_format=cfg.wire_format_for(blocking=False),
                int8_block=blk, use_kernels=kern, mask=mask)
        elif mode == Mode.BLOCKING:
            params = blocking_sync(
                params, wire_format=cfg.wire_format_for(blocking=True),
                impl=impl, int8_block=blk, use_kernels=kern, mask=mask,
                deterministic=det)
        loss = _cross_replica_loss(cfg, mask, n_active, loss_r)
        metrics = {"loss": loss, "loss_per_replica": loss_r}
        return params, opt_state, _aux_metrics(
            metrics, aux_r, mask, cfg.n_replicas, n_active)

    return step


def easgd_train_step(loss_fn, optimizer, cfg, *, mode: str, alpha: float,
                     n_micro: int = 1, membership=None):
    """step(params_R, opt_R, center_R, batch_R, lr)
        -> (params_R, opt_R, center_R, metrics).

    `mode` elastic: the ONE outer collective is the masked replica mean m
    of the post-step params; then the elastic pull `params ← (1-α)params
    + α·center` and the center update `center ← (1-β)center + β·m` with
    β = α·n_active (the symmetric coupling of Zhang et al. §2: the center
    moves by α per attached replica). `mode` blocking resets the center
    to the freshly synced params — a full average IS the consensus, so
    warm-up/cool-down leave nothing elastic to track. The center rows are
    global state (identical across replicas by construction) and are
    never membership-frozen; dead PARAM rows stay frozen ghosts."""
    assert mode in (Mode.LOCAL, Mode.BLOCKING, Mode.ELASTIC), mode
    lstep = local_step(loss_fn, optimizer, n_micro=n_micro)
    impl, kern, blk = (cfg.exchange_impl, cfg.exchange_kernels,
                       cfg.int8_block)
    det = cfg.deterministic_reduce
    mask = flatbuf.normalize_membership(membership, cfg.n_replicas)
    n_active = cfg.n_replicas if mask is None else int(sum(mask))
    beta = alpha * n_active

    def lerp(a_tree, b_tree, t):
        # (1-t)·a + t·b in f32; integer leaves round back (same treatment
        # as the arena mean in core/daso.py)
        def leaf(x, y):
            out = ((1.0 - t) * x.astype(jnp.float32)
                   + t * y.astype(jnp.float32))
            if not jnp.issubdtype(x.dtype, jnp.floating):
                out = jnp.round(out)
            return out.astype(x.dtype)
        return jax.tree.map(leaf, a_tree, b_tree)

    def step(params, opt_state, center, batch, lr):
        new_p, new_o, loss_r, aux_r = lstep(params, opt_state, batch, lr)
        if mask is not None:
            new_p = freeze_inactive(new_p, params, mask)
            new_o = freeze_inactive(new_o, opt_state, mask)
        params, opt_state = new_p, new_o
        if mode == Mode.ELASTIC:
            m = replica_mean(
                params, wire_format=cfg.wire_format_for(blocking=False),
                impl=impl, int8_block=blk, use_kernels=kern, mask=mask,
                deterministic=det)
            params = freeze_inactive(lerp(params, center, alpha),
                                     params, mask)
            center = lerp(center, m, beta)
        elif mode == Mode.BLOCKING:
            params = blocking_sync(
                params, wire_format=cfg.wire_format_for(blocking=True),
                impl=impl, int8_block=blk, use_kernels=kern, mask=mask,
                deterministic=det)
            center = jax.tree.map(jnp.array, params)
        loss = _cross_replica_loss(cfg, mask, n_active, loss_r)
        metrics = {"loss": loss, "loss_per_replica": loss_r}
        return params, opt_state, center, _aux_metrics(
            metrics, aux_r, mask, cfg.n_replicas, n_active)

    return step


def downpour_train_step(loss_fn, optimizer, cfg, *, mode: str,
                        push_scale: float = 1.0, n_micro: int = 1,
                        membership=None):
    """step(params_R, opt_R, anchor_R, batch_R, lr)
        -> (params_R, opt_R, anchor_R, metrics).

    `anchor` is the server's parameter copy at the last push (identical
    across replicas). A push applies the SUM of the active replicas'
    accumulated deltas to the server — computed as
    ``n_active · masked_mean(params - anchor)``, which is one masked
    all-reduce, the SPMD rendering of DOWNPOUR's server addition — then
    redistributes: ``params = anchor = server``. `push_scale` is the
    server-side learning rate on the delta sum (1.0 = apply verbatim).
    Dead rows contribute zero delta (masked out) and keep their frozen
    ghost params; the anchor rows update everywhere (server state)."""
    assert mode in (Mode.LOCAL, Mode.BLOCKING, Mode.PUSH), mode
    lstep = local_step(loss_fn, optimizer, n_micro=n_micro)
    impl, kern, blk = (cfg.exchange_impl, cfg.exchange_kernels,
                       cfg.int8_block)
    det = cfg.deterministic_reduce
    mask = flatbuf.normalize_membership(membership, cfg.n_replicas)
    n_active = cfg.n_replicas if mask is None else int(sum(mask))

    def step(params, opt_state, anchor, batch, lr):
        new_p, new_o, loss_r, aux_r = lstep(params, opt_state, batch, lr)
        if mask is not None:
            new_p = freeze_inactive(new_p, params, mask)
            new_o = freeze_inactive(new_o, opt_state, mask)
        params, opt_state = new_p, new_o
        if mode == Mode.PUSH:
            delta = jax.tree.map(
                lambda p, a: p.astype(jnp.float32) - a.astype(jnp.float32),
                params, anchor)
            dmean = replica_mean(
                delta, wire_format=cfg.wire_format_for(blocking=False),
                impl=impl, int8_block=blk, use_kernels=kern, mask=mask,
                deterministic=det)

            def apply(a, d):
                out = (a.astype(jnp.float32)
                       + push_scale * n_active * d.astype(jnp.float32))
                if not jnp.issubdtype(a.dtype, jnp.floating):
                    out = jnp.round(out)
                return out.astype(a.dtype)

            server = jax.tree.map(apply, anchor, dmean)
            params = freeze_inactive(server, params, mask)
            anchor = jax.tree.map(jnp.array, server)
        elif mode == Mode.BLOCKING:
            params = blocking_sync(
                params, wire_format=cfg.wire_format_for(blocking=True),
                impl=impl, int8_block=blk, use_kernels=kern, mask=mask,
                deterministic=det)
            anchor = jax.tree.map(jnp.array, params)
        loss = _cross_replica_loss(cfg, mask, n_active, loss_r)
        metrics = {"loss": loss, "loss_per_replica": loss_r}
        return params, opt_state, anchor, _aux_metrics(
            metrics, aux_r, mask, cfg.n_replicas, n_active)

    return step


# -- strategies ----------------------------------------------------------------

class PeriodicStrategy(DasoStrategy):
    """Shared base for the baseline family: replica-axis carry, a
    `PeriodicController` schedule, no overlap, no in-flight buffer. The
    DasoStrategy surface (membership baking, step-fn cache, cycle
    planning, first-active finalize) is inherited unchanged — subclasses
    provide the controller class and the per-mode step builder."""
    controller_cls = PeriodicController

    def __init__(self, loss_fn, optimizer, cfg, *, membership=None,
                 controller=None, n_micro=1):
        assert cfg is not None, f"{self.name} strategy requires a DasoConfig"
        if cfg.overlap != "off":
            raise ValueError(
                f"strategy {self.name!r} has no non-blocking exchange to "
                "overlap; run it with overlap='off'")
        if cfg.n_replicas < 2:
            raise ValueError(f"strategy {self.name!r} exchanges between "
                             f"replicas; n_replicas must be >= 2, got "
                             f"{cfg.n_replicas}")
        if controller is None:
            controller = self.make_controller(cfg)
        elif not isinstance(controller, PeriodicController):
            raise TypeError(
                f"strategy {self.name!r} needs a periodic controller "
                f"(use {type(self).__name__}.make_controller); got "
                f"{type(controller).__name__}")
        super().__init__(loss_fn, optimizer, cfg, membership=membership,
                         controller=controller, n_micro=n_micro)

    @classmethod
    def make_controller(cls, cfg, *, loss_window: int = 50):
        return cls.controller_cls(cfg, loss_window=loss_window)


@register_strategy("gossip")
class GossipStrategy(PeriodicStrategy):
    """Pairwise gossip averaging; 2-slot carry (params, opt_state)."""
    controller_cls = GossipController

    def init_carry(self, params0):
        params = replicate_params(params0, self.cfg.n_replicas)
        opt_state = replicate_params(self.optimizer.init(params0),
                                     self.cfg.n_replicas)
        return (params, opt_state)

    def build_step(self, mode, staleness):
        outer, inner = split_mode(mode)
        self._inner_syncs_of(inner)  # no topology: reject inner syncs
        base, shift = split_ov(outer)
        raw = gossip_train_step(self.loss_fn, self.optimizer, self.cfg,
                                mode=base, shift=max(shift, 1),
                                n_micro=self.n_micro,
                                membership=self._membership)

        def step(carry, batch, lr):
            params, opt_state = carry
            params, opt_state, m = raw(params, opt_state, batch, lr)
            return (params, opt_state), m

        return step


@register_strategy("easgd")
class EasgdStrategy(PeriodicStrategy):
    """Elastic Averaging SGD; 3-slot carry (params, opt_state, center).

    `alpha` is the elastic coupling (per-exchange pull toward the
    center); the center's own rate is β = α·n_active, so stability needs
    α·n_replicas < 1. Default: α = 0.5 / n_replicas (β = 0.5 with the
    full world active)."""
    controller_cls = EasgdController

    def __init__(self, loss_fn, optimizer, cfg, *,
                 alpha: Optional[float] = None, **kw):
        super().__init__(loss_fn, optimizer, cfg, **kw)
        self.alpha = 0.5 / cfg.n_replicas if alpha is None else float(alpha)
        if not 0.0 < self.alpha * cfg.n_replicas < 1.0:
            raise ValueError(
                f"easgd needs 0 < alpha * n_replicas < 1 for a stable "
                f"center (beta = alpha * n_active); got alpha={self.alpha} "
                f"with n_replicas={cfg.n_replicas}")

    def init_carry(self, params0):
        params = replicate_params(params0, self.cfg.n_replicas)
        opt_state = replicate_params(self.optimizer.init(params0),
                                     self.cfg.n_replicas)
        center = jax.tree.map(jnp.array, params)
        return (params, opt_state, center)

    def build_step(self, mode, staleness):
        outer, inner = split_mode(mode)
        self._inner_syncs_of(inner)
        base, _ = split_ov(outer)
        raw = easgd_train_step(self.loss_fn, self.optimizer, self.cfg,
                               mode=base, alpha=self.alpha,
                               n_micro=self.n_micro,
                               membership=self._membership)

        def step(carry, batch, lr):
            params, opt_state, center = carry
            params, opt_state, center, m = raw(params, opt_state, center,
                                               batch, lr)
            return (params, opt_state, center), m

        return step


@register_strategy("downpour")
class DownpourStrategy(PeriodicStrategy):
    """DOWNPOUR-style delta pushes; 3-slot carry (params, opt_state,
    anchor). `push_scale` is the server-side rate on the delta sum."""
    controller_cls = DownpourController

    def __init__(self, loss_fn, optimizer, cfg, *, push_scale: float = 1.0,
                 **kw):
        super().__init__(loss_fn, optimizer, cfg, **kw)
        if push_scale <= 0:
            raise ValueError(f"push_scale must be positive, got {push_scale}")
        self.push_scale = float(push_scale)

    def init_carry(self, params0):
        params = replicate_params(params0, self.cfg.n_replicas)
        opt_state = replicate_params(self.optimizer.init(params0),
                                     self.cfg.n_replicas)
        anchor = jax.tree.map(jnp.array, params)
        return (params, opt_state, anchor)

    def build_step(self, mode, staleness):
        outer, inner = split_mode(mode)
        self._inner_syncs_of(inner)
        base, _ = split_ov(outer)
        raw = downpour_train_step(self.loss_fn, self.optimizer, self.cfg,
                                  mode=base, push_scale=self.push_scale,
                                  n_micro=self.n_micro,
                                  membership=self._membership)

        def step(carry, batch, lr):
            params, opt_state, anchor = carry
            params, opt_state, anchor, m = raw(params, opt_state, anchor,
                                               batch, lr)
            return (params, opt_state, anchor), m

        return step
