"""Transfer-buffer compression (paper §3: parameters are cast to a 16-bit
datatype during buffer packaging for blocking global syncs; DASO uses
bfloat16, Horovod fp16 — convergence unaffected per QSGD [19])."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_bf16(tree):
    """Cast floating leaves to bf16 (what crosses the wire)."""
    def leaf(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(jnp.bfloat16)
        return x
    return jax.tree.map(leaf, tree)


def decompress_to(tree, like):
    return jax.tree.map(lambda x, l: x.astype(l.dtype), tree, like)


def compress_bf16_roundtrip(tree):
    """Emulates pack(bf16) -> wire -> unpack(orig dtype)."""
    return decompress_to(compress_bf16(tree), tree)


def transfer_bytes(tree, *, bits: int = 16) -> int:
    """Wire bytes for one global exchange of `tree` at the given precision."""
    n = sum(x.size for x in jax.tree.leaves(tree))
    return n * bits // 8
