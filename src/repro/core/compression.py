"""Wire-format byte accounting + back-compat compression wrappers.

The per-leaf compress/decompress pair that used to live here is retired:
transfer packaging now runs over the fused flat-buffer arenas
(`core/flatbuf.py` codecs, `kernels/comm_kernels.py` kernels). What remains
is (a) the byte accounting the communication model and benchmarks share,
and (b) thin wrappers that keep the old names working by delegating to the
arena codecs.

Paper §3: parameters are cast to a 16-bit datatype during buffer packaging
for blocking global syncs (DASO bfloat16, Horovod fp16 — convergence
unaffected per QSGD [19]). The beyond-paper int8 tier carries 1 byte per
element plus one f32 scale per `int8_block` elements.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import flatbuf

#: bytes per floating element on the wire, excluding int8 scale overhead
WIRE_ITEMSIZE = {"f32": 4.0, "bf16": 2.0, "f16": 2.0, "int8": 1.0}


def wire_itemsize(wire_format: str, *, int8_block: int = 256) -> float:
    """Effective bytes per floating element for `wire_format`, including
    the per-block f32 scale overhead of the int8 tier."""
    if wire_format not in WIRE_ITEMSIZE:
        raise ValueError(f"unknown wire_format {wire_format!r}; expected "
                         f"one of {sorted(WIRE_ITEMSIZE)}")
    size = WIRE_ITEMSIZE[wire_format]
    if wire_format == "int8":
        size += 4.0 / int8_block
    return size


def transfer_bytes(tree, *, wire_format: str = "bf16",
                   int8_block: int = 256) -> int:
    """Wire bytes for one global exchange of `tree`.

    Dtype-aware and arena-consistent: floating leaves are charged at the
    wire format's itemsize, with int8 scale overhead counted the way the
    fused codec actually quantizes — one block grid per packed dtype
    arena (blocks span leaf boundaries inside an arena), ceil'd once per
    arena. Non-floating leaves cross at their own dtype — they are never
    cast by the exchange."""
    if wire_format not in WIRE_ITEMSIZE:
        raise ValueError(f"unknown wire_format {wire_format!r}; expected "
                         f"one of {sorted(WIRE_ITEMSIZE)}")
    total = 0.0
    arena_elems: dict = {}
    for x in jax.tree.leaves(tree):
        if jnp.issubdtype(x.dtype, jnp.floating):
            if wire_format == "int8":
                key = jnp.dtype(x.dtype).name
                arena_elems[key] = arena_elems.get(key, 0) + x.size
            elif wire_format == "f32":
                # the "f32" tier is identity: the arena crosses at its
                # own dtype (a bf16 leaf still ships 2 bytes/elem)
                total += x.size * jnp.dtype(x.dtype).itemsize
            else:
                total += x.size * wire_itemsize(wire_format)
        else:
            total += x.size * jnp.dtype(x.dtype).itemsize
    for n in arena_elems.values():
        total += n + 4 * (-(-n // int8_block))
    return int(math.ceil(total))


# -- back-compat wrappers over the arena codecs --------------------------------

def compress_bf16(tree):
    """Cast floating leaves to bf16 (what crosses the wire). Retained for
    API compatibility; the exchange itself packs first and casts the whole
    arena at once."""
    def leaf(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(jnp.bfloat16)
        return x
    return jax.tree.map(leaf, tree)


def decompress_to(tree, like):
    return jax.tree.map(lambda x, l: x.astype(l.dtype), tree, like)


def compress_bf16_roundtrip(tree):
    """Emulates pack(bf16) -> wire -> unpack(orig dtype), via the fused
    arena codec (core/flatbuf.py)."""
    return flatbuf.tree_wire_roundtrip(tree, "bf16")
