# DASO — the paper's primary contribution: hierarchical, asynchronous,
# selective data-parallel optimization (Coquelin et al. 2021).
from repro.core.daso import (  # noqa: F401
    DasoConfig,
    blocking_sync,
    daso_train_step,
    dereplicate_params,
    global_receive,
    global_send,
    local_step,
    replicate_params,
)
from repro.core.schedule import (DasoController,  # noqa: F401
                                 HierDasoController, Mode, join_mode,
                                 split_mode)
from repro.core.compression import compress_bf16_roundtrip  # noqa: F401
# Compiled macro-cycle executor + strategy registry (one XLA dispatch per
# controller cycle instead of one per step).
from repro.core.executor import (  # noqa: F401
    CyclePlan,
    MacroCycleExecutor,
    Strategy,
    get_strategy,
    list_strategies,
    make_strategy,
    register_strategy,
    run_compiled_training,
)
