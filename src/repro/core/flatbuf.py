"""Flat-buffer parameter arenas for the fused global exchange.

The per-leaf exchange primitives in `core/daso.py` used to map over the
parameter pytree, so one global sync lowered to one cross-pod all-reduce,
one wire cast, and one Eq.(1) merge *per parameter leaf* — dozens of small
DCN collectives for a transformer config. Horovod-style tensor fusion and
DS-Sync both show the wall-clock win lives in coalescing those small
messages: this module packs the pytree into ONE contiguous arena per leaf
dtype with a static offset table, so every exchange is a single reduction
over a single large buffer regardless of leaf count.

Layout rules:

  * leaves are grouped by *storage dtype* (one arena per distinct dtype) —
    grouping by dtype is what makes `pack`/`unpack` an exact bit-identical
    roundtrip (no casts ever happen during packing);
  * `batch_dims` leading axes (the replica axis R in DASO) are preserved on
    the arena: a leaf (R, *s) contributes a (R, prod(s)) slice, so the
    cross-replica reduction stays a single axis-0 reduce over the arena and
    lowers to exactly one cross-pod all-reduce on the production mesh;
  * offsets are static Python ints baked into the layout, so unpack is pure
    static slicing — no gather, no dynamic shapes, nothing for XLA to
    re-materialize per leaf.

Wire codecs (`encode_wire` / `decode_wire`) implement the transfer tiers
over an arena: `f32` (identity), `bf16` (the paper's 16-bit packaging),
and a beyond-paper `int8` block-scaled tier (per-block absmax scales,
optional stochastic rounding). The elementwise codec math can run through
the Pallas kernels in `repro.kernels.comm_kernels` (``use_kernels=True``;
interpret=True on CPU) or through the identical pure-jnp path that the
SPMD partitioner can reason about on a sharded mesh arena.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import reduce as _reduce
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

WIRE_FORMATS = ("f32", "bf16", "int8")


@dataclass(frozen=True)
class LeafSlot:
    """Static placement of one pytree leaf inside its dtype arena."""
    arena: str              # arena key = canonical dtype name, e.g. "float32"
    offset: int             # element offset into the arena's packed axis
    size: int               # number of elements (excluding batch dims)
    shape: Tuple[int, ...]  # per-item shape (excluding batch dims)
    dtype: Any              # leaf dtype (== arena dtype)


@dataclass(frozen=True)
class ArenaLayout:
    """Static offset table for a pytree: treedef + one `LeafSlot` per leaf
    (in flatten order) + total packed size per arena."""
    treedef: Any
    slots: Tuple[LeafSlot, ...]
    arena_sizes: Dict[str, int]     # arena key -> packed elements
    batch_shape: Tuple[int, ...]    # leading axes shared by every leaf

    @property
    def n_leaves(self) -> int:
        return len(self.slots)


def _prod(xs) -> int:
    return int(_reduce(lambda a, b: a * b, xs, 1))


def build_layout(tree, *, batch_dims: int = 0) -> ArenaLayout:
    """Compute the static arena layout of `tree`. All leaves must share the
    first `batch_dims` axes (the DASO replica axis uses batch_dims=1)."""
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        raise ValueError("cannot build an arena layout for an empty pytree")
    batch_shape = tuple(leaves[0].shape[:batch_dims])
    offsets: Dict[str, int] = {}
    slots = []
    for x in leaves:
        if tuple(x.shape[:batch_dims]) != batch_shape:
            raise ValueError(
                f"leaf batch shape {x.shape[:batch_dims]} != {batch_shape}; "
                f"all leaves must share the leading {batch_dims} axes")
        key = jnp.dtype(x.dtype).name
        shape = tuple(x.shape[batch_dims:])
        size = _prod(shape)
        off = offsets.get(key, 0)
        slots.append(LeafSlot(arena=key, offset=off, size=size,
                              shape=shape, dtype=jnp.dtype(x.dtype)))
        offsets[key] = off + size
    return ArenaLayout(treedef=treedef, slots=tuple(slots),
                       arena_sizes=dict(offsets), batch_shape=batch_shape)


def pack(tree, layout: ArenaLayout) -> Dict[str, jnp.ndarray]:
    """Pack `tree` into its dtype arenas: {arena_key: (*batch, N)} arrays.
    Pure reshapes + static-offset dynamic_update_slice writes —
    bit-identical to the source leaves. (DUS instead of concatenate: XLA
    CPU lowers a concatenate of reshaped operands to a pathological
    per-element fusion, measured 4-30x slower than the same copies as
    slice updates; on TPU both are plain DMA.)"""
    leaves = jax.tree.leaves(tree)
    nb = len(layout.batch_shape)
    single = {slot.arena: layout.arena_sizes[slot.arena] == slot.size
              for slot in layout.slots}
    arenas: Dict[str, jnp.ndarray] = {}
    for x, slot in zip(leaves, layout.slots):
        flat = jnp.reshape(x, x.shape[:nb] + (slot.size,))
        if single[slot.arena]:      # single-leaf arena: the reshape is free
            arenas[slot.arena] = flat
            continue
        if slot.arena not in arenas:
            arenas[slot.arena] = jnp.zeros(
                layout.batch_shape + (layout.arena_sizes[slot.arena],),
                jnp.dtype(slot.arena))
        arenas[slot.arena] = jax.lax.dynamic_update_slice_in_dim(
            arenas[slot.arena], flat, slot.offset, axis=nb)
    return arenas


def unpack(arenas: Dict[str, jnp.ndarray], layout: ArenaLayout):
    """Exact inverse of `pack`: static slices + reshapes back to the tree."""
    nb = len(layout.batch_shape)
    leaves = []
    for slot in layout.slots:
        arena = arenas[slot.arena]
        piece = jax.lax.slice_in_dim(arena, slot.offset,
                                     slot.offset + slot.size, axis=nb)
        leaves.append(jnp.reshape(piece, arena.shape[:nb] + slot.shape)
                      .astype(slot.dtype))
    return jax.tree.unflatten(layout.treedef, leaves)


# -- elastic membership --------------------------------------------------------

def normalize_membership(mask, n_replicas: int) -> Optional[Tuple[float, ...]]:
    """Validate an active-replica mask against the replica-axis size and
    canonicalize it to a tuple of 0.0/1.0 floats — the *static* weights the
    masked arena reduction bakes into a compiled exchange. Returns None for
    the all-active mask (callers treat None as the non-elastic fast path,
    keeping the fixed-membership HLO bit-identical to pre-resilience
    code)."""
    if mask is None:
        return None
    mask = tuple(float(m) for m in mask)
    if len(mask) != n_replicas:
        raise ValueError(f"membership mask has {len(mask)} entries for "
                         f"{n_replicas} replicas")
    if any(m not in (0.0, 1.0) for m in mask):
        raise ValueError(f"membership mask must be 0/1 valued, got {mask}")
    if not any(mask):
        raise ValueError("membership mask has no active replicas")
    if all(m == 1.0 for m in mask):
        return None
    return mask


def membership_col(mask: Tuple[float, ...], dtype, ndim: int) -> jnp.ndarray:
    """The mask as a constant (R, 1, ..., 1) column broadcastable against a
    rank-`ndim` array with leading replica axis. Multiplying by it zeroes
    dropped replicas' contributions *before* the axis-0 reduction, so the
    membership-weighted exchange still lowers to exactly one cross-replica
    collective per arena (0/1 weights are exact in every wire dtype)."""
    col = jnp.asarray(mask, dtype)
    return col.reshape((len(mask),) + (1,) * (ndim - 1))


def masked_axis0_mean(arena: jnp.ndarray,
                      mask: Optional[Tuple[float, ...]],
                      deterministic: bool = False) -> jnp.ndarray:
    """Membership-weighted mean over the leading replica axis of an arena,
    kept as a (1, ...) buffer: sum of active rows / n_active, one axis-0
    `lax.reduce` (the op that lowers to the cross-pod all-reduce). With
    mask=None this is the plain mean. Computation dtype = arena dtype (the
    caller has already applied the wire cast).

    `deterministic=True` selects the transport-invariant formulation
    (`chain_axis0_sum`): same math, explicitly associated adds, so the
    result is bit-identical for any process layout of the replica axis —
    at the cost of O(R) collectives instead of one. The multi-process
    runtime (launch/distributed.py) runs its exchanges in this tier; the
    default tier keeps the one-collective HLO contract."""
    r = arena.shape[0]
    w = arena if mask is None else arena * membership_col(mask, arena.dtype,
                                                          arena.ndim)
    inv = 1.0 / (r if mask is None else sum(mask))
    if deterministic:
        m = chain_axis0_sum(w)
    else:
        m = jax.lax.reduce(w, jnp.zeros((), arena.dtype), jax.lax.add, (0,))
    return (m * jnp.asarray(inv, arena.dtype))[None]


def host_fetchable(x) -> bool:
    """True when `np.asarray(x)` is legal on this process: everything
    except an array sharded across processes without a full local copy.
    The single predicate behind metric fetches (core/executor.py), the
    checkpoint-save guard (checkpoint/io.py), and the placement gather
    (launch/distributed.py) — keep them agreeing by keeping them here."""
    return (getattr(x, "is_fully_addressable", True)
            or getattr(x, "is_fully_replicated", False))


def chain_axis0_sum(w: jnp.ndarray) -> jnp.ndarray:
    """Order-fixed sum over the leading axis: an explicitly associated
    chain ``w[0] + w[1] + ...``. Under GSPMD each row access is data
    movement plus arithmetically trivial collectives (every float add in
    the chain has its operand order pinned by the program), so the value
    does not depend on how the leading axis is sharded across devices or
    processes — unlike a single `lax.reduce`, whose lowered all-reduce
    accumulates in transport-defined order (XLA in-process and gloo
    disagree at the ULP level). The price is R-1 sequential adds; the
    multi-process equivalence contract (tests/test_multiprocess.py) is
    what buys it."""
    acc = w[0]
    for i in range(1, w.shape[0]):
        acc = acc + w[i]
    return acc


# -- wire codecs over an arena -------------------------------------------------

def _check_wire_format(wire_format: str) -> str:
    if wire_format not in WIRE_FORMATS:
        raise ValueError(f"unknown wire_format {wire_format!r}; "
                         f"expected one of {WIRE_FORMATS}")
    return wire_format


def encode_wire(arena: jnp.ndarray, wire_format: str, *,
                int8_block: int = 256, rng_key=None,
                use_kernels: bool = False):
    """Encode a floating arena into its wire representation.

    Returns the payload that would cross the DCN: the arena itself for
    ``f32``, a bf16 copy for ``bf16``, or ``(int8 values, f32 per-block
    scales)`` for ``int8``. `rng_key` enables stochastic rounding for the
    int8 tier (deterministic round-to-nearest when None)."""
    _check_wire_format(wire_format)
    if wire_format == "f32":
        return arena
    if wire_format == "bf16":
        if use_kernels:
            from repro.kernels.ops import bf16_pack
            return bf16_pack(arena)
        return arena.astype(jnp.bfloat16)
    from repro.kernels import ops, ref
    bits = None
    if rng_key is not None:
        bits = jax.random.bits(rng_key, arena.shape, jnp.uint32)
    if use_kernels:
        return ops.quantize_int8(arena, block=int8_block, bits=bits)
    return ref.quantize_int8_block_ref(arena, block=int8_block, bits=bits)


def decode_wire(wire, wire_format: str, out_dtype, *,
                int8_block: int = 256, use_kernels: bool = False):
    """Decode a wire payload back to `out_dtype`. Together with
    `encode_wire` this is the arena counterpart of the retired per-leaf
    compress/decompress pair in `core/compression.py`."""
    _check_wire_format(wire_format)
    if wire_format == "f32":
        return wire.astype(out_dtype)
    if wire_format == "bf16":
        if use_kernels:
            from repro.kernels.ops import bf16_unpack
            return bf16_unpack(wire, out_dtype=out_dtype)
        return wire.astype(out_dtype)
    values, scales = wire
    if use_kernels:
        from repro.kernels.ops import dequantize_int8
        return dequantize_int8(values, scales,
                               block=int8_block).astype(out_dtype)
    from repro.kernels import ref
    return ref.dequantize_int8_block_ref(values, scales,
                                         block=int8_block).astype(out_dtype)


def wire_roundtrip(arena: jnp.ndarray, wire_format: str, *,
                   int8_block: int = 256, rng_key=None,
                   use_kernels: bool = False) -> jnp.ndarray:
    """encode -> wire -> decode, back in the arena's own dtype. Emulates
    what a one-way transfer does to the values."""
    wire = encode_wire(arena, wire_format, int8_block=int8_block,
                       rng_key=rng_key, use_kernels=use_kernels)
    return decode_wire(wire, wire_format, arena.dtype,
                       int8_block=int8_block, use_kernels=use_kernels)


def tree_wire_roundtrip(tree, wire_format: str, *, batch_dims: int = 0,
                        int8_block: int = 256, rng_key=None,
                        use_kernels: bool = False):
    """Arena codec over a whole pytree: pack, roundtrip every floating
    arena through the wire format, unpack. Non-floating arenas pass
    through untouched (they cross the wire at their own dtype)."""
    layout = build_layout(tree, batch_dims=batch_dims)
    arenas = pack(tree, layout)
    out = {}
    for key, arena in arenas.items():
        if jnp.issubdtype(arena.dtype, jnp.floating):
            out[key] = wire_roundtrip(arena, wire_format,
                                      int8_block=int8_block, rng_key=rng_key,
                                      use_kernels=use_kernels)
        else:
            out[key] = arena
    return unpack(out, layout)
