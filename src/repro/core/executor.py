"""Compiled macro-cycle executor + unified strategy registry.

The host-side driver used to dispatch one compiled step per training step, so
a DASO cycle of B local batches plus the send/receive merge cost B+1 host
dispatches (controller decision, batch staging, dispatch, metric fetch — per
step). At small step times that host loop dominates wall-clock, the same
granularity problem DS-Sync (arXiv 2007.03298) restructures synchronization
around. This module fuses each controller macro-cycle into ONE compiled,
buffer-donating program:

  * the `DasoController` emits a *cycle plan* — the exact (mode, staleness)
    sequence the per-step path would have run, cut at natural boundaries
    (next send, phase change, plateau-window edge) so host-side feedback
    (`observe_loss`) never needs to land mid-cycle;
  * `MacroCycleExecutor` compiles one program per distinct cycle *shape*
    (e.g. ``(send, receive@1, local, local)`` for B=4/W=1, or
    ``(blocking,)*10`` for warm-up), caching compilations by shape. Inside a
    program, homogeneous runs of the same variant execute under
    ``jax.lax.scan`` over the stacked per-step batches, so the whole cycle is
    a single XLA invocation with donated carry buffers;
  * irregular tail cycles (a shape that would be compiled for a single use
    at the end of training) fall back to the existing per-step path.

Strategies (``sync`` / ``daso`` / ``local_sgd``, plus ``hier_daso`` from
repro/topo) register here behind a common *plan -> compiled-program*
interface: each provides its carry pytree, its per-(mode, staleness) step
builder, and its cycle planner. Mode tokens are opaque strings to the
executor — under an N-level topology they carry the per-level phase vector
(``"send+host"``), so a cycle shape IS the vector of per-level phases and
the executor needs no topology awareness. The executor is
strategy-agnostic; `core/simulator.py` reuses the same interface for the
per-step reference path that the equivalence tests compare against
(see tests/test_executor.py: macro path == step path, allclose at f32).
"""
from __future__ import annotations

import difflib
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flatbuf
from repro.core.daso import (DasoConfig, _cross_replica_loss,
                             daso_overlap_compute_step, daso_overlap_step,
                             daso_train_step, dereplicate_params,
                             global_receive, global_send,
                             normalize_group_perm, replica_divergence,
                             replicate_params, sync_train_step)
from repro.core.schedule import (DasoController, Mode, is_ov_mode, join_mode,
                                 split_mode, split_ov)
from repro.obs.trace import NULL_TRACER
from repro.optim.optimizers import Optimizer

# A cycle shape is the static fingerprint of a macro-cycle: one
# (mode, staleness) pair per step. Distinct shapes compile distinct programs.
CycleShape = Tuple[Tuple[str, int], ...]

# Mode-token prefix for the collective-free compute half of an
# overlap-dispatched cycle ("ovc:local", "ovc:local+host", ...). These
# tokens exist only inside OverlapCycle.compute_shape — the controller
# never emits them and they never enter its history.
OVERLAP_COMPUTE_PREFIX = "ovc:"


@dataclass(frozen=True)
class OverlapCycle:
    """Execution recipe for one overlap-dispatched macro-cycle: launch the
    exchange program on the pending arena, run the compute program (free of
    outer-axis collectives) while the exchange is in flight, then merge the
    exchange result into the computed params one cycle stale — Eq. (1) with
    effective S = staleness + extra_staleness."""
    compute_shape: CycleShape
    staleness: int
    extra_staleness: int


@dataclass(frozen=True)
class CyclePlan:
    """A controller-emitted macro-cycle: `shape[i]` is the (mode, staleness)
    of training step `start_step + i`."""
    start_step: int
    shape: CycleShape

    def __len__(self) -> int:
        return len(self.shape)


# -- strategy registry --------------------------------------------------------

_REGISTRY: Dict[str, type] = {}


def register_strategy(name: str):
    """Class decorator: register a Strategy subclass under `name`."""
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_strategy(name: str) -> type:
    try:
        return _REGISTRY[name]
    except KeyError:
        hint = difflib.get_close_matches(name, _REGISTRY, n=1)
        suggest = f"; did you mean {hint[0]!r}?" if hint else ""
        raise KeyError(f"unknown strategy {name!r}; registered: "
                       f"{sorted(_REGISTRY)}{suggest}") from None


def list_strategies() -> List[str]:
    return sorted(_REGISTRY)


def make_strategy(name: str, loss_fn: Callable, optimizer: Optimizer,
                  cfg: Optional[DasoConfig] = None, **kw) -> "Strategy":
    return get_strategy(name)(loss_fn, optimizer, cfg, **kw)


class Strategy:
    """Common plan -> compiled-program interface.

    A strategy owns (a) the carry pytree threaded through training, (b) a
    builder for statically-specialized step functions
    ``step(carry, batch, lr) -> (carry, metrics)``, and (c) a planner that
    emits the next macro-cycle. Both executors (macro-cycle and per-step
    reference) drive strategies only through this interface.
    """
    name = "?"

    def __init__(self, loss_fn: Callable, optimizer: Optimizer,
                 cfg: Optional[DasoConfig] = None, *,
                 controller: Optional[DasoController] = None,
                 n_micro: int = 1):
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.cfg = cfg
        self.n_micro = n_micro
        self.controller = controller or (DasoController(cfg) if cfg else None)
        self._steps: Dict[Tuple[str, int], Callable] = {}

    # -- carry lifecycle ---------------------------------------------------
    def init_carry(self, params0):
        raise NotImplementedError

    def finalize_params(self, carry):
        raise NotImplementedError

    # -- step building (cached per static variant) -------------------------
    def step_fn(self, mode: str, staleness: int) -> Callable:
        key = (mode, staleness)
        if key not in self._steps:
            self._steps[key] = self.build_step(mode, staleness)
        return self._steps[key]

    def build_step(self, mode: str, staleness: int) -> Callable:
        raise NotImplementedError

    # -- scheduling --------------------------------------------------------
    def plan_cycle(self, step: int, max_len: int) -> CyclePlan:
        raise NotImplementedError

    def next_mode(self, step: int) -> Tuple[str, int]:
        """Per-step decision for the reference path. Must be consumed in
        step order, exactly once per step, and must produce the same
        sequence `plan_cycle` would emit."""
        raise NotImplementedError

    def observe(self, losses: List[float]) -> None:
        """Feed per-step losses (in step order) back to the scheduler."""
        if self.controller is not None:
            for loss in losses:
                self.controller.observe_loss(loss)

    # -- reporting ---------------------------------------------------------
    def sync_fraction(self) -> float:
        return (self.controller.global_sync_fraction()
                if self.controller is not None else 1.0)

    def divergence(self, carry) -> Optional[float]:
        return None

    # -- controller factory ------------------------------------------------
    @classmethod
    def make_controller(cls, cfg: Optional[DasoConfig], *,
                        loss_window: int = 50):
        """The controller class this strategy schedules with — train/loop.py
        resolves it through here so strategies whose mode tokens need a
        non-default controller (core/baselines.py) stay registry-driven."""
        return (DasoController(cfg, loss_window=loss_window)
                if cfg is not None else None)


@register_strategy("daso")
class DasoStrategy(Strategy):
    """Paper strategy: replica-axis carry (params, opt_state, inflight),
    `DasoController`-planned cycles, step variants from core/daso.py.

    The DasoConfig carries the fused-exchange knobs (`wire_format`,
    `exchange_impl`, `int8_block`, `exchange_kernels`): every step variant
    this strategy builds runs its global exchange over the flat-buffer
    arena (one cross-replica collective per sync regardless of leaf
    count), so each compiled macro-cycle contains exactly one fused
    exchange program per sync step in its shape."""

    def __init__(self, loss_fn, optimizer, cfg, *, membership=None, **kw):
        assert cfg is not None, "daso strategy requires a DasoConfig"
        super().__init__(loss_fn, optimizer, cfg, **kw)
        self._membership = flatbuf.normalize_membership(
            membership, cfg.n_replicas)
        self._group_perm = None

    # -- elastic membership ------------------------------------------------
    @property
    def membership(self):
        """Active-replica mask as a 0/1 tuple, or None when every replica
        is active (the non-elastic fast path)."""
        return self._membership

    def n_active(self) -> int:
        return (self.cfg.n_replicas if self._membership is None
                else int(sum(self._membership)))

    def set_membership(self, mask) -> None:
        """Change the active-replica set. The mask is baked *statically*
        into every step variant (membership-weighted exchange, frozen ghost
        rows — core/daso.py), so this drops the strategy's step-fn cache;
        an executor holding compiled cycles over the old variants must be
        `invalidate()`d by the caller (resilience/supervisor.py does both).
        Static baking keeps the steady-state HLO free of membership
        arithmetic — faults are rare, recompiles at fault boundaries are
        the right trade."""
        self._membership = flatbuf.normalize_membership(
            mask, self.cfg.n_replicas)
        self._steps.clear()

    # -- straggler-aware reshuffle -----------------------------------------
    @property
    def group_perm(self):
        """Replica regrouping permutation for inner-level syncs (None =
        contiguous identity grouping, the non-reshuffled fast path)."""
        return self._group_perm

    def set_group_permutation(self, perm) -> None:
        """Rotate which replicas share an inner group: slot i of the new
        grouping holds replica `perm[i]` (repro.core.daso.
        normalize_group_perm). Same contract as `set_membership` — the
        permutation is baked statically into every step variant, so this
        drops the step-fn cache and the caller must `invalidate()` any
        executor holding compiled cycles over the old variants (the
        resilience supervisor's autotune path does both). Driven by
        per-replica cycle-time skew: `repro.topo.probe.skew_permutation`
        packs similar-speed replicas into the same group so a straggler
        delays only its own group's inner syncs."""
        self._group_perm = normalize_group_perm(perm, self.cfg.n_replicas)
        self._steps.clear()

    @property
    def overlap(self) -> bool:
        """True when this strategy runs the double-buffered overlap
        schedule (cfg.overlap != "off"): 4-slot carry, OV_* mode tokens,
        and — on the macro executor — async exchange dispatch."""
        return self.cfg.overlap != "off"

    def init_carry(self, params0):
        params = replicate_params(params0, self.cfg.n_replicas)
        opt_state = replicate_params(self.optimizer.init(params0),
                                     self.cfg.n_replicas)
        # warm buffer; a real copy (not an alias of params) so the executor
        # can donate both leaves of the carry independently
        inflight = jax.tree.map(jnp.array, params)
        if not self.overlap:
            return (params, opt_state, inflight)
        # overlap: the fourth slot is the pending snapshot arena — the
        # params image awaiting its (next cycle's) exchange
        pending = jax.tree.map(jnp.array, params)
        return (params, opt_state, inflight, pending)

    def finalize_params(self, carry):
        # under elastic membership row 0 may be a dead replica's frozen
        # ghost — report the first ACTIVE replica's params instead
        idx = (0 if self._membership is None
               else self._membership.index(1.0))
        return dereplicate_params(carry[0], index=idx)

    def _inner_syncs_of(self, inner: Tuple[str, ...]):
        """Map the inner-level names of a hierarchical mode token to the
        (name, group_size) pairs core/daso.py consumes. The base strategy
        has no topology, so any inner sync is a planning bug."""
        if inner:
            raise ValueError(
                f"mode carries inner-level syncs {inner!r} but strategy "
                f"{self.name!r} has no topology; use hier_daso")
        return ()

    def _build_raw(self, mode, staleness):
        """Build the 3-slot-carry step for one (mode, staleness) variant;
        the carry-unpacking wrapper in `build_step` stays shared across
        subclasses (HierDasoStrategy only overrides `_inner_syncs_of`)."""
        outer, inner = split_mode(mode)
        return daso_train_step(self.loss_fn, self.optimizer, self.cfg,
                               mode=outer, staleness=staleness,
                               n_micro=self.n_micro,
                               membership=self._membership,
                               inner_syncs=self._inner_syncs_of(inner),
                               group_perm=self._group_perm)

    def _build_raw_overlap(self, mode, staleness):
        """Overlap counterpart of `_build_raw`: 4-slot carry, OV_* tokens,
        extra staleness decoded from the token's "~E" suffix."""
        outer, inner = split_mode(mode)
        base, extra = split_ov(outer)
        return daso_overlap_step(self.loss_fn, self.optimizer, self.cfg,
                                 mode=base, staleness=staleness,
                                 extra_staleness=extra,
                                 n_micro=self.n_micro,
                                 membership=self._membership,
                                 inner_syncs=self._inner_syncs_of(inner),
                                 group_perm=self._group_perm)

    def build_step(self, mode, staleness):
        if mode.startswith(OVERLAP_COMPUTE_PREFIX):
            # compute half of an overlap dispatch: 2-slot carry, no outer
            # collectives (loss reduction deferred to the merge program)
            _, inner = split_mode(mode[len(OVERLAP_COMPUTE_PREFIX):])
            raw = daso_overlap_compute_step(
                self.loss_fn, self.optimizer, self.cfg,
                n_micro=self.n_micro, membership=self._membership,
                inner_syncs=self._inner_syncs_of(inner),
                group_perm=self._group_perm)

            def cstep(carry, batch, lr):
                params, opt_state = carry
                params, opt_state, m = raw(params, opt_state, batch, lr)
                return (params, opt_state), m

            return cstep
        if self.overlap:
            raw = self._build_raw_overlap(mode, staleness)

            def ostep(carry, batch, lr):
                params, opt_state, inflight, pending = carry
                params, opt_state, inflight, pending, m = raw(
                    params, opt_state, inflight, pending, batch, lr)
                return (params, opt_state, inflight, pending), m

            return ostep
        raw = self._build_raw(mode, staleness)

        def step(carry, batch, lr):
            params, opt_state, inflight = carry
            params, opt_state, inflight, m = raw(params, opt_state, inflight,
                                                 batch, lr)
            return (params, opt_state, inflight), m

        return step

    # -- overlap dispatch recipe -------------------------------------------
    def overlap_cycle(self, shape: CycleShape) -> Optional[OverlapCycle]:
        """Return the overlap-dispatch recipe for `shape`, or None when the
        shape must run as one ordinary compiled program. Dispatchable
        shapes are the controller's overlap cycling cycles: a run of local
        steps ending in one ov_sync. Everything else — blocking phases,
        the lone ov_start opener, window-cut all-local cycles — has no
        in-flight exchange to hide and the ordinary path is already
        correct for it (the OV_* step variants pass the buffers
        through)."""
        if not self.overlap or not shape:
            return None
        last_outer, _ = split_mode(shape[-1][0])
        base, extra = split_ov(last_outer)
        if base != Mode.OV_SYNC:
            return None
        for mode, _stale in shape[:-1]:
            if split_mode(mode)[0] != Mode.LOCAL:
                return None
        compute_shape = tuple(
            (OVERLAP_COMPUTE_PREFIX
             + join_mode(Mode.LOCAL, split_mode(mode)[1]), 1)
            for mode, _stale in shape)
        return OverlapCycle(compute_shape=compute_shape,
                            staleness=shape[-1][1],
                            extra_staleness=extra)

    def overlap_exchange_fn(self):
        """pending -> inflight: the ONE outer-level collective of an
        overlap cycle, compiled as its own program so the executor can put
        it in flight before the compute program."""
        cfg, mask = self.cfg, self._membership

        def exchange(pending):
            return global_send(
                pending, wire_format=cfg.wire_format_for(blocking=False),
                impl=cfg.exchange_impl, int8_block=cfg.int8_block,
                use_kernels=cfg.exchange_kernels, mask=mask,
                deterministic=cfg.deterministic_reduce)

        return exchange

    def overlap_merge_fn(self, staleness: int, extra_staleness: int):
        """(params, inflight, loss_per_replica (L,R)) -> (merged params,
        per-step loss (L,)). Runs after compute and exchange both land:
        Eq. (1) with effective S = staleness + extra_staleness, plus the
        cross-replica loss reduction the compute program deferred (same
        chained order as the per-step path — bit-exact under
        deterministic_reduce)."""
        cfg, mask = self.cfg, self._membership
        n_active = self.n_active()
        p_eff = (cfg.global_world if mask is None
                 else cfg.global_world * n_active / cfg.n_replicas)

        def merge(params, inflight, loss_r):
            params = global_receive(params, inflight, staleness=staleness,
                                    extra_staleness=extra_staleness,
                                    global_world=p_eff,
                                    impl=cfg.exchange_impl,
                                    use_kernels=cfg.exchange_kernels,
                                    mask=mask)
            loss = _cross_replica_loss(cfg, mask, n_active, loss_r, axis=1)
            return params, loss

        return merge

    def plan_cycle(self, step, max_len):
        return CyclePlan(step, self.controller.plan_cycle(step, max_len))

    def next_mode(self, step):
        return self.controller.mode_for_step(step)

    def divergence(self, carry):
        return float(replica_divergence(carry[0]))


@register_strategy("sync")
class SyncStrategy(Strategy):
    """Horovod-analog baseline: flat data parallelism, no replica axis.
    Every step is the same variant, so cycles are fixed-length chunks."""

    default_cycle_len = 8

    def init_carry(self, params0):
        # copy: the executor donates the carry, and params0 belongs to the
        # caller (who may reuse it for another run)
        return (jax.tree.map(jnp.array, params0),
                self.optimizer.init(params0))

    def finalize_params(self, carry):
        return carry[0]

    def build_step(self, mode, staleness):
        raw = sync_train_step(self.loss_fn, self.optimizer,
                              n_micro=self.n_micro)

        def step(carry, batch, lr):
            params, opt_state = carry
            params, opt_state, m = raw(params, opt_state, batch, lr)
            return (params, opt_state), m

        return step

    def plan_cycle(self, step, max_len):
        n = max(1, min(max_len, self.default_cycle_len))
        return CyclePlan(step, (("sync", 1),) * n)

    def next_mode(self, step):
        return ("sync", 1)

    def observe(self, losses):
        pass

    def sync_fraction(self):
        return 1.0


@register_strategy("local_sgd")
class LocalSGDStrategy(DasoStrategy):
    """Ablation: naive periodic parameter overwrite (hard_avg every b_max
    steps), no Eq. (1) staleness weighting, no plateau schedule."""

    def _mode_at(self, step: int) -> str:
        return Mode.HARD_AVG if step % max(1, self.cfg.b_max) == 0 \
            else Mode.LOCAL

    def plan_cycle(self, step, max_len):
        b = max(1, self.cfg.b_max)
        shape = []
        while len(shape) < max_len:
            t = step + len(shape)
            if shape and t % b == 0:
                break  # next hard_avg starts the next cycle
            shape.append(self.next_mode(t))
        return CyclePlan(step, tuple(shape))

    def next_mode(self, step):
        mode = self._mode_at(step)
        self.controller.history.append((step, mode, self.controller.b,
                                        self.controller.w))
        return (mode, 1)


# -- the executor --------------------------------------------------------------

@dataclass
class ExecutorStats:
    dispatches: int = 0        # host->device program invocations
    steps: int = 0             # training steps covered by those dispatches
    cycles: int = 0            # macro-cycles executed compiled
    compiles: int = 0          # distinct cycle shapes compiled
    fallback_steps: int = 0    # steps run on the per-step fallback path
    invalidations: int = 0     # cache flushes (membership changes etc.)
    # overlap-dispatch timing (wall-clock, host-observed):
    overlap_cycles: int = 0           # cycles run via the overlap dispatch
    overlap_compute_s: float = 0.0    # time until compute outputs are ready
    # extra wait for the in-flight exchange AFTER compute finished — the
    # part of the exchange that compute failed to hide
    overlap_exchange_visible_s: float = 0.0
    # exchange time when forced serial (serial_exchange=True): the
    # blocking-cost baseline the hidden fraction is measured against
    overlap_exchange_blocking_s: float = 0.0
    # the stale Eq.(1) merge after both legs completed, and the whole
    # overlap dispatch wall time. Every leg is bounded by
    # jax.block_until_ready, so compute + visible/blocking + merge == wall
    # exactly (tests/test_overlap.py asserts it) — the legs are device
    # completion times, not async dispatch returns
    overlap_merge_s: float = 0.0
    overlap_wall_s: float = 0.0

    def dispatches_per_step(self) -> float:
        total = self.steps + self.fallback_steps
        return self.dispatches / total if total else 0.0


def _group_runs(shape: CycleShape) -> List[Tuple[str, int, int, int]]:
    """Group consecutive identical (mode, staleness) pairs into
    (mode, staleness, offset, length) runs."""
    runs: List[Tuple[str, int, int, int]] = []
    for i, (mode, stale) in enumerate(shape):
        if runs and runs[-1][0] == mode and runs[-1][1] == stale:
            mode_, stale_, off, k = runs[-1]
            runs[-1] = (mode_, stale_, off, k + 1)
        else:
            runs.append((mode, stale, i, 1))
    return runs


class MacroCycleExecutor:
    """Compiles controller-emitted cycle plans into single XLA programs.

    One compilation per distinct `CycleShape`, cached in `_programs`.
    Homogeneous runs inside a shape execute under `jax.lax.scan`; the carry
    (params / opt state / inflight buffer) is donated so XLA reuses the
    parameter buffers in place across the whole cycle.
    """

    def __init__(self, strategy: Strategy, *, max_cycle_len: int = 32,
                 donate: bool = True, tail_fallback: bool = True,
                 placement=None, serial_exchange: bool = False,
                 health=None, tracer=None):
        self.strategy = strategy
        self.max_cycle_len = max_cycle_len
        self.donate = donate
        self.tail_fallback = tail_fallback
        # optional launch.distributed.MeshPlacement: batches staged onto
        # the global topology mesh instead of the local default device
        self.placement = placement
        # optional resilience.runtime.HealthMonitor: every completed cycle
        # is a progress report (heartbeat step + watchdog deadline push) —
        # the hook that lets a supervised run detect a peer death wedging
        # a gloo collective instead of hanging forever
        self.health = health
        # debug/measurement knob: block on the exchange BEFORE running
        # compute, turning the overlap dispatch into its blocking
        # equivalent — numerics identical, overlap_exchange_blocking_s
        # measured. benchmarks/overlap.py uses this as the baseline leg.
        self.serial_exchange = serial_exchange
        # obs.trace span/counter sink; NULL_TRACER keeps every call site
        # branch-free when tracing is off
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.stats = ExecutorStats()
        self._programs: Dict[CycleShape, Callable] = {}
        self._per_step: Dict[Tuple[str, int], Callable] = {}
        # jitted overlap exchange/merge programs ("exchange", or
        # ("merge", S, E)); dropped by invalidate() with everything else
        self._ov_fns: Dict[object, Callable] = {}

    # -- compilation -------------------------------------------------------
    @property
    def cached_shapes(self) -> List[CycleShape]:
        return list(self._programs)

    def program_for(self, shape: CycleShape) -> Callable:
        if shape not in self._programs:
            self._programs[shape] = self._build_program(shape)
            self.stats.compiles += 1
            # instant, not a span: jit is lazy, the XLA compile itself
            # lands inside the first cycle span of this shape (which is
            # why cycle spans carry a fresh_compile flag)
            self.tracer.instant("compile", cat="executor",
                                shape_len=len(shape),
                                modes=[m for m, _ in shape])
        return self._programs[shape]

    def invalidate(self) -> int:
        """Drop every compiled cycle program and per-step fallback. Called
        when something the step builders bake statically changed — a
        membership change re-bakes the exchange weights into new step
        variants (DasoStrategy.set_membership), so programs closed over the
        old variants are stale. Returns the number of programs dropped;
        subsequent cycles recompile against the strategy's current step
        fns."""
        n = len(self._programs) + len(self._per_step) + len(self._ov_fns)
        self._programs.clear()
        self._per_step.clear()
        self._ov_fns.clear()
        self.stats.invalidations += 1
        self.tracer.instant("invalidate", cat="executor", dropped=n)
        return n

    def _build_program(self, shape: CycleShape) -> Callable:
        runs = _group_runs(shape)

        def program(carry, batches, lrs):
            chunks = []
            for mode, stale, off, k in runs:
                fn = self.strategy.step_fn(mode, stale)
                if k == 1:
                    batch = jax.tree.map(lambda x, i=off: x[i], batches)
                    carry, m = fn(carry, batch, lrs[off])
                    chunks.append(jax.tree.map(lambda x: x[None], m))
                else:
                    part = jax.tree.map(
                        lambda x, i=off, n=k: x[i:i + n], batches)

                    def body(c, xs, fn=fn):
                        batch, lr = xs
                        return fn(c, batch, lr)

                    carry, ms = jax.lax.scan(body, carry,
                                             (part, lrs[off:off + k]))
                    chunks.append(ms)
            metrics = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *chunks)
            return carry, metrics

        # overlap forbids donation: the pending slot aliases the params
        # object in the carry (the snapshot is by-reference), and the
        # exchange program reads pending concurrently with compute — a
        # donated buffer could be reused while the collective still
        # needs it
        donate = ((0,) if self.donate
                  and not getattr(self.strategy, "overlap", False) else ())
        return jax.jit(program, donate_argnums=donate)

    def _per_step_fn(self, mode: str, stale: int) -> Callable:
        key = (mode, stale)
        if key not in self._per_step:
            self._per_step[key] = jax.jit(self.strategy.step_fn(mode, stale))
        return self._per_step[key]

    # -- execution ---------------------------------------------------------
    def run_cycle(self, carry, plan: CyclePlan, batches, lrs, *,
                  is_tail: bool = False):
        """Execute one macro-cycle. `batches`/`lrs` carry a leading axis of
        length len(plan). Returns (carry, stacked per-step metrics)."""
        shape = plan.shape
        ov = getattr(self.strategy, "overlap_cycle", lambda s: None)(shape)
        if ov is not None:
            return self._run_overlap(carry, ov, batches, lrs)
        if (self.tail_fallback and is_tail and len(shape) > 1
                and shape not in self._programs):
            return self._run_per_step(carry, shape, batches, lrs)
        program = self.program_for(shape)
        carry, metrics = program(carry, batches, lrs)
        self.stats.dispatches += 1
        self.stats.steps += len(shape)
        self.stats.cycles += 1
        return carry, metrics

    def _ov_exchange(self) -> Callable:
        if "exchange" not in self._ov_fns:
            self._ov_fns["exchange"] = jax.jit(
                self.strategy.overlap_exchange_fn())
        return self._ov_fns["exchange"]

    def _ov_merge(self, staleness: int, extra: int) -> Callable:
        key = ("merge", staleness, extra)
        if key not in self._ov_fns:
            self._ov_fns[key] = jax.jit(
                self.strategy.overlap_merge_fn(staleness, extra))
        return self._ov_fns[key]

    def _run_overlap(self, carry, ov: OverlapCycle, batches, lrs):
        """Execute one overlap cycle as three programs: (1) the exchange
        on the pending snapshot, (2) the collective-free compute run over
        the cycle's batches, (3) the stale merge + deferred loss
        reduction. Under JAX's async dispatch (1) and (2) execute
        concurrently — (2) has no data dependence on (1), and by the
        overlap-safety contract it carries no outer-axis collective that
        could interleave with the exchange on the wire. The host blocks on
        compute first, then on the exchange, so the extra wait attributed
        to the exchange is exactly the part compute failed to hide
        (`overlap_exchange_visible_s`). With `serial_exchange` the
        exchange is awaited up front — same numerics, blocking cost
        (`overlap_exchange_blocking_s`) — which is the baseline leg of
        benchmarks/overlap.py's hidden-fraction measurement."""
        params, opt_state, _inflight_old, pending = carry
        exchange = self._ov_exchange()
        merge = self._ov_merge(ov.staleness, ov.extra_staleness)
        program = self.program_for(ov.compute_shape)
        # every leg ends on a jax.block_until_ready and the boundary
        # timestamps are shared between consecutive legs, so the three
        # stats legs partition the dispatch wall time EXACTLY (device
        # completion, never async dispatch returns) — the invariant
        # tests/test_overlap.py asserts
        t0 = time.perf_counter()
        if self.serial_exchange:
            with self.tracer.span("ov_exchange_blocking", cat="executor"):
                inflight = exchange(pending)
                jax.block_until_ready(inflight)
                t1 = time.perf_counter()
                self.stats.overlap_exchange_blocking_s += t1 - t0
            with self.tracer.span("ov_compute", cat="executor",
                                  steps=len(ov.compute_shape)):
                (params, opt_state), m = program((params, opt_state),
                                                 batches, lrs)
                jax.block_until_ready(params)
                t2 = time.perf_counter()
                self.stats.overlap_compute_s += t2 - t1
        else:
            with self.tracer.span("ov_compute", cat="executor",
                                  steps=len(ov.compute_shape)):
                inflight = exchange(pending)      # in flight, not awaited
                (params, opt_state), m = program((params, opt_state),
                                                 batches, lrs)
                jax.block_until_ready(params)
                t1 = time.perf_counter()
                self.stats.overlap_compute_s += t1 - t0
            with self.tracer.span("ov_exchange_visible", cat="executor"):
                jax.block_until_ready(inflight)
                t2 = time.perf_counter()
                self.stats.overlap_exchange_visible_s += t2 - t1
        with self.tracer.span("ov_merge", cat="executor",
                              staleness=ov.staleness,
                              extra=ov.extra_staleness):
            params, loss = merge(params, inflight, m["loss_per_replica"])
            jax.block_until_ready(params)
            t3 = time.perf_counter()
            self.stats.overlap_merge_s += t3 - t2
        self.stats.overlap_wall_s += t3 - t0
        metrics = dict(m)
        metrics["loss"] = loss
        # pending <- merged params (by reference — donation is off under
        # overlap, so the alias is safe): the next cycle's exchange sends
        # exactly the params this cycle's merge produced
        carry = (params, opt_state, inflight, params)
        self.stats.dispatches += 3
        self.stats.steps += len(ov.compute_shape)
        self.stats.cycles += 1
        self.stats.overlap_cycles += 1
        return carry, metrics

    def _run_per_step(self, carry, shape: CycleShape, batches, lrs):
        """Irregular-tail fallback: the old one-dispatch-per-step path, so a
        shape used exactly once never pays a fresh compilation."""
        chunks = []
        for i, (mode, stale) in enumerate(shape):
            fn = self._per_step_fn(mode, stale)
            batch = jax.tree.map(lambda x, j=i: x[j], batches)
            carry, m = fn(carry, batch, lrs[i])
            chunks.append(jax.tree.map(lambda x: x[None], m))
            self.stats.dispatches += 1
            self.stats.fallback_steps += 1
        metrics = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *chunks)
        return carry, metrics


def resolve_executor(strategy: Strategy,
                     executor: Optional[MacroCycleExecutor],
                     placement) -> Tuple[MacroCycleExecutor, object]:
    """One rule for marrying a (possibly caller-built) executor with a
    (possibly absent) placement: build the executor if needed, hand it the
    placement unless it already carries one, and return the placement that
    is actually in force. Shared by `run_compiled_training` and the
    resilience supervisor so the two dispatch loops cannot drift."""
    ex = executor or MacroCycleExecutor(strategy, placement=placement)
    if placement is not None and ex.placement is None:
        ex.placement = placement
    return ex, ex.placement


def shape_sync_counts(shape: CycleShape) -> Dict[str, int]:
    """Per-level sync tally of ONE cycle shape — the plan-side counterpart
    of `DasoController.level_sync_counts` (which tallies the whole
    history). Cycle trace spans carry this so tools/trace_report.py can
    regress per-level sync costs out of cycle durations."""
    counts: Dict[str, int] = {"_outer": 0}
    for (m, _) in shape:
        if m.startswith(OVERLAP_COMPUTE_PREFIX):
            m = m[len(OVERLAP_COMPUTE_PREFIX):]
        outer, inner = split_mode(m)
        if split_ov(outer)[0] in (Mode.SEND, Mode.SEND_RECEIVE,
                                  Mode.BLOCKING, Mode.HARD_AVG,
                                  Mode.OV_SYNC, Mode.GOSSIP,
                                  Mode.ELASTIC, Mode.PUSH):
            counts["_outer"] += 1
        for name in inner:
            counts[name] = counts.get(name, 0) + 1
    return counts


def dispatch_planned_cycle(ex: MacroCycleExecutor, carry, plan: CyclePlan,
                           data_fn: Callable, lr_fn: Callable,
                           n_steps: int):
    """Stage one planned cycle's batches/lrs, execute it, and convert the
    stacked device metrics to host floats. Returns (carry, cycle_losses,
    per_step_metrics). Shared by `run_compiled_training` and the resilience
    supervisor so the two dispatch loops cannot silently drift.

    The whole staging -> dispatch -> host-fetch sequence is one "cycle"
    trace span: the np.asarray conversion below forces device completion,
    so the span duration is the cycle's true wall cost, not its async
    dispatch cost. The span's args carry the per-level sync counts and a
    fresh_compile flag (first execution of a shape pays its XLA
    compilation inside this span) — everything the drift-table fit needs."""
    compiles0, fallback0 = ex.stats.compiles, ex.stats.fallback_steps
    with ex.tracer.span("cycle", cat="executor",
                        start_step=plan.start_step, steps=len(plan),
                        syncs=shape_sync_counts(plan.shape)) as sp:
        steps = range(plan.start_step, plan.start_step + len(plan))
        per_step = [data_fn(t) for t in steps]
        lr_list = [lr_fn(t) for t in steps]
        if ex.placement is not None:
            batches, lrs = ex.placement.stage_cycle(per_step, lr_list)
        else:
            batches = jax.tree.map(lambda *xs: jnp.stack(xs), *per_step)
            lrs = jnp.asarray(lr_list, jnp.float32)
        carry, metrics = ex.run_cycle(
            carry, plan, batches, lrs,
            is_tail=plan.start_step + len(plan) >= n_steps)
        # per-replica diagnostics may be sharded across processes in a
        # distributed run; only host-fetchable metrics (scalars are always
        # replicated) feed the loss trace
        host = {k: np.asarray(v) for k, v in metrics.items()
                if flatbuf.host_fetchable(v)}
        if ex.tracer.enabled:
            # span args serialize at __exit__, so outcome flags can land
            # after the fact
            sp.args["fresh_compile"] = ex.stats.compiles > compiles0
            sp.args["fallback"] = ex.stats.fallback_steps > fallback0
    cycle_losses = [float(host["loss"][j]) for j in range(len(plan))]
    per_step_metrics = [{k: float(v[j]) for k, v in host.items()
                         if v.ndim == 1} for j in range(len(plan))]
    if ex.health is not None:
        # progress report AFTER the host conversion above forced the
        # cycle's collectives to complete: the watchdog deadline only
        # moves when the group demonstrably made it through the exchange
        ex.health.cycle_done(plan.start_step + len(plan))
    return carry, cycle_losses, per_step_metrics


def run_compiled_training(strategy: Strategy, params0, data_fn: Callable,
                          lr_fn: Callable, n_steps: int, *,
                          executor: Optional[MacroCycleExecutor] = None,
                          track_divergence: bool = False,
                          start_step: int = 0, carry=None,
                          ckpt_every: int = 0,
                          ckpt_cb: Optional[Callable] = None,
                          placement=None):
    """Macro-cycle counterpart of `simulator.run_per_step_training`: plans
    cycles from the strategy's controller, stacks the per-step batches, and
    dispatches one compiled program per cycle. Numerically equivalent to the
    per-step path (allclose at f32; tests/test_executor.py).

    With `track_divergence` the replica divergence is sampled once per cycle
    (the per-step path samples every step) — it is a host-side diagnostic
    that would otherwise force a per-step sync point.

    Resume/checkpoint surface (checkpoint/io.py TrainState): pass
    `start_step` + the restored `carry` to continue a run (the strategy's
    controller must already be restored — train/loop.py does both), and
    `ckpt_every` + `ckpt_cb(completed_steps, carry, losses)` to snapshot.
    The callback fires at the first *cycle boundary* at or past each
    `ckpt_every` multiple — a checkpointed step is therefore always a step
    where a fresh run also had a plan boundary, which is what makes a
    resumed schedule (and hence the numerics) identical to an
    uninterrupted run.

    `placement` (launch.distributed.MeshPlacement) runs the identical loop
    over the global topology mesh: carry and batches are sharded over the
    replica-level axes, final params are gathered to host. The compiled
    programs do not depend on the process count, which is what makes an
    N-process run bit-exact with the 1-process one
    (tests/test_multiprocess.py).
    """
    from repro.core.simulator import SimResult

    ex, placement = resolve_executor(strategy, executor, placement)
    carry = strategy.init_carry(params0) if carry is None else carry
    if placement is not None:
        carry = placement.put_carry(carry)
    losses: List[float] = []
    metrics_log: List[Dict[str, float]] = []
    divs: List[float] = []
    step = start_step
    next_ckpt = ((start_step // ckpt_every + 1) * ckpt_every
                 if ckpt_every else None)
    while step < n_steps:
        plan = strategy.plan_cycle(step, min(ex.max_cycle_len,
                                             n_steps - step))
        carry, cycle_losses, per_step_metrics = dispatch_planned_cycle(
            ex, carry, plan, data_fn, lr_fn, n_steps)
        losses.extend(cycle_losses)
        metrics_log.extend(per_step_metrics)
        strategy.observe(cycle_losses)
        if track_divergence:
            d = strategy.divergence(carry)
            if d is not None:
                divs.extend([d] * len(plan))
        step += len(plan)
        if next_ckpt is not None and ckpt_cb is not None and step >= next_ckpt:
            with ex.tracer.span("checkpoint_save", cat="checkpoint",
                                step=step):
                ckpt_cb(step, carry, losses)
            next_ckpt = (step // ckpt_every + 1) * ckpt_every
    params = (placement.finalize_params(strategy, carry)
              if placement is not None
              else strategy.finalize_params(carry))
    return SimResult(losses=losses, metrics=metrics_log, params=params,
                     sync_fraction=strategy.sync_fraction(),
                     controller=strategy.controller, divergence=divs,
                     executor_stats=ex.stats)


# registered on import so every registry consumer (launch/train.py argparse
# choices, train/loop.py, the conformance suite) sees the baseline family;
# imported last because baselines.py subclasses DasoStrategy from this module
from repro.core import baselines  # noqa: E402,F401
