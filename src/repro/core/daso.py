"""DASO core: hierarchical + asynchronous + selective optimization in SPMD JAX.

Layout-agnostic, level-parameterized formulation. Every parameter leaf
carries a leading *replica* axis of size R — one entry per unit of the
finest replica level of the cluster topology (repro/topo; in the paper's
two-level special case, one per node/pod). Inside a replica sits the
innermost topology tier (the `data` mesh axis); the replica axis itself can
span any number of outer tiers (host, pod, ...), inner levels varying
fastest in the replica index. The per-replica training step runs under
vmap, and syncs hit the levels like this:

  * level-0 sync — the loss mean over the per-replica batch makes XLA emit
    a gradient all-reduce over the intra-replica "data" axis only (fast
    NVLink/ICI): exactly the paper's node-local NCCL gradient averaging,
    every step.
  * inner-level sync — `level_group_mean` averages params over contiguous
    replica groups of size g_l (all replicas inside one unit of level l): a
    synchronous tier-l parameter average, one collective per arena spanning
    exactly that level's mesh axes, every B_l steps (scheduled by
    `HierDasoController`; absent from 2-level specs).
  * outermost sync — a mean over the full replica axis lowers to the
    slowest-tier (cross-pod / DCN) all-reduce: exactly the paper's MPI
    group exchange. It appears in the HLO only in the step variants that
    perform it. Every level's exchange runs on the fused flat-buffer arena
    (core/flatbuf.py): the parameter pytree is packed into one contiguous
    buffer per dtype, so a sync at any level is ONE collective per arena
    regardless of leaf count (Horovod-style tensor fusion), with the wire
    tier (f32 | bf16 | int8 block-scaled) applied to the whole arena at
    once (kernels/comm_kernels.py).

Step variants (selected by the host-side controllers in core/schedule.py,
mirroring the MPI process flow of paper Fig. 5; static per-variant
compilation keeps each HLO's collective set exact for the roofline audit).
The outermost level's action is one of:

  local     forward/backward + local optimizer step only
  send      local + snapshot params and start the outermost exchange:
            inflight <- mean_replicas(params)
  receive   local + merge the (now stale, S steps old) exchange result via
            paper Eq. (1):  x = (2S * x_local + P * x_stale_mean) / (2S + P)
            — P generalizes per level as the world size of the level that
            went stale (the full world for the outermost level)
  blocking  local + synchronous global parameter average with bf16
            transfer compression (warm-up / cool-down phases)
  hard_avg  local + naive parameter overwrite (local-SGD ablation)

and `inner_syncs` on `daso_train_step` adds the synchronous group averages
of whichever intermediate levels tick that step — empty for the paper's
two-level layout, which keeps that case's compiled step graph identical to
the pre-topology build.

Every variant optionally bakes a static elastic-membership mask
(`membership=` on `daso_train_step`): exchanges at every level become
membership-weighted means over the active replicas of each group (still one
collective per sync per level), Eq. (1) runs with the effective world size,
and dropped replicas' rows are frozen ghosts until a rejoin re-seeds them
(src/repro/resilience/; fault plans may name whole topology subtrees).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import flatbuf
from repro.optim.optimizers import Optimizer

EXCHANGE_IMPLS = ("fused", "per_leaf")

# Compute/communication overlap of the outermost exchange (DasoConfig
# .overlap). "off" keeps the paper-faithful in-cycle dataflow — the step
# graphs are bit-identical to the pre-overlap build. "one_cycle"
# double-buffers the exchange: each cycle all-reduces the PREVIOUS cycle's
# parameter snapshot (the `pending` arena) while the next B local steps
# run, and merges the result one cycle stale via Eq. (1) with the extra
# buffer age added to S (see `daso_overlap_step`).
OVERLAP_MODES = ("off", "one_cycle")


@dataclass(frozen=True)
class DasoConfig:
    n_replicas: int              # R: paper "nodes" (pods / virtual nodes)
    global_world: int            # P in Eq. (1): GPUs in the global network
    b_max: int = 4               # paper: max batches between global syncs
    warmup_steps: int = 0
    cooldown_steps: int = 0
    total_steps: int = 0
    compress_blocking: bool = True
    # BEYOND-PAPER: the paper skips 16-bit packaging for non-blocking sends
    # (MPI packaging delays the Isend). In SPMD/XLA the cast fuses into the
    # collective with no launch delay, so compressing the cycling-phase
    # exchange halves DCN bytes for free. Default False = paper-faithful.
    compress_nonblocking: bool = False
    plateau_patience: int = 5
    plateau_threshold: float = 1e-3
    # Wire format of the global exchange: None derives it from the
    # compress_* flags per phase (bf16 or f32); "f32" | "bf16" | "int8"
    # forces one tier for both phases. int8 is the beyond-paper
    # block-scaled tier (QSGD-style, see core/flatbuf.py).
    wire_format: Optional[str] = None
    # "fused" = flat-buffer arena exchange (one cross-replica reduction per
    # global sync regardless of leaf count); "per_leaf" = the legacy
    # one-collective-per-leaf reference path (equivalence oracle).
    exchange_impl: str = "fused"
    # Transport-invariant exchanges: every cross-replica mean runs as an
    # explicitly associated chain of adds (flatbuf.chain_axis0_sum) instead
    # of one lax.reduce, so results are bit-identical for ANY process
    # layout of the replica axis. The multi-process runtime switches this
    # on (its 1-process oracle too); default False keeps the
    # one-collective-per-arena HLO contract and single-program perf.
    deterministic_reduce: bool = False
    # Route the arena's elementwise exchange math (Eq.(1) merge, wire
    # casts, int8 codec) through the Pallas kernels in
    # repro.kernels.comm_kernels instead of plain jnp. Default False: the
    # jnp path lowers to HLO the SPMD partitioner can shard exactly, which
    # the cross-pod traffic audit (tests/test_distributed.py) relies on;
    # flip on for single-device arenas and compiled TPU kernels.
    exchange_kernels: bool = False
    int8_block: int = 256        # elements per int8 scale block
    # True asynchronous overlap of the outermost exchange ("off" |
    # "one_cycle", see OVERLAP_MODES above). With "one_cycle" the strategy
    # carry grows a fourth slot (the `pending` snapshot arena) and the
    # schedule switches to the ov_start/ov_sync cycle family.
    overlap: str = "off"

    def __post_init__(self):
        if self.wire_format is not None:
            flatbuf._check_wire_format(self.wire_format)
        if self.overlap not in OVERLAP_MODES:
            raise ValueError(f"unknown overlap mode {self.overlap!r}; "
                             f"expected one of {OVERLAP_MODES}")
        if self.exchange_impl not in EXCHANGE_IMPLS:
            raise ValueError(f"unknown exchange_impl "
                             f"{self.exchange_impl!r}; "
                             f"expected one of {EXCHANGE_IMPLS}")
        if self.wire_format == "int8" and self.exchange_impl == "per_leaf":
            raise ValueError("int8 wire format requires the fused arena "
                             "exchange (exchange_impl='fused')")

    def wire_format_for(self, *, blocking: bool) -> str:
        """Resolve the wire tier of a global exchange: the explicit
        `wire_format` if set, else bf16/f32 from the per-phase flag."""
        if self.wire_format is not None:
            return self.wire_format
        flag = self.compress_blocking if blocking \
            else self.compress_nonblocking
        return "bf16" if flag else "f32"


# -- replica-axis helpers ----------------------------------------------------

def replicate_params(params, n_replicas: int):
    return jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n_replicas,) + p.shape), params)


def dereplicate_params(params, index: int = 0):
    return jax.tree.map(lambda p: p[index], params)


def _wire_format_from(wire_dtype, wire_format) -> str:
    """Back-compat shim: map the legacy `wire_dtype` argument (None /
    jnp.bfloat16) onto the wire-format tiers."""
    if wire_format is not None:
        return flatbuf._check_wire_format(wire_format)
    if wire_dtype is None:
        return "f32"
    if jnp.dtype(wire_dtype) == jnp.dtype(jnp.bfloat16):
        return "bf16"
    if jnp.dtype(wire_dtype) == jnp.dtype(jnp.float32):
        return "f32"
    raise ValueError(f"unsupported wire_dtype {wire_dtype!r}; use "
                     f"wire_format={flatbuf.WIRE_FORMATS}")


def _arena_mean(arena, wire_format: str, *, int8_block: int,
                use_kernels: bool, mask=None, deterministic: bool = False):
    """Mean over the leading replica axis of one arena, kept as a (1, N)
    buffer (the caller broadcasts per leaf after unpacking — one full-size
    materialization instead of two). Exactly one axis-0 reduction per
    arena — the op that lowers to the cross-pod (DCN) all-reduce on the
    production mesh.

    `mask` (a normalized membership tuple, see
    `flatbuf.normalize_membership`) makes the mean membership-weighted:
    dropped replicas' rows are zeroed before the reduce and the divisor is
    the active count — still one collective, the elastic-membership
    contract (tests/test_resilience.py)."""
    if not jnp.issubdtype(arena.dtype, jnp.floating):
        # integer leaves cross the wire at their own dtype; the mean is
        # computed in f32 and rounded back (an int-dtype reduce would
        # truncate the 1/R scale to zero)
        w = arena.astype(jnp.float32)
        return jnp.round(flatbuf.masked_axis0_mean(
            w, mask, deterministic)).astype(arena.dtype)
    if wire_format == "int8":
        # each replica quantizes its arena (int8 + per-block scales is what
        # a real DCN transfer would carry); the mean runs over the
        # dequantized values in f32. Round-to-nearest (no rng_key): the
        # step variants are statically specialized and take no RNG, so the
        # unbiased stochastic tier stays a codec/kernel-API option.
        deq = flatbuf.wire_roundtrip(arena, "int8", int8_block=int8_block,
                                     use_kernels=use_kernels)
        return flatbuf.masked_axis0_mean(
            deq, mask, deterministic).astype(arena.dtype)
    # Pin the reduction computation dtype by reducing the wire-cast arena
    # directly (flatbuf.masked_axis0_mean uses lax.reduce): both jnp.mean
    # and jnp.sum(dtype=...) silently upcast bf16 accumulation to f32,
    # which puts f32 on the cross-pod wire (verified in HLO).
    w = (flatbuf.encode_wire(arena, "bf16", use_kernels=use_kernels)
         if wire_format == "bf16" else arena)
    return flatbuf.masked_axis0_mean(w, mask,
                                     deterministic).astype(arena.dtype)


def replica_mean_per_leaf(tree, wire_dtype=None, mask=None,
                          deterministic: bool = False):
    """Legacy per-leaf exchange: one cross-pod all-reduce PER LEAF. Kept as
    the equivalence oracle and microbenchmark baseline for the fused arena
    path (`replica_mean`); f32/bf16 wire only. `mask` applies the same
    membership weighting as the fused path."""
    def leaf(x):
        wd = jnp.dtype(wire_dtype or x.dtype)
        m = flatbuf.masked_axis0_mean(x.astype(wd), mask, deterministic)
        return jnp.broadcast_to(m, x.shape).astype(x.dtype)
    return jax.tree.map(leaf, tree)


def replica_mean(tree, wire_dtype=None, *, wire_format=None,
                 impl: str = "fused", int8_block: int = 256,
                 use_kernels: bool = False, mask=None,
                 deterministic: bool = False):
    """Mean over the leading replica axis, broadcast back.

    Default path packs the pytree into one contiguous arena per dtype
    (core/flatbuf.py) so the whole exchange is ONE cross-replica reduction
    regardless of leaf count; `wire_format` ("f32" | "bf16" | "int8")
    selects the transfer tier. `impl="per_leaf"` restores the legacy
    one-collective-per-leaf reference path. `wire_dtype` is the legacy
    spelling (None = uncompressed, jnp.bfloat16 = 16-bit packaging).
    `mask` (normalized membership tuple, or None = all active) restricts
    the mean to active replicas — the elastic-membership exchange."""
    wf = _wire_format_from(wire_dtype, wire_format)
    if impl == "per_leaf":
        if wf == "int8":
            raise ValueError("int8 wire format requires the fused arena "
                             "exchange (impl='fused')")
        return replica_mean_per_leaf(
            tree, jnp.bfloat16 if wf == "bf16" else None, mask=mask,
            deterministic=deterministic)
    layout = flatbuf.build_layout(tree, batch_dims=1)
    arenas = flatbuf.pack(tree, layout)
    out = {k: _arena_mean(a, wf, int8_block=int8_block,
                          use_kernels=use_kernels, mask=mask,
                          deterministic=deterministic)
           for k, a in arenas.items()}
    # unpack the (1, N) means, then broadcast per leaf: the broadcast fuses
    # into each leaf's consumer instead of materializing a second full-size
    # arena before slicing
    mean_tree = flatbuf.unpack(out, layout)
    r = layout.batch_shape[0]
    return jax.tree.map(
        lambda m: jnp.broadcast_to(m, (r,) + m.shape[1:]), mean_tree)


def _arena_group_mean(arena, group_size: int, mask=None,
                      deterministic: bool = False):
    """Mean over contiguous replica groups of size `group_size` on one
    arena: reshape (R, N) -> (R/g, g, N), ONE `lax.reduce` over the group
    axis, broadcast back. On a topology-lowered mesh the group axis is
    exactly the syncing level's mesh axes, so this is one tier-l collective
    per arena — the per-level one-collective contract
    (tests/test_topology.py).

    `mask` (normalized membership tuple) weights the mean by each group's
    active rows; a fully-dead group divides by 1 (its rows are frozen
    ghosts that `freeze_inactive` pins anyway)."""
    r = arena.shape[0]
    if group_size == r:
        return jnp.broadcast_to(
            flatbuf.masked_axis0_mean(arena, mask, deterministic),
            arena.shape)
    if r % group_size:
        raise ValueError(f"replica axis {r} not divisible by group size "
                         f"{group_size}")
    g, n_groups = group_size, r // group_size
    w = arena if mask is None else arena * flatbuf.membership_col(
        mask, arena.dtype, arena.ndim)
    wr = jnp.reshape(w, (n_groups, g) + arena.shape[1:])
    if deterministic:
        # same chain formulation as flatbuf.chain_axis0_sum, over the
        # group axis: order-fixed adds, transport-invariant result
        s = wr[:, 0]
        for i in range(1, g):
            s = s + wr[:, i]
    else:
        s = jax.lax.reduce(wr, jnp.zeros((), arena.dtype), jax.lax.add, (1,))
    if mask is None:
        inv = jnp.asarray(1.0 / g, arena.dtype)
    else:
        counts = [max(1.0, sum(mask[i * g:(i + 1) * g]))
                  for i in range(n_groups)]
        inv = jnp.asarray([1.0 / c for c in counts], arena.dtype).reshape(
            (n_groups,) + (1,) * (arena.ndim - 1))
    m = s * inv
    return jnp.reshape(
        jnp.broadcast_to(m[:, None], (n_groups, g) + arena.shape[1:]),
        arena.shape)


def normalize_group_perm(perm, n_replicas: int):
    """Validate and canonicalize a replica regrouping permutation: a tuple
    permutation of ``range(n_replicas)`` mapping *group slot* -> *replica
    index* (slot i holds replica perm[i], so consecutive slots share an
    inner group). The identity normalizes to None — the unpermuted HLO —
    so callers can compare against the fast path cheaply."""
    if perm is None:
        return None
    perm = tuple(int(i) for i in perm)
    if sorted(perm) != list(range(n_replicas)):
        raise ValueError(f"group permutation {perm!r} is not a permutation "
                         f"of range({n_replicas})")
    return None if perm == tuple(range(n_replicas)) else perm


def _permuted_group_mean(arena, group_size: int, mask, deterministic: bool,
                         perm):
    """`_arena_group_mean` under a replica regrouping: gather the rows into
    slot order, group-mean contiguous slots, scatter back to replica order.
    `perm` is static, so the gathers compile to fixed-index slices that XLA
    fuses into the reduction; mask weights travel with their rows. A
    whole-world group is permutation-invariant, so it skips the gathers."""
    if perm is None or group_size == arena.shape[0]:
        return _arena_group_mean(arena, group_size, mask, deterministic)
    idx = jnp.asarray(perm, dtype=jnp.int32)
    inv = [0] * len(perm)
    for slot, rep in enumerate(perm):
        inv[rep] = slot
    pmask = None if mask is None else tuple(mask[i] for i in perm)
    gm = _arena_group_mean(jnp.take(arena, idx, axis=0), group_size,
                           pmask, deterministic)
    return jnp.take(gm, jnp.asarray(inv, dtype=jnp.int32), axis=0)


def level_group_mean(tree, group_size: int, *, wire_format: str = "f32",
                     use_kernels: bool = False, mask=None,
                     deterministic: bool = False, perm=None):
    """Synchronous parameter average over contiguous replica groups of
    `group_size` — the sync primitive of one intermediate topology level
    (repro/topo: group_size = prod of replica-level fanouts up to the
    syncing level, so each group is the set of replicas inside one unit of
    that level; inner levels vary fastest in the replica index).

    Runs on the fused flat-buffer arenas, one group reduction per arena
    regardless of leaf count. `wire_format` selects the tier-l transfer
    dtype ("f32" default — intermediate links are fast; "bf16" for the
    paper-style 16-bit packaging; int8 is outermost-only). `group_size ==
    R` degenerates to the full replica mean (= `replica_mean`).

    `perm` (see `normalize_group_perm`) regroups the replicas before the
    mean: slot order replaces replica order, so which replicas share a
    group becomes a static schedule choice — the straggler-aware
    reshuffle knob (repro.topo.probe.skew_permutation). Every group mean
    preserves its group's sum and the groups partition the rows, so the
    exact global mean is invariant under ANY permutation
    (tests/test_tuning.py pins this as a hypothesis property)."""
    if wire_format not in ("f32", "bf16"):
        raise ValueError("level_group_mean supports wire_format 'f32' | "
                         f"'bf16', got {wire_format!r} (the int8 tier is "
                         "for the outermost exchange)")
    layout = flatbuf.build_layout(tree, batch_dims=1)
    arenas = flatbuf.pack(tree, layout)
    perm = normalize_group_perm(perm, layout.batch_shape[0])
    out = {}
    for k, a in arenas.items():
        if not jnp.issubdtype(a.dtype, jnp.floating):
            w = a.astype(jnp.float32)
            out[k] = jnp.round(_permuted_group_mean(
                w, group_size, mask, deterministic, perm)).astype(a.dtype)
            continue
        w = (flatbuf.encode_wire(a, "bf16", use_kernels=use_kernels)
             if wire_format == "bf16" else a)
        out[k] = _permuted_group_mean(w, group_size, mask,
                                      deterministic, perm).astype(a.dtype)
    return flatbuf.unpack(out, layout)


def replica_divergence(params) -> jnp.ndarray:
    """Max abs deviation of any replica from the replica mean (diagnostic)."""
    def leaf(x):
        x = x.astype(jnp.float32)
        return jnp.max(jnp.abs(x - x.mean(axis=0, keepdims=True)))
    return functools.reduce(jnp.maximum,
                            [leaf(x) for x in jax.tree.leaves(params)])


# -- elastic membership --------------------------------------------------------

def freeze_inactive(new_tree, old_tree, mask):
    """Select per replica row: active rows advance to `new_tree`, dropped
    rows keep `old_tree`. A dropped replica's row is a ghost in the SPMD
    emulation (the real node is gone); freezing it keeps the ghost from
    drifting so a later rejoin re-seed is the only thing that writes it.
    mask=None (all active) is the identity."""
    if mask is None:
        return new_tree
    keep = jnp.asarray([m != 0.0 for m in mask])

    def leaf(n, o):
        col = keep.reshape((len(mask),) + (1,) * (n.ndim - 1))
        return jnp.where(col, n, o)

    return jax.tree.map(leaf, new_tree, old_tree)


# -- DASO primitive operations ------------------------------------------------

def global_send(params, *, compress: bool = False, wire_format=None,
                impl: str = "fused", int8_block: int = 256,
                use_kernels: bool = False, mask=None,
                deterministic: bool = False):
    """Snapshot + start global exchange: returns the in-flight buffer
    (replica mean of current params, one copy per replica). The wire tier
    comes from `wire_format` (or legacy compress=True -> bf16,
    beyond-paper for the non-blocking path, see DasoConfig). `mask`
    restricts the mean to active replicas (elastic membership)."""
    wf = wire_format or ("bf16" if compress else "f32")
    return replica_mean(params, wire_format=wf, impl=impl,
                        int8_block=int8_block, use_kernels=use_kernels,
                        mask=mask, deterministic=deterministic)


def global_receive_per_leaf(params, inflight, *, staleness: int,
                            global_world: int, extra_staleness: int = 0):
    """Legacy per-leaf Eq. (1) merge (one fused-multiply chain per leaf);
    equivalence oracle for the fused arena merge. `extra_staleness` adds
    the overlap executor's one-cycle buffer age to S (0 = pre-overlap
    math, bit-exact)."""
    s2 = jnp.asarray(2.0 * (staleness + extra_staleness), jnp.float32)
    p_ = jnp.asarray(float(global_world), jnp.float32)
    denom = s2 + p_

    def leaf(x_local, x_stale):
        merged = (s2 * x_local.astype(jnp.float32)
                  + p_ * x_stale.astype(jnp.float32)) / denom
        return merged.astype(x_local.dtype)

    return jax.tree.map(leaf, params, inflight)


def global_receive(params, inflight, *, staleness: int, global_world,
                   impl: str = "fused", use_kernels: bool = False,
                   mask=None, extra_staleness: int = 0):
    """Paper Eq. (1): weighted merge of stale global average with current
    local params. staleness S = batches waited; global_world P — a float
    under elastic membership (the effective P of the surviving world,
    `global_world * n_active / n_replicas`), so the merge weighting tracks
    dynamic membership. Dropped replicas' rows stay frozen (`mask`).
    `extra_staleness` is the overlap executor's one-cycle buffer age — it
    adds to S in the weighting (the stale buffer really is that much
    older); 0 keeps the pre-overlap merge bit-exact.

    The merge has no collective, so in jnp-land XLA already fuses the
    leaf-wise multiply-add chains into one elementwise pass — packing an
    arena would only add two copies. With `use_kernels=True` the merge
    runs as ONE Pallas `eq1_merge` program over the packed arena (the
    TPU-kernel tier, where a single contiguous launch is the point)."""
    if impl == "per_leaf":
        merged = global_receive_per_leaf(params, inflight,
                                         staleness=staleness,
                                         global_world=global_world,
                                         extra_staleness=extra_staleness)
        return freeze_inactive(merged, params, mask)
    from repro.kernels.ref import eq1_merge_ref
    if not use_kernels:
        merged = jax.tree.map(
            lambda a, b: eq1_merge_ref(a, b, staleness=staleness,
                                       global_world=global_world,
                                       extra_staleness=extra_staleness),
            params, inflight)
        return freeze_inactive(merged, params, mask)
    from repro.kernels.ops import eq1_merge
    layout = flatbuf.build_layout(params, batch_dims=1)
    locals_ = flatbuf.pack(params, layout)
    stales = flatbuf.pack(inflight, layout)
    out = {k: (eq1_merge(a, stales[k], staleness=staleness,
                         global_world=global_world,
                         extra_staleness=extra_staleness)
               if jnp.issubdtype(a.dtype, jnp.floating) else
               eq1_merge_ref(a, stales[k], staleness=staleness,
                             global_world=global_world,
                             extra_staleness=extra_staleness))
           for k, a in locals_.items()}
    return freeze_inactive(flatbuf.unpack(out, layout), params, mask)


def blocking_sync(params, *, compress: bool = True, wire_format=None,
                  impl: str = "fused", int8_block: int = 256,
                  use_kernels: bool = False, mask=None,
                  deterministic: bool = False):
    """Synchronous global average (warm-up / cool-down), with the paper's
    16-bit transfer compression (or the tier in `wire_format`). `mask`
    restricts the average to active replicas and freezes dropped rows."""
    wf = wire_format or ("bf16" if compress else "f32")
    synced = replica_mean(params, wire_format=wf, impl=impl,
                          int8_block=int8_block, use_kernels=use_kernels,
                          mask=mask, deterministic=deterministic)
    return freeze_inactive(synced, params, mask)


# -- assembled train step ------------------------------------------------------

def microbatched_value_and_grad(loss_fn: Callable, n_micro: int):
    """Gradient accumulation: split the batch along its leading dim into
    n_micro chunks and lax.scan the fwd+bwd over them. Cuts the live
    activation/residual footprint ~n_micro-fold (beyond-paper memory
    optimization, EXPERIMENTS.md §Perf)."""
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    if n_micro <= 1:
        return grad_fn

    def fn(params, batch):
        micro = jax.tree.map(
            lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                + x.shape[1:]), batch)

        def body(carry, mb):
            loss_acc, aux_acc, g_acc = carry
            (loss, aux), g = grad_fn(params, mb)
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            aux_acc = jax.tree.map(jnp.add, aux_acc, aux)
            return (loss_acc + loss, aux_acc, g_acc), None

        (loss0, aux0), g0 = jax.eval_shape(grad_fn, params,
                                           jax.tree.map(lambda x: x[0],
                                                        micro))
        zeros = lambda t: jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), t)
        (loss, aux, grads), _ = jax.lax.scan(
            body, (jnp.zeros(loss0.shape, loss0.dtype), zeros(aux0),
                   zeros(g0)), micro)
        inv = 1.0 / n_micro
        scale = lambda t: jax.tree.map(
            lambda x: (x * inv).astype(x.dtype) if jnp.issubdtype(
                x.dtype, jnp.floating) else x, t)
        return (loss * inv, scale(aux)), scale(grads)

    return fn


def local_step(loss_fn: Callable, optimizer: Optimizer,
               spmd_axis_name: Optional[str] = None, n_micro: int = 1):
    """Returns step(params_R, opt_R, batch_R, lr) -> (params, opt, metrics).
    loss_fn(params, batch) -> (loss, aux). vmapped over the replica axis.

    On a mesh, pass spmd_axis_name="pod": sharding constraints inside the
    model then keep the replica dim pod-sharded (plain vmap would mark it
    replicated and force cross-pod all-gathers of every constrained
    activation — verified in the HLO audit, see EXPERIMENTS.md)."""
    grad_fn = microbatched_value_and_grad(loss_fn, n_micro)

    def one(params, opt_state, batch, lr):
        (loss, aux), grads = grad_fn(params, batch)
        new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
        return new_params, new_opt, loss, aux

    return jax.vmap(one, in_axes=(0, 0, 0, None),
                    spmd_axis_name=spmd_axis_name)


MODES = ("local", "send", "receive", "send_receive", "blocking", "hard_avg")

# Outermost-level actions of the overlap (double-buffered) schedule. The
# ov_* pair replaces send/receive in the cycling phase when
# DasoConfig.overlap == "one_cycle":
#   ov_start  local step + snapshot pending <- params (no exchange yet;
#             first cycling step, and the restart after any blocking phase)
#   ov_sync   local step + inflight <- mean(pending_old) [the one outer
#             all-reduce] + params <- Eq. (1) merge + pending <- params
OV_MODES = ("local", "ov_start", "ov_sync", "blocking")


def _cross_replica_loss(cfg: DasoConfig, mask, n_active: int,
                        loss_r, *, axis: int = 0):
    """The scalar training loss the plateau controller consumes: the mean
    of the per-replica losses over the ACTIVE replicas, reduced along
    `axis` (the replica axis). Shared by the in-step metric block of
    `daso_train_step` and the overlap merge program (where the reduction
    is deferred out of the compute program — it is a cross-process
    collective on a process-sharded replica axis, and the overlap contract
    requires the compute program to be collective-free). Deterministic
    mode uses the same order-fixed chain adds in both places, so deferring
    the reduction is bit-exact."""
    det = cfg.deterministic_reduce
    w_l = (jnp.ones((cfg.n_replicas,), loss_r.dtype) if mask is None
           else jnp.asarray(mask, loss_r.dtype))
    if axis != 0:
        loss_r = jnp.moveaxis(loss_r, axis, 0)
    shape = (cfg.n_replicas,) + (1,) * (loss_r.ndim - 1)
    weighted = loss_r * w_l.reshape(shape)
    if det:
        return flatbuf.chain_axis0_sum(weighted) / n_active
    if mask is None:
        return jnp.mean(loss_r, axis=0)
    return jnp.sum(weighted, axis=0) / n_active


def daso_train_step(loss_fn: Callable, optimizer: Optimizer, cfg: DasoConfig,
                    *, mode: str, staleness: int = 1,
                    spmd_axis_name: Optional[str] = None, n_micro: int = 1,
                    membership=None,
                    inner_syncs: Tuple[Tuple[str, int], ...] = (),
                    group_perm=None):
    """Build one statically-specialized DASO step function.

    step(params_R, opt_R, inflight, batch_R, lr)
        -> (params_R, opt_R, inflight, metrics)

    `mode` is the outermost level's action (one of MODES). `inner_syncs`
    is the step's intermediate-level phase vector: `(level_name,
    group_size)` pairs, innermost first, for every topology level whose
    period elapses this step — each adds one synchronous
    `level_group_mean` over that level's replica groups, applied after the
    local optimizer step and before the outermost send (so an outer
    exchange always ships tier-synced values). Empty (the default, and
    always for 2-level topologies) adds nothing: the compiled graph is the
    pre-topology one.

    `membership` (optional 0/1 mask over the R replicas) bakes elastic
    membership into the compiled step: exchanges at every level become
    membership-weighted means over the active set, Eq. (1) runs with the
    effective world size P_eff = P * n_active / R, dropped replicas' rows
    are frozen, and the reported loss averages active replicas only. The
    mask is a *static* constant — a membership change compiles new step
    variants (the executor invalidates its cycle cache, see
    resilience/supervisor.py), which keeps the fixed-membership HLO
    bit-identical to the non-elastic build.

    `group_perm` (normalize_group_perm) statically regroups the replicas
    for every inner-level sync — the straggler-aware reshuffle. Like the
    membership mask it is baked into the compiled step; changing it means
    new variants (DasoStrategy.set_group_permutation)."""
    assert mode in MODES, mode
    lstep = local_step(loss_fn, optimizer, spmd_axis_name=spmd_axis_name,
                       n_micro=n_micro)

    impl, kern, blk = (cfg.exchange_impl, cfg.exchange_kernels,
                       cfg.int8_block)
    det = cfg.deterministic_reduce
    perm = normalize_group_perm(group_perm, cfg.n_replicas)
    mask = flatbuf.normalize_membership(membership, cfg.n_replicas)
    n_active = cfg.n_replicas if mask is None else int(sum(mask))
    p_eff = (cfg.global_world if mask is None
             else cfg.global_world * n_active / cfg.n_replicas)
    for _name, g in inner_syncs:
        if not 1 < g <= cfg.n_replicas:
            raise ValueError(f"inner sync {_name!r}: group size {g} outside "
                             f"2..{cfg.n_replicas}")

    def step(params, opt_state, inflight, batch, lr):
        if mode in ("receive", "send_receive"):
            params = global_receive(params, inflight,
                                    staleness=staleness,
                                    global_world=p_eff,
                                    impl=impl, use_kernels=kern, mask=mask)
        new_p, new_o, loss_r, aux_r = lstep(params, opt_state, batch, lr)
        if mask is not None:
            new_p = freeze_inactive(new_p, params, mask)
            new_o = freeze_inactive(new_o, opt_state, mask)
        params, opt_state = new_p, new_o
        for _name, g in inner_syncs:
            params = freeze_inactive(
                level_group_mean(params, g, use_kernels=kern, mask=mask,
                                 deterministic=det, perm=perm),
                params, mask)
        if mode in ("send", "send_receive"):
            inflight = global_send(
                params, wire_format=cfg.wire_format_for(blocking=False),
                impl=impl, int8_block=blk, use_kernels=kern, mask=mask,
                deterministic=det)
        elif mode == "blocking":
            params = blocking_sync(
                params, wire_format=cfg.wire_format_for(blocking=True),
                impl=impl, int8_block=blk, use_kernels=kern, mask=mask,
                deterministic=det)
        elif mode == "hard_avg":
            params = freeze_inactive(
                replica_mean(params, impl=impl, mask=mask,
                             deterministic=det), params, mask)
        # the reported loss feeds the plateau controller on the host, so
        # it needs the same transport invariance as the exchanges
        loss = _cross_replica_loss(cfg, mask, n_active, loss_r)
        metrics = {"loss": loss, "loss_per_replica": loss_r}
        for k, v in aux_r.items():
            if isinstance(v, jnp.ndarray) and v.ndim <= 1:
                if (mask is not None and v.ndim == 1
                        and v.shape[0] == cfg.n_replicas):
                    metrics[k] = jnp.sum(
                        v * jnp.asarray(mask, v.dtype)) / n_active
                else:
                    metrics[k] = jnp.mean(v)
        return params, opt_state, inflight, metrics

    return step


def daso_overlap_step(loss_fn: Callable, optimizer: Optimizer,
                      cfg: DasoConfig, *, mode: str, staleness: int = 1,
                      extra_staleness: int = 0,
                      spmd_axis_name: Optional[str] = None, n_micro: int = 1,
                      membership=None,
                      inner_syncs: Tuple[Tuple[str, int], ...] = (),
                      group_perm=None):
    """Build one step variant of the double-buffered overlap schedule
    (DasoConfig.overlap == "one_cycle"). The carry grows a fourth slot —
    the `pending` snapshot arena awaiting its exchange:

    step(params_R, opt_R, inflight, pending, batch_R, lr)
        -> (params_R, opt_R, inflight, pending, metrics)

    `mode` is one of OV_MODES. Semantics (macro-executor order — the
    compiled overlap dispatch runs the same ops, just split across the
    exchange / compute / merge programs so the exchange can be in flight
    during the local steps):

      local     local optimizer step; both buffers pass through
      ov_start  local step, then pending <- params (snapshot only — the
                first cycling step has nothing in flight to merge)
      ov_sync   local step, then inflight <- mean(pending_old) [the ONE
                outer all-reduce, over the snapshot taken at the previous
                ov step], params <- Eq. (1) merge with S = staleness +
                extra_staleness (the snapshot's true age in batches),
                pending <- merged params
      blocking  local step + synchronous global average (warm-up /
                cool-down; buffers pass through — the next cycling phase
                restarts with ov_start, so a dangling snapshot is never
                merged)

    The merge lands AFTER the step's local update (off-mode `receive`
    merges before it): the exchange result arrives at the cycle boundary,
    which is exactly when the macro executor joins the in-flight
    collective with the computed params."""
    assert mode in OV_MODES, mode
    lstep = local_step(loss_fn, optimizer, spmd_axis_name=spmd_axis_name,
                       n_micro=n_micro)
    impl, kern, blk = (cfg.exchange_impl, cfg.exchange_kernels,
                       cfg.int8_block)
    det = cfg.deterministic_reduce
    perm = normalize_group_perm(group_perm, cfg.n_replicas)
    mask = flatbuf.normalize_membership(membership, cfg.n_replicas)
    n_active = cfg.n_replicas if mask is None else int(sum(mask))
    p_eff = (cfg.global_world if mask is None
             else cfg.global_world * n_active / cfg.n_replicas)
    for _name, g in inner_syncs:
        if not 1 < g <= cfg.n_replicas:
            raise ValueError(f"inner sync {_name!r}: group size {g} outside "
                             f"2..{cfg.n_replicas}")

    def step(params, opt_state, inflight, pending, batch, lr):
        new_p, new_o, loss_r, aux_r = lstep(params, opt_state, batch, lr)
        if mask is not None:
            new_p = freeze_inactive(new_p, params, mask)
            new_o = freeze_inactive(new_o, opt_state, mask)
        params, opt_state = new_p, new_o
        for _name, g in inner_syncs:
            params = freeze_inactive(
                level_group_mean(params, g, use_kernels=kern, mask=mask,
                                 deterministic=det, perm=perm),
                params, mask)
        if mode == "ov_start":
            pending = params
        elif mode == "ov_sync":
            inflight = global_send(
                pending, wire_format=cfg.wire_format_for(blocking=False),
                impl=impl, int8_block=blk, use_kernels=kern, mask=mask,
                deterministic=det)
            params = global_receive(params, inflight, staleness=staleness,
                                    extra_staleness=extra_staleness,
                                    global_world=p_eff, impl=impl,
                                    use_kernels=kern, mask=mask)
            pending = params
        elif mode == "blocking":
            params = blocking_sync(
                params, wire_format=cfg.wire_format_for(blocking=True),
                impl=impl, int8_block=blk, use_kernels=kern, mask=mask,
                deterministic=det)
        loss = _cross_replica_loss(cfg, mask, n_active, loss_r)
        metrics = {"loss": loss, "loss_per_replica": loss_r}
        for k, v in aux_r.items():
            if isinstance(v, jnp.ndarray) and v.ndim <= 1:
                if (mask is not None and v.ndim == 1
                        and v.shape[0] == cfg.n_replicas):
                    metrics[k] = jnp.sum(
                        v * jnp.asarray(mask, v.dtype)) / n_active
                else:
                    metrics[k] = jnp.mean(v)
        return params, opt_state, inflight, pending, metrics

    return step


def daso_overlap_compute_step(loss_fn: Callable, optimizer: Optimizer,
                              cfg: DasoConfig, *,
                              spmd_axis_name: Optional[str] = None,
                              n_micro: int = 1, membership=None,
                              inner_syncs: Tuple[Tuple[str, int],
                                                 ...] = (),
                              group_perm=None):
    """The compute-program half of one overlap-dispatched macro-cycle:

    step(params_R, opt_R, batch_R, lr) -> (params_R, opt_R, metrics)

    A plain local step (plus any inner-level group syncs) that is — by
    construction — free of collectives over the OUTER (cross-process)
    replica axes: the scalar-loss reduction of `daso_train_step` is a
    cross-replica reduce, so it is deferred to the merge program
    (`_cross_replica_loss` over the stacked per-replica losses, bit-exact
    in deterministic mode). That is the property that makes dispatching
    this program concurrently with the in-flight gloo exchange safe on the
    multi-process runtime (launch/distributed.py, dispatch="overlap"):
    at most one collective-bearing program is ever in flight, so the PR-5
    shared-TCP-pair interleaving failure cannot occur. Aux metrics are
    dropped here for the same reason (their means reduce over the replica
    axis). Inner-level syncs stay: the overlap dispatch validator requires
    them to be process-local (launch.distributed.check_overlap_topology),
    where they lower to in-process collectives gloo never sees."""
    lstep = local_step(loss_fn, optimizer, spmd_axis_name=spmd_axis_name,
                       n_micro=n_micro)
    kern = cfg.exchange_kernels
    det = cfg.deterministic_reduce
    perm = normalize_group_perm(group_perm, cfg.n_replicas)
    mask = flatbuf.normalize_membership(membership, cfg.n_replicas)

    def step(params, opt_state, batch, lr):
        new_p, new_o, loss_r, _aux_r = lstep(params, opt_state, batch, lr)
        if mask is not None:
            new_p = freeze_inactive(new_p, params, mask)
            new_o = freeze_inactive(new_o, opt_state, mask)
        params, opt_state = new_p, new_o
        for _name, g in inner_syncs:
            params = freeze_inactive(
                level_group_mean(params, g, use_kernels=kern, mask=mask,
                                 deterministic=det, perm=perm),
                params, mask)
        return params, opt_state, {"loss_per_replica": loss_r}

    return step


def sync_train_step(loss_fn: Callable, optimizer: Optimizer,
                    n_micro: int = 1):
    """Horovod-analog baseline: flat data parallelism, no replica axis; XLA
    emits the global gradient all-reduce over ("pod","data") every step."""
    grad_fn = microbatched_value_and_grad(loss_fn, n_micro)

    def step(params, opt_state, batch, lr):
        (loss, aux), grads = grad_fn(params, batch)
        new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
        metrics = {"loss": loss}
        for k, v in aux.items():
            if isinstance(v, jnp.ndarray) and v.ndim == 0:
                metrics[k] = v
        return new_params, new_opt, metrics

    return step
