"""DASO core: hierarchical + asynchronous + selective optimization in SPMD JAX.

Layout-agnostic formulation. Every parameter leaf carries a leading *replica*
axis of size R — one entry per paper "node" (TPU: one per pod; simulator: one
per virtual node). The per-replica training step runs under vmap; on a mesh
the replica axis is sharded over "pod", so:

  * local sync  — the loss mean over the per-replica batch makes XLA emit a
    gradient all-reduce over the intra-pod "data" axis only (fast ICI):
    exactly the paper's node-local NCCL gradient averaging, every step.
  * global sync — any mean over the leading replica axis lowers to a cross-pod
    (DCN) all-reduce: exactly the paper's MPI group exchange. It appears in
    the HLO only in the step variants that perform it.

Step variants (selected by the host-side DasoController, mirroring the MPI
process flow of paper Fig. 5; static per-variant compilation keeps each HLO's
collective set exact for the roofline audit):

  local     forward/backward + local optimizer step only
  send      local + snapshot params and start the global exchange:
            inflight <- mean_replicas(params)
  receive   local + merge the (now stale, S steps old) exchange result via
            paper Eq. (1):  x = (2S * x_local + P * x_stale_mean) / (2S + P)
  blocking  local + synchronous global parameter average with bf16
            transfer compression (warm-up / cool-down phases)
  hard_avg  local + naive parameter overwrite (local-SGD ablation)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer


@dataclass(frozen=True)
class DasoConfig:
    n_replicas: int              # R: paper "nodes" (pods / virtual nodes)
    global_world: int            # P in Eq. (1): GPUs in the global network
    b_max: int = 4               # paper: max batches between global syncs
    warmup_steps: int = 0
    cooldown_steps: int = 0
    total_steps: int = 0
    compress_blocking: bool = True
    # BEYOND-PAPER: the paper skips 16-bit packaging for non-blocking sends
    # (MPI packaging delays the Isend). In SPMD/XLA the cast fuses into the
    # collective with no launch delay, so compressing the cycling-phase
    # exchange halves DCN bytes for free. Default False = paper-faithful.
    compress_nonblocking: bool = False
    plateau_patience: int = 5
    plateau_threshold: float = 1e-3


# -- replica-axis helpers ----------------------------------------------------

def replicate_params(params, n_replicas: int):
    return jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n_replicas,) + p.shape), params)


def dereplicate_params(params):
    return jax.tree.map(lambda p: p[0], params)


def replica_mean(tree, wire_dtype=None):
    """Mean over the leading replica axis, broadcast back. On the production
    mesh this lowers to the cross-pod (DCN) all-reduce; `wire_dtype`
    controls the dtype that crosses the wire (None = the leaf's own dtype,
    jnp.bfloat16 = the paper's 16-bit transfer compression)."""
    def leaf(x):
        wd = jnp.dtype(wire_dtype or x.dtype)
        # Pin the reduction computation dtype with lax.reduce: both jnp.mean
        # and jnp.sum(dtype=...) silently upcast bf16 accumulation to f32,
        # which puts f32 on the cross-pod wire (verified in HLO).
        w = x.astype(wd)
        m = jax.lax.reduce(w, jnp.zeros((), wd), jax.lax.add, (0,))
        m = (m * jnp.asarray(1.0 / x.shape[0], wd))[None]
        return jnp.broadcast_to(m, x.shape).astype(x.dtype)
    return jax.tree.map(leaf, tree)


def replica_divergence(params) -> jnp.ndarray:
    """Max abs deviation of any replica from the replica mean (diagnostic)."""
    def leaf(x):
        x = x.astype(jnp.float32)
        return jnp.max(jnp.abs(x - x.mean(axis=0, keepdims=True)))
    return functools.reduce(jnp.maximum,
                            [leaf(x) for x in jax.tree.leaves(params)])


# -- DASO primitive operations ------------------------------------------------

def global_send(params, *, compress: bool = False):
    """Snapshot + start global exchange: returns the in-flight buffer
    (replica mean of current params, one copy per replica). compress=True
    puts bf16 on the wire (beyond-paper for the non-blocking path, see
    DasoConfig)."""
    return replica_mean(params,
                        wire_dtype=jnp.bfloat16 if compress else None)


def global_receive(params, inflight, *, staleness: int, global_world: int):
    """Paper Eq. (1): weighted merge of stale global average with current
    local params. staleness S = batches waited; global_world P."""
    s2 = jnp.asarray(2.0 * staleness, jnp.float32)
    p_ = jnp.asarray(float(global_world), jnp.float32)
    denom = s2 + p_

    def leaf(x_local, x_stale):
        merged = (s2 * x_local.astype(jnp.float32)
                  + p_ * x_stale.astype(jnp.float32)) / denom
        return merged.astype(x_local.dtype)

    return jax.tree.map(leaf, params, inflight)


def blocking_sync(params, *, compress: bool = True):
    """Synchronous global average (warm-up / cool-down), with the paper's
    16-bit transfer compression."""
    return replica_mean(params,
                        wire_dtype=jnp.bfloat16 if compress else None)


# -- assembled train step ------------------------------------------------------

def microbatched_value_and_grad(loss_fn: Callable, n_micro: int):
    """Gradient accumulation: split the batch along its leading dim into
    n_micro chunks and lax.scan the fwd+bwd over them. Cuts the live
    activation/residual footprint ~n_micro-fold (beyond-paper memory
    optimization, EXPERIMENTS.md §Perf)."""
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    if n_micro <= 1:
        return grad_fn

    def fn(params, batch):
        micro = jax.tree.map(
            lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                + x.shape[1:]), batch)

        def body(carry, mb):
            loss_acc, aux_acc, g_acc = carry
            (loss, aux), g = grad_fn(params, mb)
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            aux_acc = jax.tree.map(jnp.add, aux_acc, aux)
            return (loss_acc + loss, aux_acc, g_acc), None

        (loss0, aux0), g0 = jax.eval_shape(grad_fn, params,
                                           jax.tree.map(lambda x: x[0],
                                                        micro))
        zeros = lambda t: jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), t)
        (loss, aux, grads), _ = jax.lax.scan(
            body, (jnp.zeros(loss0.shape, loss0.dtype), zeros(aux0),
                   zeros(g0)), micro)
        inv = 1.0 / n_micro
        scale = lambda t: jax.tree.map(
            lambda x: (x * inv).astype(x.dtype) if jnp.issubdtype(
                x.dtype, jnp.floating) else x, t)
        return (loss * inv, scale(aux)), scale(grads)

    return fn


def local_step(loss_fn: Callable, optimizer: Optimizer,
               spmd_axis_name: Optional[str] = None, n_micro: int = 1):
    """Returns step(params_R, opt_R, batch_R, lr) -> (params, opt, metrics).
    loss_fn(params, batch) -> (loss, aux). vmapped over the replica axis.

    On a mesh, pass spmd_axis_name="pod": sharding constraints inside the
    model then keep the replica dim pod-sharded (plain vmap would mark it
    replicated and force cross-pod all-gathers of every constrained
    activation — verified in the HLO audit, see EXPERIMENTS.md)."""
    grad_fn = microbatched_value_and_grad(loss_fn, n_micro)

    def one(params, opt_state, batch, lr):
        (loss, aux), grads = grad_fn(params, batch)
        new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
        return new_params, new_opt, loss, aux

    return jax.vmap(one, in_axes=(0, 0, 0, None),
                    spmd_axis_name=spmd_axis_name)


MODES = ("local", "send", "receive", "send_receive", "blocking", "hard_avg")


def daso_train_step(loss_fn: Callable, optimizer: Optimizer, cfg: DasoConfig,
                    *, mode: str, staleness: int = 1,
                    spmd_axis_name: Optional[str] = None, n_micro: int = 1):
    """Build one statically-specialized DASO step function.

    step(params_R, opt_R, inflight, batch_R, lr)
        -> (params_R, opt_R, inflight, metrics)
    """
    assert mode in MODES, mode
    lstep = local_step(loss_fn, optimizer, spmd_axis_name=spmd_axis_name,
                       n_micro=n_micro)

    def step(params, opt_state, inflight, batch, lr):
        if mode in ("receive", "send_receive"):
            params = global_receive(params, inflight,
                                    staleness=staleness,
                                    global_world=cfg.global_world)
        params, opt_state, loss_r, aux_r = lstep(params, opt_state, batch, lr)
        if mode in ("send", "send_receive"):
            inflight = global_send(params,
                                   compress=cfg.compress_nonblocking)
        elif mode == "blocking":
            params = blocking_sync(params, compress=cfg.compress_blocking)
        elif mode == "hard_avg":
            params = replica_mean(params)
        metrics = {"loss": jnp.mean(loss_r), "loss_per_replica": loss_r}
        for k, v in aux_r.items():
            if isinstance(v, jnp.ndarray) and v.ndim <= 1:
                metrics[k] = jnp.mean(v)
        return params, opt_state, inflight, metrics

    return step


def sync_train_step(loss_fn: Callable, optimizer: Optimizer,
                    n_micro: int = 1):
    """Horovod-analog baseline: flat data parallelism, no replica axis; XLA
    emits the global gradient all-reduce over ("pod","data") every step."""
    grad_fn = microbatched_value_and_grad(loss_fn, n_micro)

    def step(params, opt_state, batch, lr):
        (loss, aux), grads = grad_fn(params, batch)
        new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
        metrics = {"loss": loss}
        for k, v in aux.items():
            if isinstance(v, jnp.ndarray) and v.ndim == 0:
                metrics[k] = v
        return new_params, new_opt, metrics

    return step
