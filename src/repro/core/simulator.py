"""Single-host DASO simulator: runs the *same* core step functions used on the
production mesh, with N virtual nodes realized as the leading replica axis on
one device. Used for the paper's convergence claims (accuracy parity vs sync,
degradation at large node counts / large B) without cluster hardware.

Since the macro-cycle executor landed (core/executor.py) this module is the
*per-step reference path*: one host dispatch per training step, modes decided
step-by-step by the strategy. The compiled path must match it allclose at f32
(tests/test_executor.py). (The executor's irregular-tail fallback uses the
same one-dispatch-per-step scheme but lives in MacroCycleExecutor, driven by
an already-planned shape.) Both paths drive strategies through the same
registry interface.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.daso import DasoConfig
from repro.core.schedule import DasoController
from repro.optim.optimizers import Optimizer


@dataclass
class SimResult:
    losses: List[float]
    metrics: List[Dict[str, float]]
    params: object
    sync_fraction: float
    controller: Optional[DasoController] = None
    divergence: List[float] = field(default_factory=list)
    # populated by the macro-cycle path (core/executor.py): dispatch /
    # compile counters proving the B+1 -> 1 host-dispatch reduction
    executor_stats: Optional[object] = None

    @property
    def final_loss(self) -> float:
        k = max(1, len(self.losses) // 10)
        return float(np.mean(self.losses[-k:]))


def run_per_step_training(strategy, params0, data_fn: Callable,
                          lr_fn: Callable, n_steps: int, *,
                          track_divergence: bool = False,
                          start_step: int = 0, carry=None,
                          ckpt_every: int = 0,
                          ckpt_cb: Optional[Callable] = None,
                          placement=None) -> SimResult:
    """Reference path: one jitted dispatch per training step, with the
    strategy's per-step mode decision (`next_mode`) and loss feedback
    (`observe`) interleaved exactly as on the original host loop.
    `strategy` is any registered Strategy (core/executor.py).

    Resume/checkpoint surface mirrors `executor.run_compiled_training`:
    `start_step` + restored `carry` continue a run; `ckpt_cb(completed,
    carry, losses)` fires after every `ckpt_every`-th step.

    `placement` (launch.distributed.MeshPlacement) runs the same loop over
    the global topology mesh — the multi-process reference path the
    macro-cycle distributed path is held against."""
    carry = strategy.init_carry(params0) if carry is None else carry
    if placement is not None:
        carry = placement.put_carry(carry)
    step_cache: Dict = {}

    def get_step(mode: str, staleness: int):
        key = (mode, staleness)
        if key not in step_cache:
            step_cache[key] = jax.jit(strategy.step_fn(mode, staleness))
        return step_cache[key]

    losses, metrics_log, divs = [], [], []
    for step in range(start_step, n_steps):
        mode, stale = strategy.next_mode(step)
        fn = get_step(mode, stale)
        batch = data_fn(step)
        if placement is not None:
            batch = placement.place_batch(batch)
        carry, m = fn(carry, batch, lr_fn(step))
        loss = float(m["loss"])
        losses.append(loss)
        metrics_log.append({k: float(v) for k, v in m.items()
                            if getattr(v, "ndim", 1) == 0})
        strategy.observe([loss])
        if track_divergence:
            d = strategy.divergence(carry)
            if d is not None:
                divs.append(d)
        if ckpt_every and ckpt_cb is not None and (step + 1) % ckpt_every == 0:
            ckpt_cb(step + 1, carry, losses)
    params = (placement.finalize_params(strategy, carry)
              if placement is not None
              else strategy.finalize_params(carry))
    return SimResult(losses=losses, metrics=metrics_log, params=params,
                     sync_fraction=strategy.sync_fraction(),
                     controller=strategy.controller, divergence=divs)


# -- back-compat wrappers ------------------------------------------------------

def run_daso_training(loss_fn: Callable, optimizer: Optimizer, params0,
                      data_fn: Callable, cfg: DasoConfig, lr_fn: Callable,
                      n_steps: int, *, controller: Optional[DasoController]
                      = None, track_divergence: bool = False,
                      mode_override: Optional[str] = None) -> SimResult:
    """data_fn(step) -> batch pytree with leading (R, per_replica_batch, ...).

    Thin wrapper over `run_per_step_training` with the `daso` strategy.
    `mode_override` (str or step -> str) forces the schedule, e.g. the
    local-SGD ablation; prefer the registered `local_sgd` strategy for
    that."""
    from repro.core.executor import DasoStrategy

    strategy = DasoStrategy(loss_fn, optimizer, cfg, controller=controller)
    if mode_override is not None:
        controller = strategy.controller

        def next_mode(step):
            mode = (mode_override(step) if callable(mode_override)
                    else mode_override)
            controller.history.append((step, mode, controller.b,
                                       controller.w))
            return mode, 1

        strategy.next_mode = next_mode
    return run_per_step_training(strategy, params0, data_fn, lr_fn, n_steps,
                                 track_divergence=track_divergence)


def run_sync_training(loss_fn: Callable, optimizer: Optimizer, params0,
                      data_fn: Callable, lr_fn: Callable,
                      n_steps: int) -> SimResult:
    """Horovod-analog baseline: one parameter copy, global batch each step.
    data_fn(step) must return the *flat* global batch (no replica axis)."""
    from repro.core.executor import SyncStrategy

    strategy = SyncStrategy(loss_fn, optimizer)
    return run_per_step_training(strategy, params0, data_fn, lr_fn, n_steps)
