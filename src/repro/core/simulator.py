"""Single-host DASO simulator: runs the *same* core step functions used on the
production mesh, with N virtual nodes realized as the leading replica axis on
one device. Used for the paper's convergence claims (accuracy parity vs sync,
degradation at large node counts / large B) without cluster hardware.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.daso import (DasoConfig, daso_train_step, dereplicate_params,
                             replica_divergence, replicate_params,
                             sync_train_step)
from repro.core.schedule import DasoController
from repro.optim.optimizers import Optimizer


@dataclass
class SimResult:
    losses: List[float]
    metrics: List[Dict[str, float]]
    params: object
    sync_fraction: float
    controller: Optional[DasoController] = None
    divergence: List[float] = field(default_factory=list)

    @property
    def final_loss(self) -> float:
        k = max(1, len(self.losses) // 10)
        return float(np.mean(self.losses[-k:]))


def run_daso_training(loss_fn: Callable, optimizer: Optimizer, params0,
                      data_fn: Callable, cfg: DasoConfig, lr_fn: Callable,
                      n_steps: int, *, controller: Optional[DasoController]
                      = None, track_divergence: bool = False,
                      mode_override: Optional[str] = None) -> SimResult:
    """data_fn(step) -> batch pytree with leading (R, per_replica_batch, ...)."""
    controller = controller or DasoController(cfg)
    params = replicate_params(params0, cfg.n_replicas)
    opt_state = replicate_params(optimizer.init(params0), cfg.n_replicas)
    inflight = jax.tree.map(lambda x: x, params)  # warm buffer

    step_cache: Dict = {}

    def get_step(mode: str, staleness: int):
        key = (mode, staleness)
        if key not in step_cache:
            step_cache[key] = jax.jit(daso_train_step(
                loss_fn, optimizer, cfg, mode=mode, staleness=staleness))
        return step_cache[key]

    losses, metrics_log, divs = [], [], []
    for step in range(n_steps):
        if mode_override is not None:
            mode = (mode_override(step) if callable(mode_override)
                    else mode_override)
            stale = 1
            controller.history.append((step, mode, controller.b, controller.w))
        else:
            mode, stale = controller.mode_for_step(step)
        fn = get_step(mode, stale)
        batch = data_fn(step)
        params, opt_state, inflight, m = fn(params, opt_state, inflight,
                                            batch, lr_fn(step))
        loss = float(m["loss"])
        losses.append(loss)
        metrics_log.append({k: float(v) for k, v in m.items()
                            if getattr(v, "ndim", 1) == 0})
        controller.observe_loss(loss)
        if track_divergence:
            divs.append(float(replica_divergence(params)))
    return SimResult(losses=losses, metrics=metrics_log,
                     params=dereplicate_params(params),
                     sync_fraction=controller.global_sync_fraction(),
                     controller=controller, divergence=divs)


def run_sync_training(loss_fn: Callable, optimizer: Optimizer, params0,
                      data_fn: Callable, lr_fn: Callable,
                      n_steps: int) -> SimResult:
    """Horovod-analog baseline: one parameter copy, global batch each step.
    data_fn(step) must return the *flat* global batch (no replica axis)."""
    step_fn = jax.jit(sync_train_step(loss_fn, optimizer))
    params, opt_state = params0, optimizer.init(params0)
    losses, metrics_log = [], []
    for step in range(n_steps):
        params, opt_state, m = step_fn(params, opt_state, data_fn(step),
                                       lr_fn(step))
        losses.append(float(m["loss"]))
        metrics_log.append({k: float(v) for k, v in m.items()
                            if getattr(v, "ndim", 1) == 0})
    return SimResult(losses=losses, metrics=metrics_log, params=params,
                     sync_fraction=1.0)
