"""Host-side DASO controllers: phases (warm-up / cycling / cool-down), the
selective B/W schedule (paper §3), and its N-level generalization.

Cycling rules from the paper (driving the *outermost* topology level):
  * B (batches between global syncs) starts at b_max (paper uses 4);
  * W (batches to wait for the exchange) starts at max(1, B/4) — "an initial
    value of B/4 was found empirically to perform best";
  * on every training-loss plateau, B and W are halved (min 1);
  * when B == W == 1 and the loss plateaus again, both reset to their initial
    values and the process repeats until cool-down.

`DasoController` is that paper schedule verbatim — the two-level world where
the only replica level is the outermost one. `HierDasoController` extends it
to an N-level topology (repro/topo): each *intermediate* replica level l
carries a fixed period B_l and gets a synchronous group sync every B_l
steps, appended to the step's mode as ``outer+lvl1,lvl2`` (see `join_mode`);
the plateau schedule keeps driving only the outermost level — the slow tier
is where adaptivity pays, the fast tiers just tick.

Controllers are pure host logic: given the step index they return which
statically-compiled step variant to run (mirroring the MPI-side decisions an
HeAT/DASO rank makes), and consume windowed loss averages for plateau
detection (paper: "training loss stable for N epochs").
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.daso import DasoConfig


class Mode:
    LOCAL = "local"
    SEND = "send"
    RECEIVE = "receive"
    SEND_RECEIVE = "send_receive"
    BLOCKING = "blocking"
    HARD_AVG = "hard_avg"
    # double-buffered overlap schedule (DasoConfig.overlap == "one_cycle"):
    # OV_START snapshots params into the pending arena (first cycling step —
    # nothing in flight to merge yet); OV_SYNC launches the exchange on the
    # PREVIOUS snapshot, merges it one cycle stale, and re-snapshots. An
    # OV_SYNC token may carry extra staleness as a "~E" suffix ("ov_sync~2"),
    # see split_ov.
    OV_START = "ov_start"
    OV_SYNC = "ov_sync"
    # baseline strategy family (core/baselines.py): GOSSIP carries its
    # ring-shift as a "~s" suffix ("gossip~2"), reusing the split_ov
    # mechanics so each shift compiles as its own step variant; ELASTIC is
    # the EASGD center pull, PUSH the DOWNPOUR delta push — both one
    # global all-reduce.
    GOSSIP = "gossip"
    ELASTIC = "elastic"
    PUSH = "push"


def split_ov(outer: str) -> Tuple[str, int]:
    """Split an outer-level overlap token into (base, extra_staleness):
    ``"ov_sync~2"`` -> ``("ov_sync", 2)``, ``"ov_sync"`` -> ``("ov_sync",
    0)``. Non-overlap tokens pass through with extra 0. The extra rides in
    the token so each distinct staleness compiles (and caches) as its own
    step variant — Eq. (1)'s S is a compile-time constant."""
    base, _, extra = outer.partition("~")
    return base, int(extra) if extra else 0


def is_ov_mode(mode: str) -> bool:
    """True when the step's outer-level action belongs to the overlap
    family (works on full hierarchical tokens like ``"ov_sync~1+host"``)."""
    base, _ = split_ov(split_mode(mode)[0])
    return base in (Mode.OV_START, Mode.OV_SYNC)


def split_mode(mode: str) -> Tuple[str, Tuple[str, ...]]:
    """Split a (possibly hierarchical) mode token into the outermost-level
    action and the inner levels syncing that step: ``"send+host"`` ->
    ``("send", ("host",))``, ``"local"`` -> ``("local", ())``. Legacy
    two-level mode strings pass through unchanged."""
    outer, _, inner = mode.partition("+")
    return outer, tuple(inner.split(",")) if inner else ()


def join_mode(outer: str, inner: Tuple[str, ...]) -> str:
    """Inverse of `split_mode`. With no inner syncs the token IS the legacy
    outer mode string — a 2-level topology therefore produces byte-identical
    mode histories and cycle shapes to the pre-topology controller."""
    return f"{outer}+{','.join(inner)}" if inner else outer


@dataclass
class DasoController:
    cfg: DasoConfig
    # plateau detection over windowed mean losses
    loss_window: int = 50
    _b: int = field(init=False)
    _w: int = field(init=False)
    _last_send: int = field(init=False, default=-(10 ** 9))
    _inflight_since: Optional[int] = field(init=False, default=None)
    _recv_staleness: int = field(init=False, default=1)
    # overlap schedule: step of the last pending-arena snapshot (ov_start or
    # ov_sync). None = the next cycling step must ov_start (fresh run, or a
    # blocking phase just invalidated the snapshot).
    _ov_last: Optional[int] = field(init=False, default=None)
    _best: float = field(init=False, default=float("inf"))
    _since_improve: int = field(init=False, default=0)
    _win_acc: List[float] = field(init=False, default_factory=list)
    _dcn_scale: float = field(init=False, default=1.0)
    history: List[Tuple[int, str, int, int]] = field(init=False,
                                                     default_factory=list)
    # resilience event log: (step, kind, detail) entries appended by the
    # notify_* hooks (resilience/supervisor.py)
    events: List[Tuple[int, str, float]] = field(init=False,
                                                 default_factory=list)

    # obs.trace sink for decision events (plateau B/W changes, membership,
    # DCN scale) — attached by train/loop.py when --trace-out is set.
    # Deliberately a plain class attribute, NOT a dataclass field: it must
    # never enter _STATE_FIELDS / state_dict (a checkpoint round-trips
    # through JSON) and a controller without one stays silent.
    tracer = None

    def _trace(self, name: str, **args) -> None:
        if self.tracer is not None:
            self.tracer.instant(name, cat="schedule", **args)

    def __post_init__(self):
        self._b = max(1, self.cfg.b_max)
        self._w = max(1, self._b // 4)

    # -- phase logic -------------------------------------------------------
    def phase(self, step: int) -> str:
        """Pure phase lookup for `step`: "warmup" for the first
        `warmup_steps`, "cooldown" for the last `cooldown_steps` (when
        `total_steps` is known), "cycling" otherwise. Does not mutate
        controller state, so it is safe to call while planning ahead."""
        if step < self.cfg.warmup_steps:
            return "warmup"
        if (self.cfg.total_steps and self.cfg.cooldown_steps
                and step >= self.cfg.total_steps - self.cfg.cooldown_steps):
            return "cooldown"
        return "cycling"

    @property
    def b(self) -> int:
        """Current B: batches between global sends (paper's selective knob,
        halved on plateau, reset when B == W == 1 plateaus again)."""
        return self._b

    @property
    def w(self) -> int:
        """Current W: batches to wait before merging an in-flight exchange
        (starts at max(1, B // 4), tracks B through halving/reset)."""
        return self._w

    def mode_for_step(self, step: int) -> Tuple[str, int]:
        """Consume one scheduling decision: returns (mode, staleness_S) for
        `step` and advances the send/receive bookkeeping. Call exactly once
        per step, in step order — out-of-order calls corrupt the in-flight
        exchange tracking. `staleness_S` is the number of batches actually
        waited since the matching send (only meaningful for receive modes;
        it feeds Eq. (1) as S)."""
        ph = self.phase(step)
        if ph in ("warmup", "cooldown"):
            # a blocking step completes any dangling exchange trivially —
            # and supersedes any pending overlap snapshot (the full-world
            # average is fresher than anything it could merge)
            self._inflight_since = None
            self._ov_last = None
            mode, stale = Mode.BLOCKING, 1
        elif self.cfg.overlap != "off":
            mode, stale = self._overlap_mode(step)
        else:
            recv = (self._inflight_since is not None
                    and step - self._inflight_since >= self._w)
            send = step - self._last_send >= self._b
            if recv:
                # S = batches actually waited since the send
                stale = step - self._inflight_since
                self._inflight_since = None
            else:
                stale = 1
            if send and self._inflight_since is not None:
                send = False  # previous exchange still in flight: skip
            if send:
                self._last_send = step
                self._inflight_since = step
            mode = {(False, False): Mode.LOCAL,
                    (True, False): Mode.SEND,
                    (False, True): Mode.RECEIVE,
                    (True, True): Mode.SEND_RECEIVE}[(send, recv)]
        self.history.append((step, mode, self._b, self._w))
        return mode, stale

    def _overlap_mode(self, step: int) -> Tuple[str, int]:
        """Cycling-phase decision under overlap == "one_cycle". Every B
        steps an OV_SYNC merges the exchange launched on the snapshot taken
        B steps earlier — so the merge is always one full cycle stale. The
        snapshot's true age (step - last snapshot) splits into the Eq. (1)
        staleness S = min(W, age) the blocking schedule would have charged
        plus the overlap's extra ``age - S``, carried in the mode token
        ("ov_sync~E") so each distinct age compiles as its own variant."""
        if self._ov_last is None:
            self._ov_last = step
            return Mode.OV_START, 1
        age = step - self._ov_last
        if age < self._b:
            return Mode.LOCAL, 1
        self._ov_last = step
        stale = min(self._w, age)
        extra = age - stale
        mode = f"{Mode.OV_SYNC}~{extra}" if extra else Mode.OV_SYNC
        return mode, stale

    # -- macro-cycle planning ----------------------------------------------
    def window_remaining(self) -> int:
        """Steps until the current plateau-detection window fills. A planned
        macro-cycle must not cross this boundary: `observe_loss` may halve or
        reset B/W exactly when the window fills, and the per-step path would
        see that change on the *next* step's decision."""
        return self.loss_window - len(self._win_acc)

    def _would_send(self, step: int) -> bool:
        """Pure peek: would `mode_for_step(step)` start a new global send
        given current state? Mirrors the send predicate in `mode_for_step`
        (B-spacing satisfied and no exchange already in flight) without
        consuming the step."""
        if self.phase(step) != "cycling":
            return False
        return (step - self._last_send >= self._b
                and self._inflight_since is None)

    def plan_cycle(self, start_step: int,
                   max_len: int = 32) -> Tuple[Tuple[str, int], ...]:
        """Emit one macro-cycle starting at `start_step`: the exact
        (mode, staleness) sequence `mode_for_step` would produce, consumed
        from the schedule in order (history is recorded normally).

        The cycle is cut at the first of: `max_len` steps, the plateau
        window filling (`window_remaining`), a phase change, or the next
        send in the cycling phase — so a B=4/W=1 cycling cycle is
        ``(send, receive@S, local, local)`` and a warm-up cycle is a run of
        ``blocking``. Cutting at these boundaries is what makes executing
        the whole cycle as one compiled program equivalent to the per-step
        path: no host-side feedback can change the schedule mid-cycle.

        Under overlap the cycling cut flips: the cycle is cut AFTER an
        ov_start/ov_sync step instead of before the next send, so a
        B=4 overlap cycle is ``(local, local, local, ov_sync)`` — the
        exchange the executor launched at the cycle's start is merged by
        its last step, and the next cycle starts with a fresh snapshot in
        flight. (Window/max_len cuts can still yield all-local cycles;
        those simply dispatch without an exchange program.)"""
        n_max = max(1, min(max_len, self.window_remaining()))
        phase0 = self.phase(start_step)
        ov = self.cfg.overlap != "off"
        shape = []
        while len(shape) < n_max:
            t = start_step + len(shape)
            if shape:
                if self.phase(t) != phase0:
                    break
                if phase0 == "cycling" and not ov and self._would_send(t):
                    break
            shape.append(self.mode_for_step(t))
            if ov and phase0 == "cycling" and is_ov_mode(shape[-1][0]):
                break
        return tuple(shape)

    # -- plateau-driven B/W schedule ----------------------------------------
    def observe_loss(self, loss: float) -> None:
        """Feed one training loss (in step order). Losses accumulate into
        windows of `loss_window`; when a window fills, its mean is compared
        against the best window so far and `plateau_patience` stale windows
        trigger the paper's halve-or-reset rule on B and W."""
        self._win_acc.append(float(loss))
        if len(self._win_acc) < self.loss_window:
            return
        mean = sum(self._win_acc) / len(self._win_acc)
        self._win_acc.clear()
        if mean < self._best * (1.0 - self.cfg.plateau_threshold):
            self._best = mean
            self._since_improve = 0
            return
        self._since_improve += 1
        if self._since_improve >= self.cfg.plateau_patience:
            self._since_improve = 0
            b0, w0 = self._b, self._w
            if self._b == 1 and self._w == 1:
                self._b = max(1, self.cfg.b_max)          # paper: reset
                self._w = max(1, self._b // 4)
                reason = "plateau_reset"
            else:
                self._b = max(1, self._b // 2)             # paper: halve
                self._w = max(1, self._w // 2)
                reason = "plateau_halve"
            self._trace("bw_change", reason=reason, b_from=b0, b_to=self._b,
                        w_from=w0, w_to=self._w, window_mean=mean,
                        best=self._best,
                        patience=self.cfg.plateau_patience)

    # -- resilience hooks --------------------------------------------------
    def notify_membership_change(self, step: int, n_active: int) -> None:
        """A replica dropped or rejoined at `step`. The loss scale of a
        different active set is not comparable to the old one, so the
        plateau statistics are flushed: the current window is discarded and
        the best-window baseline restarts (otherwise a crash-induced loss
        bump would immediately count toward `plateau_patience`). B/W are
        left alone — the paper schedule keeps adapting from wherever it
        is."""
        self._win_acc.clear()
        self._since_improve = 0
        self._best = float("inf")
        self.events.append((step, "membership", float(n_active)))
        self._trace("membership_change", reason="plateau_stats_flushed",
                    step=step, n_active=n_active)

    def notify_dcn_scale(self, scale: float, *, step: int = -1) -> None:
        """The cross-pod (DCN) network degraded to `scale`× its nominal
        bandwidth (scale < 1) or recovered (scale >= 1). Under degradation
        the controller stretches B — syncing less often keeps the exchange
        overhead per step bounded, the degraded-network adaptation DS-Sync
        argues for — capped at 4×`b_max`; on recovery B is clamped back to
        the paper's `b_max` ceiling. W tracks B at the paper's B/4 rule."""
        if scale <= 0:
            raise ValueError(f"dcn scale must be positive, got {scale}")
        self._dcn_scale = float(scale)
        b_max = max(1, self.cfg.b_max)
        b0 = self._b
        if scale < 1.0:
            stretched = int(math.ceil(b_max / scale))
            self._b = max(self._b, min(4 * b_max, stretched))
            reason = "dcn_degraded"
        else:
            self._b = min(self._b, b_max)
            reason = "dcn_recovered"
        self._w = max(1, self._b // 4)
        self.events.append((step, "dcn_scale", float(scale)))
        self._trace("dcn_scale", reason=reason, step=step, scale=scale,
                    b_from=b0, b_to=self._b)

    def retune(self, level_costs: Dict[str, float], *,
               annotated: Optional[Dict[str, float]] = None,
               step: int = -1, rel_tol: float = 0.05) -> bool:
        """Feed one round of *measured* per-level sync costs (seconds per
        sync, key ``"_outer"`` for the outermost level — the dict shape
        `repro.topo.probe` produces) back into the schedule. The base
        controller owns only the outermost level: when `annotated` carries
        the nominal ``"_outer"`` cost, the measured/annotated ratio is the
        *effective* DCN scale (a link at half bandwidth measures 2x the
        cost), and a scale that drifts past `rel_tol` of the currently
        assumed one is applied through the `notify_dcn_scale` stretch rule.

        Measurements matching the annotations are a strict no-op: no state
        change, no event, no trace — the bit-exactness contract
        tests/test_tuning.py pins. Returns True iff the schedule changed
        (the caller then invalidates its executor, same as a membership
        change)."""
        t_meas = level_costs.get("_outer")
        t_nom = (annotated or {}).get("_outer")
        if not t_meas or not t_nom or t_meas <= 0 or t_nom <= 0:
            return False
        scale = t_nom / t_meas
        if abs(scale - self._dcn_scale) <= rel_tol * self._dcn_scale:
            return False
        b0, w0 = self._b, self._w
        self.notify_dcn_scale(scale, step=step)
        self.events.append((step, "retune", float(scale)))
        self._trace("retune", step=step, scale=scale, b_from=b0,
                    b_to=self._b, bw_changed=(self._b, self._w) != (b0, w0))
        return True

    # -- checkpoint state --------------------------------------------------
    _STATE_FIELDS = ("_b", "_w", "_last_send", "_inflight_since",
                     "_recv_staleness", "_ov_last", "_best",
                     "_since_improve", "_dcn_scale")

    def state_dict(self) -> dict:
        """Full mutable state as a JSON-serializable dict (part of the
        resumable TrainState, checkpoint/io.py). Restoring it via
        `load_state_dict` makes a resumed controller schedule-identical to
        one that never stopped — history included, so `global_sync_fraction`
        and the schedule-equality asserts keep working across a resume."""
        sd = {k: getattr(self, k) for k in self._STATE_FIELDS}
        sd["win_acc"] = list(self._win_acc)
        sd["history"] = [list(h) for h in self.history]
        sd["events"] = [list(e) for e in self.events]
        sd["loss_window"] = self.loss_window
        return sd

    def load_state_dict(self, sd: dict) -> None:
        for k in self._STATE_FIELDS:
            # pre-overlap checkpoints lack _ov_last; keep the fresh default
            # (None -> next cycling step re-snapshots via ov_start)
            setattr(self, k, sd.get(k, getattr(self, k)))
        self._win_acc = [float(x) for x in sd["win_acc"]]
        self.history = [tuple(h) for h in sd["history"]]
        self.events = [tuple(e) for e in sd.get("events", [])]
        self.loss_window = int(sd["loss_window"])

    # -- audit -------------------------------------------------------------
    def global_sync_fraction(self) -> float:
        """Fraction of steps that touched the outermost-level (cross-pod /
        DCN) network, for the traffic-reduction claim. Hierarchical mode
        tokens count by their outer action — inner-level syncs ride faster
        links and are tallied separately (`level_sync_counts`)."""
        if not self.history:
            return 0.0
        touched = sum(
            1 for (_, m, _, _) in self.history
            if split_ov(split_mode(m)[0])[0] in (Mode.SEND,
                                                 Mode.SEND_RECEIVE,
                                                 Mode.BLOCKING,
                                                 Mode.OV_SYNC,
                                                 Mode.GOSSIP,
                                                 Mode.ELASTIC,
                                                 Mode.PUSH))
        return touched / len(self.history)

    def level_sync_counts(self) -> Dict[str, int]:
        """Per-level sync tally over the history: how many steps synced each
        inner level, plus the outermost under key "_outer". The docs'
        which-level-pays-which-bytes accounting reads from this
        (docs/topologies.md)."""
        counts: Dict[str, int] = {"_outer": 0}
        for (_, m, _, _) in self.history:
            outer, inner = split_mode(m)
            if split_ov(outer)[0] in (Mode.SEND, Mode.SEND_RECEIVE,
                                      Mode.BLOCKING, Mode.HARD_AVG,
                                      Mode.OV_SYNC, Mode.GOSSIP,
                                      Mode.ELASTIC, Mode.PUSH):
                counts["_outer"] += 1
            for name in inner:
                counts[name] = counts.get(name, 0) + 1
        return counts


@dataclass
class HierDasoController(DasoController):
    """N-level generalization of the paper schedule (repro/topo).

    `inner_periods` maps each intermediate replica level's name to its
    fixed sync period B_l (innermost first; derived from the topology's
    bandwidth ratios by `repro.topo.lower.derive_inner_periods` unless the
    spec pins it with ``%period``). Level l gets a synchronous group
    average on every step where ``(step + 1) % B_l == 0`` during the
    cycling phase; warm-up/cool-down `blocking` steps and the local-SGD
    `hard_avg` already average the full world, so inner syncs are elided
    there (they would be no-ops on already-equal rows).

    The outermost level keeps the full paper treatment — plateau-driven
    B/W, non-blocking send/receive, Eq. (1) staleness merge — via the
    inherited `DasoController` logic. With no intermediate levels (a
    2-level topology) this class is behaviorally identical to its base:
    same mode strings, same history, same cycle shapes.

    `pinned_periods` names the levels whose period came from an explicit
    ``%period`` pin in the spec — `retune` never moves those (an operator
    pin outranks a measurement, same precedence as at lowering time)."""
    inner_periods: Dict[str, int] = field(default_factory=dict)
    pinned_periods: Tuple[str, ...] = ()

    def __post_init__(self):
        super().__post_init__()
        for name, period in self.inner_periods.items():
            if period < 1:
                raise ValueError(f"inner level {name!r}: period must be "
                                 f">= 1, got {period}")

    def inner_syncs_at(self, step: int) -> Tuple[str, ...]:
        """Names of the intermediate levels whose period elapses at `step`
        (pure — a static function of the step index, which is what lets
        compiled macro-cycles bake the per-level phases into their
        shapes)."""
        return tuple(name for name, period in self.inner_periods.items()
                     if (step + 1) % period == 0)

    def mode_for_step(self, step: int) -> Tuple[str, int]:
        outer, stale = super().mode_for_step(step)
        if outer in (Mode.BLOCKING, Mode.HARD_AVG):
            return outer, stale
        inner = self.inner_syncs_at(step)
        if not inner:
            return outer, stale
        mode = join_mode(outer, inner)
        # rewrite the history entry the base class just appended so the
        # recorded schedule names the full per-level phase vector
        s, _, b, w = self.history[-1]
        self.history[-1] = (s, mode, b, w)
        return mode, stale

    def retune(self, level_costs: Dict[str, float], *,
               annotated: Optional[Dict[str, float]] = None,
               step: int = -1, rel_tol: float = 0.05) -> bool:
        """N-level retune: the base class handles the outermost level
        (effective-DCN-scale inference), then every *measured* intermediate
        level gets its period re-derived from the cost ratio

            B_l = clamp(round(b_max * t_l / t_outer), 1, b_max)

        — the lowering rule of `repro.topo.lower.derive_inner_periods` with
        measured seconds standing in for annotated bandwidths (bandwidth is
        bytes over time, so the ratios are the same quantity). ``%period``
        -pinned levels and levels absent from `level_costs` keep their
        current period. Probing with costs that match the annotations
        therefore reproduces the statically lowered schedule exactly — the
        no-op invariant. Returns True iff anything changed; the caller must
        then drop compiled cycles (`MacroCycleExecutor.invalidate`) exactly
        as after a membership change, since the new periods change the
        cycle shapes the planner emits."""
        changed = super().retune(level_costs, annotated=annotated,
                                 step=step, rel_tol=rel_tol)
        t_outer = level_costs.get("_outer")
        if not t_outer or t_outer <= 0:
            return changed
        b_max = max(1, self.cfg.b_max)
        new = dict(self.inner_periods)
        for name in self.inner_periods:
            t_l = level_costs.get(name)
            if name in self.pinned_periods or not t_l or t_l <= 0:
                continue
            new[name] = max(1, min(b_max, round(b_max * t_l / t_outer)))
        if new != self.inner_periods:
            old = dict(self.inner_periods)
            self.inner_periods = new
            self.events.append(
                (step, "retune_periods",
                 float(sum(1 for n in new if new[n] != old[n]))))
            self._trace("retune", step=step, periods_from=old,
                        periods_to=dict(new), bw_changed=False)
            changed = True
        return changed

    # -- checkpoint state --------------------------------------------------
    def state_dict(self) -> dict:
        """Base state plus the *effective* per-level periods. Online
        retuning makes `inner_periods` mutable state: a run checkpointed
        mid-retune must resume with the tuned periods, not re-lower the
        spec's static annotations (checkpoint/io.py TRAIN_STATE_VERSION 3;
        v2 checkpoints lack the key and load as static — see
        `load_state_dict`)."""
        sd = super().state_dict()
        sd["inner_periods"] = dict(self.inner_periods)
        return sd

    def load_state_dict(self, sd: dict) -> None:
        super().load_state_dict(sd)
        # v2 (pre-retune) checkpoints carry no inner_periods: keep the
        # statically lowered defaults this controller was built with
        if "inner_periods" in sd:
            self.inner_periods = {str(k): int(v)
                                  for k, v in sd["inner_periods"].items()}
