"""Host-side DASO controller: phases (warm-up / cycling / cool-down) and the
selective B/W schedule (paper §3).

Cycling rules from the paper:
  * B (batches between global syncs) starts at b_max (paper uses 4);
  * W (batches to wait for the exchange) starts at max(1, B/4) — "an initial
    value of B/4 was found empirically to perform best";
  * on every training-loss plateau, B and W are halved (min 1);
  * when B == W == 1 and the loss plateaus again, both reset to their initial
    values and the process repeats until cool-down.

The controller is pure host logic: given the step index it returns which
statically-compiled step variant to run (mirroring the MPI-side decisions an
HeAT/DASO rank makes), and consumes windowed loss averages for plateau
detection (paper: "training loss stable for N epochs").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.core.daso import DasoConfig


class Mode:
    LOCAL = "local"
    SEND = "send"
    RECEIVE = "receive"
    SEND_RECEIVE = "send_receive"
    BLOCKING = "blocking"
    HARD_AVG = "hard_avg"


@dataclass
class DasoController:
    cfg: DasoConfig
    # plateau detection over windowed mean losses
    loss_window: int = 50
    _b: int = field(init=False)
    _w: int = field(init=False)
    _last_send: int = field(init=False, default=-(10 ** 9))
    _inflight_since: Optional[int] = field(init=False, default=None)
    _recv_staleness: int = field(init=False, default=1)
    _best: float = field(init=False, default=float("inf"))
    _since_improve: int = field(init=False, default=0)
    _win_acc: List[float] = field(init=False, default_factory=list)
    history: List[Tuple[int, str, int, int]] = field(init=False,
                                                     default_factory=list)

    def __post_init__(self):
        self._b = max(1, self.cfg.b_max)
        self._w = max(1, self._b // 4)

    # -- phase logic -------------------------------------------------------
    def phase(self, step: int) -> str:
        if step < self.cfg.warmup_steps:
            return "warmup"
        if (self.cfg.total_steps and self.cfg.cooldown_steps
                and step >= self.cfg.total_steps - self.cfg.cooldown_steps):
            return "cooldown"
        return "cycling"

    @property
    def b(self) -> int:
        return self._b

    @property
    def w(self) -> int:
        return self._w

    def mode_for_step(self, step: int) -> Tuple[str, int]:
        """Returns (mode, staleness_S). Call exactly once per step, in order."""
        ph = self.phase(step)
        if ph in ("warmup", "cooldown"):
            # a blocking step completes any dangling exchange trivially
            self._inflight_since = None
            mode, stale = Mode.BLOCKING, 1
        else:
            recv = (self._inflight_since is not None
                    and step - self._inflight_since >= self._w)
            send = step - self._last_send >= self._b
            if recv:
                # S = batches actually waited since the send
                stale = step - self._inflight_since
                self._inflight_since = None
            else:
                stale = 1
            if send and self._inflight_since is not None:
                send = False  # previous exchange still in flight: skip
            if send:
                self._last_send = step
                self._inflight_since = step
            mode = {(False, False): Mode.LOCAL,
                    (True, False): Mode.SEND,
                    (False, True): Mode.RECEIVE,
                    (True, True): Mode.SEND_RECEIVE}[(send, recv)]
        self.history.append((step, mode, self._b, self._w))
        return mode, stale

    # -- plateau-driven B/W schedule ----------------------------------------
    def observe_loss(self, loss: float) -> None:
        self._win_acc.append(float(loss))
        if len(self._win_acc) < self.loss_window:
            return
        mean = sum(self._win_acc) / len(self._win_acc)
        self._win_acc.clear()
        if mean < self._best * (1.0 - self.cfg.plateau_threshold):
            self._best = mean
            self._since_improve = 0
            return
        self._since_improve += 1
        if self._since_improve >= self.cfg.plateau_patience:
            self._since_improve = 0
            if self._b == 1 and self._w == 1:
                self._b = max(1, self.cfg.b_max)          # paper: reset
                self._w = max(1, self._b // 4)
            else:
                self._b = max(1, self._b // 2)             # paper: halve
                self._w = max(1, self._w // 2)

    # -- audit -------------------------------------------------------------
    def global_sync_fraction(self) -> float:
        """Fraction of steps that touched the cross-pod network (for the
        traffic-reduction claim)."""
        if not self.history:
            return 0.0
        touched = sum(1 for (_, m, _, _) in self.history
                      if m in (Mode.SEND, Mode.SEND_RECEIVE, Mode.BLOCKING))
        return touched / len(self.history)
