"""RecurrentGemma-9B (Griffin): RG-LRU + local attention hybrid, pattern
2 recurrent : 1 local-attention [arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1, head_dim=256) d_ff=12288 vocab=256000,
local attention window 2048. Natively sub-quadratic -> long_500k runs as-is.
38 = 12 * (rglru, rglru, attn_local) + (rglru, rglru) remainder.
"""
from repro.configs.base import ArchConfig, ATTN_LOCAL, RGLRU, RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    layer_pattern=(RGLRU, RGLRU, ATTN_LOCAL),
    rglru=RGLRUConfig(lru_width=4096, conv_width=4, c_exponent=8.0),
    sliding_window=2048,
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="[arXiv:2402.19427]",
)
