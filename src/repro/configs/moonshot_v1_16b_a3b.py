"""Moonlight-16B-A3B (moonshot): DeepSeek-style fine-grained MoE
[hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (kv=16, head_dim=128) vocab=163840,
MoE: 64 experts, top-6, expert d_ff=1408, plus shared-expert branch
(Moonlight uses DeepSeek-V3-style shared experts; we model 2 shared experts
of the same 1408 hidden as one dense branch).
64 % 16 == 0 -> expert-parallel sharding over the "model" mesh axis.
"""
from repro.configs.base import ArchConfig, ATTN, MoEConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="dense",  # assignment labels it dense; structurally MoE
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,  # all FFN capacity lives in the MoE branch
    vocab_size=163840,
    layer_pattern=(ATTN,),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408, n_shared_experts=2,
                  capacity_factor=1.25, sharding="expert"),
    rope_theta=50_000.0,
    long_context_window=8192,
    source="[hf:moonshotai/Moonlight-16B-A3B]",
)
