"""MusicGen-large decoder backbone over EnCodec tokens [arXiv:2306.05284].

48L d_model=2048 32H (kv=32, i.e. MHA) d_ff=8192 vocab=2048. The text/melody
conditioning frontend is stubbed: input_specs() provides a precomputed
conditioning-embedding prefix of shape (B, prefix, d_model) which the backbone
consumes via the embedding-splice path. long_500k runs with a sliding-window
variant (the arch itself is full-attention).
"""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    layer_pattern=(ATTN,),
    rope_type="none",  # musicgen uses learned/sinusoidal positions; we use rope_type none + sinusoidal
    tie_embeddings=False,
    long_context_window=8192,
    prefix_embed_len=64,
    source="[arXiv:2306.05284]",
)
