"""Granite-3.0 MoE [hf:ibm-granite/granite-3.0-1b-a400m-base family].

32L d_model=1536 24H (GQA kv=8, head_dim=64) vocab=49155,
MoE 40 experts top-8, expert d_ff=512.
NOTE: the assignment bracket says "32 experts top-8" while the structured
field says "MoE 40e top-8"; we follow the structured field (40 experts).
40 % 16 != 0 -> tensor-parallel expert sharding (per-expert d_ff over "model").
24 heads % 16 != 0 -> projections sharded on the fused dim, not the head axis.
"""
from repro.configs.base import ArchConfig, ATTN, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=0,
    vocab_size=49155,
    layer_pattern=(ATTN,),
    moe=MoEConfig(n_experts=40, top_k=8, d_ff=512, n_shared_experts=0,
                  capacity_factor=1.25, sharding="tensor"),
    rope_theta=10_000.0,
    tie_embeddings=True,
    long_context_window=8192,
    source="[hf:ibm-granite/granite-3.0-1b-a400m-base]",
)
