"""Minitron-8B: pruned Nemotron-4 [arXiv:2407.14679].

32L d_model=4096 32H (GQA kv=8, head_dim=128) d_ff=16384 vocab=256000.
"""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    layer_pattern=(ATTN,),
    rope_theta=10_000.0,
    long_context_window=8192,
    source="[arXiv:2407.14679]",
)
