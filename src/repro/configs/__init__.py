from repro.configs.base import (  # noqa: F401
    ARCH_IDS,
    ArchConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
    get_config,
    get_reduced,
    reduce_config,
)
