"""Architecture configuration system.

Every assigned architecture gets one module in this package exporting CONFIG
(the exact published shape, used only via the ShapeDtypeStruct dry-run) and
reduced() (a tiny same-family variant for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp

# Layer kinds usable in ArchConfig.layer_pattern.
ATTN = "attn"              # global causal attention
ATTN_SWA = "attn_swa"      # sliding-window causal attention
ATTN_LOCAL = "attn_local"  # local attention (recurrentgemma-style window)
MAMBA = "mamba"            # Mamba-1 selective-SSM mixer
RGLRU = "rglru"            # RG-LRU gated linear recurrence mixer

ATTENTION_KINDS = (ATTN, ATTN_SWA, ATTN_LOCAL)
RECURRENT_KINDS = (MAMBA, RGLRU)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                     # per-expert hidden size
    n_shared_experts: int = 0     # dense "shared expert" branch (DeepSeek-style)
    capacity_factor: float = 1.25
    # routing group length (GShard "groups"): capacity is allocated per
    # group of this many tokens, bounding the (G, E, C) dispatch tensor to
    # O(group_size^2 * top_k / n_experts) instead of O(seq_len^2 ...).
    group_size: int = 2048
    # "expert": shard the expert axis over the "model" mesh axis (requires
    #           n_experts % model_parallel == 0)
    # "tensor": shard each expert's d_ff over "model" (always valid)
    sharding: str = "expert"
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:  # Mamba-1
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0     # 0 -> d_model
    conv_width: int = 4
    c_exponent: float = 8.0


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | ssm | moe | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int                       # dense FFN hidden (0 for attn-free / pure-MoE)
    vocab_size: int
    layer_pattern: Tuple[str, ...] = (ATTN,)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    qk_norm: bool = False
    rope_type: str = "standard"     # standard | mrope | none
    rope_theta: float = 10000.0
    sliding_window: int = 0         # window for attn_swa / attn_local layers
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # Dense archs run long_500k with this window via a sliding-window variant;
    # 0 means the arch is natively sub-quadratic (or attention-free).
    long_context_window: int = 0
    # vlm / audio: input_specs() provides precomputed frontend embeddings of
    # shape (batch, prefix_len, d_model) consumed by the backbone.
    prefix_embed_len: int = 0
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    source: str = ""                # citation bracket from the assignment

    # -- derived ----------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def dt_rank(self) -> int:
        if self.ssm is None:
            return 0
        return self.ssm.dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return 0 if self.ssm is None else self.ssm.expand * self.d_model

    @property
    def lru_width(self) -> int:
        if self.rglru is None:
            return 0
        return self.rglru.lru_width or self.d_model

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def has_attention(self) -> bool:
        return any(k in ATTENTION_KINDS for k in self.layer_pattern)

    def is_subquadratic(self) -> bool:
        """True if no layer attends over unbounded context."""
        return all(
            k in RECURRENT_KINDS or (k in ATTENTION_KINDS and k != ATTN)
            for k in self.layer_pattern
        ) and (self.sliding_window > 0 or not self.has_attention())

    def validate(self) -> None:
        assert self.n_layers >= 1 and self.d_model >= 1
        if self.has_attention():
            assert self.n_heads >= 1 and self.head_dim >= 1
            assert self.n_heads % max(self.n_kv_heads, 1) == 0, self.name
        for k in self.layer_pattern:
            assert k in ATTENTION_KINDS + RECURRENT_KINDS, k
        if MAMBA in self.layer_pattern:
            assert self.ssm is not None
        if RGLRU in self.layer_pattern:
            assert self.rglru is not None
        if any(k in (ATTN_SWA, ATTN_LOCAL) for k in self.layer_pattern):
            assert self.sliding_window > 0, self.name
        if self.moe is not None:
            assert self.moe.top_k <= self.moe.n_experts

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


def reduce_config(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family variant for CPU smoke tests (<=2 pattern repeats,
    d_model<=256, <=4 experts)."""
    pat = cfg.layer_pattern
    n_layers = len(pat) if len(pat) > 1 else 2
    d_model = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    head_dim = max(8, d_model // max(n_heads, 1))
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(
            moe, n_experts=min(4, moe.n_experts), top_k=min(2, moe.top_k),
            d_ff=min(64, moe.d_ff),
            n_shared_experts=min(1, moe.n_shared_experts))
    rglru = cfg.rglru
    if rglru is not None:
        rglru = dataclasses.replace(
            rglru, lru_width=min(rglru.lru_width or cfg.d_model, d_model))
    return cfg.replace(
        n_layers=n_layers, d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=head_dim, d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512), moe=moe, rglru=rglru,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        long_context_window=min(cfg.long_context_window, 64)
        if cfg.long_context_window else 0,
        prefix_embed_len=min(cfg.prefix_embed_len, 8),
        param_dtype="float32", compute_dtype="float32",
    )


ARCH_IDS = (
    "musicgen-large",
    "falcon-mamba-7b",
    "qwen3-8b",
    "llama3.2-1b",
    "moonshot-v1-16b-a3b",
    "recurrentgemma-9b",
    "granite-moe-3b-a800m",
    "minitron-8b",
    "qwen2-vl-2b",
    "mixtral-8x22b",
    "resnet50",  # the paper's own benchmark model (CNN family)
)


def get_config(arch_id: str) -> ArchConfig:
    mod_name = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.CONFIG
    if isinstance(cfg, ArchConfig):
        cfg.validate()
    return cfg


def get_reduced(arch_id: str):
    mod_name = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    if hasattr(mod, "reduced"):
        return mod.reduced()
    return reduce_config(mod.CONFIG)
