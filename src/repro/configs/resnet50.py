"""ResNet-50 — the paper's own ImageNet benchmark model [He et al. 2016].

Used for the paper-faithful convergence/scaling experiments (DASO vs sync on
an image classifier with node-local synchronized batch norm). The CNN family
lives in repro.models.cnn; this config is NOT part of the assigned 10x4
transformer dry-run matrix.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ResNetConfig:
    name: str = "resnet50"
    family: str = "cnn"
    stage_sizes: Tuple[int, ...] = (3, 4, 6, 3)   # ResNet-50
    width: int = 64
    bottleneck: bool = True
    n_classes: int = 1000
    image_size: int = 224
    param_dtype: str = "float32"
    source: str = "[He et al., CVPR 2016; paper's own benchmark]"


CONFIG = ResNetConfig()


def reduced() -> ResNetConfig:
    """Tiny same-family variant for CPU smoke tests / convergence runs."""
    return ResNetConfig(
        name="resnet-tiny", stage_sizes=(1, 1), width=8, bottleneck=False,
        n_classes=10, image_size=32)
