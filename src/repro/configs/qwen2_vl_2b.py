"""Qwen2-VL-2B language backbone with M-RoPE [arXiv:2409.12191].

28L d_model=1536 12H (GQA kv=2, head_dim=128) d_ff=8960 vocab=151936.
The ViT vision encoder + projector is stubbed: input_specs() provides
precomputed patch embeddings (B, prefix, d_model) plus 3D M-RoPE position ids
(temporal / height / width) for the spliced sequence.
"""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    layer_pattern=(ATTN,),
    rope_type="mrope",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    long_context_window=8192,
    prefix_embed_len=256,  # 16x16 patch grid stub
    source="[arXiv:2409.12191]",
)
