"""Mixtral-8x22B: 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088].

56L d_model=6144 48H (GQA kv=8, head_dim=128) expert d_ff=16384 vocab=32768,
SWA window 4096 on every layer (per the assignment bracket). SWA makes the
arch sub-quadratic -> long_500k runs natively.
8 experts % 16 != 0 -> tensor-parallel expert sharding.
"""
from repro.configs.base import ArchConfig, ATTN_SWA, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab_size=32768,
    layer_pattern=(ATTN_SWA,),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=16384, n_shared_experts=0,
                  capacity_factor=1.25, sharding="tensor"),
    sliding_window=4096,
    rope_theta=1_000_000.0,
    source="[arXiv:2401.04088]",
)
