"""Llama-3.2-1B: small dense GQA decoder, tied embeddings [hf:meta-llama/Llama-3.2-1B].

16L d_model=2048 32H (GQA kv=8, head_dim=64) d_ff=8192 vocab=128256.
"""
from repro.configs.base import ArchConfig, ATTN

CONFIG = ArchConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    layer_pattern=(ATTN,),
    rope_theta=500_000.0,
    tie_embeddings=True,
    long_context_window=8192,
    source="[hf:meta-llama/Llama-3.2-1B]",
)
