"""Falcon-Mamba-7B: attention-free Mamba-1 architecture [arXiv:2410.05355].

64L d_model=4096, d_inner=8192 (expand=2), ssm_state=16, vocab=65024.
Natively sub-quadratic: all four input shapes run, decode uses the recurrent
SSM state (no KV cache).
"""
from repro.configs.base import ArchConfig, MAMBA, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,  # attention-free, FFN-free: the mamba mixer is the whole block
    vocab_size=65024,
    layer_pattern=(MAMBA,),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    rope_type="none",
    tie_embeddings=False,
    source="[arXiv:2410.05355]",
)
