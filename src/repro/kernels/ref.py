"""Pure-jnp oracles for the Pallas kernels. Deliberately naive (materialize
full score matrices / state histories) — correctness reference only."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q (B,Hq,Sq,D); k,v (B,Hk,Sk,D); GQA by head grouping. fp32 softmax."""
    B, Hq, Sq, D = q.shape
    Hk, Sk = k.shape[1], k.shape[2]
    G = Hq // Hk
    qg = q.reshape(B, Hk, G, Sq, D)
    s = jnp.einsum("bkgqd,bkld->bkgql", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    row = jnp.arange(Sq)[:, None] + (Sk - Sq)  # align ends (q suffix of k)
    col = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= col <= row
    if window:
        mask &= col > row - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgql,bkld->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)


def ssm_scan_ref(x, dt, A, Bm, Cm, h0):
    """Mamba selective scan, sequential reference.
    x, dt (B,S,Di); A (Di,N); Bm, Cm (B,S,N); h0 (B,Di,N).
    Returns (y (B,S,Di) f32, h_final)."""
    B, S, Di = x.shape

    def step(h, t):
        da = jnp.exp(dt[:, t, :, None] * A)
        db = ((dt[:, t] * x[:, t].astype(jnp.float32))[..., None]
              * Bm[:, t].astype(jnp.float32)[:, None, :])
        h = da * h + db
        y = jnp.einsum("bdn,bn->bd", h, Cm[:, t].astype(jnp.float32))
        return h, y

    h, ys = jax.lax.scan(step, h0, jnp.arange(S))
    return ys.swapaxes(0, 1), h


def rglru_scan_ref(a, gx, h0):
    """Diagonal recurrence h_t = a_t * h_{t-1} + gx_t.
    a, gx (B,S,W) f32; h0 (B,W). Returns (hs (B,S,W), h_final)."""
    def step(h, t):
        h = a[:, t] * h + gx[:, t]
        return h, h

    h, hs = jax.lax.scan(step, h0, jnp.arange(a.shape[1]))
    return hs.swapaxes(0, 1), h
