"""Pure-jnp oracles for the Pallas kernels. Deliberately naive (materialize
full score matrices / state histories) — correctness reference only."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q (B,Hq,Sq,D); k,v (B,Hk,Sk,D); GQA by head grouping. fp32 softmax."""
    B, Hq, Sq, D = q.shape
    Hk, Sk = k.shape[1], k.shape[2]
    G = Hq // Hk
    qg = q.reshape(B, Hk, G, Sq, D)
    s = jnp.einsum("bkgqd,bkld->bkgql", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    row = jnp.arange(Sq)[:, None] + (Sk - Sq)  # align ends (q suffix of k)
    col = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= col <= row
    if window:
        mask &= col > row - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgql,bkld->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)


def ssm_scan_ref(x, dt, A, Bm, Cm, h0):
    """Mamba selective scan, sequential reference.
    x, dt (B,S,Di); A (Di,N); Bm, Cm (B,S,N); h0 (B,Di,N).
    Returns (y (B,S,Di) f32, h_final)."""
    B, S, Di = x.shape

    def step(h, t):
        da = jnp.exp(dt[:, t, :, None] * A)
        db = ((dt[:, t] * x[:, t].astype(jnp.float32))[..., None]
              * Bm[:, t].astype(jnp.float32)[:, None, :])
        h = da * h + db
        y = jnp.einsum("bdn,bn->bd", h, Cm[:, t].astype(jnp.float32))
        return h, y

    h, ys = jax.lax.scan(step, h0, jnp.arange(S))
    return ys.swapaxes(0, 1), h


def eq1_merge_ref(local, stale, *, staleness, global_world,
                  extra_staleness=0):
    """Paper Eq. (1) over an arena (or any array): f32 accumulation,
    result in local's dtype.

    `extra_staleness` is the extra age the stale buffer accrued beyond the
    scheduled wait — the overlap executor merges each exchange one cycle
    late, so the effective S in Eq. (1) is `staleness + extra_staleness`.
    The default 0 keeps this function bit-identical to the pre-overlap
    kernel (tests/test_overlap.py pins that property)."""
    s2 = 2.0 * (staleness + extra_staleness)
    p = float(global_world)
    merged = (s2 * local.astype(jnp.float32)
              + p * stale.astype(jnp.float32)) / (s2 + p)
    return merged.astype(local.dtype)


# keeps all-zero blocks finite (q == 0 regardless); shared with the
# Pallas kernels in comm_kernels.py so oracle and kernel cannot drift
INT8_SCALE_FLOOR = 1e-12


def _blocked(x, block):
    """(…, N) -> ((rows, n_blocks, block) padded view, (lead, N, Np))."""
    lead, n = x.shape[:-1], x.shape[-1]
    rows = 1
    for d in lead:
        rows *= d
    npad = -(-n // block) * block
    xr = x.reshape((rows, n))
    if npad != n:
        xr = jnp.pad(xr, ((0, 0), (0, npad - n)))
    return xr.reshape((rows, npad // block, block)), (lead, n, npad)


def quantize_int8_block_ref(x, *, block: int = 256, bits=None):
    """Block-scaled int8 quantization over the trailing axis (blocks never
    span leading axes). scale = absmax(block)/127; `bits` (uint32, same
    shape as x) enables stochastic rounding, None = round-to-nearest.
    Returns (values int8 like x, scales f32 (*lead, n_blocks))."""
    xb, (lead, n, npad) = _blocked(x.astype(jnp.float32), block)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1, keepdims=True),
                        INT8_SCALE_FLOOR) / 127.0
    v = xb / scale
    if bits is None:
        q = jnp.round(v)
    else:
        bb, _ = _blocked(bits, block)
        u = (bb >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
        q = jnp.floor(v + u)
    values = jnp.clip(q, -127.0, 127.0).astype(jnp.int8)
    values = values.reshape((-1, npad))[:, :n].reshape(lead + (n,))
    return values, scale.reshape(lead + (npad // block,))


def dequantize_int8_block_ref(values, scales, *, block: int = 256):
    """Inverse of `quantize_int8_block_ref` (f32 output)."""
    vb, (lead, n, npad) = _blocked(values, block)
    out = vb.astype(jnp.float32) * scales.reshape(vb.shape[:-1] + (1,))
    return out.reshape((-1, npad))[:, :n].reshape(lead + (n,))


def rglru_scan_ref(a, gx, h0):
    """Diagonal recurrence h_t = a_t * h_{t-1} + gx_t.
    a, gx (B,S,W) f32; h0 (B,W). Returns (hs (B,S,W), h_final)."""
    def step(h, t):
        h = a[:, t] * h + gx[:, t]
        return h, h

    h, hs = jax.lax.scan(step, h0, jnp.arange(a.shape[1]))
    return hs.swapaxes(0, 1), h
