"""jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run with interpret=True (the kernel body
executes in Python op-by-op — same math, same blocking); on TPU set
interpret=False (default resolves via repro.kernels.ops.INTERPRET)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import comm_kernels as _comm
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rglru_scan import rglru_scan as _rglru
from repro.kernels.ssm_scan import ssm_scan as _ssm

# CPU container default; flipped to False on real TPU deployments.
INTERPRET = jax.default_backend() == "cpu"


def _pad_rows(x, block: int):
    """View (…, N) as (rows, block) with the trailing axis padded to a
    block multiple. Blocks never span leading axes (replica rows)."""
    lead, n = x.shape[:-1], x.shape[-1]
    rows = 1
    for d in lead:
        rows *= d
    npad = -(-n // block) * block
    xr = x.reshape((rows, n))
    if npad != n:
        xr = jnp.pad(xr, ((0, 0), (0, npad - n)))
    return xr.reshape((rows * (npad // block), block)), (lead, n, npad)


def _unpad_rows(rows_view, meta):
    lead, n, npad = meta
    return rows_view.reshape((-1, npad))[:, :n].reshape(lead + (n,))


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    interpret = INTERPRET if interpret is None else interpret
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def ssm_scan(x, dt, A, Bm, Cm, h0, *, block_d: int = 512,
             interpret: bool | None = None):
    interpret = INTERPRET if interpret is None else interpret
    return _ssm(x, dt, A, Bm, Cm, h0, block_d=block_d, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def rglru_scan(a, gx, h0, *, block_w: int = 512,
               interpret: bool | None = None):
    interpret = INTERPRET if interpret is None else interpret
    return _rglru(a, gx, h0, block_w=block_w, interpret=interpret)


# -- fused flat-buffer exchange kernels (core/flatbuf.py arenas) ---------------

@functools.partial(jax.jit, static_argnames=("staleness", "global_world",
                                             "extra_staleness", "block",
                                             "interpret"))
def eq1_merge(local, stale, *, staleness: int, global_world: int,
              extra_staleness: int = 0, block: int = 1024,
              interpret: bool | None = None):
    """Paper Eq. (1) merge fused over an arena of any shape (trailing axis
    is the packed axis). Output in local's dtype. `extra_staleness` is the
    overlap executor's one-cycle buffer age, added to S (0 = the
    pre-overlap kernel, bit-exact)."""
    interpret = INTERPRET if interpret is None else interpret
    lr, meta = _pad_rows(local, block)
    sr, _ = _pad_rows(stale, block)
    out = _comm.eq1_merge(lr, sr, staleness=staleness,
                          global_world=global_world,
                          extra_staleness=extra_staleness, block=block,
                          interpret=interpret)
    return _unpad_rows(out, meta)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def bf16_pack(x, *, block: int = 1024, interpret: bool | None = None):
    """Arena -> bf16 wire buffer (same shape)."""
    interpret = INTERPRET if interpret is None else interpret
    xr, meta = _pad_rows(x, block)
    return _unpad_rows(_comm.bf16_pack(xr, block=block,
                                       interpret=interpret), meta)


@functools.partial(jax.jit, static_argnames=("out_dtype", "block",
                                             "interpret"))
def bf16_unpack(x, *, out_dtype=jnp.float32, block: int = 1024,
                interpret: bool | None = None):
    """bf16 wire buffer -> arena in `out_dtype` (same shape)."""
    interpret = INTERPRET if interpret is None else interpret
    xr, meta = _pad_rows(x, block)
    return _unpad_rows(_comm.bf16_unpack(xr, out_dtype=out_dtype,
                                         block=block, interpret=interpret),
                       meta)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quantize_int8(x, bits=None, *, block: int = 256,
                  interpret: bool | None = None):
    """Block-scaled int8 quantization over the trailing axis. `bits`
    (uint32, same shape as x) enables stochastic rounding; None =
    round-to-nearest. Returns (values int8 like x,
    scales f32 (*lead, ceil(N/block)))."""
    interpret = INTERPRET if interpret is None else interpret
    xr, meta = _pad_rows(x, block)
    if bits is not None:
        bits, _ = _pad_rows(bits, block)
    values, scales = _comm.quantize_int8(xr, bits, block=block,
                                         interpret=interpret)
    lead, n, npad = meta
    return (_unpad_rows(values, meta),
            scales.reshape(lead + (npad // block,)))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dequantize_int8(values, scales, *, block: int = 256,
                    interpret: bool | None = None):
    """Inverse of `quantize_int8` (f32 output, values' shape)."""
    interpret = INTERPRET if interpret is None else interpret
    vr, meta = _pad_rows(values, block)
    out = _comm.dequantize_int8(vr, scales.reshape((-1, 1)), block=block,
                                interpret=interpret)
    return _unpad_rows(out, meta)
