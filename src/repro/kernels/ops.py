"""jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run with interpret=True (the kernel body
executes in Python op-by-op — same math, same blocking); on TPU set
interpret=False (default resolves via repro.kernels.ops.INTERPRET)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rglru_scan import rglru_scan as _rglru
from repro.kernels.ssm_scan import ssm_scan as _ssm

# CPU container default; flipped to False on real TPU deployments.
INTERPRET = jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    interpret = INTERPRET if interpret is None else interpret
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def ssm_scan(x, dt, A, Bm, Cm, h0, *, block_d: int = 512,
             interpret: bool | None = None):
    interpret = INTERPRET if interpret is None else interpret
    return _ssm(x, dt, A, Bm, Cm, h0, block_d=block_d, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def rglru_scan(a, gx, h0, *, block_w: int = 512,
               interpret: bool | None = None):
    interpret = INTERPRET if interpret is None else interpret
    return _rglru(a, gx, h0, block_w=block_w, interpret=interpret)
