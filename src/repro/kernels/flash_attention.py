"""Flash attention forward kernel (Pallas TPU): blocked online-softmax,
causal + sliding-window + GQA.

TPU adaptation of the FlashAttention blocking: q tiles of (block_q, head_dim)
stream from HBM into VMEM per grid step; the full K/V for one (batch, kv-head)
pair is VMEM-resident and walked in block_k chunks by an in-kernel fori_loop
carrying the running (max, denom, acc) — MXU-aligned tiles (block sizes are
multiples of 128 on the contracting dims).

Layout: q (B, Hq, Sq, D); k/v (B, Hk, Sk, D); Hq = G * Hk (GQA). Grid is
(B, Hq, Sq/block_q); the k/v BlockSpec index map folds the GQA group
(h -> h // G), so no materialized head expansion.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, window,
                block_k, kv_len, q_offset):
    block_q, d = q_ref.shape
    q = q_ref[...].astype(jnp.float32) * scale
    qi = pl.program_id(2)
    row = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, 1), 0)

    nk = kv_len // block_k
    if causal:
        # skip kv blocks strictly above the causal frontier of this q block
        hi = ((q_offset + (qi + 1) * block_q + block_k - 1) // block_k)
        nk_eff = jnp.minimum(nk, hi)
    else:
        nk_eff = nk

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[pl.dslice(j * block_k, block_k), :]
        v = v_ref[pl.dslice(j * block_k, block_k), :]
        s = jax.lax.dot_general(q, k.astype(jnp.float32),
                                (((1,), (1,)), ((), ())))  # (bq, bk)
        col = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        mask = jnp.ones(s.shape, jnp.bool_)
        if causal:
            mask &= col <= row
        if window > 0:
            mask &= col > row - window
        s = jnp.where(mask, s, NEG_INF)
        m = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m)
        alpha = jnp.exp(m_prev - m)
        l = l_prev * alpha + p.sum(axis=1, keepdims=True)
        acc = acc * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ()))).astype(jnp.float32)
        return m, l, acc

    m0 = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nk_eff, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q (B,Hq,Sq,D); k,v (B,Hk,Sk,D) -> (B,Hq,Sq,D).

    Sq may be shorter than Sk (the q rows are the suffix of the kv range,
    e.g. chunked prefill); rows are aligned at the end."""
    B, Hq, Sq, D = q.shape
    Hk, Sk = k.shape[1], k.shape[2]
    G = Hq // Hk
    bq = min(block_q, Sq)
    while Sq % bq:
        bq //= 2
    bk = min(block_k, Sk)
    while Sk % bk:
        bk //= 2
    grid = (B, Hq, Sq // bq)
    kernel = functools.partial(
        _fwd_kernel, scale=D ** -0.5, causal=causal, window=window,
        block_k=bk, kv_len=Sk, q_offset=Sk - Sq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, bq, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((None, None, Sk, D), lambda b, h, i: (b, h // G, 0, 0)),
            pl.BlockSpec((None, None, Sk, D), lambda b, h, i: (b, h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, bq, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
