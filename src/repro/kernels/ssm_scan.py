"""Mamba-1 selective-scan Pallas kernel.

TPU adaptation: the GPU kernel (mamba's fused CUDA scan) parallelizes over
(batch, channel) threads; here the grid is (batch, d_inner / block_d) with a
(block_d, N) state tile resident in VMEM and a sequential fori_loop over time
steps in groups of `step_unroll` (VPU elementwise work; no MXU involvement —
the surrounding projections use it instead). dt/x stream per (batch, channel
block); B/C per batch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssm_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref,
                *, seq_len):
    block_d, N = a_ref.shape

    def body(t, h):
        dt_t = dt_ref[t, :].astype(jnp.float32)            # (bd,)
        x_t = x_ref[t, :].astype(jnp.float32)              # (bd,)
        b_t = b_ref[t, :].astype(jnp.float32)              # (N,)
        c_t = c_ref[t, :].astype(jnp.float32)              # (N,)
        da = jnp.exp(dt_t[:, None] * a_ref[...])           # (bd,N)
        db = (dt_t * x_t)[:, None] * b_t[None, :]
        h = da * h + db
        y_ref[t, :] = (h * c_t[None, :]).sum(axis=1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, seq_len, body,
                          h0_ref[...].astype(jnp.float32))
    hout_ref[...] = h


def ssm_scan(x, dt, A, Bm, Cm, h0, *, block_d: int = 512,
             interpret: bool = False):
    """x, dt (B,S,Di); A (Di,N) f32; Bm, Cm (B,S,N); h0 (B,Di,N) f32.
    Returns (y (B,S,Di) f32, h_final (B,Di,N) f32)."""
    B, S, Di = x.shape
    N = A.shape[1]
    bd = min(block_d, Di)
    while Di % bd:
        bd //= 2
    grid = (B, Di // bd)
    kernel = functools.partial(_ssm_kernel, seq_len=S)
    y, hout = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, S, bd), lambda b, d: (b, 0, d)),   # x
            pl.BlockSpec((None, S, bd), lambda b, d: (b, 0, d)),   # dt
            pl.BlockSpec((bd, N), lambda b, d: (d, 0)),            # A
            pl.BlockSpec((None, S, N), lambda b, d: (b, 0, 0)),    # B
            pl.BlockSpec((None, S, N), lambda b, d: (b, 0, 0)),    # C
            pl.BlockSpec((None, bd, N), lambda b, d: (b, d, 0)),   # h0
        ],
        out_specs=[
            pl.BlockSpec((None, S, bd), lambda b, d: (b, 0, d)),
            pl.BlockSpec((None, bd, N), lambda b, d: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, Di), jnp.float32),
            jax.ShapeDtypeStruct((B, Di, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A, Bm, Cm, h0)
    return y, hout
