"""RG-LRU linear-recurrence Pallas kernel (RecurrentGemma / Griffin).

The gate projections (matmuls) run outside on the MXU; this kernel is the
memory-bound diagonal recurrence h_t = a_t * h_{t-1} + gx_t over (B, S, W)
with a (block_w,) state vector resident in VMEM per grid cell.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rglru_kernel(a_ref, gx_ref, h0_ref, hs_ref, hout_ref, *, seq_len):
    def body(t, h):
        h = a_ref[t, :].astype(jnp.float32) * h + gx_ref[t, :].astype(
            jnp.float32)
        hs_ref[t, :] = h.astype(hs_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, seq_len, body, h0_ref[...].astype(jnp.float32))
    hout_ref[...] = h


def rglru_scan(a, gx, h0, *, block_w: int = 512, interpret: bool = False):
    """a, gx (B,S,W); h0 (B,W) f32 -> (hs (B,S,W) f32, h_final (B,W) f32)."""
    B, S, W = a.shape
    bw = min(block_w, W)
    while W % bw:
        bw //= 2
    grid = (B, W // bw)
    kernel = functools.partial(_rglru_kernel, seq_len=S)
    hs, hout = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, S, bw), lambda b, w: (b, 0, w)),
            pl.BlockSpec((None, S, bw), lambda b, w: (b, 0, w)),
            pl.BlockSpec((None, bw), lambda b, w: (b, w)),
        ],
        out_specs=[
            pl.BlockSpec((None, S, bw), lambda b, w: (b, 0, w)),
            pl.BlockSpec((None, bw), lambda b, w: (b, w)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        interpret=interpret,
    )(a, gx, h0)
    return hs, hout
