"""Pallas kernels for the fused flat-buffer global exchange (core/flatbuf.py).

The exchange hot path operates on one contiguous arena per dtype instead of
per parameter leaf; these kernels fuse the elementwise exchange math over
that arena:

  * `eq1_merge`       — paper Eq. (1): (2S*x_local + P*x_stale) / (2S + P),
                        f32 accumulation, output in the arena dtype;
  * `bf16_pack` /
    `bf16_unpack`     — the paper's 16-bit transfer packaging over the
                        arena (one cast kernel instead of one per leaf);
  * `quantize_int8` /
    `dequantize_int8` — beyond-paper int8 tier: per-block absmax scales
                        (QSGD-style), optional stochastic rounding from
                        caller-supplied uint32 bits.

All kernels view the arena as rows of `block` contiguous elements: the
`repro.kernels.ops` wrappers flatten, pad the trailing axis to a block
multiple, and run a (rows, blocks) grid. Blocks never span the leading
batch (replica) axis, so int8 scales are always per-replica. On this CPU
container they run with interpret=True; on TPU set interpret=False and
size `block` to the dtype tile (int8 wants multiples of 32*128).

Random bits for stochastic rounding are passed in as a uint32 arena
(generated with jax.random.bits) rather than drawn via pltpu.prng_* so the
same kernel body runs under plain interpret mode; a TPU deployment can
swap in the on-core PRNG without changing the contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import INT8_SCALE_FLOOR


def _row_specs(block: int):
    """One (block,)-row of the (rows, block) arena view per grid cell."""
    return pl.BlockSpec((None, block), lambda i: (i, 0))


# -- Eq. (1) merge -------------------------------------------------------------

def _eq1_kernel(local_ref, stale_ref, out_ref, *, s2, p):
    inv = 1.0 / (s2 + p)
    x = local_ref[...].astype(jnp.float32)
    y = stale_ref[...].astype(jnp.float32)
    out_ref[...] = ((s2 * x + p * y) * inv).astype(out_ref.dtype)


def eq1_merge(local, stale, *, staleness: int, global_world: int,
              extra_staleness: int = 0, block: int = 1024,
              interpret: bool = False):
    """local, stale: (rows, block) arena views, same shape/dtype.
    Returns the Eq. (1) merge in local's dtype. `extra_staleness` adds the
    overlap executor's one-cycle buffer age to S (0 = the pre-overlap
    kernel, bit-exact)."""
    rows, bk = local.shape
    assert bk == block, (local.shape, block)
    kernel = functools.partial(_eq1_kernel,
                               s2=2.0 * (staleness + extra_staleness),
                               p=float(global_world))
    return pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[_row_specs(block), _row_specs(block)],
        out_specs=_row_specs(block),
        out_shape=jax.ShapeDtypeStruct((rows, block), local.dtype),
        interpret=interpret,
    )(local, stale)


# -- bf16 wire packaging -------------------------------------------------------

def _cast_kernel(x_ref, out_ref):
    out_ref[...] = x_ref[...].astype(out_ref.dtype)


def bf16_pack(x, *, block: int = 1024, interpret: bool = False):
    """(rows, block) floating arena view -> bf16 wire buffer."""
    rows, bk = x.shape
    assert bk == block, (x.shape, block)
    return pl.pallas_call(
        _cast_kernel,
        grid=(rows,),
        in_specs=[_row_specs(block)],
        out_specs=_row_specs(block),
        out_shape=jax.ShapeDtypeStruct((rows, block), jnp.bfloat16),
        interpret=interpret,
    )(x)


def bf16_unpack(x, *, out_dtype=jnp.float32, block: int = 1024,
                interpret: bool = False):
    """bf16 wire buffer -> (rows, block) arena view in `out_dtype`."""
    rows, bk = x.shape
    assert bk == block, (x.shape, block)
    return pl.pallas_call(
        _cast_kernel,
        grid=(rows,),
        in_specs=[_row_specs(block)],
        out_specs=_row_specs(block),
        out_shape=jax.ShapeDtypeStruct((rows, block), out_dtype),
        interpret=interpret,
    )(x)


# -- int8 block-scaled quantization --------------------------------------------

def _quantize_kernel(x_ref, bits_ref, v_ref, s_ref, *, stochastic):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), INT8_SCALE_FLOOR) / 127.0
    v = x / scale
    if stochastic:
        # floor(v + u), u ~ U[0,1) from the top 24 bits: E[q] = v exactly
        u = (bits_ref[...] >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
        q = jnp.floor(v + u)
    else:
        q = jnp.round(v)
    v_ref[...] = jnp.clip(q, -127.0, 127.0).astype(jnp.int8)
    s_ref[0] = scale


def quantize_int8(x, bits, *, block: int = 256, interpret: bool = False):
    """x: (blocks, block) arena view; bits: uint32 of the same shape or None
    (deterministic round-to-nearest). Returns (int8 values (blocks, block),
    f32 scales (blocks, 1)) with scale = absmax(block)/127."""
    rows, bk = x.shape
    assert bk == block, (x.shape, block)
    stochastic = bits is not None
    if bits is None:
        bits = jnp.zeros((rows, block), jnp.uint32)
    kernel = functools.partial(_quantize_kernel, stochastic=stochastic)
    return pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[_row_specs(block), _row_specs(block)],
        out_specs=[_row_specs(block), pl.BlockSpec((None, 1),
                                                   lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, block), jnp.int8),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32)],
        interpret=interpret,
    )(x, bits)


def _dequantize_kernel(v_ref, s_ref, out_ref):
    out_ref[...] = v_ref[...].astype(jnp.float32) * s_ref[0]


def dequantize_int8(values, scales, *, block: int = 256,
                    interpret: bool = False):
    """Inverse of `quantize_int8`: (blocks, block) int8 + (blocks, 1) f32
    scales -> f32 (blocks, block)."""
    rows, bk = values.shape
    assert bk == block, (values.shape, block)
    return pl.pallas_call(
        _dequantize_kernel,
        grid=(rows,),
        in_specs=[_row_specs(block), pl.BlockSpec((None, 1),
                                                  lambda i: (i, 0))],
        out_specs=_row_specs(block),
        out_shape=jax.ShapeDtypeStruct((rows, block), jnp.float32),
        interpret=interpret,
    )(values, scales)
