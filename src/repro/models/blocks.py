"""Residual block assembly: mixer (attn / mamba / rglru) + FFN (dense / MoE)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, ATTN_LOCAL, ATTN_SWA, MAMBA, RGLRU)
from repro.models.attention import attn_apply, init_attn
from repro.models.common import dense_init, rms_norm, silu_mlp
from repro.models.mamba import init_mamba, init_mamba_cache, mamba_apply
from repro.models.moe import init_moe, moe_apply
from repro.models.rglru import init_rglru, init_rglru_cache, rglru_apply
from repro.sharding import constrain

ZERO_AUX = {"moe_lb_loss": 0.0, "moe_z_loss": 0.0, "moe_drop_frac": 0.0}


def _init_ffn(key, cfg, dtype):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "norm": jnp.zeros((D,), dtype),
        "w1": dense_init(ks[0], (D, F), dtype),
        "w3": dense_init(ks[1], (D, F), dtype),
        "w2": dense_init(ks[2], (F, D), dtype,
                         scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }


def _has_ffn(cfg, kind) -> bool:
    return kind != MAMBA and (cfg.d_ff > 0 or cfg.moe is not None)


def init_block(key, cfg, kind, dtype):
    k1, k2 = jax.random.split(key)
    p = {}
    if kind in (ATTN, ATTN_SWA, ATTN_LOCAL):
        p["attn"] = init_attn(k1, cfg, dtype)
    elif kind == MAMBA:
        p["mamba"] = init_mamba(k1, cfg, dtype)
    elif kind == RGLRU:
        p["rec"] = init_rglru(k1, cfg, dtype)
    else:
        raise ValueError(kind)
    if _has_ffn(cfg, kind):
        if cfg.moe is not None:
            p["moe"] = init_moe(k2, cfg, dtype)
            p["moe_norm"] = jnp.zeros((cfg.d_model,), dtype)
        else:
            p["ffn"] = _init_ffn(k2, cfg, dtype)
    return p


def init_block_cache(cfg, kind, batch, cache_len, dtype):
    if kind in (ATTN, ATTN_SWA, ATTN_LOCAL):
        K, hd = max(cfg.n_kv_heads, 1), max(cfg.head_dim, 1)
        return {"k": jnp.zeros((batch, cache_len, K, hd), dtype),
                "v": jnp.zeros((batch, cache_len, K, hd), dtype)}
    if kind == MAMBA:
        return init_mamba_cache(cfg, batch, dtype)
    if kind == RGLRU:
        return init_rglru_cache(cfg, batch, dtype)
    raise ValueError(kind)


def block_window(cfg, kind, window_override: int) -> int:
    """Effective attention window for this block kind (0 = unbounded)."""
    if kind in (ATTN_SWA, ATTN_LOCAL):
        return cfg.sliding_window
    if kind == ATTN and window_override:
        return window_override
    return 0


def apply_block(kind, p, x, positions, cfg, *, cache: Optional[dict] = None,
                pos=None, window_override: int = 0, q_chunk: int = 1024,
                mamba_chunk: int = 64, unroll_inner: bool = False,
                attn_impl: str = "jnp"):
    """x (B,S,D) -> (x, new_cache, aux)."""
    aux = dict(ZERO_AUX)
    new_cache = {}
    if kind in (ATTN, ATTN_SWA, ATTN_LOCAL):
        win = block_window(cfg, kind, window_override)
        delta, nc = attn_apply(p["attn"], x, positions, cfg, window=win,
                               cache=None if cache is None else cache,
                               pos=pos, q_chunk=q_chunk, impl=attn_impl)
        x = x + delta
        new_cache = nc
    elif kind == MAMBA:
        h = rms_norm(x, p["mamba"]["norm"], cfg.norm_eps)
        delta, nc = mamba_apply(p["mamba"], h, cfg, cache=cache,
                                chunk=mamba_chunk, unroll=unroll_inner)
        x = x + delta
        new_cache = nc
    elif kind == RGLRU:
        h = rms_norm(x, p["rec"]["norm"], cfg.norm_eps)
        delta, nc = rglru_apply(p["rec"], h, cfg, cache=cache,
                                unroll=unroll_inner)
        x = x + delta
        new_cache = nc
    if _has_ffn(cfg, kind):
        if cfg.moe is not None:
            h = rms_norm(x, p["moe_norm"], cfg.norm_eps)
            delta, aux = moe_apply(p["moe"], h, cfg)
            x = x + delta
        else:
            h = rms_norm(x, p["ffn"]["norm"], cfg.norm_eps)
            x = x + silu_mlp(h, p["ffn"]["w1"], p["ffn"]["w3"], p["ffn"]["w2"])
    x = constrain(x, "batch", None, None)
    return x, new_cache, aux
