"""Mixture-of-Experts FFN with GShard-style dispatch-mask routing.

TPU-idiomatic dense dispatch (one-hot capacity einsums, no gather/scatter):
under GSPMD this partitions as expert parallelism (expert axis over "model")
or tensor parallelism (per-expert d_ff over "model") per MoEConfig.sharding —
see DESIGN.md §6. Aux losses: switch load-balance + router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, silu_mlp


def init_moe(key, cfg, dtype):
    m = cfg.moe
    D, F, E = cfg.d_model, m.d_ff, m.n_experts
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (D, E), jnp.float32),
        "we1": dense_init(ks[1], (E, D, F), dtype),
        "we3": dense_init(ks[2], (E, D, F), dtype),
        "we2": dense_init(ks[3], (E, F, D), dtype,
                          scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }
    if m.n_shared_experts:
        Fs = m.d_ff * m.n_shared_experts
        p["shared"] = {
            "w1": dense_init(ks[4], (D, Fs), dtype),
            "w3": dense_init(ks[5], (D, Fs), dtype),
            "w2": dense_init(ks[6], (Fs, D), dtype,
                             scale=1.0 / (2 * cfg.n_layers) ** 0.5),
        }
    return p


def moe_apply(p, x, cfg):
    """x (B,S,D) -> (out (B,S,D), aux dict of scalar losses).

    Tokens are routed in groups of moe.group_size (GShard-style): capacity
    is per group, so the dispatch/combine tensors stay O(G^2 K/E) per group
    regardless of sequence length (a 32k sequence routed as ONE group would
    need a (32768, E, 8192)-sized combine — see EXPERIMENTS.md §Perf P3)."""
    m = cfg.moe
    B0, S0, D = x.shape
    G = m.group_size
    if S0 > G and S0 % G == 0:
        x = x.reshape(B0 * (S0 // G), G, D)
    out, aux = _moe_grouped(p, x, cfg)
    if out.shape[:2] != (B0, S0):
        out = out.reshape(B0, S0, D)
    return out, aux


def _moe_grouped(p, x, cfg):
    m = cfg.moe
    B, S, D = x.shape
    E, K = m.n_experts, m.top_k
    C = max(1, int(S * K * m.capacity_factor / E))

    logits = (x.astype(jnp.float32) @ p["router"])          # (B,S,E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)           # (B,S,K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # -- capacity assignment (per-group), GShard style ---------------------
    # combine accumulates in the compute dtype: it holds disjoint one-hot
    # slots weighted by gates in [0,1], so bf16 is exact enough and halves
    # the largest routing tensor (§Perf P3).
    combine = jnp.zeros((B, S, E, C), x.dtype)
    counts = jnp.zeros((B, E), jnp.float32)
    for slot in range(K):
        oh = jax.nn.one_hot(gate_idx[:, :, slot], E)        # (B,S,E)
        pos = jnp.cumsum(oh, axis=1) - 1 + counts[:, None]  # (B,S,E)
        in_cap = ((pos < C) * oh).astype(x.dtype)            # (B,S,E)
        pos_oh = jax.nn.one_hot(jnp.clip(pos, 0, C - 1).astype(jnp.int32), C,
                                dtype=x.dtype)
        combine = combine + (gate_vals[:, :, slot, None, None].astype(x.dtype)
                             * in_cap[..., None] * pos_oh)
        counts = counts + oh.sum(axis=1)

    dispatch = (combine > 0).astype(x.dtype)                # (B,S,E,C)
    expert_in = jnp.einsum("bsec,bsd->ebcd", dispatch, x)   # (E,B,C,D)
    h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", expert_in, p["we1"]))
    h = h * jnp.einsum("ebcd,edf->ebcf", expert_in, p["we3"])
    expert_out = jnp.einsum("ebcf,efd->ebcd", h, p["we2"])  # (E,B,C,D)
    out = jnp.einsum("bsec,ebcd->bsd", combine, expert_out)

    if "shared" in p:
        sh = p["shared"]
        out = out + silu_mlp(x, sh["w1"], sh["w3"], sh["w2"])

    # -- aux losses (Switch/GShard) ---------------------------------------
    me = probs.mean(axis=(0, 1))                             # mean router prob
    # fraction of tokens whose top-1 goes to each expert
    top1 = jax.nn.one_hot(gate_idx[:, :, 0], E).mean(axis=(0, 1))
    lb_loss = E * jnp.sum(me * top1) * m.load_balance_loss
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_loss
    dropped = 1.0 - (dispatch.sum() / (B * S * K))
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
           "moe_drop_frac": dropped}
    return out, aux
