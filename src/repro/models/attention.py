"""GQA attention: chunked-q causal/sliding-window training path + cached decode.

The training/prefill path iterates q-chunks in a *python* loop with static
slice bounds: (a) only the causally/window-reachable K/V slice is read per
chunk, so FLOPs match the true masked cost (0.5x full for causal, O(S*W) for
windowed); (b) no lax.scan, so XLA cost_analysis counts every chunk (scan
bodies are counted once — see EXPERIMENTS.md roofline methodology).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import head_rms_norm, rms_norm
from repro.models.rope import apply_mrope, apply_rope
from repro.sharding import constrain

NEG_INF = -1e30


def _pick_chunk(seq: int, target: int) -> int:
    c = min(target, seq)
    while seq % c:
        c //= 2
    return max(c, 1)


def multihead_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        q_chunk: int = 1024):
    """q (B,Sq,Hq,D); k,v (B,Sk,K,D); GQA via grouped einsum. Returns (B,Sq,Hq,D).

    Assumes q and k cover the same token range starting at position 0
    (training / prefill). window > 0 restricts attention to the last `window`
    positions (inclusive of self).
    """
    B, Sq, Hq, D = q.shape
    K = k.shape[2]
    G = Hq // K
    qg = q.reshape(B, Sq, K, G, D)
    scale = D ** -0.5
    C = _pick_chunk(Sq, q_chunk)
    outs = []
    for qc in range(0, Sq, C):
        # static K/V slice reachable from rows [qc, qc+C)
        hi = min(qc + C, k.shape[1]) if causal else k.shape[1]
        lo = max(0, qc - window + 1) if window else 0
        # NOTE (EXPERIMENTS.md §Perf P1): constraining these slices was
        # tried to remove a small GSPMD pod-axis partial-reduction in the
        # chunk backward — it backfired (forces k/v resharding per chunk,
        # ~2x more cross-pod bytes). Refuted; left unconstrained.
        ks, vs = k[:, lo:hi], v[:, lo:hi]
        qs = qg[:, qc:qc + C]
        scores = jnp.einsum("bckgd,blkd->bkgcl", qs, ks,
                            preferred_element_type=jnp.float32) * scale
        row = qc + jnp.arange(C)[:, None]
        col = lo + jnp.arange(hi - lo)[None, :]
        mask = jnp.ones((C, hi - lo), bool)
        if causal:
            mask &= col <= row
        if window:
            mask &= col > row - window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        outs.append(jnp.einsum("bkgcl,blkd->bckgd", probs, vs))
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out.reshape(B, Sq, Hq, D)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0):
    """One-token decode. q (B,1,Hq,D); caches:
      full:  (B,S_max,K,D), valid slots are indices <= pos
      ring:  (B,W,K,D) with W == window; slot i holds absolute position
             pos - ((pos - i) mod W)
    pos: scalar int32 — absolute position of the current token (0-based).
    """
    B, _, Hq, D = q.shape
    K = k_cache.shape[2]
    G = Hq // K
    qg = q.reshape(B, 1, K, G, D)
    scale = D ** -0.5
    scores = jnp.einsum("bckgd,blkd->bkgcl", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    S = k_cache.shape[1]
    slots = jnp.arange(S)
    if window:
        abs_pos = pos - jnp.mod(pos - slots, S)
        valid = abs_pos >= 0
    else:
        valid = slots <= pos
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgcl,blkd->bckgd", probs, v_cache)
    return out.reshape(B, 1, Hq, D)


def init_attn(key, cfg, dtype):
    from repro.models.common import dense_init
    D, Hq, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, Hq * hd), dtype),
        "wk": dense_init(ks[1], (D, K * hd), dtype),
        "wv": dense_init(ks[2], (D, K * hd), dtype),
        "wo": dense_init(ks[3], (Hq * hd, D), dtype,
                         scale=1.0 / (2 * cfg.n_layers) ** 0.5),
        "norm": jnp.zeros((D,), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def pallas_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """Route (B,S,H,D)-layout attention through the Pallas flash kernel
    (repro.kernels). Per-device execution: use on single-device paths or
    inside shard_map; the GSPMD dry-run path uses the jnp implementation
    (identical math, freely partitionable)."""
    from repro.kernels.ops import flash_attention as _fa
    out = _fa(q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
              causal=causal, window=window)
    return out.swapaxes(1, 2)


def attn_apply(p, x, positions, cfg, *, window: int = 0,
               cache: Optional[dict] = None, pos=None, q_chunk: int = 1024,
               impl: str = "jnp"):
    """Pre-norm attention sub-block. Returns (residual_delta, new_cache).

    Training/prefill: cache is None or an empty cache dict to fill.
    Decode: x is (B,1,D), cache holds K/V, pos is the absolute position.
    """
    B, S, D = x.shape
    Hq, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(B, S, Hq, hd)
    k = (h @ p["wk"]).reshape(B, S, K, hd)
    v = (h @ p["wv"]).reshape(B, S, K, hd)
    q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, None, None)
    v = constrain(v, "batch", None, None, None)
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_type == "standard":
        q, k = apply_rope(q, k, positions, cfg.rope_theta)
    elif cfg.rope_type == "mrope":
        q, k = apply_mrope(q, k, positions, cfg.rope_theta)

    decode = cache is not None and pos is not None and S == 1
    if decode:
        S_c = cache["k"].shape[1]
        slot = jnp.mod(pos, S_c) if window else pos
        iota = jnp.arange(S_c)[None, :, None, None]
        k_cache = jnp.where(iota == slot, k, cache["k"])
        v_cache = jnp.where(iota == slot, v, cache["v"])
        out = decode_attention(q, k_cache, v_cache, pos, window=window)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        if impl == "pallas":
            out = pallas_attention(q, k, v, causal=True, window=window)
        else:
            out = multihead_attention(q, k, v, causal=True, window=window,
                                      q_chunk=q_chunk)
        new_cache = None
        if cache is not None:  # prefill: populate cache
            S_c = cache["k"].shape[1]
            if window and S_c < S:
                # keep the last S_c positions; ring layout slot = pos % S_c
                tail_k, tail_v = k[:, -S_c:], v[:, -S_c:]
                shift = S % S_c
                new_cache = {"k": jnp.roll(tail_k, shift, axis=1),
                             "v": jnp.roll(tail_v, shift, axis=1)}
            else:
                pad = [(0, 0), (0, S_c - S), (0, 0), (0, 0)]
                new_cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
    out = constrain(out, "batch", None, "model", None)
    delta = out.reshape(B, S, Hq * hd) @ p["wo"]
    return delta, new_cache
