"""Shared building blocks: norms, initializers, activations, losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def head_rms_norm(x, scale, eps=1e-6):
    """qk-norm: RMS over the head_dim of (B, S, H, D) tensors."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def dense_init(key, shape, dtype, scale=None, axis=0):
    fan_in = shape[axis]
    if scale is None:
        scale = 1.0
    std = scale / jnp.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * 0.02).astype(dtype)


def silu_mlp(x, w1, w3, w2):
    """SwiGLU FFN. x (..., D); w1,w3 (D,F); w2 (F,D)."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def sinusoidal_positions(positions, dim, max_wavelength=10000.0):
    """positions (...,) int -> (..., dim) float32 sinusoidal embedding."""
    half = dim // 2
    freq = jnp.exp(-jnp.log(max_wavelength) * jnp.arange(half) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def cross_entropy_loss(logits, labels, mask=None, *, vocab_chunk: int = 0):
    """Mean token CE in fp32. labels == -1 are ignored.

    vocab_chunk > 0 enables the chunked-vocab path (never materializes the
    fp32 (tokens, V) log-softmax at once) — a beyond-paper memory optimization
    for 150k-256k vocabularies; see EXPERIMENTS.md §Perf.
    """
    valid = (labels >= 0)
    if mask is not None:
        valid = valid & mask.astype(bool)
    labels_c = jnp.clip(labels, 0)
    if vocab_chunk and logits.shape[-1] % vocab_chunk == 0:
        nll = _chunked_nll(logits, labels_c, vocab_chunk)
    else:
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # one-hot contraction, NOT take_along_axis: a gather over the
        # model-sharded vocab dim would all-gather the full logits; the
        # masked reduce partitions cleanly (partial sums + psum).
        V = logits.shape[-1]
        oh = (labels_c[..., None] == jnp.arange(V, dtype=labels_c.dtype))
        tgt = jnp.sum(logits * oh, axis=-1)
        nll = lse - tgt
    nll = jnp.where(valid, nll, 0.0)
    denom = jnp.maximum(valid.sum(), 1)
    return nll.sum() / denom


def _chunked_nll(logits, labels, chunk):
    """Two-pass (max, then sum-exp) vocab-chunked NLL; fp32 accumulators only
    of shape (tokens,)."""
    V = logits.shape[-1]
    n = V // chunk

    def scan_max(carry, i):
        sl = jax.lax.dynamic_slice_in_dim(logits, i * chunk, chunk, axis=-1)
        return jnp.maximum(carry, sl.astype(jnp.float32).max(-1)), None

    m, _ = jax.lax.scan(scan_max,
                        jnp.full(logits.shape[:-1], -jnp.inf, jnp.float32),
                        jnp.arange(n))

    def scan_sum(carry, i):
        s, tgt = carry
        sl = jax.lax.dynamic_slice_in_dim(logits, i * chunk, chunk, axis=-1)
        sl = sl.astype(jnp.float32)
        s = s + jnp.exp(sl - m[..., None]).sum(-1)
        idx = labels - i * chunk
        hit = (idx >= 0) & (idx < chunk)
        t = jnp.take_along_axis(sl, jnp.clip(idx, 0, chunk - 1)[..., None],
                                axis=-1)[..., 0]
        tgt = jnp.where(hit, t, tgt)
        return (s, tgt), None

    (s, tgt), _ = jax.lax.scan(
        scan_sum, (jnp.zeros_like(m), jnp.zeros_like(m)), jnp.arange(n))
    return jnp.log(s) + m - tgt
