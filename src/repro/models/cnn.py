"""ResNet family (the paper's own ImageNet benchmark model) in pure JAX.

Batch norm computes batch statistics with plain jnp.mean over the (sharded)
batch dim — under GSPMD that mean is reduced over the "data" axis, i.e. it IS
the paper's node-local synchronized batch norm; under the DASO vmap-over-pod
replica axis the stats stay per-pod, matching the paper's setup (§4.2).
Running statistics are carried in a separate `state` pytree.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.resnet50 import ResNetConfig


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return jax.random.truncated_normal(key, -2, 2, (kh, kw, cin, cout)) * (
        (2.0 / fan_in) ** 0.5)


def _bn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _bn_state(c):
    return {"mean": jnp.zeros((c,)), "var": jnp.ones((c,))}


def batch_norm(x, p, s, *, train: bool, momentum=0.9, eps=1e-5):
    if train:
        mean = x.mean(axis=(0, 1, 2))
        var = x.var(axis=(0, 1, 2))
        new_s = {"mean": momentum * s["mean"] + (1 - momentum) * mean,
                 "var": momentum * s["var"] + (1 - momentum) * var}
    else:
        mean, var = s["mean"], s["var"]
        new_s = s
    y = (x - mean) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y, new_s


def init_resnet(cfg: ResNetConfig, key):
    keys = iter(jax.random.split(key, 256))
    width = cfg.width
    params = {"stem": {"conv": _conv_init(next(keys), 7, 7, 3, width),
                       "bn": _bn_init(width)}}
    state = {"stem": {"bn": _bn_state(width)}}
    exp = 4 if cfg.bottleneck else 1
    cin = width
    for i, n_blocks in enumerate(cfg.stage_sizes):
        cmid = width * (2 ** i)
        cout = cmid * exp
        stage_p, stage_s = [], []
        for b in range(n_blocks):
            stride = 2 if (b == 0 and i > 0) else 1
            blk_p, blk_s = {}, {}
            if cfg.bottleneck:
                blk_p["conv1"] = _conv_init(next(keys), 1, 1, cin, cmid)
                blk_p["conv2"] = _conv_init(next(keys), 3, 3, cmid, cmid)
                blk_p["conv3"] = _conv_init(next(keys), 1, 1, cmid, cout)
                for j, c in (("bn1", cmid), ("bn2", cmid), ("bn3", cout)):
                    blk_p[j] = _bn_init(c)
                    blk_s[j] = _bn_state(c)
            else:
                blk_p["conv1"] = _conv_init(next(keys), 3, 3, cin, cmid)
                blk_p["conv2"] = _conv_init(next(keys), 3, 3, cmid, cout)
                for j, c in (("bn1", cmid), ("bn2", cout)):
                    blk_p[j] = _bn_init(c)
                    blk_s[j] = _bn_state(c)
            if stride != 1 or cin != cout:
                blk_p["proj"] = _conv_init(next(keys), 1, 1, cin, cout)
                blk_p["proj_bn"] = _bn_init(cout)
                blk_s["proj_bn"] = _bn_state(cout)
            stage_p.append(blk_p)
            stage_s.append(blk_s)
            cin = cout
        params[f"stage{i}"] = stage_p
        state[f"stage{i}"] = stage_s
    params["head"] = {"w": jnp.zeros((cin, cfg.n_classes)),
                      "b": jnp.zeros((cfg.n_classes,))}
    return params, state


def _block_apply(p, s, x, *, stride: int, bottleneck: bool, train: bool):
    new_s = {}
    r = x
    if bottleneck:
        h = _conv(x, p["conv1"])
        h, new_s["bn1"] = batch_norm(h, p["bn1"], s["bn1"], train=train)
        h = jax.nn.relu(h)
        h = _conv(h, p["conv2"], stride)
        h, new_s["bn2"] = batch_norm(h, p["bn2"], s["bn2"], train=train)
        h = jax.nn.relu(h)
        h = _conv(h, p["conv3"])
        h, new_s["bn3"] = batch_norm(h, p["bn3"], s["bn3"], train=train)
    else:
        h = _conv(x, p["conv1"], stride)
        h, new_s["bn1"] = batch_norm(h, p["bn1"], s["bn1"], train=train)
        h = jax.nn.relu(h)
        h = _conv(h, p["conv2"])
        h, new_s["bn2"] = batch_norm(h, p["bn2"], s["bn2"], train=train)
    if "proj" in p:
        r = _conv(x, p["proj"], stride)
        r, new_s["proj_bn"] = batch_norm(r, p["proj_bn"], s["proj_bn"],
                                         train=train)
    return jax.nn.relu(h + r), new_s


def resnet_apply(params, state, images, cfg: ResNetConfig, *, train: bool):
    """images (B,H,W,3) -> (logits (B,n_classes), new_state)."""
    new_state = {"stem": {}}
    h = _conv(images, params["stem"]["conv"], stride=2)
    h, new_state["stem"]["bn"] = batch_norm(
        h, params["stem"]["bn"], state["stem"]["bn"], train=train)
    h = jax.nn.relu(h)
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    for i in range(len(cfg.stage_sizes)):
        stage_s = []
        for b, (p, s) in enumerate(zip(params[f"stage{i}"],
                                       state[f"stage{i}"])):
            stride = 2 if (b == 0 and i > 0) else 1
            h, ns = _block_apply(p, s, h, stride=stride,
                                 bottleneck=cfg.bottleneck, train=train)
            stage_s.append(ns)
        new_state[f"stage{i}"] = stage_s
    h = h.mean(axis=(1, 2))
    logits = h @ params["head"]["w"] + params["head"]["b"]
    return logits, new_state
