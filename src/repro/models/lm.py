"""Unified decoder LM covering all assigned transformer-family architectures.

Layers follow cfg.layer_pattern (e.g. recurrentgemma's (rglru, rglru,
attn_local)). The repeated pattern groups are stacked and iterated with
jax.lax.scan to keep HLO size / compile time bounded for 64-layer configs;
remainder layers (n_layers % len(pattern)) are applied unrolled.

Entry points:
  init_params(cfg, key)                      -> params pytree
  forward(params, tokens, cfg, ...)          -> {"logits", "aux", "cache"}
  init_cache(cfg, batch, cache_len, ...)     -> decode cache pytree
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.blocks import (ZERO_AUX, apply_block, block_window,
                                 init_block, init_block_cache)
from repro.models.common import dense_init, embed_init, rms_norm, \
    sinusoidal_positions
from repro.sharding import constrain


def _pattern_counts(cfg: ArchConfig):
    plen = len(cfg.layer_pattern)
    return cfg.n_layers // plen, cfg.n_layers % plen


def init_params(cfg: ArchConfig, key):
    dtype = cfg.pdtype()
    n_full, n_rem = _pattern_counts(cfg)
    k_embed, k_blocks, k_rem, k_out = jax.random.split(key, 4)
    params = {"embed": {"tok": embed_init(k_embed,
                                          (cfg.vocab_size, cfg.d_model),
                                          dtype)}}
    blocks = []
    bkeys = jax.random.split(k_blocks, max(n_full, 1) * len(cfg.layer_pattern))
    for j, kind in enumerate(cfg.layer_pattern):
        per_repeat = [init_block(bkeys[r * len(cfg.layer_pattern) + j],
                                 cfg, kind, dtype) for r in range(n_full)]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_repeat))
    params["blocks"] = blocks
    rkeys = jax.random.split(k_rem, max(n_rem, 1))
    params["rem"] = [init_block(rkeys[j], cfg, cfg.layer_pattern[j], dtype)
                     for j in range(n_rem)]
    params["final_norm"] = {"scale": jnp.zeros((cfg.d_model,), dtype)}
    if not cfg.tie_embeddings:
        params["unembed"] = {"w": dense_init(k_out,
                                             (cfg.d_model, cfg.vocab_size),
                                             dtype)}
    return params


def init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype=None,
               window_override: int = 0):
    """Decode cache. cache_len: max positions for full-attention layers;
    windowed layers allocate min(window, cache_len)."""
    dtype = dtype or cfg.cdtype()
    n_full, n_rem = _pattern_counts(cfg)

    def one(kind):
        win = block_window(cfg, kind, window_override)
        clen = min(win, cache_len) if win else cache_len
        return init_block_cache(cfg, kind, batch, clen, dtype)

    groups = []
    for kind in cfg.layer_pattern:
        per = [one(kind) for _ in range(n_full)]
        groups.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
    rem = [one(cfg.layer_pattern[j]) for j in range(n_rem)]
    return {"groups": groups, "rem": rem}


def _acc_aux(acc, aux):
    return {k: acc[k] + aux[k] for k in acc}


def forward(params, tokens, cfg: ArchConfig, *,
            prefix_embeds: Optional[jnp.ndarray] = None,
            positions: Optional[jnp.ndarray] = None,
            cache: Optional[dict] = None, pos=None,
            window_override: int = 0, q_chunk: int = 1024,
            mamba_chunk: int = 64, remat: bool = False,
            logits_f32: bool = False, unroll_layers: bool = False,
            attn_impl: str = "jnp"):
    """tokens (B, S_tok) int32. Returns {"logits" (B,S,V), "aux", "cache"}.

    prefix_embeds (B, P, D): frontend stub embeddings (vlm/audio) spliced
    before the token embeddings; logits/labels cover the full spliced length.
    decode: tokens (B,1), cache + pos given.
    """
    x = jnp.take(params["embed"]["tok"], tokens, axis=0)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, D = x.shape
    if positions is None:
        base = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        positions = (jnp.tile(base[..., None], (1, 1, 3))
                     if cfg.rope_type == "mrope" else base)
    if cfg.rope_type == "none":
        pos1 = positions if positions.ndim == 2 else positions[..., 0]
        x = x + sinusoidal_positions(pos1, D).astype(x.dtype)
    x = constrain(x, "batch", None, None)

    n_full, n_rem = _pattern_counts(cfg)
    decode_mode = cache is not None and x.shape[1] == 1
    prefill_mode = cache is not None and not decode_mode

    block_fn = functools.partial(
        apply_block, positions=positions, cfg=cfg, pos=pos,
        window_override=window_override, q_chunk=q_chunk,
        mamba_chunk=mamba_chunk, unroll_inner=unroll_layers,
        attn_impl=attn_impl)

    def group_body(carry, xs):
        x, aux = carry
        p_slices, c_slices = xs
        new_caches = []
        for j, kind in enumerate(cfg.layer_pattern):
            c_j = None if c_slices is None else c_slices[j]
            x, nc, a = block_fn(kind, p_slices[j], x, cache=c_j)
            aux = _acc_aux(aux, a)
            new_caches.append(nc)
        return (x, aux), (tuple(new_caches) if cache is not None else None)

    body = jax.checkpoint(group_body) if remat else group_body
    aux0 = {k: jnp.zeros((), jnp.float32) for k in ZERO_AUX}
    cache_groups = tuple(cache["groups"]) if cache is not None else None
    if n_full > 0 and unroll_layers:
        # python-loop over repeats: larger HLO, but XLA cost_analysis counts
        # every repeat (scan bodies are counted once) — used by the roofline
        # per-layer cost extraction, never by the production path.
        carry, ys = (x, aux0), []
        xs = (tuple(params["blocks"]), cache_groups)
        for r in range(n_full):
            xs_r = jax.tree.map(lambda a: a[r], xs)
            carry, y = body(carry, xs_r)
            ys.append(y)
        (x, aux) = carry
        new_groups = (jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
                      if cache is not None else None)
    elif n_full > 0:
        (x, aux), new_groups = jax.lax.scan(
            body, (x, aux0), (tuple(params["blocks"]), cache_groups))
    else:
        aux, new_groups = aux0, None

    new_rem = []
    for j in range(n_rem):
        kind = cfg.layer_pattern[j]
        c_j = None if cache is None else cache["rem"][j]
        x, nc, a = block_fn(kind, params["rem"][j], x, cache=c_j)
        aux = _acc_aux(aux, a)
        new_rem.append(nc)

    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["tok"].T
    else:
        logits = x @ params["unembed"]["w"]
    if logits_f32:
        logits = logits.astype(jnp.float32)
    logits = constrain(logits, "batch", None, "model")
    new_cache = None
    if cache is not None:
        new_cache = {"groups": list(new_groups), "rem": new_rem}
    return {"logits": logits, "aux": aux, "cache": new_cache}


class DecoderLM:
    """Thin OO convenience wrapper used by examples."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    def init(self, key):
        return init_params(self.cfg, key)

    def __call__(self, params, tokens, **kw):
        return forward(params, tokens, self.cfg, **kw)

    def init_cache(self, batch, cache_len, **kw):
        return init_cache(self.cfg, batch, cache_len, **kw)
