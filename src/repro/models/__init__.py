from repro.models.lm import (  # noqa: F401
    DecoderLM,
    init_params,
)
