"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence: r_t = sigmoid(W_a x_t + b_a), i_t = sigmoid(W_i x_t + b_i),
log a_t = -c * softplus(Lambda) * r_t,  h_t = a_t h_{t-1} + sqrt(1-a_t^2) * (i_t * x_t).
Uses the same chunked linear-recurrence machinery as the mamba mixer.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.models.mamba import causal_conv1d, linear_recurrence
from repro.sharding import constrain


def init_rglru(key, cfg, dtype):
    g = cfg.rglru
    D, W = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 7)
    # Lambda init so a = exp(-c*softplus(L)) is in ~[0.9, 0.999]
    u = jax.random.uniform(ks[5], (W,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / g.c_exponent))
    return {
        "norm": jnp.zeros((D,), dtype),
        "wx": dense_init(ks[0], (D, W), dtype),
        "wy": dense_init(ks[1], (D, W), dtype),
        "conv1d_w": dense_init(ks[2], (W, g.conv_width), dtype, scale=1.0, axis=1),
        "conv1d_b": jnp.zeros((W,), dtype),
        "w_a": dense_init(ks[3], (W, W), jnp.float32),
        "b_a": jnp.zeros((W,), jnp.float32),
        "w_i": dense_init(ks[4], (W, W), jnp.float32),
        "b_i": jnp.zeros((W,), jnp.float32),
        "a_param": lam,
        "wo_rec": dense_init(ks[6], (W, D), dtype,
                             scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }


def rglru_apply(p, x, cfg, *, cache: Optional[dict] = None, chunk: int = 512,
                unroll: bool = False):
    """Pre-normed recurrent mixer body. x (B,S,D) -> (delta, new_cache)."""
    g = cfg.rglru
    B, S, D = x.shape
    y_branch = jax.nn.gelu(x @ p["wy"])                       # (B,S,W)
    xb = x @ p["wx"]
    conv_carry = cache["conv"] if cache is not None else None
    xb, new_conv = causal_conv1d(xb, p["conv1d_w"], p["conv1d_b"], conv_carry)

    # §Perf P4: gate matmuls run in the compute dtype (bf16 MXU; halves the
    # per-layer cross-shard bytes vs fp32); the sigmoid/recurrence math that
    # needs range stays fp32. Outputs constrained model-sharded so the psum
    # fuses to a reduce-scatter on TPU.
    wd = x.dtype
    r = jax.nn.sigmoid(constrain(
        xb @ p["w_a"].astype(wd) + p["b_a"].astype(wd),
        "batch", None, "model").astype(jnp.float32))
    i = jax.nn.sigmoid(constrain(
        xb @ p["w_i"].astype(wd) + p["b_i"].astype(wd),
        "batch", None, "model").astype(jnp.float32))
    xf = xb.astype(jnp.float32)
    log_a = -g.c_exponent * jax.nn.softplus(p["a_param"]) * r  # (B,S,W)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)

    h0 = (cache["h"] if cache is not None
          else jnp.zeros((B, xb.shape[-1]), jnp.float32))
    if S == 1:
        h = a[:, 0] * h0 + gated[:, 0]
        hs = h[:, None]
    else:
        hs, h = linear_recurrence(a, gated, h0, chunk=chunk, unroll=unroll)
    out = (hs.astype(x.dtype) * y_branch) @ p["wo_rec"]
    new_cache = {"conv": new_conv, "h": h} if cache is not None else None
    return out, new_cache


def init_rglru_cache(cfg, batch, dtype):
    g = cfg.rglru
    return {"conv": jnp.zeros((batch, g.conv_width - 1, cfg.lru_width), dtype),
            "h": jnp.zeros((batch, cfg.lru_width), jnp.float32)}
