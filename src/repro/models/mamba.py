"""Mamba-1 selective SSM mixer (Falcon-Mamba style).

Sequence mixing uses a chunked linear recurrence: a python loop over
sequence chunks (static trip count -> correct FLOP accounting; bounded
(B, chunk, d_inner, N) temporaries) with `jax.lax.associative_scan` inside
each chunk. The recurrence h_t = da_t * h_{t-1} + db_t is combined with
(aL,bL)x(aR,bR) = (aR*aL, aR*bL + bR) — stable since da in (0,1).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def init_mamba(key, cfg, dtype):
    s = cfg.ssm
    D, Di, N, R = cfg.d_model, cfg.d_inner, s.d_state, cfg.dt_rank
    ks = jax.random.split(key, 6)
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba paper)
    u = jax.random.uniform(ks[4], (Di,), jnp.float32)
    dt_init = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (Di, 1))
    return {
        "norm": jnp.zeros((D,), dtype),
        "in_proj": dense_init(ks[0], (D, 2 * Di), dtype),
        "conv_w": dense_init(ks[1], (Di, s.d_conv), dtype, scale=1.0, axis=1),
        "conv_b": jnp.zeros((Di,), dtype),
        "x_proj": dense_init(ks[2], (Di, R + 2 * N), dtype),
        "dt_proj": dense_init(ks[3], (R, Di), jnp.float32),
        "dt_bias": dt_bias,
        "A_log": jnp.log(A),
        "Dskip": jnp.ones((Di,), jnp.float32),
        "out_proj": dense_init(ks[5], (Di, D), dtype,
                               scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }


def _assoc_combine(l, r):
    al, bl = l
    ar, br = r
    return ar * al, ar * bl + br


def _pick_chunk(S: int, chunk: int) -> int:
    c = min(chunk, S)
    while S % c:
        c //= 2
    return max(c, 1)


def _to_chunks(x, nc, c):
    """(B, S, ...) -> (nc, B, c, ...) for lax.scan consumption."""
    B = x.shape[0]
    return x.reshape((B, nc, c) + x.shape[2:]).swapaxes(0, 1)


def selective_scan(xh, dt, A, Bm, Cm, h0, *, chunk: int = 64,
                   unroll: bool = False):
    """Fused selective scan: y_t = C_t . h_t with
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t. Never materializes the full
    (B,S,Di,N) state history — the (da, db) chunk tensors live only inside the
    (checkpointed) chunk body, and only (B,S,Di) outputs are stacked.

    xh (B,S,Di) compute dtype; dt (B,S,Di) f32; A (Di,N) f32;
    Bm, Cm (B,S,N); h0 (B,Di,N) f32. Returns (y (B,S,Di) f32->xh dtype, h)."""
    B, S, Di = xh.shape
    c = _pick_chunk(S, chunk)
    nc = S // c

    def chunk_body(h, xs):
        dt_c, x_c, B_c, C_c = xs  # (B,c,Di), (B,c,Di), (B,c,N), (B,c,N)
        da = jnp.exp(dt_c[..., None] * A)                     # (B,c,Di,N)
        db = ((dt_c * x_c.astype(jnp.float32))[..., None]
              * B_c.astype(jnp.float32)[:, :, None, :])
        acc_a, acc_b = jax.lax.associative_scan(_assoc_combine, (da, db),
                                                axis=1)
        hc = acc_a * h[:, None] + acc_b                       # (B,c,Di,N)
        y = jnp.einsum("bcdn,bcn->bcd", hc, C_c.astype(jnp.float32))
        return hc[:, -1], y.astype(xh.dtype)

    body = jax.checkpoint(chunk_body)
    xs = (_to_chunks(dt, nc, c), _to_chunks(xh, nc, c),
          _to_chunks(Bm, nc, c), _to_chunks(Cm, nc, c))
    if unroll:
        ys, h = [], h0
        for i in range(nc):
            h, y = body(h, jax.tree.map(lambda t: t[i], xs))
            ys.append(y)
        ys = jnp.stack(ys)
    else:
        h, ys = jax.lax.scan(body, h0, xs)
    y = ys.swapaxes(0, 1).reshape(B, S, Di)
    return y, h


def linear_recurrence(da, db, h0, *, chunk: int = 512, unroll: bool = False):
    """Diagonal recurrence h_t = da_t*h_{t-1} + db_t along axis 1 for (B,S,W)
    tensors (RG-LRU). Returns (hs (B,S,W) in db dtype, h_final f32)."""
    B, S = da.shape[:2]
    c = _pick_chunk(S, chunk)
    nc = S // c

    def chunk_body(h, xs):
        a_c, b_c = xs
        acc_a, acc_b = jax.lax.associative_scan(_assoc_combine, (a_c, b_c),
                                                axis=1)
        hc = acc_a * h[:, None] + acc_b
        return hc[:, -1], hc

    body = jax.checkpoint(chunk_body)
    xs = (_to_chunks(da, nc, c), _to_chunks(db, nc, c))
    if unroll:
        ys, h = [], h0
        for i in range(nc):
            h, y = body(h, jax.tree.map(lambda t: t[i], xs))
            ys.append(y)
        ys = jnp.stack(ys)
    else:
        h, ys = jax.lax.scan(body, h0, xs)
    hs = ys.swapaxes(0, 1).reshape(da.shape)
    return hs, h


def causal_conv1d(x, w, b, carry: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over seq. x (B,S,Di); w (Di,Kc); carry (B,Kc-1,Di)
    holds the previous Kc-1 inputs (decode). Returns (y, new_carry)."""
    B, S, Di = x.shape
    Kc = w.shape[1]
    if carry is None:
        carry = jnp.zeros((B, Kc - 1, Di), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)          # (B, S+Kc-1, Di)
    y = sum(xp[:, i:i + S] * w[:, i] for i in range(Kc)) + b
    new_carry = xp[:, -(Kc - 1):] if Kc > 1 else carry
    return y, new_carry


def mamba_apply(p, x, cfg, *, cache: Optional[dict] = None, chunk: int = 64,
                unroll: bool = False):
    """Pre-normed mamba mixer body (norm applied by caller). x (B,S,D).
    Returns (delta (B,S,D), new_cache)."""
    s = cfg.ssm
    B, S, D = x.shape
    Di, N, R = cfg.d_inner, s.d_state, cfg.dt_rank
    xz = x @ p["in_proj"]
    xh, z = jnp.split(xz, 2, axis=-1)                 # (B,S,Di) each
    conv_carry = cache["conv"] if cache is not None else None
    xh, new_conv = causal_conv1d(xh, p["conv_w"], p["conv_b"], conv_carry)
    xh = jax.nn.silu(xh)

    proj = xh @ p["x_proj"]                           # (B,S,R+2N)
    dt, Bm, Cm = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) @ p["dt_proj"]
                         + p["dt_bias"])              # (B,S,Di) fp32
    A = -jnp.exp(p["A_log"])                          # (Di,N)

    h0 = (cache["h"] if cache is not None
          else jnp.zeros((B, Di, N), jnp.float32))
    if S == 1:  # decode fast-path
        da = jnp.exp(dt[:, 0, :, None] * A)
        db = ((dt[:, 0] * xh[:, 0].astype(jnp.float32))[..., None]
              * Bm[:, 0].astype(jnp.float32)[:, None, :])
        h = da * h0 + db
        y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0].astype(jnp.float32))[:, None]
    else:
        y, h = selective_scan(xh, dt, A, Bm, Cm, h0, chunk=chunk,
                              unroll=unroll)
    y = y.astype(jnp.float32) + p["Dskip"] * xh.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    delta = y @ p["out_proj"]
    new_cache = {"conv": new_conv, "h": h} if cache is not None else None
    return delta, new_cache


def init_mamba_cache(cfg, batch, dtype):
    s = cfg.ssm
    return {"conv": jnp.zeros((batch, s.d_conv - 1, cfg.d_inner), dtype),
            "h": jnp.zeros((batch, cfg.d_inner, s.d_state), jnp.float32)}
