"""Rotary position embeddings: standard 1-D RoPE and Qwen2-VL style M-RoPE."""
from __future__ import annotations

import jax.numpy as jnp


def _rot(x, sin, cos):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def rope_freqs(head_dim, theta):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(q, k, positions, theta):
    """q (B,S,Hq,D), k (B,S,Hk,D), positions (B,S) int32."""
    freqs = rope_freqs(q.shape[-1], theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    return (_rot(q.astype(jnp.float32), sin, cos).astype(q.dtype),
            _rot(k.astype(jnp.float32), sin, cos).astype(k.dtype))


def mrope_sections(head_dim):
    """Split of rotary pairs into (temporal, height, width) sections."""
    half = head_dim // 2
    h = half // 4
    return (half - 2 * h, h, h)


def apply_mrope(q, k, positions, theta):
    """M-RoPE: positions (B,S,3) int32 — (t, h, w) per token. Rotary pairs are
    split into three sections, each rotated by its own position stream
    [arXiv:2409.12191]."""
    half = q.shape[-1] // 2
    freqs = rope_freqs(q.shape[-1], theta)  # (half,)
    secs = mrope_sections(q.shape[-1])
    # build per-pair position: section s uses positions[..., s]
    sec_id = jnp.concatenate([
        jnp.full((n,), i, jnp.int32) for i, n in enumerate(secs)])  # (half,)
    pos = jnp.take_along_axis(
        positions[:, :, :],  # (B,S,3)
        sec_id[None, None, :].astype(jnp.int32), axis=-1)  # (B,S,half)
    ang = pos.astype(jnp.float32) * freqs
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    return (_rot(q.astype(jnp.float32), sin, cos).astype(q.dtype),
            _rot(k.astype(jnp.float32), sin, cos).astype(k.dtype))
