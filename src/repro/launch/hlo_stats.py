"""Parse collective ops (kind, bytes, mesh axis) out of compiled HLO text.

Used by the dry-run records and the roofline analysis: cost_analysis() has no
collective accounting, so we regex the optimized HLO for
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute,
sum their result-buffer bytes, and classify each op onto a mesh axis via its
replica_groups (explicit {{0,1,..}} or iota [G,S]<=[dims]T(perm) form).

Caveat (documented in EXPERIMENTS.md): ops inside while-loop bodies appear
once; per-layer costs are therefore extracted from unrolled 1-group /
2-group lowerings and scaled analytically.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_group(rg: str) -> Optional[List[int]]:
    """First replica group from either representation."""
    m = re.match(r"\{\{([0-9,]+)\}", rg)
    if m:
        return [int(x) for x in m.group(1).split(",")]
    # iota form: [G,S]<=[d0,d1,...]T(p0,p1,...) or [G,S]<=[N]
    m = re.match(r"\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?", rg)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        n = int(np.prod(dims))
        order = np.arange(n).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            order = order.transpose(perm)
        return list(order.reshape(g, s)[0])
    return None


def classify_axis(group: Optional[List[int]], mesh_shape: Dict[str, int]
                  ) -> str:
    """Map a replica group to the mesh axis it spans. Device ids are row-major
    over the mesh axes in order."""
    if not group or len(group) < 2:
        return "none"
    axes = list(mesh_shape.items())
    strides = {}
    s = 1
    for name, size in reversed(axes):
        strides[name] = s
        s *= size
    stride = group[1] - group[0]
    for name, size in axes:
        if stride == strides[name] and len(group) == size:
            # verify arithmetic progression
            if all(group[i + 1] - group[i] == stride
                   for i in range(len(group) - 1)):
                return name
    # combined axes (e.g. ("pod","data") batch sharding): match product sizes
    for i in range(len(axes)):
        for j in range(i + 1, len(axes) + 1):
            names = [a for a, _ in axes[i:j]]
            size = int(np.prod([mesh_shape[a] for a in names]))
            if len(group) == size:
                return "+".join(names)
    return "mixed"


def collective_stats(hlo_text: str, mesh_shape: Dict[str, int],
                     min_bytes: int = 0):
    """Returns {(kind, axis): {"bytes": int, "count": int}} plus totals.

    `min_bytes` drops individual ops below that result size *before*
    aggregating — the per-level one-collective contract tests use it to
    count parameter-scale exchanges exactly, without scalar metric
    reductions (loss means) polluting the per-axis counts."""
    stats = defaultdict(lambda: {"bytes": 0, "count": 0})
    # one HLO instruction per line in optimized dumps
    for line in hlo_text.splitlines():
        m = re.search(
            r"=\s*((?:\([^)]*\))|(?:[\w\[\],{}\/ ]+?))\s*"
            r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start|-done)?\(", line)
        if not m:
            continue
        if "-done(" in line:
            continue  # bytes counted at the -start op
        shape_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_str)
        if nbytes < min_bytes:
            continue
        rg = re.search(r"replica_groups=(\{\{[0-9,{} ]+\}\}|\[[^\]]+\]"
                       r"<=\[[0-9,]+\](?:T\([0-9,]+\))?)", line)
        axis = "unknown"
        if rg:
            axis = classify_axis(_first_group(rg.group(1)), mesh_shape)
        elif "collective-permute" in kind:
            sp = re.search(r"source_target_pairs=\{\{(\d+),(\d+)\}", line)
            if sp:
                axis = classify_axis([int(sp.group(1)), int(sp.group(2))],
                                     mesh_shape)
        key = (kind, axis)
        stats[key]["bytes"] += nbytes
        stats[key]["count"] += 1
    out = {f"{k}@{a}": v for (k, a), v in stats.items()}
    out["_total_bytes"] = sum(v["bytes"] for v in stats.values())
    out["_total_count"] = sum(v["count"] for v in stats.values())
    return out
