"""Multi-process distributed runtime (`jax.distributed`).

Everything below this module runs the paper's algorithm as one SPMD program;
what this module adds is the *real* deployment shape: N coordinator-connected
processes (one per host in production; `tools/launch_procs.py` spawns local
CPU-pinned ones for development and CI), each hosting a contiguous block of
the topology's devices, jointly executing that same program over the global
mesh. Three pieces:

  * `DistributedConfig` / `initialize` — `jax.distributed.initialize`
    bootstrap from flags or the ``DASO_COORDINATOR`` / ``DASO_NUM_PROCS`` /
    ``DASO_PROC_ID`` environment (what `tools/launch_procs.py` exports).
    Must run before any JAX device use; `launch/train.py` calls it first.
  * `MeshPlacement` — the placement layer the train loop, both executors,
    and the resilience supervisor thread their arrays through: the
    `TopologySpec` lowered to the global mesh (one axis per level, so
    levels map onto (process, local-device) axes — each process owns
    exactly the subtree `launch.mesh.process_node_paths` reports), carry
    and batch shardings over the replica-level axes, and host gather for
    metrics/checkpoints (only process 0 writes).
  * the SPMD-equivalence contract — because every process runs the same
    deterministic host loop (synthetic data, controller, fault plans are
    all seeded) and the global mesh is identical for any process count, an
    N-process run is bit-exact with the 1-process run of the same spec,
    seed, and fault plan (tests/test_multiprocess.py asserts it on both
    executors, with real subprocesses).

The contract's load-bearing assumption — worth stating because it is the
thing a new backend could break — is that the per-device programs GSPMD
emits depend only on the mesh, never on process boundaries; the only
cross-process difference is collective transport (XLA in-process vs gloo),
which is reduction-order-identical on the CPU backend.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np

# the one host-fetchability predicate, shared with the executor's metric
# filter and the checkpoint-save guard
from repro.core.flatbuf import host_fetchable  # noqa: F401  (re-exported)
from repro.launch.mesh import make_topology_mesh, validate_process_topology

ENV_COORDINATOR = "DASO_COORDINATOR"
ENV_NUM_PROCS = "DASO_NUM_PROCS"
ENV_PROC_ID = "DASO_PROC_ID"
ENV_DISPATCH = "DASO_DISPATCH"

DISPATCH_MODES = ("serial", "overlap")

_initialized = False


@dataclass(frozen=True)
class DistributedConfig:
    """Who we are in the process group. `num_processes == 1` means the
    single-process SPMD simulation — same code path, no coordinator.

    `dispatch` picks the executable-dispatch discipline for multi-process
    gloo runs:

      * "serial" (default) — async dispatch disabled; at most one
        executable in flight per process. Safe for every program mix:
        concurrent executables' gloo collectives would interleave on the
        same shared TCP pairs and abort (see `initialize`).
      * "overlap" — async dispatch left ON so the overlap executor can
        keep the exchange program in flight under the compute program.
        Safe ONLY because that executor's dispatch discipline guarantees
        at most one collective-bearing program in flight at a time (the
        compute program is collective-free over the outer axis and the
        merge data-depends on the exchange); `launch/train.py` therefore
        refuses this mode unless the strategy runs with overlap on.
    """
    coordinator: Optional[str] = None     # "host:port"
    num_processes: int = 1
    process_id: int = 0
    dispatch: str = "serial"

    def __post_init__(self):
        if self.dispatch not in DISPATCH_MODES:
            raise ValueError(f"unknown dispatch mode {self.dispatch!r}; "
                             f"expected one of {DISPATCH_MODES}")

    @classmethod
    def from_env(cls, *, coordinator: Optional[str] = None,
                 num_processes: Optional[int] = None,
                 process_id: Optional[int] = None,
                 dispatch: Optional[str] = None) -> "DistributedConfig":
        """Resolve explicit flag values, falling back to the DASO_* env
        vars `tools/launch_procs.py` exports for its children."""
        coord = coordinator or os.environ.get(ENV_COORDINATOR)
        n = num_processes if num_processes is not None else int(
            os.environ.get(ENV_NUM_PROCS, "1"))
        pid = process_id if process_id is not None else int(
            os.environ.get(ENV_PROC_ID, "0"))
        disp = dispatch or os.environ.get(ENV_DISPATCH, "serial")
        if n > 1 and not coord:
            raise ValueError(
                f"{n} processes need a coordinator address "
                f"(--coordinator host:port or ${ENV_COORDINATOR})")
        if not 0 <= pid < n:
            raise ValueError(f"process_id {pid} outside 0..{n - 1}")
        return cls(coordinator=coord, num_processes=n, process_id=pid,
                   dispatch=disp)


# Failure signatures of a transient coordinator connect/bind race: the
# coordinator process losing the port between free_port() and bind (a
# just-torn-down group's socket in TIME_WAIT, or a concurrent test group),
# or clients racing a coordinator that died and is being restarted. Fresh
# attempts resolve these — TIME_WAIT drains and regrouped coordinators come
# back — so `initialize` retries them with exponential backoff. Anything
# not matching fails immediately; a retry must never paper over a real
# failure. (tests/conftest.py used to carry a retry-once wrapper around
# whole subprocess groups for the same races; fixed here at the source.)
CONNECT_RACE_SIGNATURES = (
    "Address already in use",
    "ADDRESS_IN_USE",
    "Failed to bind",
    "Connection reset by peer",
    "coordinator service failed to start",
    "DEADLINE_EXCEEDED",
    "UNAVAILABLE",
)


def _is_connect_race(exc: BaseException) -> bool:
    return any(sig in str(exc) for sig in CONNECT_RACE_SIGNATURES)


def initialize(cfg: DistributedConfig, *, max_attempts: int = 5,
               backoff_s: float = 0.5) -> None:
    """Connect this process to the coordinator (idempotent; no-op for a
    single process). Must be called before anything touches JAX devices —
    the backend is configured here (CPU cross-process collectives run on
    gloo).

    Connect/bind failures matching `CONNECT_RACE_SIGNATURES` are retried
    up to `max_attempts` times with exponential backoff (0.5 s, 1 s, 2 s,
    …): the coordinator port race is transient by construction, and a
    regrouped epoch's workers may connect while the fresh coordinator is
    still coming up. Non-transient errors raise on the first attempt."""
    global _initialized
    if cfg.num_processes <= 1 or _initialized:
        return
    try:
        # gloo is the CPU cross-process transport; newer jaxlibs select it
        # automatically once distributed is initialized
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except AttributeError:
        pass
    if cfg.dispatch == "serial":
        try:
            # async dispatch lets consecutive executables be in flight at
            # once; their gloo collectives then interleave on the same TCP
            # pairs and abort with size-mismatch errors (observed: "op.
            # preamble.length <= op.nbytes" / "connection reset by peer"
            # flakes under load). Serial dispatch pins one collective in
            # flight per process — the same order on every process.
            jax.config.update("jax_cpu_enable_async_dispatch", False)
        except AttributeError:
            pass
    # dispatch == "overlap": async dispatch stays on. The overlap
    # executor's discipline (one collective-bearing program in flight,
    # enforced by construction — see DistributedConfig.dispatch) is what
    # stands in for the serial-dispatch guarantee.
    for attempt in range(max_attempts):
        try:
            jax.distributed.initialize(coordinator_address=cfg.coordinator,
                                       num_processes=cfg.num_processes,
                                       process_id=cfg.process_id)
            break
        except Exception as e:
            if attempt == max_attempts - 1 or not _is_connect_race(e):
                raise
            try:
                # a half-initialized client/service must be torn down
                # before the next attempt re-binds
                jax.distributed.shutdown()
            except Exception:
                pass
            delay = backoff_s * (2 ** attempt)
            print(f"[distributed] initialize attempt {attempt + 1}/"
                  f"{max_attempts} hit a transient connect race ({e}); "
                  f"retrying in {delay:.1f}s")
            time.sleep(delay)
    _initialized = True


def check_overlap_topology(spec, n_procs: int) -> None:
    """Fail fast when a topology cannot run under dispatch="overlap".

    The overlap compute program may carry INNER-level group syncs; those
    are safe concurrently with the in-flight outer exchange only when
    every inner group lies within one process (they then lower to
    in-process collectives gloo never sees). Each process owns a
    contiguous block of R // n_procs replica rows, so an inner level with
    cumulative group size g is process-local iff g divides that block
    evenly. Raises with the offending level spelled out — the actionable
    alternative being dispatch="serial" (correct for every topology,
    just no overlap win)."""
    if n_procs <= 1:
        return
    rows_per_proc, rem = divmod(spec.n_replicas, n_procs)
    if rem:
        return  # validate_process_topology already rejects this split
    for name in spec.inner_names():  # intermediate replica levels
        g = spec.group_size(name)
        if rows_per_proc % g != 0:
            raise ValueError(
                f"dispatch='overlap' needs process-local inner syncs, but "
                f"level {name!r} groups {g} replicas while each of the "
                f"{n_procs} processes holds only {rows_per_proc} "
                f"({spec.to_str()}): a {name!r} group sync would be a "
                f"cross-process gloo collective racing the in-flight "
                f"exchange. Use --dispatch serial for this topology, or "
                f"launch with a process count whose per-process replica "
                f"block is a multiple of {g}.")


def is_coordinator() -> bool:
    return jax.process_index() == 0


def forced_cpu_env(devices: int, base: Optional[dict] = None) -> dict:
    """Environment for a spawned CPU-JAX subprocess, with the JAX-relevant
    variables pinned EXPLICITLY — never inherited — so a local run behaves
    exactly like CI: platform is cpu (a developer's exported
    JAX_PLATFORMS=cuda would silently turn the forced-device-count flag
    into a no-op), and XLA_FLAGS forces `devices` host devices. The single
    definition behind both tests/conftest.py's subprocess helpers and
    tools/launch_procs.py's child environments."""
    env = dict(os.environ if base is None else base)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    src = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))  # .../src
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _is_jax_array(x) -> bool:
    return isinstance(x, jax.Array)


class MeshPlacement:
    """Array placement for one topology on the global device set.

    Construction validates that the topology fits the process group (world
    == device count, each process an integral subtree) and lowers the spec
    to the global mesh. The same placement object drives single-process
    SPMD runs (the equivalence oracle) and N-process runs — the shardings,
    and therefore the compiled programs, are identical in both.
    """

    def __init__(self, spec, *, mesh=None):
        from jax.sharding import NamedSharding, PartitionSpec

        self.spec = spec
        n_procs = jax.process_count()
        if n_procs > 1:
            validate_process_topology(spec, n_procs)
        if jax.device_count() != spec.world:
            raise ValueError(
                f"topology world {spec.world} ({spec.to_str()}) != global "
                f"device count {jax.device_count()}; launch with "
                f"world/num_processes devices per process "
                f"(tools/launch_procs.py does this)")
        self.mesh = mesh if mesh is not None else make_topology_mesh(spec)
        names = spec.mesh_axis_names()           # outermost first
        self.replica_axes = names[:-1]           # all replica levels
        self.level0_axis = names[-1]             # intra-replica tier
        self._P = PartitionSpec
        self._NS = NamedSharding
        self.replicated = NamedSharding(self.mesh, PartitionSpec())
        # leading replica axis sharded over every replica-level mesh axis
        # at once: level-l group means lower to collectives spanning
        # exactly levels <= l (the per-level HLO contract)
        self.carry_sharding = NamedSharding(
            self.mesh, PartitionSpec(self.replica_axes))
        self._gather = None

    # -- identity ----------------------------------------------------------
    @property
    def is_coordinator(self) -> bool:
        return is_coordinator()

    # -- placement ---------------------------------------------------------
    def _put(self, x, sharding):
        """Build a global array from host data WITHOUT cross-process
        traffic: every process holds the full value (the deterministic
        host loops guarantee they agree), so each can materialize its own
        addressable shards locally. `jax.device_put` would instead run an
        assert-equal broadcast per leaf — a per-transfer collective on its
        own communicator clique, which both costs a round-trip and races
        other gloo traffic."""
        if _is_jax_array(x) and not x.is_fully_addressable:
            return x  # already global (resumed carry re-placed twice)
        host = np.asarray(jax.device_get(x))
        return jax.make_array_from_callback(host.shape, sharding,
                                            lambda idx: host[idx])

    def put_carry(self, carry):
        """Place a strategy carry: every leaf with a leading replica axis
        shards over the replica-level mesh axes; anything else (scalar
        counters) replicates."""
        R = self.spec.n_replicas

        def one(x):
            sh = (self.carry_sharding
                  if getattr(x, "ndim", 0) >= 1 and x.shape[0] == R
                  else self.replicated)
            return self._put(x, sh)

        return jax.tree.map(one, carry)

    def _batch_sharding(self, ndim: int, shape, lead: int):
        """Batch leaves are (R, per, ...) with `lead` extra leading axes
        (the macro executor stacks a cycle axis in front). The per-replica
        batch dim shards over the level-0 axis when it divides — the
        intra-replica "data" tier of the topology."""
        axes = [None] * lead + [self.replica_axes]
        per_dim = lead + 1
        if (ndim > per_dim and self.spec.local_world > 1
                and shape[per_dim] % self.spec.local_world == 0):
            axes.append(self.level0_axis)
        return self._NS(self.mesh, self._P(*axes))

    def place_batch(self, batch, *, lead: int = 0):
        """Place one step's batch pytree (`lead=1` for a stacked cycle)."""
        R = self.spec.n_replicas

        def one(x):
            x = np.asarray(jax.device_get(x))
            if x.ndim <= lead or x.shape[lead] != R:
                raise ValueError(
                    f"batch leaf shape {x.shape} lacks the replica axis "
                    f"R={R} at dim {lead} (distributed runs use "
                    "replica-axis strategies)")
            return self._put(x, self._batch_sharding(x.ndim, x.shape,
                                                     lead))

        return jax.tree.map(one, batch)

    def stage_cycle(self, per_step_batches, lrs):
        """Stack a macro-cycle's per-step batches on the host and place
        them: batches (L, R, per, ...) sharded over the replica axes, lrs
        (L,) replicated."""
        stacked = jax.tree.map(
            lambda *xs: np.stack([np.asarray(jax.device_get(x))
                                  for x in xs]), *per_step_batches)
        return (self.place_batch(stacked, lead=1),
                self._put(np.asarray(lrs, np.float32), self.replicated))

    # -- host gather -------------------------------------------------------
    def fetch(self, tree):
        """Gather a (possibly process-sharded) pytree to host numpy — the
        same values on every process. Collective: every process must call
        it at the same point (they do: the host loops are deterministic)."""
        leaves = jax.tree.leaves(tree)
        if all(host_fetchable(x) for x in leaves):
            return jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                tree)
        if self._gather is None:
            self._gather = jax.jit(lambda t: t,
                                   out_shardings=self.replicated)
        rep = self._gather(tree)
        return jax.tree.map(lambda x: np.asarray(jax.device_get(x)), rep)

    def finalize_params(self, strategy, carry):
        """Host-side final params: gather the carry, then the strategy's
        own finalize (membership-aware row selection) on numpy."""
        return strategy.finalize_params(self.fetch(carry))
