import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes (single-pod 16x16 and multi-pod 2x16x16), prove the
sharding config is coherent, and record memory/cost/collective statistics
for the roofline analysis.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--daso] [--jobs-file f]

Per (arch, shape, mesh) this lowers:
  train_4k     sync train_step (Horovod-analog baseline); with --daso on the
               multi-pod mesh, additionally the DASO B=4 cycle (send /
               receive / local / local) whose HLO carries the cross-pod
               collectives only in the send/receive sub-steps.
  prefill_32k  prefill (returns populated KV cache)
  decode_32k   serve_step: ONE token against a seq-length cache
  long_500k    serve_step with recurrent state / ring window cache
               (sliding-window variant for full-attention archs)

Records land in experiments/dryrun/<arch>__<shape>__<mesh>[__daso].json.
--unroll-groups N lowers with N unrolled pattern groups instead of the full
scanned stack (used by the roofline per-layer cost extraction).
"""
import argparse
import functools
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.daso import DasoConfig, daso_train_step, sync_train_step
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (INPUT_SHAPES, batch_shardings, batch_specs,
                                cache_shardings, decode_specs, make_policy,
                                make_param_shardings, needs_window_override,
                                param_bytes, params_struct)
from repro.models.lm import forward, init_cache
from repro.optim.optimizers import sgd
from repro.serve.engine import make_decode_fn
from repro.sharding import use_policy
from repro.train.step import make_lm_loss

SDS = jax.ShapeDtypeStruct
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

FSDP_TRAIN_BYTES = 6e9    # enable ZeRO-3 when params*4/model_shards exceeds
FSDP_SERVE_BYTES = 10e9   # enable weight-gathered serving above this


def _scalar_sh(mesh):
    return NamedSharding(mesh, P())


def _mesh_dict(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _record(lowered, compiled, t_lower, t_compile, mesh, extra):
    from repro.launch.hlo_stats import collective_stats
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax: one dict per device
        ca = ca[0] if ca else {}
    rec = {
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "peak_estimate_per_device": (mem.argument_size_in_bytes
                                         + mem.output_size_in_bytes
                                         + mem.temp_size_in_bytes
                                         - mem.alias_size_in_bytes),
        },
        "cost": {"flops": ca.get("flops", -1.0),
                 "bytes_accessed": ca.get("bytes accessed", -1.0)},
        "collectives": collective_stats(compiled.as_text(),
                                        _mesh_dict(mesh)),
    }
    rec.update(extra)
    return rec


def build_train_lowering(cfg, mesh, *, daso: bool, unroll_groups: int = 0,
                         fsdp=None, remat: bool = True, q_chunk: int = 1024,
                         vocab_chunk: int = 0, n_micro: int = 1,
                         compress_nonblocking: bool = False):
    """Returns a jax .lower()-ed sync train step (or DASO cycle)."""
    params = params_struct(cfg)
    pb = param_bytes(params)
    model_shards = mesh.shape["model"]
    if fsdp is None:
        fsdp = pb * 4 / model_shards > FSDP_TRAIN_BYTES
    n_replicas = mesh.shape.get("pod", 1) if daso else 0
    policy = make_policy(mesh, daso=daso, fsdp=fsdp)

    if unroll_groups:
        plen = len(cfg.layer_pattern)
        cfg = cfg.replace(n_layers=unroll_groups * plen)
        params = params_struct(cfg)

    loss_fn = make_lm_loss(cfg, q_chunk=q_chunk, remat=remat,
                           vocab_chunk=vocab_chunk,
                           unroll_layers=bool(unroll_groups),
                           mamba_chunk=512 if unroll_groups else 64)
    optimizer = sgd(momentum=0.9, weight_decay=1e-4)
    opt = jax.eval_shape(optimizer.init, params)
    specs = batch_specs(cfg, "train_4k")
    bspecs, bsh = batch_shardings(specs, policy, n_replicas=n_replicas)
    if daso:
        R = n_replicas
        params = jax.tree.map(lambda x: SDS((R,) + x.shape, x.dtype), params)
        p_sh = make_param_shardings(cfg, params, policy, replicated=True)
        o_sh = {"mu": p_sh}
        opt = jax.eval_shape(
            lambda p: {"mu": jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), p)}, params)
        inflight = params
        dcfg = DasoConfig(n_replicas=R,
                          global_world=R * mesh.shape["data"], b_max=4,
                          compress_nonblocking=compress_nonblocking)
        steps = [daso_train_step(loss_fn, optimizer, dcfg, mode=m,
                                 staleness=1, spmd_axis_name="pod",
                                 n_micro=n_micro)
                 for m in ("send", "receive", "local", "local")]

        def cycle(params, opt_state, inflight, batches, lr):
            metrics = None
            for i, s in enumerate(steps):
                b = jax.tree.map(lambda x: x[i], batches)
                params, opt_state, inflight, metrics = s(
                    params, opt_state, inflight, b, lr)
            return params, opt_state, inflight, metrics

        batches = jax.tree.map(lambda x: SDS((4,) + x.shape, x.dtype), bspecs)
        bsh4 = jax.tree.map(
            lambda s: NamedSharding(mesh, P(*((None,) + s.spec))), bsh)
        with use_policy(policy):
            lowered = jax.jit(
                cycle,
                in_shardings=(p_sh, {"mu": o_sh["mu"]}, p_sh, bsh4,
                              _scalar_sh(mesh)),
                donate_argnums=(0, 1, 2)).lower(
                params, opt, inflight, batches,
                SDS((), jnp.float32))
        return lowered, {"fsdp": bool(fsdp), "param_bytes": pb,
                         "variant": "daso_cycle_b4"}

    p_sh = make_param_shardings(cfg, params, policy)
    o_sh = {"mu": p_sh}
    step = sync_train_step(loss_fn, optimizer, n_micro=n_micro)
    with use_policy(policy):
        lowered = jax.jit(step, in_shardings=(p_sh, o_sh, bsh,
                                              _scalar_sh(mesh)),
                          donate_argnums=(0, 1)).lower(
            params, opt, bspecs, SDS((), jnp.float32))
    return lowered, {"fsdp": bool(fsdp), "param_bytes": pb,
                     "variant": "sync_step"}


def build_prefill_lowering(cfg, mesh, *, unroll_groups: int = 0,
                           q_chunk: int = 1024):
    seq, gb, _ = INPUT_SHAPES["prefill_32k"]
    params = params_struct(cfg)
    pb = param_bytes(params)
    fsdp = pb / mesh.shape["model"] > FSDP_SERVE_BYTES
    policy = make_policy(mesh, fsdp=fsdp)
    if unroll_groups:
        cfg = cfg.replace(n_layers=unroll_groups * len(cfg.layer_pattern))
        params = params_struct(cfg)
    specs = batch_specs(cfg, "prefill_32k")
    bspecs, bsh = batch_shardings(specs, policy)
    p_sh = make_param_shardings(cfg, params, policy)

    def prefill(params, batch):
        cache = init_cache(cfg, gb, seq, dtype=cfg.cdtype())
        out = forward(params, batch["tokens"], cfg,
                      prefix_embeds=batch.get("prefix_embeds"),
                      cache=cache, q_chunk=q_chunk,
                      unroll_layers=bool(unroll_groups),
                      mamba_chunk=512 if unroll_groups else 64)
        return out["logits"][:, -1], out["cache"]

    with use_policy(policy):
        lowered = jax.jit(prefill, in_shardings=(p_sh, bsh)).lower(
            params, bspecs)
    return lowered, {"fsdp": bool(fsdp), "param_bytes": pb,
                     "variant": "prefill"}


def build_decode_lowering(cfg, mesh, shape_name: str, *,
                          unroll_groups: int = 0):
    seq, gb, _ = INPUT_SHAPES[shape_name]
    params = params_struct(cfg)
    pb = param_bytes(params)
    fsdp = pb / mesh.shape["model"] > FSDP_SERVE_BYTES
    wo = needs_window_override(cfg, shape_name)
    policy = make_policy(mesh, fsdp=fsdp, seq_sharded=(gb == 1))
    if unroll_groups:
        cfg = cfg.replace(n_layers=unroll_groups * len(cfg.layer_pattern))
        params = params_struct(cfg)
    d = decode_specs(cfg, shape_name)
    p_sh = make_param_shardings(cfg, params, policy)
    c_sh = cache_shardings(d["cache"], cfg, policy, gb)
    b_axes = policy.resolve("batch")
    b_axes = b_axes if isinstance(b_axes, tuple) else (b_axes,)
    nb = 1
    for a in b_axes:
        nb *= mesh.shape[a]
    tok_sh = NamedSharding(mesh, P(b_axes if gb % nb == 0 else None, None))

    serve_step = make_decode_fn(cfg, window_override=wo)

    def step(params, cache, token, pos):
        out = serve_step(params, cache, token, pos)
        return out["logits"], out["cache"]

    with use_policy(policy):
        lowered = jax.jit(step, in_shardings=(
            p_sh, c_sh, tok_sh, _scalar_sh(mesh)),
            donate_argnums=(1,)).lower(
            params, d["cache"], d["token"], d["pos"])
    return lowered, {"fsdp": bool(fsdp), "param_bytes": pb,
                     "variant": f"serve_step(window={wo})" if wo
                     else "serve_step"}


def run_one(arch: str, shape_name: str, *, multi_pod: bool, daso: bool = False,
            unroll_groups: int = 0, compile_too: bool = True):
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = INPUT_SHAPES[shape_name][2]
    t0 = time.time()
    if kind == "train":
        lowered, extra = build_train_lowering(cfg, mesh, daso=daso,
                                              unroll_groups=unroll_groups)
    elif kind == "prefill":
        lowered, extra = build_prefill_lowering(cfg, mesh,
                                                unroll_groups=unroll_groups)
    else:
        lowered, extra = build_decode_lowering(cfg, mesh, shape_name,
                                               unroll_groups=unroll_groups)
    t_lower = time.time() - t0
    if not compile_too:
        return {"ok": True, "lower_s": round(t_lower, 2), **extra}
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    rec = _record(lowered, compiled, t_lower, t_compile, mesh, extra)
    rec.update({"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "devices": 512 if multi_pod else 256,
                "unroll_groups": unroll_groups})
    print(compiled.memory_analysis())
    return rec


def _out_path(arch, shape, multi_pod, daso, unroll_groups):
    tag = f"{arch}__{shape}__{'2x16x16' if multi_pod else '16x16'}"
    if daso:
        tag += "__daso"
    if unroll_groups:
        tag += f"__u{unroll_groups}"
    return os.path.join(OUT_DIR, tag + ".json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--daso", action="store_true",
                    help="lower the DASO B=4 cycle (train_4k, multi-pod)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--unroll-groups", type=int, default=0)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.configs.base import ARCH_IDS
    archs = [args.arch] if args.arch else [a for a in ARCH_IDS
                                           if a != "resnet50"]
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    os.makedirs(OUT_DIR, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            path = _out_path(arch, shape, args.multi_pod, args.daso,
                             args.unroll_groups)
            if args.skip_existing and os.path.exists(path):
                continue
            label = f"{arch} x {shape} ({'2x16x16' if args.multi_pod else '16x16'}{' daso' if args.daso else ''})"
            print(f"== {label}", flush=True)
            try:
                rec = run_one(arch, shape, multi_pod=args.multi_pod,
                              daso=args.daso,
                              unroll_groups=args.unroll_groups)
                print(f"   flops={rec['cost']['flops']:.3e} "
                      f"coll={rec['collectives']['_total_bytes']:.3e}B "
                      f"lower={rec['lower_s']}s compile={rec['compile_s']}s",
                      flush=True)
            except Exception as e:
                failures += 1
                rec = {"ok": False, "arch": arch, "shape": shape,
                       "error": repr(e),
                       "traceback": traceback.format_exc()}
                print(f"   FAILED: {e!r}", flush=True)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
