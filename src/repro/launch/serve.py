"""Serving launcher: batched generation against a (reduced or checkpointed)
architecture — the end-to-end inference driver companion to train.py.

  python -m repro.launch.serve --arch falcon-mamba-7b --batch 4 \
      --prompt-len 32 --max-new 64 [--ckpt path] [--temperature 0.8]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.io import load_checkpoint
from repro.configs import get_config, get_reduced
from repro.models.lm import init_params
from repro.serve.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    key = jax.random.PRNGKey(args.seed)
    if args.ckpt:
        params, manifest = load_checkpoint(args.ckpt)
        print(f"[serve] restored checkpoint step={manifest['step']}")
    else:
        params = init_params(cfg, key)
    eng = Engine(cfg, params, max_len=args.prompt_len + args.max_new)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    out = eng.generate(prompts, args.max_new,
                       temperature=args.temperature,
                       key=jax.random.fold_in(key, 1))
    jax.block_until_ready(out)
    dt = time.time() - t0
    toks = out.shape[0] * out.shape[1]
    print(f"[serve] {args.arch}: {out.shape} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s)")
    for row in out[: min(4, args.batch)]:
        print("  ", list(map(int, row[:16])), "...")


if __name__ == "__main__":
    main()
