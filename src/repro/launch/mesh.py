"""Production meshes. TPU v5e target: one pod = 256 chips as (data=16,
model=16); multi-pod adds a leading DCN "pod" axis (the DASO global axis).

A function, not a module constant: importing this module must never touch
jax device state (smoke tests see 1 CPU device)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_pods: int = 2, data: int = 2, model: int = 2):
    """Small mesh for multi-device CPU tests (XLA host platform devices)."""
    return jax.make_mesh((n_pods, data, model), ("pod", "data", "model"))


# -- hardware constants (TPU v5e) used by the roofline analysis -------------
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link (intra-pod)
DCN_BW = 25e9                  # bytes/s per host aggregate (cross-pod)
