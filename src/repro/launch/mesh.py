"""Production meshes. TPU v5e target: one pod = 256 chips as (data=16,
model=16); multi-pod adds a leading "pod" axis — in topology terms
(repro/topo) that is the 2-level ``data x pod`` layout, with "pod" the
outermost (DASO-async) replica level. `make_topology_mesh` lowers an
arbitrary N-level `TopologySpec` to a mesh with one axis per level, so
syncs at level l produce collectives spanning exactly that level's axis
(the per-level HLO contract, tests/test_topology.py).

Functions, not module constants: importing this module must never touch
jax device state (smoke tests see 1 CPU device)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_pods: int = 2, data: int = 2, model: int = 2):
    """Small mesh for multi-device CPU tests (XLA host platform devices)."""
    return jax.make_mesh((n_pods, data, model), ("pod", "data", "model"))


def make_topology_mesh(spec, model: int = 1):
    """Lower a `repro.topo.TopologySpec` to a JAX mesh: one axis per
    topology level, outermost level first (major-to-minor device order
    matches the replica-index layout: inner levels vary fastest), plus a
    trailing "model" axis for tensor parallelism inside level 0.

    The replica axis of the training arrays shards over ALL replica-level
    axes at once (``PartitionSpec((outer_name, ..., inner_name))``), which
    is what makes a level-l group mean lower to an all-reduce whose
    replica groups span exactly the axes of levels <= l.

    Under `jax.distributed` the same call on every process builds the same
    *global* mesh: `jax.devices()` orders devices process-major, and the
    mesh axes are outermost-level-first, so each process's contiguous
    device block lands on a contiguous replica range — the subtree that
    `process_node_paths` reports it as owning."""
    shape = spec.mesh_shape() + (model,)
    axes = spec.mesh_axis_names() + ("model",)
    return jax.make_mesh(shape, axes)


# -- process <-> topology partitioning (multi-process runtime) ----------------
#
# Pure host-side functions — no jax device state — so the partition contract
# is testable without spawning processes (tests/test_process_mesh.py).

def replica_unit_sizes(spec):
    """Replicas per unit of each replica level, innermost first:
    ``{level_name: unit_size}``. A unit of the finest replica level is one
    replica; a unit of level l contains the product of the replica-level
    fanouts below it."""
    sizes, u = {}, 1
    for lvl in spec.replica_levels:
        sizes[lvl.name] = u
        u *= lvl.fanout
    return sizes


def validate_process_topology(spec, num_processes: int) -> int:
    """Check that `num_processes` coordinator-connected processes can carve
    the topology into equal per-process subtrees. Returns the number of
    devices each process must host (``spec.world // num_processes``).

    Raises ValueError with a precise reason when the split is impossible:
    the world not dividing evenly, a replica straddling two processes, or
    a process block cutting through a topology level's units."""
    if num_processes < 1:
        raise ValueError(f"num_processes must be >= 1, got {num_processes}")
    if spec.world % num_processes:
        raise ValueError(
            f"topology world {spec.world} ({spec.to_str()}) does not divide "
            f"over {num_processes} processes")
    local = spec.world // num_processes
    if local % spec.local_world:
        raise ValueError(
            f"{num_processes} processes would split a replica: each process "
            f"gets {local} devices but one replica spans "
            f"{spec.local_world} (level {spec.levels[0].name!r} fanout)")
    block = spec.n_replicas // num_processes
    for name, u in replica_unit_sizes(spec).items():
        if block % u and u % block:
            raise ValueError(
                f"process blocks of {block} replicas cut through "
                f"{name!r} units of {u} replicas: {num_processes} processes "
                f"cannot own whole subtrees of {spec.to_str()!r}")
    return local


def process_replica_slice(spec, num_processes: int,
                          process_id: int) -> range:
    """Replica indices owned by `process_id` (contiguous: the mesh lowers
    the replica axis process-major, inner levels varying fastest)."""
    validate_process_topology(spec, num_processes)
    if not 0 <= process_id < num_processes:
        raise ValueError(f"process_id {process_id} outside "
                         f"0..{num_processes - 1}")
    block = spec.n_replicas // num_processes
    return range(process_id * block, (process_id + 1) * block)


def _node_path(spec, level_index: int, replica: int) -> str:
    """Node path ("pod1/host0") of the level-`level_index` unit containing
    `replica`, descending outermost-first as `TopologySpec.replicas_of`
    expects."""
    sizes = replica_unit_sizes(spec)
    segs = []
    for i in range(len(spec.levels) - 1, level_index - 1, -1):
        lvl = spec.levels[i]
        u = sizes[lvl.name]
        idx = (replica // u) % lvl.fanout if i < len(spec.levels) - 1 \
            else replica // u
        segs.append(f"{lvl.name}{idx}")
    return "/".join(segs)


def process_node_paths(spec, num_processes: int, process_id: int):
    """The maximal topology subtrees owned by `process_id`, as node paths
    (`TopologySpec.replicas_of` round-trips them). With processes mapped
    one-to-one onto units of some level this is a single path — the
    process's subtree; coarser splits own several sibling subtrees."""
    rng = process_replica_slice(spec, num_processes, process_id)
    block = len(rng)
    best_i, best_u = 1, 1
    for i, lvl in enumerate(spec.levels[1:], start=1):
        u = replica_unit_sizes(spec)[lvl.name]
        if block % u == 0 and u >= best_u:
            best_i, best_u = i, u
    return tuple(_node_path(spec, best_i, r)
                 for r in range(rng.start, rng.stop, best_u))


def device_node_path(spec, device_index: int) -> str:
    """Topology path of one global device: the finest replica-level node it
    sits in, plus its rank inside that replica's level-0 tier —
    ``"pod1/host0:chip2"``."""
    if not 0 <= device_index < spec.world:
        raise ValueError(f"device {device_index} outside the topology "
                         f"world 0..{spec.world - 1}")
    replica, local = divmod(device_index, spec.local_world)
    return (f"{_node_path(spec, 1, replica)}:"
            f"{spec.levels[0].name}{local}")


# -- hardware constants (TPU v5e) used by the roofline analysis -------------
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link (intra-pod)
DCN_BW = 25e9                  # bytes/s per host aggregate (cross-pod)
