"""Production meshes. TPU v5e target: one pod = 256 chips as (data=16,
model=16); multi-pod adds a leading "pod" axis — in topology terms
(repro/topo) that is the 2-level ``data x pod`` layout, with "pod" the
outermost (DASO-async) replica level. `make_topology_mesh` lowers an
arbitrary N-level `TopologySpec` to a mesh with one axis per level, so
syncs at level l produce collectives spanning exactly that level's axis
(the per-level HLO contract, tests/test_topology.py).

Functions, not module constants: importing this module must never touch
jax device state (smoke tests see 1 CPU device)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_pods: int = 2, data: int = 2, model: int = 2):
    """Small mesh for multi-device CPU tests (XLA host platform devices)."""
    return jax.make_mesh((n_pods, data, model), ("pod", "data", "model"))


def make_topology_mesh(spec, model: int = 1):
    """Lower a `repro.topo.TopologySpec` to a JAX mesh: one axis per
    topology level, outermost level first (major-to-minor device order
    matches the replica-index layout: inner levels vary fastest), plus a
    trailing "model" axis for tensor parallelism inside level 0.

    The replica axis of the training arrays shards over ALL replica-level
    axes at once (``PartitionSpec((outer_name, ..., inner_name))``), which
    is what makes a level-l group mean lower to an all-reduce whose
    replica groups span exactly the axes of levels <= l."""
    shape = spec.mesh_shape() + (model,)
    axes = spec.mesh_axis_names() + ("model",)
    return jax.make_mesh(shape, axes)


# -- hardware constants (TPU v5e) used by the roofline analysis -------------
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link (intra-pod)
DCN_BW = 25e9                  # bytes/s per host aggregate (cross-pod)
