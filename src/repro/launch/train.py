"""Training launcher.

On this container it runs REAL training of a reduced architecture with DASO
(virtual nodes on one device) or sync; on a TPU cluster the same entry points
drive the production mesh (the dry-run proves those shardings compile).

Training drives through the strategy registry and the compiled macro-cycle
executor (core/executor.py) by default: one buffer-donating XLA dispatch per
controller cycle instead of one per step. `--executor per_step` selects the
reference path (identical numerics, allclose at f32).

Resilience surface:

  * ``--ckpt DIR --ckpt-every N`` writes a full resumable TrainState
    (params + optimizer + controller + in-flight exchange) every N steps;
  * ``--resume DIR/step_XXXXXXXX`` continues such a run with numerics
    identical to an uninterrupted one;
  * ``--fault-plan plan.json`` replays a declarative fault plan (node
    crash / rejoin / straggler / DCN degradation) through the resilience
    supervisor (resilience/supervisor.py).

Distributed surface (launch/distributed.py): ``--distributed`` runs the
same training over `jax.distributed` — one process per host, the topology
mesh spanning all of them, process 0 owning logs/checkpoints/metrics.
``--coordinator``/``--procs``/``--proc-id`` come from flags or from the
``DASO_*`` environment that ``tools/launch_procs.py`` exports when it
spawns N local coordinator-connected processes:

  python tools/launch_procs.py --procs 2 -- \
      --arch llama3.2-1b --topology "chip:1 x host:2 x pod:2" \
      --distributed --steps 40

  python -m repro.launch.train --arch llama3.2-1b --strategy daso \
      --steps 300 --nodes 4 --b-max 4 [--executor macro|per_step] [--full]
"""
import argparse
import dataclasses
import json
import os

import jax

from repro.configs import get_config, get_reduced
from repro.core.executor import list_strategies
from repro.data.synthetic import SyntheticLM
from repro.models.lm import init_params
from repro.train.loop import TrainLoopConfig, run_training
from repro.train.step import make_lm_loss
from repro.optim.schedules import warmup_linear_scaled
from repro.checkpoint.io import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--strategy", default="daso",
                    choices=list_strategies())
    ap.add_argument("--executor", default="macro",
                    choices=["macro", "per_step"],
                    help="macro = one compiled dispatch per controller "
                         "cycle; per_step = reference path")
    ap.add_argument("--max-cycle-len", type=int, default=32)
    ap.add_argument("--wire-format", default=None,
                    choices=["f32", "bf16", "int8"],
                    help="wire tier of the global exchange; default derives "
                         "bf16/f32 from the DASO compress flags, int8 is "
                         "the beyond-paper block-scaled tier")
    ap.add_argument("--exchange-impl", default="fused",
                    choices=["fused", "per_leaf"],
                    help="fused = one flat-buffer collective per exchange; "
                         "per_leaf = legacy reference path")
    ap.add_argument("--overlap", default="off",
                    choices=["off", "one_cycle"],
                    help="double-buffered compute/communication overlap: "
                         "one_cycle hides each global exchange behind the "
                         "next B local steps and merges it one cycle stale "
                         "(Eq. (1) with the snapshot's true age as S); off "
                         "is bit-exact with pre-overlap runs. daso family "
                         "only")
    ap.add_argument("--overlap-serial-exchange", action="store_true",
                    help="debug/benchmark: block on each overlap exchange "
                         "before running compute — identical numerics, no "
                         "hiding; the baseline leg of benchmarks/"
                         "overlap.py")
    ap.add_argument("--dispatch", default=None,
                    choices=["serial", "overlap"],
                    help="multi-process executable dispatch (default "
                         "$DASO_DISPATCH or serial): serial pins one "
                         "program in flight per process (safe for every "
                         "program mix on gloo); overlap leaves async "
                         "dispatch on so the overlap executor can hide the "
                         "exchange — requires --overlap one_cycle")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--nodes", type=int, default=4,
                    help="DASO replicas (paper nodes / pods); superseded "
                         "by --topology when given")
    ap.add_argument("--local-world", type=int, default=4)
    ap.add_argument("--b-max", type=int, default=4)
    ap.add_argument("--topology", default=None, metavar="SPEC",
                    help="explicit N-level cluster topology (repro/topo): "
                         "a spec string like 'chip:4 x host:2 x pod:2', "
                         "inline JSON, or a JSON file path. Replica count "
                         "and world size derive from the level fanouts; "
                         ">2-level specs run the hier_daso per-level sync "
                         "schedule (docs/topologies.md)")
    ap.add_argument("--per-node-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds both the parameter init PRNGKey and the "
                         "synthetic data stream")
    ap.add_argument("--full", action="store_true",
                    help="use the full (published) config instead of reduced"
                         " — only sensible on real hardware")
    ap.add_argument("--tiny", action="store_true",
                    help="shrink the reduced LM config to quickstart scale "
                         "(2 layers, d_model 128, vocab 256) — the CI / "
                         "multiprocess-smoke arch. At this scale per-device "
                         "compute sits below XLA CPU's intra-op partitioning "
                         "thresholds, which the N-process bit-exactness "
                         "contract relies on (docs/architecture.md)")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint directory: final params always land "
                         "here; with --ckpt-every, periodic TrainStates in "
                         "step_XXXXXXXX/ subdirs")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="save a full resumable TrainState every N steps "
                         "(requires --ckpt)")
    ap.add_argument("--resume", default=None, metavar="STATE_DIR",
                    help="resume from a TrainState directory written by "
                         "--ckpt-every; the run continues deterministically")
    ap.add_argument("--fault-plan", default=None, metavar="PLAN_JSON",
                    help="replay a declarative fault plan (JSON: crash/"
                         "rejoin/straggle/degrade_dcn events) through the "
                         "resilience supervisor; daso-family strategies "
                         "only")
    ap.add_argument("--autotune", action="store_true",
                    help="self-tuning topology (docs/tuning.md): probe the "
                         "live mesh's per-level sync cost and retune the "
                         "lowered schedule online (controller.retune — "
                         "periods re-derived from measurements, effective "
                         "DCN scale inferred). Plain runs probe once at "
                         "startup; --fault-plan runs re-probe every "
                         "--autotune-every cycles and reshuffle inner "
                         "groups by straggler skew. Measurements matching "
                         "the spec's annotations are a strict no-op")
    ap.add_argument("--autotune-every", type=int, default=8, metavar="K",
                    help="probe cadence in macro-cycles for --autotune "
                         "under --fault-plan (default 8; the adapt-within-K "
                         "bound BENCH_tuning.json gates)")
    ap.add_argument("--metrics-out", default=None)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a JSONL run trace (obs/trace.py): spans "
                         "from the executor/scheduler/resilience layers + "
                         "comm meters. Multi-process runs write one "
                         "PATH.e{epoch}p{proc}.jsonl stream per process, "
                         "merged into PATH by tools/launch_procs.py; "
                         "export/inspect with tools/trace_report.py")
    ap.add_argument("--distributed", action="store_true",
                    help="run over jax.distributed: the topology mesh "
                         "spans every coordinator-connected process "
                         "(launch/distributed.py); requires --topology. "
                         "With 1 process this is the SPMD oracle the "
                         "N-process run is bit-exact with")
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT",
                    help="jax.distributed coordinator address (default "
                         "$DASO_COORDINATOR — tools/launch_procs.py "
                         "exports it)")
    ap.add_argument("--procs", type=int, default=None,
                    help="total process count (default $DASO_NUM_PROCS)")
    ap.add_argument("--proc-id", type=int, default=None,
                    help="this process's id (default $DASO_PROC_ID)")
    args = ap.parse_args()

    say = print
    health = None
    live_cfg = None
    tracer = None
    if args.distributed:
        from repro.launch.distributed import (DistributedConfig, initialize,
                                              is_coordinator)
        from repro.resilience.runtime import HealthConfig, HealthMonitor
        if not args.topology:
            ap.error("--distributed derives its mesh from --topology")
        dist = DistributedConfig.from_env(coordinator=args.coordinator,
                                          num_processes=args.procs,
                                          process_id=args.proc_id,
                                          dispatch=args.dispatch)
        live_cfg = HealthConfig.from_env()  # None unless supervised
        if args.trace_out:
            # one stream per (epoch, proc), next to the heartbeat files'
            # run dir semantics; the launcher merges them into the single
            # run trace at --trace-out after the group exits
            from repro.obs.trace import Tracer, stream_path
            tracer = Tracer(stream_path(
                args.trace_out, dist.process_id,
                live_cfg.epoch if live_cfg is not None else 0),
                proc_id=dist.process_id)
        if live_cfg is not None:
            if args.executor != "macro":
                ap.error("supervised runs (DASO_RUN_DIR set) report "
                         "progress from the macro executor; drop "
                         "--executor per_step")
            # heartbeats start BEFORE the coordinator connect so even a
            # wedged initialize is watchdog-bounded
            health = HealthMonitor(live_cfg, proc_id=dist.process_id,
                                   tracer=tracer)
            health.start()
            health.phase("init")
        if dist.dispatch == "overlap" and args.overlap == "off":
            # fail BEFORE jax.distributed comes up: async dispatch with the
            # blocking schedule would put two collective-bearing programs
            # in flight on the shared gloo TCP pairs (the PR-5 interleaving
            # failure). Only the overlap executor's dispatch discipline
            # makes "overlap" safe.
            ap.error("--dispatch overlap requires --overlap one_cycle: "
                     "without the overlap executor's one-collective-in-"
                     "flight discipline, async dispatch interleaves gloo "
                     "collectives on shared TCP pairs and aborts. Use "
                     "--dispatch serial (default) for blocking schedules.")
        initialize(dist)  # before anything touches devices
        if not is_coordinator():
            if args.metrics_out:
                # raw print: `say` is about to be silenced, and the user
                # deserves to know why the file never appears on this rank
                print(f"[train][proc {dist.process_id}] --metrics-out is "
                      f"written by the coordinator only; this rank drops "
                      f"{args.metrics_out}")
            # one process speaks for the group; files are proc-0-only too
            say = lambda *a, **k: None
            args.metrics_out = None
        say(f"[train] distributed: process {dist.process_id}/"
            f"{dist.num_processes} "
            f"({jax.local_device_count()} local of "
            f"{jax.device_count()} global devices)")

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    if args.tiny:
        if args.full:
            ap.error("--tiny and --full are mutually exclusive")
        for f in ("n_layers", "d_model", "n_heads", "d_ff", "vocab_size"):
            if not hasattr(cfg, f):
                ap.error(f"--tiny shrinks LM configs; {args.arch!r} has no "
                         f"{f!r}")
        cfg = cfg.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                          head_dim=32, d_ff=256, vocab_size=256)
    key = jax.random.PRNGKey(args.seed)
    params0 = init_params(cfg, key)
    loss_fn = make_lm_loss(cfg)
    src = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      seed=args.seed)
    spec = None
    if args.topology:
        if args.strategy not in ("daso", "hier_daso", "gossip", "easgd",
                                 "downpour"):
            ap.error("--topology drives the replica-axis strategies "
                     "(daso / hier_daso / gossip / easgd / downpour)")
        from repro.topo import TopologySpec, derive_inner_periods
        spec = TopologySpec.load(args.topology)
        args.nodes, args.local_world = spec.n_replicas, spec.local_world
        # a %period on the outermost level overrides --b-max (exactly as
        # build_strategy's lowering does), so log the schedule that runs
        b_eff = (spec.outer.period if spec.outer.period is not None
                 else args.b_max)
        say(f"[train] topology: {spec.to_str()} -> R={spec.n_replicas} "
            f"world={spec.world} inner_periods="
            f"{derive_inner_periods(spec, b_max=b_eff)}")
    if args.distributed and spec is not None and dist.dispatch == "overlap":
        # inner-level group syncs ride inside the overlap compute program;
        # they must be process-local or they'd race the in-flight exchange
        from repro.launch.distributed import check_overlap_topology
        check_overlap_topology(spec, dist.num_processes)
    R, per = args.nodes, args.per_node_batch

    def daso_data(step):
        b = src.batch(R * per, step)
        return {k: v.reshape((R, per) + v.shape[1:]) for k, v in b.items()}

    def sync_data(step):
        return src.batch(R * per, step)

    if args.ckpt_every and not args.ckpt:
        ap.error("--ckpt-every requires --ckpt")
    loop_cfg = TrainLoopConfig(
        strategy=args.strategy, n_steps=args.steps, n_replicas=R,
        local_world=args.local_world, b_max=args.b_max,
        # canonical string from the spec parsed above — the strategy must
        # train on exactly the topology R/data shapes were derived from,
        # even if --topology named a file that changes under us
        topology=spec.to_str() if spec is not None else None, lr=args.lr,
        executor=args.executor, max_cycle_len=args.max_cycle_len,
        wire_format=args.wire_format, exchange_impl=args.exchange_impl,
        overlap=args.overlap,
        overlap_serial_exchange=args.overlap_serial_exchange,
        ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt,
        resume_from=args.resume, distributed=args.distributed,
        autotune=args.autotune, autotune_every=args.autotune_every)
    lr_fn = warmup_linear_scaled(args.lr / (R * args.local_world),
                                 R * args.local_world,
                                 max(1, args.steps // 10))
    data_fn = sync_data if args.strategy == "sync" else daso_data

    if args.trace_out and tracer is None:  # single-process run
        from repro.obs.trace import Tracer, stream_path
        tracer = Tracer(stream_path(args.trace_out, 0), proc_id=0)
    if tracer is not None:
        # everything tools/trace_report.py needs to price the model side
        # of its drift table rides in the stream itself
        param_bytes = sum(int(x.size) * x.dtype.itemsize
                          for x in jax.tree.leaves(params0))
        tracer.metadata(
            arch=args.arch, strategy=args.strategy, steps=args.steps,
            topology=spec.to_str() if spec is not None else None,
            n_replicas=R, local_world=args.local_world,
            b_max=(spec.outer.period if spec is not None
                   and spec.outer.period is not None else args.b_max),
            wire_format=args.wire_format, exchange_impl=args.exchange_impl,
            overlap=args.overlap, param_bytes=param_bytes,
            procs=dist.num_processes if args.distributed else 1,
            seed=args.seed, tiny=bool(args.tiny))

    # a supervised regroup epoch (launcher relaunched us after a real
    # process death) turns into a fault-plan run: resume from the newest
    # intact checkpoint, the death replayed as crash event(s) at the
    # resume step — numerics identical to the simulated oracle
    regroup = None
    if live_cfg is not None and live_cfg.regroup_file:
        from repro.resilience.runtime import load_regroup
        regroup = load_regroup(live_cfg.regroup_file)
        if not args.ckpt:
            ap.error("a regrouped epoch resumes from --ckpt; the "
                     "supervisor must pass --ckpt DIR --ckpt-every N")

    report = None
    live_meta = None
    if args.fault_plan or regroup is not None:
        if args.strategy == "sync":
            ap.error("--fault-plan requires a replica-axis strategy "
                     "(daso / local_sgd / gossip / easgd / downpour)")
        if args.executor != "macro":
            ap.error("--fault-plan drives the macro-cycle supervisor; "
                     "--executor per_step is not supported with it")
        if args.overlap != "off":
            ap.error("--fault-plan with --overlap is not supported: a "
                     "membership change mid-cycle would merge a pending "
                     "snapshot taken under the old active set (stale "
                     "exchange weights). Run fault plans with the blocking "
                     "schedule (--overlap off).")
        from repro.checkpoint.io import (TrainState, load_latest_train_state,
                                         load_train_state, save_train_state)
        from repro.resilience.faults import FaultPlan
        from repro.resilience.supervisor import run_with_faults
        from repro.train.loop import build_strategy, ckpt_step_dir
        from repro.optim.optimizers import sgd

        ts = None
        if regroup is not None:
            from repro.resilience.runtime import regroup_fault_events
            resumed_from, ts = load_latest_train_state(
                args.ckpt, expect_overlap="off")
            events = regroup_fault_events(ts.step, ts.membership,
                                          regroup.dead_replicas,
                                          rejoin=regroup.rejoin)
            plan = FaultPlan(tuple(events))
            if args.fault_plan:
                # keep any scripted events still ahead of the resume step
                scripted = FaultPlan.from_json(args.fault_plan)
                if spec is not None:
                    scripted = scripted.resolve(spec)
                plan = FaultPlan(plan.events + tuple(
                    e for e in scripted.events if e.step >= ts.step))
            live_meta = {"epoch": regroup.epoch, "crash_step": ts.step,
                         "dead_replicas": list(regroup.dead_replicas),
                         "rejoin": regroup.rejoin,
                         "resumed_from": resumed_from,
                         "watchdog_s": live_cfg.watchdog_s}
            say(f"[train] regroup epoch {regroup.epoch}: resumed "
                f"{resumed_from} at step {ts.step}, replaying "
                f"{len(plan.events)} event(s) for dead replicas "
                f"{list(regroup.dead_replicas)}"
                + (" with elastic rejoin" if regroup.rejoin else ""))
        else:
            plan = FaultPlan.from_json(args.fault_plan)
            if spec is not None:
                plan = plan.resolve(spec)  # topology-node events -> replicas
            if args.resume:
                ts = load_train_state(args.resume, expect_overlap="off",
                                      fallback=True)
        strategy = build_strategy(loss_fn, loop_cfg,
                                  sgd(momentum=0.9, weight_decay=1e-4))
        placement = None
        if args.distributed:
            from repro.launch.distributed import MeshPlacement
            placement = MeshPlacement(spec)

        start_step, carry, membership, prior_losses = 0, None, None, []
        if ts is not None:
            if ts.strategy != args.strategy:
                ap.error(f"checkpoint was written by strategy "
                         f"{ts.strategy!r}, run requests {args.strategy!r}")
            start_step, carry, membership = ts.step, ts.carry, ts.membership
            prior_losses = list(ts.losses)
            if ts.controller is not None and strategy.controller is not None:
                strategy.controller.load_state_dict(ts.controller)

        ckpt_cb = None
        if args.ckpt_every:
            def ckpt_cb(step, carry, seg_losses):
                if placement is not None:
                    carry = placement.fetch(carry)  # collective: all procs
                    if not placement.is_coordinator:
                        return
                save_train_state(
                    ckpt_step_dir(args.ckpt, step),
                    TrainState(
                        step=step, carry=carry,
                        controller=strategy.controller.state_dict(),
                        membership=(list(strategy.membership)
                                    if strategy.membership is not None
                                    else None),
                        strategy=args.strategy,
                        losses=prior_losses + list(seg_losses)))

        if health is not None:
            health.phase("train")
        if tracer is not None and strategy.controller is not None:
            strategy.controller.tracer = tracer
        report = run_with_faults(strategy, params0, daso_data, lr_fn,
                                 args.steps, plan,
                                 ckpt_every=args.ckpt_every,
                                 ckpt_cb=ckpt_cb, placement=placement,
                                 start_step=start_step, carry=carry,
                                 membership=membership, health=health,
                                 tracer=tracer,
                                 autotune_every=(args.autotune_every
                                                 if args.autotune else 0))
        result = report.result
        if prior_losses:
            result.losses = prior_losses + result.losses
        say(f"[train] fault plan: {len(plan.events)} events, "
            f"{report.invalidations} cycle-cache invalidations, "
            f"simulated_time={report.simulated_time_s:.2f}s")
        for rt in report.retunes:
            say(f"[train]   step {rt['step']:>5} retune       "
                f"cycle={rt['cycle']} changed={rt['schedule_changed']} "
                f"reshuffled={rt['reshuffled']}")
        for ev in report.applied:
            say(f"[train]   step {ev['step']:>5} {ev['kind']:<12} "
                f"replica={ev.get('replica')} "
                f"handle={ev['handle_s'] * 1e3:.1f}ms "
                f"first_cycle={ev['first_cycle_s'] * 1e3:.1f}ms")
    else:
        if health is not None:
            health.phase("train")
        result = run_training(loss_fn, params0, data_fn, loop_cfg,
                              lr_fn=lr_fn, log=say, health=health,
                              tracer=tracer)
    if health is not None:
        health.phase("finalize")
    if result.executor_stats is not None:
        s = result.executor_stats
        say(f"[train] executor: {s.dispatches} host dispatches for "
            f"{args.steps} steps ({s.compiles} compiled cycle shapes, "
            f"{s.fallback_steps} tail-fallback steps, "
            f"{s.invalidations} invalidations)")

    comm_rows = None
    if tracer is not None and result.controller is not None:
        # per-level comm accounting over the whole run, carried both in
        # the trace (counter event) and the metrics JSON
        from repro.obs import meters
        ctrl = result.controller
        comm_rows = meters.level_bytes_report(
            params0, ctrl.level_sync_counts(), ctrl.cfg, topo=spec,
            outer_split=meters.outer_sync_split(ctrl.history))
        tracer.counter("comm_meters", meters.rows_as_counter(comm_rows))

    if args.ckpt and (not args.distributed or jax.process_index() == 0):
        save_checkpoint(args.ckpt, result.params, step=args.steps)
        say(f"[train] checkpoint -> {args.ckpt}")
    if args.metrics_out:
        os.makedirs(os.path.dirname(args.metrics_out) or ".", exist_ok=True)
        metrics = {"losses": result.losses,
                   "sync_fraction": result.sync_fraction,
                   "final_loss": result.final_loss,
                   "seed": args.seed}
        if result.executor_stats is not None:
            metrics["executor_stats"] = dataclasses.asdict(
                result.executor_stats)
        if report is not None:
            metrics["resilience"] = {
                "events": report.applied,
                "invalidations": report.invalidations,
                "simulated_time_s": report.simulated_time_s,
                "retunes": report.retunes,
                "reshuffles": report.reshuffles,
                "wasted_wait_s": report.wasted_wait_s}
            if live_meta is not None:
                metrics["resilience"]["live"] = live_meta
        if comm_rows is not None:
            metrics["comm_meters"] = [
                {**dataclasses.asdict(r), "total_bytes": r.total_bytes}
                for r in comm_rows]
        with open(args.metrics_out, "w") as f:
            json.dump(metrics, f)
        print(f"[train] metrics -> {args.metrics_out}")
    if health is not None:
        health.close()
    if tracer is not None:
        tracer.close()
        if not args.distributed:
            # single-process runs merge their own (only) stream so
            # --trace-out names a ready run trace; distributed runs leave
            # the merge to tools/launch_procs.py after the group exits
            from repro.obs.trace import merge_streams
            merge_streams(args.trace_out, log=say)
        say(f"[train] trace events={tracer.n_events} "
            f"overhead={tracer.overhead_s * 1e3:.1f}ms -> {args.trace_out}")


if __name__ == "__main__":
    main()
