"""Training launcher.

On this container it runs REAL training of a reduced architecture with DASO
(virtual nodes on one device) or sync; on a TPU cluster the same entry points
drive the production mesh (the dry-run proves those shardings compile).

Training drives through the strategy registry and the compiled macro-cycle
executor (core/executor.py) by default: one buffer-donating XLA dispatch per
controller cycle instead of one per step. `--executor per_step` selects the
reference path (identical numerics, allclose at f32).

  python -m repro.launch.train --arch llama3.2-1b --strategy daso \
      --steps 300 --nodes 4 --b-max 4 [--executor macro|per_step] [--full]
"""
import argparse
import json
import os

import jax

from repro.configs import get_config, get_reduced
from repro.core.executor import list_strategies
from repro.data.synthetic import SyntheticLM
from repro.models.lm import init_params
from repro.train.loop import TrainLoopConfig, run_training
from repro.train.step import make_lm_loss
from repro.optim.schedules import warmup_linear_scaled
from repro.checkpoint.io import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--strategy", default="daso",
                    choices=list_strategies())
    ap.add_argument("--executor", default="macro",
                    choices=["macro", "per_step"],
                    help="macro = one compiled dispatch per controller "
                         "cycle; per_step = reference path")
    ap.add_argument("--max-cycle-len", type=int, default=32)
    ap.add_argument("--wire-format", default=None,
                    choices=["f32", "bf16", "int8"],
                    help="wire tier of the global exchange; default derives "
                         "bf16/f32 from the DASO compress flags, int8 is "
                         "the beyond-paper block-scaled tier")
    ap.add_argument("--exchange-impl", default="fused",
                    choices=["fused", "per_leaf"],
                    help="fused = one flat-buffer collective per exchange; "
                         "per_leaf = legacy reference path")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--nodes", type=int, default=4,
                    help="DASO replicas (paper nodes / pods)")
    ap.add_argument("--local-world", type=int, default=4)
    ap.add_argument("--b-max", type=int, default=4)
    ap.add_argument("--per-node-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--full", action="store_true",
                    help="use the full (published) config instead of reduced"
                         " — only sensible on real hardware")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    key = jax.random.PRNGKey(0)
    params0 = init_params(cfg, key)
    loss_fn = make_lm_loss(cfg)
    src = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq_len, seed=0)
    R, per = args.nodes, args.per_node_batch

    def daso_data(step):
        b = src.batch(R * per, step)
        return {k: v.reshape((R, per) + v.shape[1:]) for k, v in b.items()}

    def sync_data(step):
        return src.batch(R * per, step)

    loop_cfg = TrainLoopConfig(
        strategy=args.strategy, n_steps=args.steps, n_replicas=R,
        local_world=args.local_world, b_max=args.b_max, lr=args.lr,
        executor=args.executor, max_cycle_len=args.max_cycle_len,
        wire_format=args.wire_format, exchange_impl=args.exchange_impl)
    lr_fn = warmup_linear_scaled(args.lr / (R * args.local_world),
                                 R * args.local_world,
                                 max(1, args.steps // 10))
    data_fn = sync_data if args.strategy == "sync" else daso_data
    result = run_training(loss_fn, params0, data_fn, loop_cfg, lr_fn=lr_fn)
    if result.executor_stats is not None:
        s = result.executor_stats
        print(f"[train] executor: {s.dispatches} host dispatches for "
              f"{args.steps} steps ({s.compiles} compiled cycle shapes, "
              f"{s.fallback_steps} tail-fallback steps)")

    if args.ckpt:
        save_checkpoint(args.ckpt, result.params, step=args.steps)
        print(f"[train] checkpoint -> {args.ckpt}")
    if args.metrics_out:
        os.makedirs(os.path.dirname(args.metrics_out) or ".", exist_ok=True)
        with open(args.metrics_out, "w") as f:
            json.dump({"losses": result.losses,
                       "sync_fraction": result.sync_fraction,
                       "final_loss": result.final_loss}, f)
        print(f"[train] metrics -> {args.metrics_out}")


if __name__ == "__main__":
    main()
