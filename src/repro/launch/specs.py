"""ShapeDtypeStruct input stand-ins + sharding assembly for every
(architecture x input shape) pair — the shannon/kernels dry-run pattern:
weak-type-correct, shardable, zero device allocation.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.lm import init_cache, init_params
from repro.sharding import MeshPolicy, param_specs
from repro.sharding.policy import param_shardings

S = jax.ShapeDtypeStruct

# name -> (seq_len, global_batch, kind)
INPUT_SHAPES = {
    "train_4k":    (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k":  (32_768, 128, "decode"),
    "long_500k":   (524_288, 1, "decode"),
}


def needs_window_override(cfg: ArchConfig, shape_name: str) -> int:
    """long_500k on a full-attention arch runs the sliding-window variant."""
    if shape_name == "long_500k" and not cfg.is_subquadratic():
        assert cfg.long_context_window > 0, cfg.name
        return cfg.long_context_window
    return 0


def batch_specs(cfg: ArchConfig, shape_name: str):
    """ShapeDtypeStructs for one *training/prefill* batch (no replica dim)."""
    seq, gb, kind = INPUT_SHAPES[shape_name]
    pre = cfg.prefix_embed_len
    specs = {"tokens": S((gb, seq - pre), jnp.int32)}
    if kind == "train":
        specs["labels"] = S((gb, seq), jnp.int32)
    if pre:
        specs["prefix_embeds"] = S((gb, pre, cfg.d_model), cfg.cdtype())
    return specs


def decode_specs(cfg: ArchConfig, shape_name: str):
    """(token, pos, cache) ShapeDtypeStructs for a serve_step."""
    seq, gb, kind = INPUT_SHAPES[shape_name]
    assert kind == "decode"
    wo = needs_window_override(cfg, shape_name)
    cache = jax.eval_shape(
        functools.partial(init_cache, cfg, gb, seq, dtype=cfg.cdtype(),
                          window_override=wo))
    return {"token": S((gb, 1), jnp.int32), "pos": S((), jnp.int32),
            "cache": cache}


def params_struct(cfg: ArchConfig):
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.random.PRNGKey(0))


def param_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))


# -- sharding assembly -------------------------------------------------------

def make_policy(mesh, *, daso: bool = False, fsdp: bool = False,
                seq_sharded: bool = False) -> MeshPolicy:
    multi_pod = "pod" in mesh.axis_names
    if daso:
        assert multi_pod, "DASO replicas need the pod axis"
        batch_axes = ("data",)           # per-replica batch (under vmap)
        replica = "pod"
    else:
        batch_axes = ("pod", "data") if multi_pod else ("data",)
        replica = None
    return MeshPolicy(mesh=mesh, batch_axes=batch_axes, model_axis="model",
                      replica_axis=replica,
                      fsdp_axis="data" if fsdp else None,
                      seq_axis="data" if seq_sharded else None)


def batch_shardings(specs, policy: MeshPolicy, *, n_replicas: int = 0):
    """n_replicas > 0: add the leading DASO replica dim (sharded over pod)."""
    def one(leaf):
        lead = ("replica", "batch") if n_replicas else ("batch",)
        spec = lead + (None,) * (leaf.ndim - len(lead))
        return policy.sharding(*spec)

    out = {}
    for k, v in specs.items():
        if n_replicas:
            v = S((n_replicas, v.shape[0] // n_replicas) + v.shape[1:],
                  v.dtype)
        out[k] = (v, one(v))
    return ({k: v for k, (v, _) in out.items()},
            {k: s for k, (_, s) in out.items()})


def cache_shardings(cache, cfg: ArchConfig, policy: MeshPolicy,
                    global_batch: int):
    """PartitionSpecs for the decode cache.

    Batch shards over (pod)x(data) when divisible; the KV-cache *sequence*
    dim additionally shards over "model" (split-KV decode — GSPMD inserts the
    partial-softmax reduction). For global_batch==1 (long_500k) the seq dim
    takes every mesh axis instead. State caches (mamba/rglru) shard their
    channel dim over "model"."""
    mesh = policy.mesh
    b_axes = policy.resolve("batch")
    b_axes_t = b_axes if isinstance(b_axes, tuple) else (b_axes,)
    b_shards = 1
    for a in b_axes_t:
        b_shards *= mesh.shape[a]
    batch_ok = global_batch % b_shards == 0

    b_spec = b_axes if batch_ok else None
    seq_spec = ("model",) if batch_ok else tuple(mesh.axis_names)

    def one(path, leaf):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = str(p.key)
                break
        nd = leaf.ndim
        model_ax = policy.model_axis
        if name in ("k", "v"):        # (B, S, K, hd)
            spec = (b_spec, seq_spec, None, None)
        elif name == "h" and cfg.ssm is not None:   # mamba: (B, Di, N)
            spec = (b_spec, model_ax, None)
        elif name == "h":                            # rglru: (B, W)
            spec = (b_spec, model_ax)
        elif name == "conv":          # (B, kc-1, C)
            spec = (b_spec, None, model_ax)
        else:
            spec = (None,) * nd
        # stacked group caches carry a leading repeat dim
        spec = (None,) * (nd - len(spec)) + spec
        assert len(spec) == nd, (name, spec, leaf.shape)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache)


def make_param_shardings(cfg: ArchConfig, params, policy: MeshPolicy,
                         *, replicated: bool = False):
    moe_mode = cfg.moe.sharding if cfg.moe is not None else "expert"
    return param_shardings(params, policy, moe_sharding=moe_mode,
                           replicated=replicated)
