from repro.serve.engine import Engine, make_decode_fn, make_prefill_fn  # noqa: F401
