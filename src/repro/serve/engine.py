"""Serving engine: batched prefill + one-token decode over the unified LM.

Decode shapes in the assignment (decode_32k, long_500k) lower
`make_decode_fn`'s serve_step — one new token against a populated cache.
Window caches (SWA / local attention / dense long-context override) are ring
buffers; SSM / RG-LRU layers carry recurrent state instead of KV.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.lm import forward, init_cache


def _decode_positions(cfg: ArchConfig, batch: int, pos):
    p = jnp.full((batch, 1), pos, jnp.int32)
    if cfg.rope_type == "mrope":
        return jnp.tile(p[..., None], (1, 1, 3))
    return p


def make_prefill_fn(cfg: ArchConfig, *, cache_len: int,
                    window_override: int = 0, q_chunk: int = 1024,
                    mamba_chunk: int = 64):
    """prefill(params, tokens, prefix_embeds=None, positions=None)
    -> {"logits_last" (B,V), "cache"}. Cache is sized for `cache_len` total
    positions (the prompt occupies the first S slots)."""
    def prefill(params, tokens, prefix_embeds=None, positions=None):
        B = tokens.shape[0]
        cache = init_cache(cfg, B, cache_len, dtype=cfg.cdtype(),
                           window_override=window_override)
        out = forward(params, tokens, cfg, prefix_embeds=prefix_embeds,
                      positions=positions, cache=cache,
                      window_override=window_override, q_chunk=q_chunk,
                      mamba_chunk=mamba_chunk)
        return {"logits_last": out["logits"][:, -1], "cache": out["cache"]}

    return prefill


def make_decode_fn(cfg: ArchConfig, *, window_override: int = 0):
    """serve_step(params, cache, token (B,1), pos scalar) ->
    {"logits" (B,V), "cache"} — exactly one new token."""
    def serve_step(params, cache, token, pos):
        B = token.shape[0]
        out = forward(params, token, cfg,
                      positions=_decode_positions(cfg, B, pos),
                      cache=cache, pos=pos,
                      window_override=window_override)
        return {"logits": out["logits"][:, -1], "cache": out["cache"]}

    return serve_step


@dataclass
class Engine:
    """Minimal batched generation engine (greedy / temperature sampling)."""
    cfg: ArchConfig
    params: object
    max_len: int = 256
    window_override: int = 0

    def __post_init__(self):
        self._prefill = jax.jit(make_prefill_fn(
            self.cfg, cache_len=self.max_len,
            window_override=self.window_override))
        self._decode = jax.jit(make_decode_fn(
            self.cfg, window_override=self.window_override))

    def generate(self, prompts: jnp.ndarray, max_new_tokens: int,
                 *, temperature: float = 0.0,
                 key: Optional[jax.Array] = None,
                 prefix_embeds=None):
        """prompts (B, S_prompt) int32 -> (B, max_new_tokens) int32."""
        B, S = prompts.shape
        state = self._prefill(self.params, prompts,
                              prefix_embeds=prefix_embeds)
        cache, logits = state["cache"], state["logits_last"]
        prefix = 0 if prefix_embeds is None else prefix_embeds.shape[1]
        pos = S + prefix  # next absolute position
        outs = []
        for t in range(max_new_tokens):
            if temperature > 0.0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            nxt = nxt.astype(jnp.int32)[:, None]
            outs.append(nxt)
            if t == max_new_tokens - 1:
                break
            step_out = self._decode(self.params, cache, nxt,
                                    jnp.asarray(pos, jnp.int32))
            logits, cache = step_out["logits"], step_out["cache"]
            pos += 1
        return jnp.concatenate(outs, axis=1)
