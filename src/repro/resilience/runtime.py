"""Live fault-tolerance runtime: the health plane of a supervised
multi-process run.

PR 3 made crash recovery a *simulation* — fault plans replayed in-process,
membership masks flipped by a supervisor that never loses a real process.
This module is the piece that turns those semantics into a guarantee on the
real `jax.distributed` runtime (launch/distributed.py): every worker of a
supervised group runs a `HealthMonitor`, and `tools/launch_procs.py`'s
supervisor mode reads what it writes. Three mechanisms:

  * **heartbeats** — a daemon thread writes ``hb_{epoch}_{proc}.json`` into
    the shared run directory every `hb_interval` seconds: proc id, epoch,
    the last completed training step, and a phase tag ("init" → "train" →
    "done"). The launcher uses them to trigger `--kill proc:step` at a
    precise training step and to time detection/recovery.
  * **collective watchdog** — the same thread bounds *progress*: the
    training loop must complete a cycle (or announce a phase change) every
    `watchdog_s` seconds, else the process writes a status marker and
    hard-exits with `EXIT_PEER_LOST`. A dead peer leaves survivors blocked
    inside a gloo collective with no Python control flow; a watchdog
    *around* each blocking region is the only way out. In practice the JAX
    coordination service aborts the stuck group earlier (~10 s missed
    heartbeats); the watchdog is the backstop that bounds detection even
    when that service is itself wedged. One progress rule covers every
    blocking region — cycle dispatch, checkpoint gathers, init collectives
    — because they all sit between progress events.
  * **regroup protocol** — on a detected death the launcher tears the
    epoch down and relaunches survivors under a fresh coordinator epoch
    (new port, `DASO_EPOCH` += 1) with a ``regroup.json`` naming the dead
    replicas. The new epoch resumes from the newest *intact* TrainState
    (checkpoint/io.py's crash-safe loaders) and replays the death as a PR-3
    membership-mask crash event at the resume step — which is exactly why
    the regrouped run is bit-exact with the simulated fault-plan oracle
    for the same crash (tests/test_live_faults.py).

Workers keep spanning the FULL topology world after a regroup (fewer
processes, more local devices each): the mesh, the compiled programs, and
the masked-ghost numerics are identical to the pre-crash run by the PR-5
SPMD contract, so nothing about the reduced process count can perturb the
oracle equivalence.
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

# exit code a worker uses when ITS watchdog detects lost progress (a dead
# peer wedging a collective). Distinct from crash codes so the launcher can
# tell "I detected a peer loss" from "I am the root failure".
EXIT_PEER_LOST = 75

ENV_RUN_DIR = "DASO_RUN_DIR"
ENV_EPOCH = "DASO_EPOCH"
ENV_WATCHDOG_S = "DASO_WATCHDOG_S"
ENV_HB_INTERVAL = "DASO_HB_INTERVAL"
ENV_REGROUP_FILE = "DASO_REGROUP_FILE"

DEFAULT_WATCHDOG_S = 300.0   # must exceed the worst single blocking region
DEFAULT_HB_INTERVAL = 0.25   # (first-cycle XLA compile included)


def heartbeat_path(run_dir: str, epoch: int, proc_id: int) -> str:
    return os.path.join(run_dir, f"hb_{epoch}_{proc_id}.json")


def status_path(run_dir: str, epoch: int, proc_id: int) -> str:
    return os.path.join(run_dir, f"status_{epoch}_{proc_id}.json")


@dataclass(frozen=True)
class HealthConfig:
    """Supervision parameters, exported by the launcher's supervisor mode
    (`tools/launch_procs.py --kill/--supervise`) through the environment.
    `from_env` returns None in unsupervised runs — the health plane costs
    nothing unless a supervisor asked for it."""
    run_dir: str
    epoch: int = 0
    watchdog_s: float = DEFAULT_WATCHDOG_S
    hb_interval: float = DEFAULT_HB_INTERVAL
    regroup_file: Optional[str] = None

    @classmethod
    def from_env(cls) -> Optional["HealthConfig"]:
        run_dir = os.environ.get(ENV_RUN_DIR)
        if not run_dir:
            return None
        return cls(run_dir=run_dir,
                   epoch=int(os.environ.get(ENV_EPOCH, "0")),
                   watchdog_s=float(os.environ.get(
                       ENV_WATCHDOG_S, str(DEFAULT_WATCHDOG_S))),
                   hb_interval=float(os.environ.get(
                       ENV_HB_INTERVAL, str(DEFAULT_HB_INTERVAL))),
                   regroup_file=os.environ.get(ENV_REGROUP_FILE) or None)


class HealthMonitor:
    """Per-worker heartbeat writer + progress watchdog (one daemon thread).

    The training loop reports progress via `phase(name)` and
    `cycle_done(step)` (the executor calls the latter after every compiled
    cycle — core/executor.py::dispatch_planned_cycle). Each report pushes
    the watchdog deadline out by `watchdog_s`; if the deadline passes the
    thread writes a status marker and `os._exit(EXIT_PEER_LOST)` — an
    ordinary exception could never unwind a thread that is parked inside a
    gloo collective."""

    def __init__(self, cfg: HealthConfig, proc_id: int, *, tracer=None):
        self.cfg = cfg
        self.proc_id = proc_id
        # obs.trace sink: phase flips become "phase" instants in the run
        # trace, so launcher-observed detection timings line up with the
        # worker's own record (None = untraced, zero cost)
        self.tracer = tracer
        self._lock = threading.Lock()
        self._phase = "start"
        self._step = -1
        self._deadline = time.monotonic() + cfg.watchdog_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- progress reports (called from the training thread) ---------------
    def phase(self, name: str) -> None:
        with self._lock:
            self._phase = name
            self._deadline = time.monotonic() + self.cfg.watchdog_s
        self._write()  # phase flips are rare and the launcher times them
        if self.tracer is not None:
            self.tracer.instant("phase", cat="resilience", phase=name,
                                epoch=self.cfg.epoch)

    def cycle_done(self, step: int) -> None:
        with self._lock:
            self._step = step
            self._deadline = time.monotonic() + self.cfg.watchdog_s

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "HealthMonitor":
        os.makedirs(self.cfg.run_dir, exist_ok=True)
        self._write()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="daso-health")
        self._thread.start()
        return self

    def close(self) -> None:
        """Normal shutdown: disarm the watchdog, write a final beat."""
        self._stop.set()
        with self._lock:
            self._phase = "done"
        self._write()
        if self.tracer is not None:
            self.tracer.instant("phase", cat="resilience", phase="done",
                                epoch=self.cfg.epoch)
        if self._thread is not None:
            self._thread.join(timeout=2 * self.cfg.hb_interval + 1)

    # -- internals ---------------------------------------------------------
    def _write(self) -> None:
        with self._lock:
            doc = {"proc": self.proc_id, "epoch": self.cfg.epoch,
                   "phase": self._phase, "step": self._step,
                   "t": time.time()}
        path = heartbeat_path(self.cfg.run_dir, self.cfg.epoch,
                              self.proc_id)
        tmp = f"{path}.tmp"
        try:
            with open(tmp, "w") as f:
                json.dump(doc, f)
            os.replace(tmp, path)  # readers never see a torn beat
        except OSError:
            pass  # a missed beat is survivable; a crashed writer is not

    def _run(self) -> None:
        while not self._stop.wait(self.cfg.hb_interval):
            self._write()
            with self._lock:
                expired = time.monotonic() > self._deadline
                phase, step = self._phase, self._step
            if expired:
                try:
                    with open(status_path(self.cfg.run_dir, self.cfg.epoch,
                                          self.proc_id), "w") as f:
                        json.dump({"proc": self.proc_id,
                                   "reason": "watchdog",
                                   "phase": phase, "step": step,
                                   "watchdog_s": self.cfg.watchdog_s,
                                   "t": time.time()}, f)
                except OSError:
                    pass
                os._exit(EXIT_PEER_LOST)


def read_heartbeat(run_dir: str, epoch: int, proc_id: int) -> Optional[dict]:
    """Launcher-side: latest beat of one worker, None before its first."""
    try:
        with open(heartbeat_path(run_dir, epoch, proc_id)) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def snapshot_heartbeats(run_dir: str, epoch: int, n_procs: int) -> dict:
    """One fleet-wide heartbeat snapshot: proc id -> latest beat document
    (processes that have not beaten yet are omitted)."""
    out = {}
    for p in range(n_procs):
        doc = read_heartbeat(run_dir, epoch, p)
        if doc is not None:
            out[p] = doc
    return out


def heartbeat_skew(before: dict, after: dict, *,
                   min_dt_s: float = 0.0) -> dict:
    """Per-process relative slowdown from two heartbeat snapshots
    (`snapshot_heartbeats` taken a probe interval apart): each process's
    step-progress rate between the snapshots, normalized so the fastest
    process reads 1.0 — a process advancing at half the fastest rate reads
    2.0, the same unit as the fault plan's straggle factors. Processes
    without usable progress in both snapshots are omitted.

    This is the live-runtime skew source for the straggler-aware group
    reshuffle: the launcher maps process ids to the replicas they own and
    hands the slowdown vector to `repro.topo.probe.skew_permutation`
    (simulated runs use the fault plan's injected slowdowns directly —
    resilience/supervisor.py)."""
    rates = {}
    for p, b in before.items():
        a = after.get(p)
        if a is None:
            continue
        dt = float(a["t"]) - float(b["t"])
        ds = int(a["step"]) - int(b["step"])
        if dt <= min_dt_s or ds <= 0:
            continue
        rates[p] = ds / dt
    if not rates:
        return {}
    fastest = max(rates.values())
    return {p: fastest / r for p, r in rates.items()}


#: heartbeat wire format: required key -> type check. This IS the schema —
#: the launcher's kill/supervise triggers key off `phase`/`step`, and the
#: trace streams are written next to these files, so the two planes share
#: one compatibility stance: required keys are stable, extra keys are
#: always tolerated (tests/test_obs.py round-trips both directions).
HEARTBEAT_SCHEMA = {
    "proc": lambda v: isinstance(v, int) and v >= 0,
    "epoch": lambda v: isinstance(v, int) and v >= 0,
    "phase": lambda v: isinstance(v, str) and bool(v),
    "step": lambda v: isinstance(v, int),
    "t": lambda v: isinstance(v, (int, float)) and v >= 0,
}


def validate_heartbeat(doc) -> Optional[str]:
    """Schema check for one heartbeat document; error string or None.
    Unknown keys pass — forward compatibility is part of the contract."""
    if not isinstance(doc, dict):
        return f"heartbeat is {type(doc).__name__}, not an object"
    for key, ok in HEARTBEAT_SCHEMA.items():
        if key not in doc:
            return f"missing required key {key!r}"
        if not ok(doc[key]):
            return f"bad value for {key!r}: {doc[key]!r}"
    return None


# -- regroup protocol ---------------------------------------------------------

@dataclass(frozen=True)
class RegroupPlan:
    """What the launcher tells a regrouped epoch: which replicas died
    (root-cause processes' subtrees — collateral aborts keep their state),
    and whether the restarted ranks should rejoin (elastic mode). The
    crash/rejoin *step* is deliberately absent: it is defined as the resume
    step of the newest intact TrainState, which only the workers can
    determine (the supervisor cannot know which snapshot survived the
    crash intact)."""
    epoch: int
    dead_replicas: tuple
    rejoin: bool = False

    def to_json(self) -> str:
        return json.dumps({"epoch": self.epoch,
                           "dead_replicas": list(self.dead_replicas),
                           "rejoin": self.rejoin}, indent=1)


def save_regroup(path: str, plan: RegroupPlan) -> None:
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        f.write(plan.to_json())
    os.replace(tmp, path)


def load_regroup(path: str) -> RegroupPlan:
    with open(path) as f:
        doc = json.load(f)
    return RegroupPlan(epoch=int(doc["epoch"]),
                       dead_replicas=tuple(int(r)
                                           for r in doc["dead_replicas"]),
                       rejoin=bool(doc.get("rejoin", False)))


def regroup_fault_events(resume_step: int,
                         membership: Optional[Sequence[float]],
                         dead_replicas: Sequence[int], *,
                         rejoin: bool = False) -> List:
    """Translate a RegroupPlan into PR-3 fault events at the resume step.

    A crash is replayed only for replicas still ACTIVE in the resumed
    membership — a checkpoint taken after an earlier regroup already has
    the victim masked out, and re-crashing a dead replica is (rightly)
    rejected by FaultPlan.validate. With `rejoin`, every dead replica also
    rejoins at the same step: FaultPlan orders crash before rejoin at equal
    steps, so the restarted rank is re-seeded from the survivors' mean
    (resilience/membership.py) exactly as a simulated rejoin would be."""
    from repro.resilience.faults import FaultEvent

    events: List[FaultEvent] = []
    for r in dead_replicas:
        active = membership is None or membership[r] > 0.0
        if active:
            events.append(FaultEvent(step=resume_step, kind="crash",
                                     replica=int(r)))
        if rejoin:
            events.append(FaultEvent(step=resume_step, kind="rejoin",
                                     replica=int(r)))
    return events
