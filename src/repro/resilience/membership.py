"""Elastic-membership carry surgery: re-seeding rejoined replicas.

The exchange-side membership math (masked arena reduction, frozen ghost
rows, dynamic-P Eq. (1)) lives in core/daso.py + core/flatbuf.py so it
compiles into the step variants. What lives here is the host-side piece: a
replica that rejoins after a crash has a stale (frozen) row and must be
re-seeded from the survivors' merged state before it re-enters the active
set — the DASO analogue of an elastic-Horovod worker bootstrapping from the
current consensus parameters.
"""
from __future__ import annotations

from typing import Iterable, Tuple

import jax
import jax.numpy as jnp

from repro.core import flatbuf


def donor_mean_rows(tree, donor_mask: Tuple[float, ...]):
    """Membership-weighted mean over the donor rows of every leaf, shape
    (1, ...) per leaf — the consensus state a joiner bootstraps from.
    Floating leaves average in their own dtype; integer leaves round."""
    mask = flatbuf.normalize_membership(donor_mask, len(donor_mask))

    def leaf(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return flatbuf.masked_axis0_mean(x, mask)
        m = flatbuf.masked_axis0_mean(x.astype(jnp.float32), mask)
        return jnp.round(m).astype(x.dtype)

    return jax.tree.map(leaf, tree)


def reseed_carry(carry, donor_mask: Tuple[float, ...],
                 joining: Iterable[int]):
    """Overwrite the rows of `joining` replicas in every carry leaf with
    the donors' membership-weighted mean. Applied to the whole strategy
    carry — params, optimizer state (a rejoined node has no momentum
    history; the donors' mean is the least-surprising bootstrap), and the
    in-flight exchange buffer — so the joiner is indistinguishable from a
    replica that just received a blocking sync."""
    joining = sorted(set(joining))
    if not joining:
        return carry
    n = len(donor_mask)
    for j in joining:
        if not 0 <= j < n:
            raise ValueError(f"joining replica {j} outside 0..{n - 1}")
        if donor_mask[j]:
            raise ValueError(f"replica {j} is both donor and joiner")
    sel = jnp.asarray([i in joining for i in range(n)])
    means = donor_mean_rows(carry, donor_mask)

    def leaf(x, m):
        col = sel.reshape((n,) + (1,) * (x.ndim - 1))
        return jnp.where(col, jnp.broadcast_to(m, x.shape), x)

    return jax.tree.map(leaf, carry, means)
