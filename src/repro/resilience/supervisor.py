"""Resilience supervisor: drives a fault plan end-to-end.

Wraps the compiled macro-cycle executor loop (core/executor.py) with the
three resilience pillars:

  * **elastic membership** — at a crash/rejoin boundary the supervisor
    updates the strategy's static membership mask
    (`DasoStrategy.set_membership`), invalidates the executor's compiled
    cycle cache (the old programs bake the old exchange weights), and on
    rejoin re-seeds the joiner's carry rows from the survivors' merged
    state (resilience/membership.py);
  * **deterministic fault injection** — cycle plans are cut at fault-plan
    boundaries, so every event lands between compiled cycles exactly where
    the plan says, and the controller is notified
    (`notify_membership_change` / `notify_dcn_scale`) so the B/W schedule
    adapts;
  * **full-state checkpointing** — optional periodic TrainState saves, same
    contract as train/loop.py, so a faulty run is also resumable.

Besides the training result the supervisor reports per-event recovery cost
(host handling time + the first post-event cycle, which carries the
recompile) and a simulated wall-clock that charges compute at each step's
worst active straggler and exchanges at the degraded DCN rate — the numbers
`benchmarks/resilience.py` turns into BENCH_resilience.json.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.executor import (MacroCycleExecutor, Strategy,
                                 dispatch_planned_cycle, resolve_executor)
from repro.core.schedule import Mode, split_mode, split_ov
from repro.core.simulator import SimResult
from repro.resilience.faults import FaultPlan
from repro.resilience.membership import reseed_carry
from repro.topo import probe as probe_mod

# outermost-level actions that touch the cross-pod network (charged an
# exchange on the simulated clock; hierarchical mode tokens are split to
# their outer action first — intermediate-level syncs ride faster links and
# are not charged at the DCN rate)
_SYNC_MODES = (Mode.SEND, Mode.SEND_RECEIVE, Mode.BLOCKING, Mode.HARD_AVG,
               Mode.GOSSIP, Mode.ELASTIC, Mode.PUSH)


@dataclass
class ResilienceReport:
    result: SimResult
    applied: List[Dict] = field(default_factory=list)  # per-event records
    invalidations: int = 0
    simulated_time_s: float = 0.0
    membership_timeline: List = field(default_factory=list)  # (step, mask)
    # autotune plane (run_with_faults autotune_every > 0): one record per
    # probe round that changed the schedule, count of group reshuffles,
    # and the accumulated straggler wait an inner-group barrier wasted on
    # the simulated clock (repro.topo.probe.wasted_wait_s)
    retunes: List[Dict] = field(default_factory=list)
    reshuffles: int = 0
    wasted_wait_s: float = 0.0

    def recovery_s(self) -> List[float]:
        """Per membership event: host handling + first post-event cycle
        (the recompile)."""
        return [e["handle_s"] + e["first_cycle_s"] for e in self.applied
                if e["kind"] in ("crash", "rejoin")]


def run_with_faults(strategy: Strategy, params0, data_fn: Callable,
                    lr_fn: Callable, n_steps: int, plan: FaultPlan, *,
                    executor: Optional[MacroCycleExecutor] = None,
                    t_compute_s: float = 0.0,
                    exchange_cost_fn: Optional[Callable] = None,
                    topo=None,
                    ckpt_every: int = 0,
                    ckpt_cb: Optional[Callable] = None,
                    placement=None,
                    start_step: int = 0, carry=None,
                    membership=None,
                    health=None, tracer=None,
                    autotune_every: int = 0,
                    oracle_notify: Optional[bool] = None,
                    reshuffle: bool = True) -> ResilienceReport:
    """Run `n_steps` of compiled training while replaying `plan`.

    `strategy` must be a replica-axis strategy (daso / hier_daso /
    local_sgd); its controller receives the notify_* adaptation hooks.
    `t_compute_s` and `exchange_cost_fn(n_active, dcn_scale) -> seconds`
    feed the simulated clock (both optional — zero cost models 'numerics
    only'). `topo` (a `repro.topo.TopologySpec`) resolves plans whose
    events name topology nodes ("pod1", "pod1/host0") into the per-replica
    events of those subtrees; without it such plans are rejected by
    `validate`. `ckpt_every`/`ckpt_cb` follow the
    executor.run_compiled_training contract.

    `placement` (launch.distributed.MeshPlacement) replays the same plan
    over the multi-process mesh: every process applies the identical
    membership flips and cache invalidations (the plan is deterministic),
    a lost process's replicas are exactly a membership-mask event on its
    subtree, and rejoin re-seeding runs on the gathered host carry so the
    re-placed rows are identical on every process.

    Resume surface (mirrors executor.run_compiled_training, used by the
    live regroup path): `start_step` + restored `carry` + the checkpoint's
    `membership` mask continue an interrupted fault run — the strategy's
    controller must already be restored by the caller. Events scheduled
    before `start_step` are rejected: anything already in the past is
    either reflected in the checkpoint's membership or meaningless to
    replay. `health` (resilience.runtime.HealthMonitor) arms the progress
    watchdog around every dispatched cycle.

    **Self-tuning** (`autotune_every` = K > 0, docs/tuning.md): every K
    cycles the supervisor probes one exchange at the current network state
    (`exchange_cost_fn(n_active, dcn_scale)` — charged to the simulated
    clock: probing is not free), compares it against the nominal cost
    (`dcn_scale == 1`), and feeds the result through
    `controller.retune(...)`; a schedule change invalidates the executor's
    compiled cycles, exactly the membership machinery. With `reshuffle`
    on, the same probe round sorts the per-replica slowdowns into a
    `repro.topo.probe.skew_permutation` regrouping and applies it via
    `strategy.set_group_permutation`. `oracle_notify` controls whether the
    degrade_dcn/restore_dcn fault events tell the controller directly (the
    pre-autotune oracle behavior); it defaults to True only when autotune
    is off — a self-tuning run must *discover* the degradation by probing,
    and a static-baseline run (`oracle_notify=False`, autotune off) never
    learns of it at all (the honest comparison BENCH_tuning.json gates)."""
    cfg = strategy.cfg
    if cfg is None:
        raise ValueError("run_with_faults needs a replica-axis strategy "
                         "with a DasoConfig (daso / hier_daso / local_sgd / "
                         "gossip / easgd / downpour)")
    n_replicas = cfg.n_replicas
    if topo is None:
        topo = getattr(strategy, "topo", None)
    if topo is not None:
        plan = plan.resolve(topo)
    mask = (list(membership) if membership is not None
            else [1.0] * n_replicas)
    past = [e for e in plan.events if e.step < start_step]
    if past:
        raise ValueError(
            f"fault plan has {len(past)} event(s) before resume step "
            f"{start_step} (first: {past[0]}); a resumed run replays only "
            "future events — the past is already in the checkpoint")
    plan.validate(n_replicas, alive0=[m > 0.0 for m in mask])

    ex, placement = resolve_executor(strategy, executor, placement)
    if health is not None and ex.health is None:
        ex.health = health
    if tracer is not None and not ex.tracer.enabled:
        ex.tracer = tracer
    if (strategy.controller is not None and ex.tracer.enabled
            and getattr(strategy.controller, "tracer", None) is None):
        # schedule decisions (plateau, dcn, retune) land in the same trace
        strategy.controller.tracer = ex.tracer
    if membership is not None and any(m <= 0.0 for m in mask):
        # the checkpoint was taken under a reduced active set: rebuild the
        # step variants with its mask baked in before anything compiles
        strategy.set_membership(mask)
    carry = strategy.init_carry(params0) if carry is None else carry
    if placement is not None:
        carry = placement.put_carry(carry)
    slowdowns = [1.0] * n_replicas
    dcn_scale = 1.0
    if oracle_notify is None:
        oracle_notify = autotune_every <= 0
    # probe pricing: the exchange cost model doubles as the probe's
    # measurement (one timed exchange at the live network state); without
    # a cost model the probe still observes the *normalized* cost 1/scale
    # vs nominal 1 — same inferred scale, zero simulated price
    probe_cost = (exchange_cost_fn if exchange_cost_fn is not None
                  else (lambda n, s: 1.0 / max(s, 1e-9)))
    # innermost non-degenerate inner-group size, for the wasted-wait
    # accounting of the inner barrier (no inner levels -> the only barrier
    # is the global one and reshuffling has nothing to recover)
    inner_group = n_replicas
    if topo is not None:
        sizes = [topo.group_size(lvl.name) for lvl in topo.levels[1:-1]
                 if topo.group_size(lvl.name) > 1]
        if sizes:
            inner_group = min(sizes)

    report = ResilienceReport(result=None)
    report.membership_timeline.append((start_step, tuple(mask)))
    losses: List[float] = []
    metrics_log: List[Dict[str, float]] = []
    sim_time = 0.0
    pending_first_cycle: List[Dict] = []  # events awaiting recompile timing
    next_ckpt = ((start_step // ckpt_every + 1) * ckpt_every
                 if ckpt_every else None)

    def apply_event(ev, step):
        nonlocal carry, dcn_scale
        t0 = time.perf_counter()
        rec = {"step": step, "kind": ev.kind, "replica": ev.replica,
               "factor": ev.factor, "first_cycle_s": 0.0}
        if ev.kind == "crash":
            mask[ev.replica] = 0.0
            strategy.set_membership(mask)
            ex.invalidate()
            if strategy.controller is not None:
                strategy.controller.notify_membership_change(
                    step, int(sum(mask)))
            report.membership_timeline.append((step, tuple(mask)))
            pending_first_cycle.append(rec)
        elif ev.kind == "rejoin":
            # re-seed BEFORE flipping the mask: donors are the survivors.
            # Distributed: surgery on the gathered host carry, re-placed —
            # identical bytes on every process by construction.
            if placement is not None:
                carry = placement.put_carry(
                    reseed_carry(placement.fetch(carry), tuple(mask),
                                 [ev.replica]))
            else:
                carry = reseed_carry(carry, tuple(mask), [ev.replica])
            mask[ev.replica] = 1.0
            strategy.set_membership(mask)
            ex.invalidate()
            if strategy.controller is not None:
                strategy.controller.notify_membership_change(
                    step, int(sum(mask)))
            report.membership_timeline.append((step, tuple(mask)))
            pending_first_cycle.append(rec)
        elif ev.kind == "straggle":
            slowdowns[ev.replica] = ev.factor
        elif ev.kind == "recover":
            slowdowns[ev.replica] = 1.0
        elif ev.kind == "degrade_dcn":
            dcn_scale = ev.factor
            if oracle_notify and strategy.controller is not None:
                strategy.controller.notify_dcn_scale(ev.factor, step=step)
        elif ev.kind == "restore_dcn":
            dcn_scale = 1.0
            if oracle_notify and strategy.controller is not None:
                strategy.controller.notify_dcn_scale(1.0, step=step)
        rec["handle_s"] = time.perf_counter() - t0
        report.applied.append(rec)

    def autotune(step, cycle_idx):
        """One probe round: measure the exchange at the live network state,
        retune the controller against the nominal cost, reshuffle groups by
        straggler skew. Returns the probe's simulated price."""
        nonlocal sim_time
        ctl = strategy.controller
        if ctl is None or not hasattr(ctl, "retune"):
            return
        n_active = int(sum(1 for m in mask if m > 0.0))
        measured = probe_cost(n_active, dcn_scale)
        nominal = probe_cost(n_active, 1.0)
        if exchange_cost_fn is not None:
            sim_time += measured  # the probe's own exchange is not free
        with ex.tracer.span("autotune_probe", cat="resilience", step=step,
                            cycle=cycle_idx, measured_s=measured,
                            nominal_s=nominal):
            changed = ctl.retune({"_outer": measured},
                                 annotated={"_outer": nominal}, step=step)
            reshuffled = False
            if reshuffle and hasattr(strategy, "set_group_permutation") \
                    and inner_group < n_replicas:
                perm = probe_mod.skew_permutation(slowdowns)
                if perm != strategy.group_perm:
                    strategy.set_group_permutation(perm)
                    reshuffled = True
                    report.reshuffles += 1
        if changed or reshuffled:
            ex.invalidate()
            report.retunes.append(
                {"step": step, "cycle": cycle_idx, "measured_s": measured,
                 "nominal_s": nominal, "schedule_changed": bool(changed),
                 "reshuffled": reshuffled})

    step = start_step
    cycle_idx = 0
    while step < n_steps:
        for ev in plan.events_at(step):
            # the span covers membership surgery + cache invalidation; the
            # recompile it provokes lands in the NEXT cycle span (its
            # fresh_compile flag — same attribution as first_cycle_s)
            with ex.tracer.span("fault_event", cat="resilience",
                                kind=ev.kind, step=step,
                                replica=ev.replica, factor=ev.factor):
                apply_event(ev, step)
        if autotune_every > 0 and cycle_idx % autotune_every == 0:
            autotune(step, cycle_idx)
        # cut the cycle at the next fault boundary: events must land
        # between compiled cycles, mirroring the plateau-window cut
        max_len = min(ex.max_cycle_len, n_steps - step)
        boundary = plan.next_boundary_after(step)
        if boundary is not None:
            max_len = min(max_len, boundary - step)
        cycle_plan = strategy.plan_cycle(step, max_len)
        t0 = time.perf_counter()
        carry, cycle_losses, per_step_metrics = dispatch_planned_cycle(
            ex, carry, cycle_plan, data_fn, lr_fn, n_steps)
        cycle_s = time.perf_counter() - t0
        for rec in pending_first_cycle:
            rec["first_cycle_s"] = cycle_s
        pending_first_cycle.clear()
        # simulated clock: compute gated on the slowest ACTIVE replica,
        # sync steps charged one exchange at the degraded DCN rate
        worst = max((s for s, m in zip(slowdowns, mask) if m), default=1.0)
        sim_time += len(cycle_plan) * t_compute_s * worst
        if exchange_cost_fn is not None:
            n_active = int(sum(mask))
            for mode, _ in cycle_plan.shape:
                if split_ov(split_mode(mode)[0])[0] in _SYNC_MODES:
                    sim_time += exchange_cost_fn(n_active, dcn_scale)
        # straggler wait the inner-group barrier wastes under the current
        # grouping (the reshuffle's target metric — the makespan above is
        # gated by the global worst either way)
        report.wasted_wait_s += len(cycle_plan) * probe_mod.wasted_wait_s(
            slowdowns, mask, inner_group,
            getattr(strategy, "group_perm", None), t_compute_s)
        losses.extend(cycle_losses)
        metrics_log.extend(per_step_metrics)
        strategy.observe(cycle_losses)
        step += len(cycle_plan)
        cycle_idx += 1
        if next_ckpt is not None and ckpt_cb is not None and step >= next_ckpt:
            with ex.tracer.span("checkpoint_save", cat="checkpoint",
                                step=step):
                ckpt_cb(step, carry, losses)
            next_ckpt = (step // ckpt_every + 1) * ckpt_every

    final = (placement.finalize_params(strategy, carry)
             if placement is not None else strategy.finalize_params(carry))
    report.result = SimResult(losses=losses, metrics=metrics_log,
                              params=final,
                              sync_fraction=strategy.sync_fraction(),
                              controller=strategy.controller,
                              executor_stats=ex.stats)
    report.invalidations = ex.stats.invalidations
    report.simulated_time_s = sim_time
    return report
