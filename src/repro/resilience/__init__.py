"""Resilience subsystem: elastic replica membership, deterministic fault
injection (replica- or topology-node-addressed), full-state resume, and the
live health/regroup plane for real process death (runtime.py).
See docs/architecture.md §Resilience / §Live fault tolerance and
docs/topologies.md §Faults."""
from repro.resilience.faults import FaultEvent, FaultPlan, KINDS  # noqa: F401
from repro.resilience.membership import (donor_mean_rows,  # noqa: F401
                                         reseed_carry)
from repro.resilience.runtime import (EXIT_PEER_LOST,  # noqa: F401
                                      HealthConfig, HealthMonitor,
                                      RegroupPlan, load_regroup,
                                      regroup_fault_events, save_regroup)
from repro.resilience.supervisor import (ResilienceReport,  # noqa: F401
                                         run_with_faults)
