"""Resilience subsystem: elastic replica membership, deterministic fault
injection (replica- or topology-node-addressed), and full-state resume.
See docs/architecture.md §Resilience and docs/topologies.md §Faults."""
from repro.resilience.faults import FaultEvent, FaultPlan, KINDS  # noqa: F401
from repro.resilience.membership import (donor_mean_rows,  # noqa: F401
                                         reseed_carry)
from repro.resilience.supervisor import (ResilienceReport,  # noqa: F401
                                         run_with_faults)
