"""Declarative, deterministic fault plans.

A `FaultPlan` is a step-indexed list of `FaultEvent`s — the failure script a
resilience run replays. Determinism is the point: the same plan against the
same seed produces the same training trajectory, so fault-injection runs are
testable and benchmarkable like any other experiment (the "chaos testing as
a first-class scenario" the Hitchhiker's-guide line of work argues for).

Event kinds (all applied host-side, *before* the step they are indexed at):

  crash        replica `replica` drops out of the active set. Its row in
               the SPMD emulation is frozen; exchanges become
               membership-weighted over the survivors (core/daso.py).
  rejoin       replica `replica` comes back. Its row is re-seeded from the
               survivors' membership-weighted mean (params, optimizer
               state, in-flight buffer) before it re-enters the active set.
  straggle     replica `replica` slows down by `factor`× (>= 1). Numerics
               are unaffected (DASO already absorbs slow nodes via the
               staleness weighting); the supervisor charges the slowdown to
               the simulated clock.
  recover      replica `replica` returns to nominal speed.
  degrade_dcn  the outermost-level (cross-pod) network drops to `factor`×
               nominal bandwidth (0 < factor <= 1). The controller
               stretches B in response (schedule.py::notify_dcn_scale) and
               the simulated clock charges exchanges at the degraded rate.
  restore_dcn  DCN bandwidth back to nominal.

Replica-addressed kinds may name a *topology node* instead of a replica
index (`node` instead of `replica`): a "/"-joined path like ``"pod1"`` or
``"pod1/host0"`` into an N-level `repro.topo.TopologySpec`. The event then
covers every replica in that subtree — crashing a pod takes all of its
hosts down in one scripted event. Node events are symbolic until
`FaultPlan.resolve(spec)` expands them against a concrete topology
(``launch/train.py --topology --fault-plan`` does this automatically);
`validate` rejects unresolved plans.

JSON wire format (FaultPlan.from_json / to_json):

    {"events": [{"step": 10, "kind": "crash", "replica": 3},
                {"step": 30, "kind": "rejoin", "replica": 3},
                {"step": 40, "kind": "straggle", "node": "pod1",
                 "factor": 2.0},
                {"step": 12, "kind": "degrade_dcn", "factor": 0.25}]}
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional, Tuple

KINDS = ("crash", "rejoin", "straggle", "recover",
         "degrade_dcn", "restore_dcn")
_REPLICA_KINDS = ("crash", "rejoin", "straggle", "recover")


@dataclass(frozen=True)
class FaultEvent:
    step: int
    kind: str
    replica: Optional[int] = None
    # topology-node path ("pod1", "pod1/host0", ...) — the symbolic
    # alternative to `replica`; expanded by FaultPlan.resolve(spec)
    node: Optional[str] = None
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0, got {self.step}")
        if self.kind in _REPLICA_KINDS and \
                (self.replica is None) == (self.node is None):
            raise ValueError(f"{self.kind!r} event needs exactly one of a "
                             "replica index or a topology node path")
        if self.kind not in _REPLICA_KINDS and self.node is not None:
            raise ValueError(f"{self.kind!r} event does not address a "
                             "node (it is cluster-wide)")
        if self.kind == "straggle" and self.factor < 1.0:
            raise ValueError(f"straggle factor is a slowdown multiplier "
                             f">= 1, got {self.factor}")
        if self.kind == "degrade_dcn" and not 0.0 < self.factor <= 1.0:
            raise ValueError(f"degrade_dcn factor is a bandwidth fraction "
                             f"in (0, 1], got {self.factor}")


@dataclass(frozen=True)
class FaultPlan:
    events: Tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events,
                         key=lambda e: (e.step, e.kind,
                                        -1 if e.replica is None
                                        else e.replica, e.node or ""))))

    # -- construction / serialization --------------------------------------
    @classmethod
    def from_dicts(cls, dicts: List[Dict]) -> "FaultPlan":
        return cls(tuple(FaultEvent(**d) for d in dicts))

    @classmethod
    def from_json(cls, path_or_text: str) -> "FaultPlan":
        """Load from a JSON file path, or from a JSON string."""
        if os.path.exists(path_or_text):
            with open(path_or_text) as f:
                doc = json.load(f)
        else:
            doc = json.loads(path_or_text)
        return cls.from_dicts(doc["events"])

    def to_json(self) -> str:
        return json.dumps({"events": [
            {k: v for k, v in asdict(e).items() if v is not None}
            for e in self.events]}, indent=1)

    def resolve(self, spec) -> "FaultPlan":
        """Expand topology-node events against a concrete
        `repro.topo.TopologySpec`: each node-addressed event becomes one
        per-replica event per replica in the node's subtree (same step /
        kind / factor). Replica-addressed events pass through; the result
        is fully concrete and `validate`-able. Crashing a node that
        contains an already-crashed replica is rejected by `validate`,
        exactly as the equivalent scripted per-replica crashes would
        be."""
        out: List[FaultEvent] = []
        for e in self.events:
            if e.node is None:
                out.append(e)
                continue
            for r in spec.replicas_of(e.node):
                out.append(FaultEvent(step=e.step, kind=e.kind, replica=r,
                                      factor=e.factor))
        return FaultPlan(tuple(out))

    # -- queries ------------------------------------------------------------
    def boundaries(self) -> List[int]:
        """Sorted unique steps with at least one event — a macro-cycle plan
        must never span one (the supervisor cuts cycles here, the
        'replanning on membership change' contract)."""
        return sorted({e.step for e in self.events})

    def events_at(self, step: int) -> List[FaultEvent]:
        return [e for e in self.events if e.step == step]

    def next_boundary_after(self, step: int) -> Optional[int]:
        later = [b for b in self.boundaries() if b > step]
        return min(later) if later else None

    def membership_at(self, step: int, n_replicas: int) -> Tuple[float, ...]:
        """Active mask in force while `step` runs (events at step k apply
        before step k)."""
        mask = [1.0] * n_replicas
        for e in self.events:
            if e.step > step:
                break
            if e.kind == "crash":
                mask[e.replica] = 0.0
            elif e.kind == "rejoin":
                mask[e.replica] = 1.0
        return tuple(mask)

    def dcn_scale_at(self, step: int) -> float:
        scale = 1.0
        for e in self.events:
            if e.step > step:
                break
            if e.kind == "degrade_dcn":
                scale = e.factor
            elif e.kind == "restore_dcn":
                scale = 1.0
        return scale

    def slowdowns_at(self, step: int, n_replicas: int) -> Tuple[float, ...]:
        slow = [1.0] * n_replicas
        for e in self.events:
            if e.step > step:
                break
            if e.kind == "straggle":
                slow[e.replica] = e.factor
            elif e.kind == "recover":
                slow[e.replica] = 1.0
        return tuple(slow)

    # -- validation ----------------------------------------------------------
    def validate(self, n_replicas: int,
                 alive0: Optional[List[bool]] = None) -> None:
        """Replay the plan symbolically and reject incoherent scripts:
        out-of-range replicas, crashing a dead replica, rejoining a live
        one, or leaving zero survivors at any point. `alive0` overrides
        the all-alive starting membership — a plan replayed from a resumed
        checkpoint (live regroup) starts from the membership the snapshot
        recorded, not from a fresh cluster."""
        alive = ([bool(a) for a in alive0] if alive0 is not None
                 else [True] * n_replicas)
        if len(alive) != n_replicas:
            raise ValueError(f"alive0 has {len(alive)} entries for "
                             f"{n_replicas} replicas")
        for e in self.events:
            if e.node is not None:
                raise ValueError(
                    f"event {e} addresses topology node {e.node!r}; call "
                    "plan.resolve(topology_spec) before validate/replay")
            if e.replica is not None and not 0 <= e.replica < n_replicas:
                raise ValueError(f"event {e} addresses replica "
                                 f"{e.replica} outside 0..{n_replicas - 1}")
            if e.kind == "crash":
                if not alive[e.replica]:
                    raise ValueError(f"step {e.step}: crash of replica "
                                     f"{e.replica}, already down")
                alive[e.replica] = False
                if not any(alive):
                    raise ValueError(f"step {e.step}: plan leaves no "
                                     "active replicas")
            elif e.kind == "rejoin":
                if alive[e.replica]:
                    raise ValueError(f"step {e.step}: rejoin of replica "
                                     f"{e.replica}, already active")
                alive[e.replica] = True
