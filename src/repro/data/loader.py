"""Sharded data loader: places host batches onto the mesh with the right
sharding (batch over ("pod","data")), optionally adding the DASO replica
leading dim. Single-host in this container; the device_put path is the same
one a multi-host launcher would use per-process."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding import MeshPolicy


class ShardedLoader:
    def __init__(self, source, batch_size: int, policy: Optional[MeshPolicy]
                 = None, n_replicas: int = 1):
        """source: object with .batch(batch_size, step) -> dict of arrays.
        n_replicas > 1 reshapes batch to (R, B/R, ...) for DASO."""
        self.source = source
        self.batch_size = batch_size
        self.policy = policy
        self.n_replicas = n_replicas

    def __call__(self, step: int):
        batch = self.source.batch(self.batch_size, step)
        if self.n_replicas > 1:
            R = self.n_replicas
            batch = {k: v.reshape((R, v.shape[0] // R) + v.shape[1:])
                     for k, v in batch.items()}
        if self.policy is not None:
            def put(x):
                spec = (("replica", "batch") if self.n_replicas > 1
                        else ("batch",))
                spec = spec + (None,) * (x.ndim - len(spec))
                return jax.device_put(x, self.policy.sharding(*spec))
            batch = {k: put(v) for k, v in batch.items()}
        return batch
