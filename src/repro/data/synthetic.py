"""Deterministic synthetic datasets (offline container — no ImageNet/CityScapes).

SyntheticLM emits token streams with learnable structure (Zipf unigram prior +
first-order Markov chains + induction-head copy patterns) so cross-entropy
meaningfully decreases during training; SyntheticImages emits class-dependent
Gaussian-blob images for the ResNet experiments. Both are seeded and
reproducible across hosts/processes.

make_noniid_class_partition breaks the paper's iid assumption on purpose (each
virtual node sees a skewed class marginal) for the §Ablations experiment.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    seed: int = 0
    n_states: int = 64          # Markov states
    copy_prob: float = 0.25     # induction pattern density

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V, M = self.vocab_size, min(self.n_states, self.vocab_size)
        # sparse-ish Markov transition over M frequent tokens
        trans = rng.dirichlet(np.full(M, 0.3), size=M).astype(np.float32)
        self._trans_cum = np.cumsum(trans, axis=1)
        # Zipf tail over the rest of the vocab
        ranks = np.arange(1, V + 1)
        zipf = 1.0 / ranks ** 1.2
        self._zipf_cum = np.cumsum(zipf / zipf.sum()).astype(np.float64)
        self._M = M

    def batch(self, batch_size: int, step: int):
        """Returns dict(tokens (B,S) int32, labels (B,S) int32). labels are
        next-token targets (shifted), last position ignored (-1)."""
        rng = np.random.default_rng((self.seed, step))
        B, S, M = batch_size, self.seq_len, self._M
        toks = np.empty((B, S + 1), np.int64)
        state = rng.integers(0, M, size=B)
        toks[:, 0] = state
        u = rng.random((B, S))
        mix = rng.random((B, S))
        zipf_draw = np.searchsorted(self._zipf_cum, rng.random((B, S)))
        for t in range(1, S + 1):
            nxt = np.array([np.searchsorted(self._trans_cum[s], x)
                            for s, x in zip(state, u[:, t - 1])])
            nxt = np.minimum(nxt, M - 1)
            # occasionally jump to a zipf token (keeps full vocab in play)
            jump = mix[:, t - 1] < 0.15
            nxt = np.where(jump, zipf_draw[:, t - 1], nxt)
            # induction: with copy_prob, repeat the token seen 8 steps ago
            if t > 8:
                copy = mix[:, t - 1] > 1.0 - self.copy_prob
                nxt = np.where(copy, toks[:, t - 8], nxt)
            state = np.minimum(nxt, M - 1)
            toks[:, t] = nxt
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}


@dataclass
class SyntheticImages:
    n_classes: int
    image_size: int = 32
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # class prototypes: smooth random fields
        base = rng.normal(size=(self.n_classes, self.image_size,
                                self.image_size, 3)).astype(np.float32)
        k = np.ones((5, 5)) / 25.0
        for c in range(self.n_classes):
            for ch in range(3):
                base[c, :, :, ch] = _conv2d_same(base[c, :, :, ch], k)
        self._protos = base * 3.0

    def batch(self, batch_size: int, step: int, class_weights=None):
        rng = np.random.default_rng((self.seed, step))
        if class_weights is None:
            labels = rng.integers(0, self.n_classes, size=batch_size)
        else:
            labels = rng.choice(self.n_classes, size=batch_size,
                                p=class_weights)
        noise = rng.normal(size=(batch_size, self.image_size,
                                 self.image_size, 3)).astype(np.float32)
        imgs = self._protos[labels] + noise
        return {"images": jnp.asarray(imgs),
                "labels": jnp.asarray(labels.astype(np.int32))}


def _conv2d_same(x, k):
    from numpy.lib.stride_tricks import sliding_window_view
    ph, pw = k.shape[0] // 2, k.shape[1] // 2
    xp = np.pad(x, ((ph, ph), (pw, pw)), mode="reflect")
    win = sliding_window_view(xp, k.shape)
    return np.einsum("ijkl,kl->ij", win, k)


def make_noniid_class_partition(n_classes: int, n_nodes: int,
                                alpha: float = 0.3, seed: int = 0):
    """Dirichlet class-skew per node (breaks iid): returns (n_nodes, n_classes)
    class weight rows."""
    rng = np.random.default_rng(seed)
    w = rng.dirichlet(np.full(n_classes, alpha), size=n_nodes)
    return w.astype(np.float64) / w.sum(axis=1, keepdims=True)
