from repro.data.synthetic import (  # noqa: F401
    SyntheticImages,
    SyntheticLM,
    make_noniid_class_partition,
)
from repro.data.loader import ShardedLoader  # noqa: F401
