"""End-to-end training driver: strategy selection via the registry
(sync / daso / local_sgd), LR scheduling, metrics, and full-state
checkpointing (`ckpt_every`/`ckpt_dir` save a resumable
`checkpoint.io.TrainState` — carry, controller schedule state, membership,
loss trace; `resume_from` continues a run with numerics identical to an
uninterrupted one, tests/test_resilience.py). Used by launch/train.py, the
examples, and the convergence benchmarks.

Two execution paths, numerically equivalent (allclose at f32):

  * ``executor="macro"`` (default) — the compiled macro-cycle path
    (core/executor.py): one buffer-donating XLA dispatch per controller
    cycle instead of one per step. Checkpoints land on cycle boundaries.
  * ``executor="per_step"`` — the reference path (core/simulator.py): one
    dispatch per step, useful for debugging and as the equivalence oracle.
    Checkpoints land on exact `ckpt_every` multiples.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Callable, Optional

from repro.checkpoint.io import (TrainState, load_train_state,
                                 save_train_state)
from repro.core.daso import DasoConfig
from repro.core.executor import (MacroCycleExecutor, get_strategy,
                                 list_strategies, make_strategy,
                                 run_compiled_training)
from repro.core.simulator import SimResult, run_per_step_training
from repro.optim.optimizers import Optimizer, sgd
from repro.optim.schedules import constant_lr


@dataclass
class TrainLoopConfig:
    strategy: str = "daso"            # registered name: daso|hier_daso|sync|...
    n_steps: int = 200
    n_replicas: int = 4               # paper "nodes"
    local_world: int = 4              # paper GPUs-per-node (data-axis size)
    b_max: int = 4
    # explicit N-level cluster topology (repro/topo): a spec string
    # ("chip:4 x host:2 x pod:2"), inline JSON, or a JSON file path. When
    # set it *supersedes* n_replicas/local_world (derived from the level
    # fanouts) and selects the per-level sync schedule: 2-level specs
    # lower to the stock daso strategy (bit-exact with the legacy path),
    # deeper specs to hier_daso. Only meaningful for the daso family.
    topology: Optional[str] = None
    warmup_frac: float = 0.1          # paper: warm-up epochs -> step fraction
    cooldown_frac: float = 0.1
    lr: float = 0.05
    loss_window: int = 20
    log_every: int = 50
    executor: str = "macro"           # macro | per_step
    max_cycle_len: int = 32           # cap on compiled macro-cycle length
    # fused flat-buffer exchange knobs (core/flatbuf.py): wire_format None
    # derives bf16/f32 from the DasoConfig compress_* flags; "f32" | "bf16"
    # | "int8" forces one tier. exchange_impl "per_leaf" selects the legacy
    # one-collective-per-leaf reference path.
    wire_format: Optional[str] = None
    exchange_impl: str = "fused"
    # double-buffered compute/communication overlap (core/daso.py
    # OVERLAP_MODES): "off" = the blocking schedule, bit-exact with
    # pre-overlap runs; "one_cycle" = each global exchange runs on the
    # previous sync's snapshot, hidden behind the next B local steps and
    # merged one cycle stale (Eq. (1) with the snapshot's true age as S).
    # Only meaningful for the daso family.
    overlap: str = "off"
    # debug/benchmark knob: execute overlap cycles with the exchange
    # blocked BEFORE compute (same numerics, no hiding) — the baseline leg
    # of benchmarks/overlap.py's hidden-fraction measurement
    overlap_serial_exchange: bool = False
    # full-state checkpointing: every `ckpt_every` steps (0 = off) a
    # TrainState lands in `ckpt_dir/step_XXXXXXXX/`; `resume_from` points at
    # one such directory to continue the run deterministically.
    ckpt_every: int = 0
    ckpt_dir: Optional[str] = None
    resume_from: Optional[str] = None
    # multi-process runtime (launch/distributed.py): run over the global
    # topology mesh — jax.distributed must already be initialized (the
    # launcher entry point does it) and `topology` must be set; replica
    # levels shard over the (process, local-device) axes, process 0 owns
    # logging and checkpoint writes. The same flag with one process is the
    # single-process SPMD oracle the N-process run is bit-exact with.
    distributed: bool = False
    # self-tuning topology (repro/topo/probe, docs/tuning.md): time one
    # real per-level sync on the live mesh at startup and retune the
    # lowered schedule against the spec's annotations before training
    # (controller.retune — measured == annotated is a strict no-op).
    # `autotune_every` is the probe cadence in cycles for the supervised
    # fault path (resilience/supervisor.py; the plain loop probes once).
    autotune: bool = False
    autotune_every: int = 8


# strategies that take a topology spec purely for sizing — replica count,
# world size, outer sync period — with no per-level sync schedule
# (core/baselines.py; a spec with intermediate levels is rejected for them)
_FLAT_TOPOLOGY_STRATEGIES = ("gossip", "easgd", "downpour")


def resolve_topology(cfg: TrainLoopConfig):
    """The `TopologySpec` of this run, or None when cfg.topology is unset.
    Validates that the strategy is topology-capable."""
    if cfg.topology is None:
        return None
    if cfg.strategy not in (("daso", "hier_daso")
                            + _FLAT_TOPOLOGY_STRATEGIES):
        raise ValueError(f"topology specs drive the replica-axis strategies "
                         f"(daso / hier_daso / gossip / easgd / downpour); "
                         f"strategy {cfg.strategy!r} does not take one")
    from repro.topo import TopologySpec
    return TopologySpec.load(cfg.topology)


def build_strategy(loss_fn: Callable, cfg: TrainLoopConfig,
                   optimizer: Optimizer):
    """Resolve cfg.strategy through the registry into a Strategy instance
    (with its DasoConfig + controller for the replica-axis strategies).
    With cfg.topology set, the instance is lowered from the spec instead
    (repro.topo.lower.build_topology_strategy): replica count and world
    size come from the level fanouts, intermediate levels get their
    per-level sync periods, and the plateau controller drives the
    outermost level."""
    import repro.topo.strategy  # noqa: F401  (registers "hier_daso")

    if cfg.strategy not in list_strategies():
        raise KeyError(f"unknown strategy {cfg.strategy!r}; "
                       f"registered: {list_strategies()}")
    if cfg.strategy == "sync":
        if cfg.topology is not None:
            resolve_topology(cfg)  # raises with the explanation
        if cfg.overlap != "off":
            raise ValueError("overlap is a daso-family schedule; the sync "
                             "baseline has no non-blocking exchange to "
                             "overlap (drop --overlap or switch strategy)")
        return make_strategy("sync", loss_fn, optimizer)
    spec = resolve_topology(cfg)
    n_replicas = spec.n_replicas if spec is not None else cfg.n_replicas
    world = spec.world if spec is not None \
        else cfg.n_replicas * cfg.local_world
    b_max = (spec.outer.period if spec is not None
             and spec.outer.period is not None else cfg.b_max)
    dcfg = DasoConfig(
        n_replicas=n_replicas,
        global_world=world,
        b_max=b_max,
        warmup_steps=int(cfg.warmup_frac * cfg.n_steps),
        cooldown_steps=int(cfg.cooldown_frac * cfg.n_steps),
        total_steps=cfg.n_steps,
        wire_format=cfg.wire_format,
        exchange_impl=cfg.exchange_impl,
        overlap=cfg.overlap,
        # distributed runs pin every cross-replica reduction to the
        # order-fixed chain formulation so the result is independent of
        # the process layout (the N-proc == 1-proc bit-exactness contract)
        deterministic_reduce=cfg.distributed)
    if spec is not None and cfg.strategy not in _FLAT_TOPOLOGY_STRATEGIES:
        from repro.topo import build_topology_strategy
        return build_topology_strategy(loss_fn, optimizer, spec, dcfg,
                                       loss_window=cfg.loss_window)
    if spec is not None and tuple(spec.inner_names()):
        raise ValueError(
            f"strategy {cfg.strategy!r} has no per-level sync schedule; "
            f"topology spec carries intermediate levels "
            f"{tuple(spec.inner_names())} — use a 2-level spec, or "
            f"daso/hier_daso for hierarchical syncing")
    if cfg.strategy == "hier_daso":
        raise ValueError("strategy 'hier_daso' needs a topology spec "
                         "(TrainLoopConfig.topology / --topology)")
    cls = get_strategy(cfg.strategy)
    controller = cls.make_controller(dcfg, loss_window=cfg.loss_window)
    return cls(loss_fn, optimizer, dcfg, controller=controller)


def ckpt_step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def run_training(loss_fn: Callable, params0, data_fn: Callable,
                 cfg: TrainLoopConfig, *, optimizer: Optional[Optimizer] = None,
                 lr_fn: Optional[Callable] = None,
                 log: Optional[Callable] = print,
                 health=None, tracer=None) -> SimResult:
    """data_fn(step) -> batch. For daso/local_sgd strategies the batch must
    carry the leading replica axis; for sync it is flat.

    On resume (`cfg.resume_from`), the returned SimResult's loss trace is
    the *full* run (checkpointed prefix + resumed segment), so downstream
    reporting (final_loss, metrics JSON) is seamless across restarts.

    `health` (resilience.runtime.HealthMonitor) threads the live-fault
    heartbeat/watchdog into the macro executor — supervised multi-process
    runs only (launch/train.py wires it from the launcher environment).

    `tracer` (obs.trace.Tracer) threads the telemetry plane through the
    macro executor (cycle/overlap/checkpoint spans) and the strategy's
    controller (decision events) — launch/train.py wires it from
    --trace-out. The per-step reference path is deliberately untraced:
    it exists as a numerics oracle, not a performance surface."""
    optimizer = optimizer or sgd(momentum=0.9, weight_decay=1e-4)
    lr_fn = lr_fn or constant_lr(cfg.lr)
    if cfg.executor not in ("macro", "per_step"):
        raise ValueError(f"unknown executor {cfg.executor!r}; "
                         "expected 'macro' or 'per_step'")
    if health is not None and cfg.executor != "macro":
        raise ValueError("live supervision (health monitor) reports "
                         "progress from the macro executor's cycle "
                         "dispatch; run supervised jobs with "
                         "--executor macro")
    strategy = build_strategy(loss_fn, cfg, optimizer)
    if tracer is not None and strategy.controller is not None:
        strategy.controller.tracer = tracer

    if cfg.autotune:
        spec = resolve_topology(cfg)
        if spec is None or strategy.controller is None:
            if log is not None:
                log("[train] autotune: no topology spec to probe; "
                    "schedule left as configured")
        elif cfg.distributed:
            # per-process wall-clock probes could disagree and desync the
            # schedule; the distributed probe channel is the supervised
            # path's deterministic cost model (launch/train.py
            # --fault-plan --autotune) or the passive tracer samples
            if log is not None:
                log("[train] autotune: startup wall-clock probe skipped "
                    "under --distributed (see docs/tuning.md)")
        else:
            from repro.topo import probe as topo_probe
            pr = topo_probe.active_probe(spec)
            changed = strategy.controller.retune(
                pr.costs, annotated=topo_probe.annotated_level_costs(
                    spec, pr.param_bytes))
            if log is not None:
                periods = getattr(strategy.controller, "inner_periods", {})
                log(f"[train] autotune probe: measured "
                    f"{ {k: round(v * 1e6, 1) for k, v in pr.costs.items()} }"
                    f" us/sync -> retuned={changed} b={strategy.controller.b}"
                    f" inner_periods={periods}")

    placement = None
    if cfg.distributed:
        from repro.launch.distributed import MeshPlacement
        spec = resolve_topology(cfg)
        if spec is None:
            raise ValueError("distributed runs derive their mesh from the "
                             "topology; set TrainLoopConfig.topology "
                             "(--topology)")
        placement = MeshPlacement(spec)
        if log is not None and not placement.is_coordinator:
            log = None  # one process speaks for the group

    start_step, carry, prior_losses = 0, None, []
    if cfg.resume_from:
        # reject carry-layout mismatches up front: a pre-overlap (v1 /
        # overlap="off") checkpoint has no pending arena to resume
        # mid-overlap from, and vice versa
        expect = cfg.overlap if cfg.strategy != "sync" else "off"
        # fallback=True: a crash mid-save (the live-fault SIGKILL case)
        # leaves the newest snapshot torn; resume from the newest intact
        # sibling instead of dying on it
        ts = load_train_state(cfg.resume_from, expect_overlap=expect,
                              fallback=True)
        if ts.strategy != cfg.strategy:
            raise ValueError(f"checkpoint was written by strategy "
                             f"{ts.strategy!r}, run requests "
                             f"{cfg.strategy!r}")
        start_step, carry = ts.step, ts.carry
        prior_losses = list(ts.losses)
        if ts.controller is not None and strategy.controller is not None:
            strategy.controller.load_state_dict(ts.controller)
        if ts.membership is not None and hasattr(strategy, "set_membership"):
            strategy.set_membership(ts.membership)
        if log is not None:
            log(f"[train] resumed from {cfg.resume_from} at step "
                f"{start_step}")

    ckpt_cb = None
    if cfg.ckpt_every and cfg.ckpt_dir:
        def ckpt_cb(step, cur_carry, seg_losses):
            # process-aware: the carry is gathered on EVERY process (the
            # gather is a collective), then only process 0 touches the
            # filesystem
            if placement is not None:
                cur_carry = placement.fetch(cur_carry)
                if not placement.is_coordinator:
                    return
            state = TrainState(
                step=step, carry=cur_carry,
                controller=(strategy.controller.state_dict()
                            if strategy.controller is not None else None),
                membership=(list(strategy.membership)
                            if getattr(strategy, "membership", None)
                            is not None else None),
                strategy=cfg.strategy,
                overlap=(cfg.overlap if cfg.strategy != "sync" else "off"),
                losses=prior_losses + seg_losses)
            save_train_state(ckpt_step_dir(cfg.ckpt_dir, step), state)

    t0 = time.time()
    if cfg.executor == "per_step":
        result = run_per_step_training(
            strategy, params0, data_fn, lr_fn, cfg.n_steps,
            start_step=start_step, carry=carry,
            ckpt_every=cfg.ckpt_every, ckpt_cb=ckpt_cb,
            placement=placement)
    else:
        executor = MacroCycleExecutor(
            strategy, max_cycle_len=cfg.max_cycle_len, placement=placement,
            serial_exchange=cfg.overlap_serial_exchange, health=health,
            tracer=tracer)
        result = run_compiled_training(
            strategy, params0, data_fn, lr_fn, cfg.n_steps,
            executor=executor, start_step=start_step, carry=carry,
            ckpt_every=cfg.ckpt_every, ckpt_cb=ckpt_cb)
    if prior_losses:
        result.losses = prior_losses + result.losses
    if log is not None:
        dt = time.time() - t0
        stats = result.executor_stats
        disp = (f" dispatches={stats.dispatches}/{cfg.n_steps}"
                if stats is not None else "")
        wire = (f" wire={cfg.wire_format or 'auto'}/{cfg.exchange_impl}"
                if cfg.strategy != "sync" else "")
        log(f"[train] strategy={cfg.strategy} steps={cfg.n_steps} "
            f"final_loss={result.final_loss:.4f} "
            f"sync_frac={result.sync_fraction:.3f} wall={dt:.1f}s"
            f"{disp}{wire}")
    return result
