"""End-to-end training driver: strategy selection (sync / daso / local_sgd),
LR scheduling, metrics, checkpointing. Used by launch/train.py, the examples,
and the convergence benchmarks."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax

from repro.core.daso import DasoConfig
from repro.core.schedule import DasoController, Mode
from repro.core.simulator import (SimResult, run_daso_training,
                                  run_sync_training)
from repro.optim.optimizers import Optimizer, sgd
from repro.optim.schedules import constant_lr


@dataclass
class TrainLoopConfig:
    strategy: str = "daso"            # daso | sync | local_sgd
    n_steps: int = 200
    n_replicas: int = 4               # paper "nodes"
    local_world: int = 4              # paper GPUs-per-node (data-axis size)
    b_max: int = 4
    warmup_frac: float = 0.1          # paper: warm-up epochs -> step fraction
    cooldown_frac: float = 0.1
    lr: float = 0.05
    loss_window: int = 20
    log_every: int = 50


def run_training(loss_fn: Callable, params0, data_fn: Callable,
                 cfg: TrainLoopConfig, *, optimizer: Optional[Optimizer] = None,
                 lr_fn: Optional[Callable] = None,
                 log: Optional[Callable] = print) -> SimResult:
    """data_fn(step) -> batch. For daso/local_sgd strategies the batch must
    carry the leading replica axis; for sync it is flat."""
    optimizer = optimizer or sgd(momentum=0.9, weight_decay=1e-4)
    lr_fn = lr_fn or constant_lr(cfg.lr)
    t0 = time.time()
    if cfg.strategy == "sync":
        result = run_sync_training(loss_fn, optimizer, params0, data_fn,
                                   lr_fn, cfg.n_steps)
    else:
        dcfg = DasoConfig(
            n_replicas=cfg.n_replicas,
            global_world=cfg.n_replicas * cfg.local_world,
            b_max=cfg.b_max,
            warmup_steps=int(cfg.warmup_frac * cfg.n_steps),
            cooldown_steps=int(cfg.cooldown_frac * cfg.n_steps),
            total_steps=cfg.n_steps)
        controller = DasoController(dcfg, loss_window=cfg.loss_window)
        local_sgd = (lambda step: Mode.HARD_AVG if step % cfg.b_max == 0
                     else Mode.LOCAL)
        result = run_daso_training(
            loss_fn, optimizer, params0, data_fn, dcfg, lr_fn, cfg.n_steps,
            controller=controller,
            mode_override=local_sgd if cfg.strategy == "local_sgd" else None)
    if log is not None:
        dt = time.time() - t0
        log(f"[train] strategy={cfg.strategy} steps={cfg.n_steps} "
            f"final_loss={result.final_loss:.4f} "
            f"sync_frac={result.sync_fraction:.3f} wall={dt:.1f}s")
    return result
