from repro.train.step import make_lm_loss, make_resnet_loss  # noqa: F401
from repro.train.loop import TrainLoopConfig, run_training  # noqa: F401
