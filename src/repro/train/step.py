"""Loss builders connecting models to the DASO / sync step machinery."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import cross_entropy_loss
from repro.models.lm import forward


def make_lm_loss(cfg: ArchConfig, *, q_chunk: int = 1024,
                 mamba_chunk: int = 64, remat: bool = False,
                 vocab_chunk: int = 0, window_override: int = 0,
                 unroll_layers: bool = False):
    """loss_fn(params, batch) -> (total_loss, aux). batch keys:
    tokens (B,S), labels (B,S) (-1 = ignore), optional prefix_embeds,
    positions."""
    def loss_fn(params, batch):
        out = forward(params, batch["tokens"], cfg,
                      prefix_embeds=batch.get("prefix_embeds"),
                      positions=batch.get("positions"),
                      q_chunk=q_chunk, mamba_chunk=mamba_chunk,
                      remat=remat, window_override=window_override,
                      unroll_layers=unroll_layers)
        ce = cross_entropy_loss(out["logits"], batch["labels"],
                                vocab_chunk=vocab_chunk)
        aux = dict(out["aux"])
        total = ce + aux["moe_lb_loss"] + aux["moe_z_loss"]
        aux["ce"] = ce
        return total, aux

    return loss_fn


def make_resnet_loss(cfg, *, mutable_state: bool = False):
    """ResNet loss. batch: images (B,H,W,3), labels (B,).

    Batch-norm note: for the convergence experiments we fold the batch-stat
    update into aux (functional); the training loop threads it back. When
    mutable_state=False the running stats in `batch["bn_state"]` are used
    read-through (simpler for vmapped DASO replicas, matching the paper's
    per-node batch norm)."""
    from repro.models.cnn import resnet_apply

    def loss_fn(params, batch):
        import jax
        logits, new_state = resnet_apply(params["net"], batch["bn_state"],
                                         batch["images"], cfg, train=True)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        loss = -jnp.take_along_axis(
            logp, labels[:, None].astype(jnp.int32), axis=-1).mean()
        acc = (logits.argmax(-1) == labels).mean()
        aux = {"acc": acc}
        if mutable_state:
            aux["bn_state"] = new_state
        return loss, aux

    return loss_fn
