"""Sharding policy: logical-axis -> mesh-axis mapping + rule-based param specs.

Model code annotates activations with *logical* axis names via `constrain`;
the active MeshPolicy (a contextvar, so smoke tests on 1 device run with no
policy and every annotation is a no-op) maps them onto physical mesh axes.

Logical axes:
  batch   -> ("pod", "data") multi-pod, ("data",) single-pod
  seq     -> usually unsharded for training; "data" for split-KV long decode
  model   -> "model" (tensor parallel: heads / ffn hidden / vocab / experts)
  replica -> "pod" (the DASO per-pod parameter replica axis)
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class MeshPolicy:
    mesh: Mesh
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: Optional[str] = "model"
    replica_axis: Optional[str] = None  # "pod" when DASO replicas are active
    seq_axis: Optional[str] = None      # set for split-KV long-context decode
    fsdp_axis: Optional[str] = None     # shard the non-TP weight dim (ZeRO-3)

    def resolve(self, logical: Optional[str]):
        if logical is None:
            return None
        if logical == "batch":
            return self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0]
        if logical == "model":
            return self.model_axis
        if logical == "replica":
            return self.replica_axis
        if logical == "seq":
            return self.seq_axis
        if logical == "fsdp":
            return self.fsdp_axis
        raise ValueError(f"unknown logical axis {logical!r}")

    def spec(self, *logical) -> P:
        return P(*[self.resolve(l) for l in logical])

    def sharding(self, *logical) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))


_POLICY: contextvars.ContextVar[Optional[MeshPolicy]] = contextvars.ContextVar(
    "mesh_policy", default=None)


def current_policy() -> Optional[MeshPolicy]:
    return _POLICY.get()


@contextlib.contextmanager
def use_policy(policy: Optional[MeshPolicy]):
    tok = _POLICY.set(policy)
    try:
        yield policy
    finally:
        _POLICY.reset(tok)


def constrain(x, *logical):
    """Annotate activation x with logical axis names (None = unsharded dim).

    No-op when no policy is active (single-device smoke tests) — and also when
    the value's rank doesn't match (lets the same model code run vmapped).
    """
    pol = current_policy()
    if pol is None:
        return x
    if len(logical) != x.ndim:
        return x
    return jax.lax.with_sharding_constraint(x, pol.sharding(*logical))


# ---------------------------------------------------------------------------
# Rule-based parameter PartitionSpecs.
#
# Rules are matched against the '/'-joined tree path of each leaf; the first
# match wins. Specs are expressed in logical axes and resolved by the policy.
# A leading "replica" axis is prepended when the params carry the DASO
# per-pod replica dimension.
# ---------------------------------------------------------------------------

# (path regex, logical spec per trailing dim).
_RULES = (
    # embeddings / unembed: vocab over model
    (r"embed/tok$",            ("model", "fsdp")),
    (r"unembed/w$",            ("fsdp", "model")),
    # attention projections: fused head dim over model
    (r"(wq|wk|wv)$",           ("fsdp", "model")),
    (r"wo$",                   ("model", "fsdp")),
    # dense / shared-expert FFN
    (r"(w1|w3)$",              ("fsdp", "model")),
    (r"w2$",                   ("model", "fsdp")),
    # MoE expert weights — handled dynamically (expert vs tensor sharding)
    (r"moe/(we1|we3)$",        "MOE_IN"),
    (r"moe/we2$",              "MOE_OUT"),
    (r"moe/router$",           (None, None)),
    # mamba
    (r"in_proj$",              ("fsdp", "model")),
    (r"out_proj$",             ("model", "fsdp")),
    (r"(x_proj|dt_proj)$",     (None, None)),
    (r"conv_w$",               ("model", None)),
    (r"(conv_b|dt_bias|A_log|Dskip)$", ("model",) ),
    # rglru
    (r"(wx|wy)$",              ("fsdp", "model")),
    (r"(w_a|w_i)$",            ("model", "fsdp")),
    (r"(a_param|b_a|b_i|conv1d_b)$", ("model",)),
    (r"conv1d_w$",             ("model", None)),
    # norms, biases, scalars: replicated
    (r".*",                    None),
)


def _leaf_spec(path: str, ndim: int, moe_sharding: str) -> Tuple:
    for pat, spec in _RULES:
        if re.search(pat, path):
            if spec == "MOE_IN":    # (E, D, F)
                spec = (("model", "fsdp", None) if moe_sharding == "expert"
                        else (None, "fsdp", "model"))
            elif spec == "MOE_OUT":  # (E, F, D)
                spec = (("model", None, "fsdp") if moe_sharding == "expert"
                        else (None, "model", "fsdp"))
            if spec is None:
                spec = (None,) * ndim
            spec = tuple(spec)
            # stacked-layer leading dims (scan over layer groups) are unsharded
            if len(spec) < ndim:
                spec = (None,) * (ndim - len(spec)) + spec
            assert len(spec) == ndim, (path, spec, ndim)
            return spec
    raise AssertionError("unreachable")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def param_specs(params, policy: MeshPolicy, *, moe_sharding: str = "expert",
                replicated: bool = False):
    """PartitionSpec pytree matching `params` (works on ShapeDtypeStructs too).

    replicated=True -> params carry a leading DASO replica dim sharded over
    the replica ("pod") axis. Dims not divisible by the resolved axis size
    fall back to replicated (e.g. granite's 49155 vocab vs 16-way model
    axis — noted in EXPERIMENTS.md §Perf).
    """
    def one(path, leaf):
        path = _path_str(path)
        ndim = len(leaf.shape)
        if replicated:
            spec = _leaf_spec(path, ndim - 1, moe_sharding)
            spec = ("replica",) + spec
        else:
            spec = _leaf_spec(path, ndim, moe_sharding)
        phys = [policy.resolve(s) for s in spec]
        phys = [a if leaf.shape[i] % _axis_size(policy.mesh, a) == 0 else None
                for i, a in enumerate(phys)]
        return P(*phys)

    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params, policy: MeshPolicy, **kw):
    specs = param_specs(params, policy, **kw)
    return jax.tree.map(lambda s: NamedSharding(policy.mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
