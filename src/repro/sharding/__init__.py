from repro.sharding.policy import (  # noqa: F401
    MeshPolicy,
    constrain,
    current_policy,
    param_specs,
    use_policy,
)
