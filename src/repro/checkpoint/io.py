"""Checkpointing: flat-key npz tensors + JSON manifest (structure, step,
dtypes). Sharding-aware: arrays are gathered to host on save and placed back
with the provided shardings on restore."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.startswith("#") for k in node):
            return [fix(node[f"#{i}"]) for i in range(len(node))]
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def save_checkpoint(path: str, tree, *, step: int = 0,
                    extra: Optional[dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    arrays, manifest = {}, {"step": step, "dtypes": {}, "extra": extra or {}}
    for k, v in flat.items():
        arr = np.asarray(jax.device_get(v))
        manifest["dtypes"][k] = str(v.dtype)
        if arr.dtype == jnp.bfloat16:
            arr = arr.astype(np.float32)  # npz-safe container
        arrays[k] = arr
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load_checkpoint(path: str, *, shardings=None):
    """Returns (tree, manifest). shardings: optional matching pytree of
    NamedShardings for distributed placement."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat = {}
    for k in data.files:
        arr = data[k]
        dt = manifest["dtypes"][k]
        flat[k] = jnp.asarray(arr, dtype=dt)
    tree = _unflatten(flat)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest
