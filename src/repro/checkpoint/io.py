"""Checkpointing: flat-key npz tensors + JSON manifest (structure, step,
dtypes). Sharding-aware: arrays are gathered to host on save and placed back
with the provided shardings on restore.

Two layers:

  * `save_checkpoint` / `load_checkpoint` — a bare pytree of arrays. The
    flatten preserves container kinds (dict / list / tuple), so a strategy
    carry round-trips with its exact treedef — which is what lets a resumed
    run hit the same compiled programs as the uninterrupted one.
  * `save_train_state` / `load_train_state` — the versioned full training
    snapshot (`TrainState`): strategy carry (params + optimizer state +
    in-flight exchange buffer), `DasoController` schedule state, RNG key,
    data cursor, elastic-membership mask, and the loss trace so far. A run
    resumed from a TrainState reproduces the uninterrupted run's losses and
    final params exactly at f32 (tests/test_resilience.py).
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flatbuf import host_fetchable

# bump when TrainState's layout changes incompatibly; loaders refuse
# newer-than-known versions instead of misreading them.
# v2: records the strategy's overlap mode ("off" | "one_cycle") — an
# overlap carry has a fourth (pending-snapshot) slot, and resuming it
# into a non-overlap run (or vice versa) would mis-thread the buffers.
TRAIN_STATE_VERSION = 2


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        # distinct markers so tuples restore as tuples (treedef-exact
        # round-trip: a carry saved as a tuple must not come back a list,
        # or the resumed run would retrace every compiled program)
        mark = "#" if isinstance(tree, list) else "!"
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{mark}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return node
        if node and all(k[:1] == "#" for k in node):
            return [fix(node[f"#{i}"]) for i in range(len(node))]
        if node and all(k[:1] == "!" for k in node):
            return tuple(fix(node[f"!{i}"]) for i in range(len(node)))
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def save_checkpoint(path: str, tree, *, step: int = 0,
                    extra: Optional[dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    arrays, manifest = {}, {"step": step, "dtypes": {}, "extra": extra or {}}
    for k, v in flat.items():
        # process-aware contract: in a multi-process run, arrays sharded
        # across processes must be gathered BEFORE the (process-0-only)
        # write — train/loop.py does this via MeshPlacement.fetch. Fail
        # with the fix spelled out rather than letting device_get throw a
        # cross-process transfer error mid-save.
        if not host_fetchable(v):
            raise ValueError(
                f"checkpoint leaf {k!r} is sharded across processes; "
                "gather it to host first (launch.distributed."
                "MeshPlacement.fetch) — only process 0 writes checkpoints")
        arr = np.asarray(jax.device_get(v))
        manifest["dtypes"][k] = str(jnp.asarray(v).dtype)
        if arr.dtype == jnp.bfloat16:
            arr = arr.astype(np.float32)  # npz-safe container (exact widen)
        arrays[k] = arr
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load_checkpoint(path: str, *, shardings=None):
    """Returns (tree, manifest). shardings: optional matching pytree of
    NamedShardings for distributed placement."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat = {}
    for k in data.files:
        arr = data[k]
        dt = manifest["dtypes"][k]
        flat[k] = jnp.asarray(arr, dtype=dt)
    tree = _unflatten(flat)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest


# -- full-state training snapshots ---------------------------------------------

@dataclass
class TrainState:
    """Everything needed to resume training deterministically.

    `carry` is the strategy's carry pytree exactly as threaded through the
    executor — for DASO that is (params_R, opt_state_R, inflight_R), so the
    in-flight exchange snapshot survives a crash mid-cycle-sequence.
    `controller` is `DasoController.state_dict()` (None for the sync
    strategy). `membership` is the elastic active-replica mask in force
    when the snapshot was taken. `step` doubles as the data cursor: the
    synthetic sources are seeded per (seed, step), so resuming draws
    `data_fn(step)` onward with no separate stream state. `rng` is for
    callers that thread an explicit PRNGKey through training (the built-in
    loop derives everything from step + seed and stores None)."""
    step: int
    carry: Any
    controller: Optional[Dict[str, Any]] = None
    membership: Optional[List[float]] = None
    rng: Optional[Any] = None          # PRNGKey data (array) or None
    strategy: str = "daso"
    losses: List[float] = field(default_factory=list)
    extra: Dict[str, Any] = field(default_factory=dict)
    # DasoConfig.overlap in force when the snapshot was taken: "off" ->
    # 3-slot carry, "one_cycle" -> 4-slot (… + pending snapshot arena)
    overlap: str = "off"
    version: int = TRAIN_STATE_VERSION


def save_train_state(path: str, state: TrainState) -> None:
    """Write a TrainState: arrays (carry, rng) into the npz layer, host
    scheduling state into the manifest."""
    arrays = {"carry": state.carry}
    if state.rng is not None:
        arrays["rng"] = state.rng
    host = {"version": state.version, "step": state.step,
            "controller": state.controller,
            "membership": state.membership,
            "strategy": state.strategy,
            "overlap": state.overlap,
            "losses": [float(x) for x in state.losses],
            "extra": state.extra}
    save_checkpoint(path, arrays, step=state.step,
                    extra={"train_state": host})


def load_train_state(path: str, *, carry_shardings=None,
                     expect_overlap: Optional[str] = None) -> TrainState:
    """Read a TrainState back. `carry_shardings`: optional pytree of
    NamedShardings matching the carry, for distributed placement. Raises on
    a checkpoint written by a newer TrainState version, or on a plain
    parameter checkpoint (use `load_checkpoint` for those).

    `expect_overlap`: the overlap mode the resuming run will use; pass it
    to reject a carry whose buffer layout cannot be resumed into that run
    (a v1 / overlap="off" single-arena checkpoint has no pending snapshot
    to resume mid-overlap from, and an overlap checkpoint's fourth slot
    would silently mis-thread into a 3-slot run)."""
    tree, manifest = load_checkpoint(path)
    host = manifest.get("extra", {}).get("train_state")
    if host is None:
        raise ValueError(f"{path} is not a TrainState checkpoint "
                         "(no train_state manifest entry); use "
                         "load_checkpoint for bare parameter snapshots")
    if host["version"] > TRAIN_STATE_VERSION:
        raise ValueError(f"TrainState version {host['version']} is newer "
                         f"than supported {TRAIN_STATE_VERSION}")
    # pre-overlap (v1) checkpoints carry no overlap field: they are
    # single-arena snapshots, i.e. overlap "off"
    ck_overlap = host.get("overlap", "off")
    if expect_overlap is not None and ck_overlap != expect_overlap:
        raise ValueError(
            f"checkpoint {path} was written with overlap={ck_overlap!r} "
            f"(TrainState v{host['version']}) but this run uses "
            f"overlap={expect_overlap!r}; the carry layouts differ "
            f"({'3-slot, no pending arena' if ck_overlap == 'off' else '4-slot with pending arena'}). "
            f"Restart with --overlap {ck_overlap}, or train from scratch.")
    carry = tree["carry"]
    if carry_shardings is not None:
        carry = jax.tree.map(lambda x, s: jax.device_put(x, s),
                             carry, carry_shardings)
    return TrainState(step=int(host["step"]), carry=carry,
                      controller=host.get("controller"),
                      membership=host.get("membership"),
                      rng=tree.get("rng"),
                      strategy=host.get("strategy", "daso"),
                      losses=[float(x) for x in host.get("losses", [])],
                      extra=host.get("extra", {}),
                      overlap=ck_overlap,
                      version=int(host["version"]))
