"""Checkpointing: flat-key npz tensors + JSON manifest (structure, step,
dtypes). Sharding-aware: arrays are gathered to host on save and placed back
with the provided shardings on restore.

Two layers:

  * `save_checkpoint` / `load_checkpoint` — a bare pytree of arrays. The
    flatten preserves container kinds (dict / list / tuple), so a strategy
    carry round-trips with its exact treedef — which is what lets a resumed
    run hit the same compiled programs as the uninterrupted one.
  * `save_train_state` / `load_train_state` — the versioned full training
    snapshot (`TrainState`): strategy carry (params + optimizer state +
    in-flight exchange buffer), `DasoController` schedule state, RNG key,
    data cursor, elastic-membership mask, and the loss trace so far. A run
    resumed from a TrainState reproduces the uninterrupted run's losses and
    final params exactly at f32 (tests/test_resilience.py).

Writes are crash-safe: each file lands via tmp-file + fsync + atomic
rename, and the arrays/manifest pair shares a save token so a process
SIGKILLed between the two renames leaves a checkpoint that is *detected* as
torn (`CheckpointCorruptError`) rather than silently mixed. Loaders can
fall back to the newest intact `step_XXXXXXXX/` sibling
(`load_train_state(..., fallback=True)` / `load_latest_train_state`) — the
contract the live fault-tolerance plane (resilience/runtime.py) resumes
through after killing a real process mid-save.
"""
from __future__ import annotations

import json
import os
import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flatbuf import host_fetchable

# bump when TrainState's layout changes incompatibly; loaders refuse
# newer-than-known versions instead of misreading them.
# v2: records the strategy's overlap mode ("off" | "one_cycle") — an
# overlap carry has a fourth (pending-snapshot) slot, and resuming it
# into a non-overlap run (or vice versa) would mis-thread the buffers.
# v3: the controller dict carries the EFFECTIVE per-level periods
# (HierDasoController.state_dict "inner_periods") — online retuning
# (topo/probe) makes them mutable state, and a run checkpointed
# mid-retune must resume with the tuned schedule, not re-lower the
# spec's static annotations. A v2 checkpoint lacks the key and loads
# as static (the periods the controller was built with stand).
TRAIN_STATE_VERSION = 3


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        # distinct markers so tuples restore as tuples (treedef-exact
        # round-trip: a carry saved as a tuple must not come back a list,
        # or the resumed run would retrace every compiled program)
        mark = "#" if isinstance(tree, list) else "!"
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{mark}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return node
        if node and all(k[:1] == "#" for k in node):
            return [fix(node[f"#{i}"]) for i in range(len(node))]
        if node and all(k[:1] == "!" for k in node):
            return tuple(fix(node[f"!{i}"]) for i in range(len(node)))
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


class CheckpointCorruptError(ValueError):
    """A checkpoint directory is unreadable: missing/truncated files, an
    unparseable manifest, or an arrays/manifest pair from two different
    saves (a crash landed between the two atomic renames)."""


def _atomic_write(path: str, write_fn) -> None:
    """Crash-safe single-file write: tmp sibling + fsync + atomic rename.
    A SIGKILL at any point leaves either the old complete file or the new
    complete file at `path`, never a truncated one."""
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        write_fn(f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    # fsync the directory so the rename itself survives a host crash
    dfd = os.open(os.path.dirname(os.path.abspath(path)) or ".", os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


def save_checkpoint(path: str, tree, *, step: int = 0,
                    extra: Optional[dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    # arrays and manifest are renamed-in independently; the shared token
    # (stored in BOTH files) is what lets the loader detect a torn pair
    save_id = f"{step}-{os.getpid()}-{os.urandom(4).hex()}"
    arrays = {"__save_id__": np.frombuffer(save_id.encode(), np.uint8)}
    manifest = {"step": step, "dtypes": {}, "extra": extra or {},
                "save_id": save_id}
    for k, v in flat.items():
        # process-aware contract: in a multi-process run, arrays sharded
        # across processes must be gathered BEFORE the (process-0-only)
        # write — train/loop.py does this via MeshPlacement.fetch. Fail
        # with the fix spelled out rather than letting device_get throw a
        # cross-process transfer error mid-save.
        if not host_fetchable(v):
            raise ValueError(
                f"checkpoint leaf {k!r} is sharded across processes; "
                "gather it to host first (launch.distributed."
                "MeshPlacement.fetch) — only process 0 writes checkpoints")
        arr = np.asarray(jax.device_get(v))
        manifest["dtypes"][k] = str(jnp.asarray(v).dtype)
        if arr.dtype == jnp.bfloat16:
            arr = arr.astype(np.float32)  # npz-safe container (exact widen)
        arrays[k] = arr
    _atomic_write(os.path.join(path, "arrays.npz"),
                  lambda f: np.savez(f, **arrays))
    _atomic_write(os.path.join(path, "manifest.json"),
                  lambda f: f.write(json.dumps(manifest, indent=1)
                                    .encode()))


def load_checkpoint(path: str, *, shardings=None):
    """Returns (tree, manifest). shardings: optional matching pytree of
    NamedShardings for distributed placement. Raises
    `CheckpointCorruptError` on a missing/truncated/torn checkpoint (a
    crash mid-save) so callers can fall back to an older snapshot."""
    man_path = os.path.join(path, "manifest.json")
    npz_path = os.path.join(path, "arrays.npz")
    try:
        with open(man_path) as f:
            manifest = json.load(f)
    except FileNotFoundError:
        raise CheckpointCorruptError(f"{path}: no manifest.json "
                                     "(incomplete checkpoint)")
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointCorruptError(f"{path}: manifest.json is truncated "
                                     f"or corrupt ({e})")
    try:
        data = np.load(npz_path)
        files = list(data.files)
    except FileNotFoundError:
        raise CheckpointCorruptError(f"{path}: no arrays.npz "
                                     "(incomplete checkpoint)")
    except Exception as e:  # zipfile.BadZipFile, truncated streams, ...
        raise CheckpointCorruptError(f"{path}: arrays.npz is unreadable "
                                     f"({e})")
    man_id = manifest.get("save_id")
    if man_id is not None:
        if "__save_id__" not in files:
            raise CheckpointCorruptError(
                f"{path}: manifest carries save_id {man_id!r} but "
                "arrays.npz has no token — torn write (arrays from an "
                "older save)")
        npz_id = bytes(data["__save_id__"]).decode()
        if npz_id != man_id:
            raise CheckpointCorruptError(
                f"{path}: arrays save_id {npz_id!r} != manifest save_id "
                f"{man_id!r} — a crash landed between the two renames")
    flat = {}
    try:
        for k in files:
            if k == "__save_id__":
                continue
            arr = data[k]
            dt = manifest["dtypes"][k]
            flat[k] = jnp.asarray(arr, dtype=dt)
    except KeyError as e:
        raise CheckpointCorruptError(f"{path}: arrays/manifest key "
                                     f"mismatch ({e})")
    except Exception as e:  # truncated member streams surface on read
        raise CheckpointCorruptError(f"{path}: arrays.npz member "
                                     f"unreadable ({e})")
    if set(manifest["dtypes"]) - set(flat):
        missing = sorted(set(manifest["dtypes"]) - set(flat))
        raise CheckpointCorruptError(f"{path}: arrays.npz is missing "
                                     f"manifest keys {missing[:4]}...")
    tree = _unflatten(flat)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest


# -- full-state training snapshots ---------------------------------------------

@dataclass
class TrainState:
    """Everything needed to resume training deterministically.

    `carry` is the strategy's carry pytree exactly as threaded through the
    executor — for DASO that is (params_R, opt_state_R, inflight_R), so the
    in-flight exchange snapshot survives a crash mid-cycle-sequence.
    `controller` is `DasoController.state_dict()` (None for the sync
    strategy). `membership` is the elastic active-replica mask in force
    when the snapshot was taken. `step` doubles as the data cursor: the
    synthetic sources are seeded per (seed, step), so resuming draws
    `data_fn(step)` onward with no separate stream state. `rng` is for
    callers that thread an explicit PRNGKey through training (the built-in
    loop derives everything from step + seed and stores None)."""
    step: int
    carry: Any
    controller: Optional[Dict[str, Any]] = None
    membership: Optional[List[float]] = None
    rng: Optional[Any] = None          # PRNGKey data (array) or None
    strategy: str = "daso"
    losses: List[float] = field(default_factory=list)
    extra: Dict[str, Any] = field(default_factory=dict)
    # DasoConfig.overlap in force when the snapshot was taken: "off" ->
    # 3-slot carry, "one_cycle" -> 4-slot (… + pending snapshot arena)
    overlap: str = "off"
    version: int = TRAIN_STATE_VERSION


def save_train_state(path: str, state: TrainState) -> None:
    """Write a TrainState: arrays (carry, rng) into the npz layer, host
    scheduling state into the manifest."""
    arrays = {"carry": state.carry}
    if state.rng is not None:
        arrays["rng"] = state.rng
    host = {"version": state.version, "step": state.step,
            "controller": state.controller,
            "membership": state.membership,
            "strategy": state.strategy,
            "overlap": state.overlap,
            "losses": [float(x) for x in state.losses],
            "extra": state.extra}
    save_checkpoint(path, arrays, step=state.step,
                    extra={"train_state": host})


_STEP_DIR = re.compile(r"^step_(\d{8})$")


def list_train_state_dirs(ckpt_dir: str) -> List[str]:
    """`step_XXXXXXXX/` snapshot directories under `ckpt_dir`, NEWEST
    first (by step number — the order the corruption fallback probes)."""
    try:
        names = os.listdir(ckpt_dir)
    except FileNotFoundError:
        return []
    steps = sorted((m.group(1) for m in map(_STEP_DIR.match, names) if m),
                   reverse=True)
    return [os.path.join(ckpt_dir, f"step_{s}") for s in steps]


def load_train_state(path: str, *, carry_shardings=None,
                     expect_overlap: Optional[str] = None,
                     fallback: bool = False) -> TrainState:
    """Read a TrainState back. `carry_shardings`: optional pytree of
    NamedShardings matching the carry, for distributed placement. Raises on
    a checkpoint written by a newer TrainState version, or on a plain
    parameter checkpoint (use `load_checkpoint` for those).

    `expect_overlap`: the overlap mode the resuming run will use; pass it
    to reject a carry whose buffer layout cannot be resumed into that run
    (a v1 / overlap="off" single-arena checkpoint has no pending snapshot
    to resume mid-overlap from, and an overlap checkpoint's fourth slot
    would silently mis-thread into a 3-slot run).

    `fallback`: when `path` turns out truncated/torn (a crash mid-save),
    walk its `step_XXXXXXXX/` siblings newest-first and resume from the
    newest intact one instead of crashing — the post-SIGKILL recovery
    contract. The substituted path is reported via a warning print; an
    older-but-valid state only costs recomputing the lost steps."""
    if fallback:
        try:
            return load_train_state(path, carry_shardings=carry_shardings,
                                    expect_overlap=expect_overlap)
        except CheckpointCorruptError as e:
            for cand in list_train_state_dirs(os.path.dirname(
                    os.path.abspath(path))):
                if os.path.abspath(cand) == os.path.abspath(path):
                    continue
                try:
                    st = load_train_state(cand,
                                          carry_shardings=carry_shardings,
                                          expect_overlap=expect_overlap)
                except CheckpointCorruptError:
                    continue
                print(f"[checkpoint] {path} is corrupt ({e}); falling "
                      f"back to newest intact snapshot {cand} "
                      f"(step {st.step})")
                return st
            raise
    tree, manifest = load_checkpoint(path)
    host = manifest.get("extra", {}).get("train_state")
    if host is None:
        raise ValueError(f"{path} is not a TrainState checkpoint "
                         "(no train_state manifest entry); use "
                         "load_checkpoint for bare parameter snapshots")
    if host["version"] > TRAIN_STATE_VERSION:
        raise ValueError(f"TrainState version {host['version']} is newer "
                         f"than supported {TRAIN_STATE_VERSION}")
    # pre-overlap (v1) checkpoints carry no overlap field: they are
    # single-arena snapshots, i.e. overlap "off"
    ck_overlap = host.get("overlap", "off")
    if expect_overlap is not None and ck_overlap != expect_overlap:
        raise ValueError(
            f"checkpoint {path} was written with overlap={ck_overlap!r} "
            f"(TrainState v{host['version']}) but this run uses "
            f"overlap={expect_overlap!r}; the carry layouts differ "
            f"({'3-slot, no pending arena' if ck_overlap == 'off' else '4-slot with pending arena'}). "
            f"Restart with --overlap {ck_overlap}, or train from scratch.")
    carry = tree["carry"]
    if carry_shardings is not None:
        carry = jax.tree.map(lambda x, s: jax.device_put(x, s),
                             carry, carry_shardings)
    return TrainState(step=int(host["step"]), carry=carry,
                      controller=host.get("controller"),
                      membership=host.get("membership"),
                      rng=tree.get("rng"),
                      strategy=host.get("strategy", "daso"),
                      losses=[float(x) for x in host.get("losses", [])],
                      extra=host.get("extra", {}),
                      overlap=ck_overlap,
                      version=int(host["version"]))


def load_latest_train_state(ckpt_dir: str, *, carry_shardings=None,
                            expect_overlap: Optional[str] = None
                            ) -> Tuple[str, TrainState]:
    """Newest intact TrainState under `ckpt_dir` (skipping any snapshot a
    crash left truncated/torn). Returns (path, state). This is what a
    regrouped epoch resumes from after a real process death — the victim
    may have been killed mid-save, so "latest" must mean "latest that
    still loads"."""
    skipped = []
    for cand in list_train_state_dirs(ckpt_dir):
        try:
            return cand, load_train_state(cand,
                                          carry_shardings=carry_shardings,
                                          expect_overlap=expect_overlap)
        except CheckpointCorruptError as e:
            skipped.append(f"{os.path.basename(cand)}: {e}")
    raise CheckpointCorruptError(
        f"{ckpt_dir}: no intact TrainState snapshot found"
        + (f" (skipped {'; '.join(skipped)})" if skipped else ""))
