"""Explicit N-level cluster-topology subsystem.

`TopologySpec` declares the bandwidth hierarchy (levels with name, fanout,
link bandwidth/latency — e.g. ``chip:4 x host:4 x pod:2``); `lower` turns
it into a JAX mesh, a `DasoConfig`, a per-level sync schedule, and a
registered training strategy; `strategy` holds the `hier_daso` strategy
whose step variants sync exactly the levels that tick each step. See
docs/topologies.md for the full model.
"""
from repro.topo.lower import (build_topology_strategy, daso_config_from,
                              derive_inner_periods, make_controller)
from repro.topo.spec import Level, TopologySpec
from repro.topo.strategy import HierDasoStrategy

__all__ = ["Level", "TopologySpec", "HierDasoStrategy",
           "build_topology_strategy", "daso_config_from",
           "derive_inner_periods", "make_controller"]
