"""Declarative N-level cluster topology.

A `TopologySpec` describes the bandwidth hierarchy of a cluster as an
ordered list of *levels*, innermost first: each level names one tier of the
interconnect (chip-to-chip NVLink/ICI, host-to-host rack network, pod-to-pod
DCN, ...), its fanout (how many child units one unit of the next level up
contains), and the bandwidth/latency of the links crossed when units at that
level talk to each other. DS-Sync (arXiv 2007.03298) and the Hitchhiker's
Guide survey (arXiv 1810.11787) both observe that real clusters have more
than the two tiers the original DASO paper models — this spec is what the
whole control plane (step variants, sync schedule, mesh, comm model, fault
plans) is lowered from; see docs/topologies.md for the lowering model.

Spec grammar (one level per segment, segments joined by ``x``/``×``/``,``,
innermost first)::

    level   := NAME ":" FANOUT ["@" BANDWIDTH ["/" LATENCY]] ["%" PERIOD]
    NAME    := lowercase identifier, unique per spec
    FANOUT  := int >= 1   (units of the previous level per unit of this one;
                           for the outermost level: total units)
    BANDWIDTH := float, bytes/s per link at this level
    LATENCY := float, seconds per message at this level
    PERIOD  := int >= 1, sync this level every PERIOD steps (B_l); for the
               outermost level this overrides b_max of the plateau schedule

Omitted bandwidth/latency default per depth (NVLink-ish innermost, DCN-ish
outermost — `DEFAULT_BANDWIDTHS` / `DEFAULT_LATENCIES`); an omitted period
is derived from the bandwidth ratios at lowering time
(`repro.topo.lower.derive_inner_periods`).

Usage:

>>> spec = TopologySpec.parse("chip:4 x host:2 x pod:2")
>>> [lvl.name for lvl in spec.levels]
['chip', 'host', 'pod']
>>> spec.local_world, spec.n_replicas, spec.world
(4, 4, 16)
>>> spec.group_size("host"), spec.group_size("pod")
(2, 4)
>>> spec.replicas_of("pod1")
(2, 3)
>>> spec.replicas_of("pod1/host0")
(2,)
>>> TopologySpec.parse(spec.to_str()) == spec
True

The paper's original two-level layout is just the 2-level spec:

>>> two = TopologySpec.parse("chip:16 x pod:2")
>>> two.n_replicas, two.inner_names()
(2, ())
"""
from __future__ import annotations

import json
import os
import re
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

# Per-depth defaults, innermost first: NVLink-class chip interconnect, ICI /
# rack-network host links, DCN pod links; each level beyond the third is
# another order of magnitude slower (WAN-ish). Matched to the constants the
# analytic cluster model already uses (benchmarks/comm_model.py,
# launch/mesh.py).
DEFAULT_BANDWIDTHS = (600e9, 50e9, 25e9)
DEFAULT_LATENCIES = (1e-6, 10e-6, 30e-6)

_NAME_RE = re.compile(r"^[a-z][a-z0-9_]*$")
_LEVEL_RE = re.compile(
    r"^(?P<name>[a-z][a-z0-9_]*):(?P<fanout>\d+)"
    r"(?:@(?P<bw>[0-9.eE+-]+)(?:/(?P<lat>[0-9.eE+-]+))?)?"
    r"(?:%(?P<period>\d+))?$")
# the ascii 'x' separator needs surrounding whitespace (level names may
# legally contain 'x' — "proxy:4 x pod:2"); '×' and ',' cannot appear in
# names, so they separate with or without spaces
_SEP_RE = re.compile(r"\s+x\s+|\s*[×,]\s*")


def default_bandwidth(i: int) -> float:
    """Default link bandwidth of level `i` (innermost = 0), bytes/s."""
    if i < len(DEFAULT_BANDWIDTHS):
        return DEFAULT_BANDWIDTHS[i]
    return DEFAULT_BANDWIDTHS[-1] / 10 ** (i - len(DEFAULT_BANDWIDTHS) + 1)


def default_latency(i: int) -> float:
    """Default per-message latency of level `i` (innermost = 0), seconds."""
    if i < len(DEFAULT_LATENCIES):
        return DEFAULT_LATENCIES[i]
    return DEFAULT_LATENCIES[-1] * 10 ** (i - len(DEFAULT_LATENCIES) + 1)


@dataclass(frozen=True)
class Level:
    """One tier of the bandwidth hierarchy.

    `fanout` counts units of the previous (inner) level per unit of this
    level; for the outermost level it is the total number of its units.
    `bandwidth`/`latency` describe the links crossed when this level's
    units exchange data (e.g. the host level's bandwidth is the
    host-to-host rack network). `period` is the explicit sync period B_l
    (None = derive from bandwidth ratios at lowering)."""
    name: str
    fanout: int
    bandwidth: float
    latency: float
    period: Optional[int] = None

    def __post_init__(self):
        if not _NAME_RE.match(self.name):
            raise ValueError(f"level name {self.name!r} must be a lowercase "
                             "identifier ([a-z][a-z0-9_]*)")
        if self.fanout < 1:
            raise ValueError(f"level {self.name!r}: fanout must be >= 1, "
                             f"got {self.fanout}")
        if self.bandwidth <= 0:
            raise ValueError(f"level {self.name!r}: bandwidth must be > 0, "
                             f"got {self.bandwidth}")
        if self.latency < 0:
            raise ValueError(f"level {self.name!r}: latency must be >= 0, "
                             f"got {self.latency}")
        if self.period is not None and self.period < 1:
            raise ValueError(f"level {self.name!r}: period must be >= 1, "
                             f"got {self.period}")

    def to_str(self) -> str:
        s = f"{self.name}:{self.fanout}@{self.bandwidth:g}/{self.latency:g}"
        if self.period is not None:
            s += f"%{self.period}"
        return s


@dataclass(frozen=True)
class TopologySpec:
    """An N-level cluster topology, levels innermost first.

    Level 0 is the intra-replica tier (the paper's GPUs-per-node: the
    `data` mesh axis that the loss-mean gradient all-reduce crosses every
    step). Levels 1..N-1 are the *replica levels*: their fanout product is
    the replica-axis size R, with inner levels varying fastest in the
    replica index (replica r of a ``chip x host x pod`` spec sits in
    ``pod r // f_host, host r % f_host``)."""
    levels: Tuple[Level, ...]

    def __post_init__(self):
        if len(self.levels) < 2:
            raise ValueError("a topology needs at least 2 levels (the "
                             "intra-replica tier plus one replica level); "
                             f"got {len(self.levels)}")
        names = [lvl.name for lvl in self.levels]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate level names in {names}")

    # -- derived structure ---------------------------------------------------
    @property
    def n_levels(self) -> int:
        return len(self.levels)

    @property
    def local_world(self) -> int:
        """Fanout of level 0: workers inside one replica (paper
        GPUs-per-node)."""
        return self.levels[0].fanout

    @property
    def replica_levels(self) -> Tuple[Level, ...]:
        """Levels 1..N-1 — the tiers the replica axis spans."""
        return self.levels[1:]

    @property
    def n_replicas(self) -> int:
        """Replica-axis size R: product of the replica-level fanouts."""
        r = 1
        for lvl in self.replica_levels:
            r *= lvl.fanout
        return r

    @property
    def world(self) -> int:
        """Total workers (paper's P): product of every fanout."""
        return self.local_world * self.n_replicas

    @property
    def outer(self) -> Level:
        """The outermost (slowest) level — the one the plateau-driven DASO
        schedule drives asynchronously."""
        return self.levels[-1]

    def inner_names(self) -> Tuple[str, ...]:
        """Names of the intermediate replica levels (between level 0 and
        the outermost), innermost first — the levels that get synchronous
        per-level group syncs every B_l steps. Empty for a 2-level spec."""
        return tuple(lvl.name for lvl in self.levels[1:-1])

    def level(self, name: str) -> Level:
        for lvl in self.levels:
            if lvl.name == name:
                return lvl
        raise KeyError(f"no level named {name!r}; levels: "
                       f"{[lvl.name for lvl in self.levels]}")

    def level_index(self, name: str) -> int:
        for i, lvl in enumerate(self.levels):
            if lvl.name == name:
                return i
        raise KeyError(f"no level named {name!r}")

    def group_size(self, name: str) -> int:
        """Replica-group size of a sync at replica level `name`: the number
        of replicas one unit of that level contains
        (prod of replica-level fanouts up to and including it). Syncing the
        outermost level groups all R replicas — the legacy global
        exchange."""
        i = self.level_index(name)
        if i == 0:
            raise ValueError(f"level {name!r} is the intra-replica tier; "
                             "it syncs implicitly every step (the gradient "
                             "all-reduce), not as a replica group")
        g = 1
        for lvl in self.levels[1:i + 1]:
            g *= lvl.fanout
        return g

    def mesh_axis_names(self) -> Tuple[str, ...]:
        """Mesh axes for the lowered JAX mesh, outermost level first (the
        conventional major-to-minor device order)."""
        return tuple(lvl.name for lvl in reversed(self.levels))

    def mesh_shape(self) -> Tuple[int, ...]:
        return tuple(lvl.fanout for lvl in reversed(self.levels))

    # -- node addressing -----------------------------------------------------
    def replicas_of(self, node: str) -> Tuple[int, ...]:
        """Replica indices inside a topology node.

        `node` is a "/"-joined path of ``<level-name><index>`` segments,
        outermost level first, descending contiguously: ``"pod1"`` is every
        replica of pod 1, ``"pod1/host0"`` narrows to host 0 of pod 1.
        Level 0 units cannot be addressed (they live inside a replica).
        Fault plans use these paths to crash whole subtrees
        (resilience/faults.py)."""
        segs = node.strip().split("/")
        lo, hi = 0, self.n_replicas
        expect = len(self.levels) - 1  # index into self.levels, walking in
        for seg in segs:
            # match against the actual level names (longest-name aware —
            # a level may itself end in a digit, e.g. "tier2" so that
            # "tier21" is tier2 unit 1), preferring the level expected
            # next in the outermost-first descent
            matches = [(i, int(seg[len(lvl.name):]))
                       for i, lvl in enumerate(self.levels)
                       if seg.startswith(lvl.name)
                       and seg[len(lvl.name):].isdigit()]
            if not matches:
                raise ValueError(
                    f"bad node segment {seg!r}; expected "
                    "<level-name><index> with a level name from "
                    f"{[lvl.name for lvl in self.levels]}")
            chosen = next(((i, idx) for i, idx in matches if i == expect),
                          matches[0])
            i, idx = chosen
            if i == 0:
                raise ValueError(f"segment {seg!r} addresses the "
                                 "intra-replica tier; the finest faultable "
                                 f"unit is {self.levels[1].name!r}")
            if i != expect:
                raise ValueError(
                    f"segment {seg!r} out of order: expected level "
                    f"{self.levels[expect].name!r} next (paths descend "
                    "outermost-first without skipping)")
            if not 0 <= idx < self.levels[i].fanout:
                raise ValueError(f"segment {seg!r}: index {idx} outside "
                                 f"0..{self.levels[i].fanout - 1}")
            span = (hi - lo) // self.levels[i].fanout
            lo, hi = lo + idx * span, lo + (idx + 1) * span
            expect = i - 1
        return tuple(range(lo, hi))

    # -- serialization -------------------------------------------------------
    @classmethod
    def parse(cls, text: str) -> "TopologySpec":
        """Parse the spec grammar (see module docstring)."""
        segs = [s for s in _SEP_RE.split(text.strip()) if s]
        if not segs:
            raise ValueError(f"empty topology spec {text!r}")
        levels = []
        for i, seg in enumerate(segs):
            m = _LEVEL_RE.match(seg)
            if not m:
                raise ValueError(
                    f"bad level segment {seg!r}; expected "
                    "name:fanout[@bandwidth[/latency]][%period]")
            # per-depth defaults; the OUTERMOST level is the cross-cluster
            # tier and defaults to (at least) the DCN class even in
            # shallow specs, matching the legacy ICI/DCN pair
            di = max(i, 2) if i == len(segs) - 1 else i
            bw = (float(m.group("bw")) if m.group("bw")
                  else default_bandwidth(di))
            lat = (float(m.group("lat")) if m.group("lat")
                   else default_latency(di))
            period = int(m.group("period")) if m.group("period") else None
            levels.append(Level(name=m.group("name"),
                                fanout=int(m.group("fanout")),
                                bandwidth=bw, latency=lat, period=period))
        return cls(tuple(levels))

    def to_str(self) -> str:
        """Canonical spec string; `parse` round-trips it exactly."""
        return " x ".join(lvl.to_str() for lvl in self.levels)

    def to_json(self) -> str:
        return json.dumps({"levels": [
            {k: v for k, v in
             (("name", lvl.name), ("fanout", lvl.fanout),
              ("bandwidth", lvl.bandwidth), ("latency", lvl.latency),
              ("period", lvl.period)) if v is not None}
            for lvl in self.levels]}, indent=1)

    @classmethod
    def from_json(cls, text: str) -> "TopologySpec":
        doc = json.loads(text)
        return cls(tuple(Level(**d) for d in doc["levels"]))

    @classmethod
    def load(cls, spec: str) -> "TopologySpec":
        """Resolve any user-facing spelling: a JSON file path, inline JSON
        (starts with '{'), or the spec grammar string. This is what
        ``launch/train.py --topology`` and `TrainLoopConfig.topology`
        accept."""
        if os.path.exists(spec):
            with open(spec) as f:
                return cls.from_json(f.read())
        if spec.lstrip().startswith("{"):
            return cls.from_json(spec)
        return cls.parse(spec)

    # -- legacy bridge -------------------------------------------------------
    @classmethod
    def two_level(cls, *, local_world: int, n_replicas: int,
                  inner_name: str = "chip",
                  outer_name: str = "pod") -> "TopologySpec":
        """The implicit pre-topology layout as an explicit spec: one
        intra-replica tier of `local_world` workers, one replica level of
        `n_replicas` units. Lowering this reproduces the legacy two-level
        DASO bit-exactly (tests/test_topology.py)."""
        return cls((Level(inner_name, local_world, default_bandwidth(0),
                          default_latency(0)),
                    Level(outer_name, n_replicas, default_bandwidth(2),
                          default_latency(2))))

    def inner_periods_explicit(self) -> Dict[str, int]:
        """Explicit `%period` overrides of the intermediate levels (the
        derived schedule fills the rest — repro.topo.lower)."""
        return {lvl.name: lvl.period for lvl in self.levels[1:-1]
                if lvl.period is not None}
