"""Runtime topology probing: measure what the links actually deliver and
feed it back into the lowered schedule.

A `TopologySpec` carries hand-written bandwidth *annotations*; the lowering
(`repro.topo.lower.derive_inner_periods`) freezes per-level periods from
them. On a drifting cluster those annotations go stale — the reason
DS-Sync-style degraded-network adaptation exists. This module closes the
loop with three probes feeding one hook:

  * **active probe** (`active_probe`) — time one real `level_group_mean`
    per replica level on the live mesh at startup (and optionally every K
    cycles): a few extra collectives, ground truth per level;
  * **passive probe** (`fit_level_costs`) — the PR 8 tracer already spans
    every per-level sync (`obs.meters.LevelMeter.measured_sync_s`); the
    per-level median of those samples is a probe that costs zero extra
    traffic;
  * **skew probe** (`skew_permutation`) — per-replica cycle-time skew
    (heartbeat step deltas on the live runtime, injected slowdowns in the
    fault simulator) sorted into a regrouping permutation, so
    similar-speed replicas share inner groups.

All three produce plain dicts/tuples consumed by
`DasoController.retune` / `HierDasoController.retune` (period re-derivation
+ effective-DCN-scale inference) and `DasoStrategy.set_group_permutation`
(reshuffle); the resilience supervisor wires them together under
``autotune_every`` (resilience/supervisor.py), the launcher under
``--autotune`` (docs/tuning.md walks the whole loop).

The cost model is deliberately first-order — ``t_l = bytes / bw_l`` — so
that probing a cluster that matches its annotations is a *strict no-op*:
`annotated_level_costs` -> `derive_retuned_periods` reproduces the static
lowering bit-for-bit (doctested below; latency/wire-format refinements
live in benchmarks.comm_model.topology_level_costs).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Iterable, Optional, Sequence, Tuple

from repro.topo.spec import TopologySpec

# key for the outermost level in cost dicts: the controllers have no spec,
# so the outer level travels under a fixed name rather than its spec name
OUTER_KEY = "_outer"


def annotated_level_costs(spec: TopologySpec,
                          param_bytes: float = 4e6) -> Dict[str, float]:
    """Nominal seconds-per-sync of every non-degenerate replica level under
    the pure bandwidth model ``t_l = param_bytes / bw_l`` (outermost under
    `OUTER_KEY`). This is the probe's reference point: `retune` infers the
    effective DCN scale from measured/annotated ``_outer`` ratio, and
    `derive_retuned_periods` on these exact costs reproduces the static
    lowering — the no-op invariant tests/test_tuning.py pins.

    >>> s = TopologySpec.parse("chip:4 x host:2@50e9 x pod:2@25e9")
    >>> c = annotated_level_costs(s, param_bytes=100e9)
    >>> c["host"], c["_outer"]
    (2.0, 4.0)
    """
    costs: Dict[str, float] = {}
    for lvl in spec.levels[1:-1]:
        if spec.group_size(lvl.name) == 1:
            continue  # elided from the schedule — nothing to retune
        costs[lvl.name] = param_bytes / lvl.bandwidth
    costs[OUTER_KEY] = param_bytes / spec.outer.bandwidth
    return costs


def measured_bandwidths(spec: TopologySpec, costs: Dict[str, float],
                        param_bytes: float = 4e6) -> Dict[str, float]:
    """Invert measured per-sync costs back to effective bytes/s, keyed by
    spec level name — the dict `repro.topo.lower.derive_inner_periods`
    accepts as its ``bandwidths`` override (this is how measurement enters
    the lowering). Non-positive costs are dropped (a failed probe leaves
    the annotation in force).

    >>> s = TopologySpec.parse("chip:4 x host:2@50e9 x pod:2@25e9")
    >>> bw = measured_bandwidths(s, {"host": 2.0, "_outer": 4.0},
    ...                          param_bytes=100e9)
    >>> bw["host"], bw["pod"]
    (50000000000.0, 25000000000.0)
    """
    out: Dict[str, float] = {}
    for name, t in costs.items():
        if not t or t <= 0:
            continue
        out[spec.outer.name if name == OUTER_KEY else name] = param_bytes / t
    return out


def derive_retuned_periods(spec: TopologySpec, costs: Dict[str, float], *,
                           b_max: int = 4,
                           param_bytes: float = 4e6) -> Dict[str, int]:
    """Re-derive the inner periods from *measured* costs: the same
    bandwidth-ratio rule as the static lowering, with measurements standing
    in for annotations (bandwidth is bytes over time, so cost ratios and
    bandwidth ratios are the same quantity). ``%period`` pins keep winning.

    Annotated costs reproduce the static schedule exactly:

    >>> from repro.topo.lower import derive_inner_periods
    >>> s = TopologySpec.parse("chip:4 x host:2@50e9 x pod:2@25e9")
    >>> (derive_retuned_periods(s, annotated_level_costs(s))
    ...  == derive_inner_periods(s, b_max=4))
    True

    A host link measured at quarter speed syncs that level less often:

    >>> c = annotated_level_costs(s)
    >>> c["host"] *= 4
    >>> derive_retuned_periods(s, c)
    {'host': 4}
    """
    from repro.topo.lower import derive_inner_periods
    return derive_inner_periods(
        spec, b_max=b_max,
        bandwidths=measured_bandwidths(spec, costs,
                                       param_bytes=param_bytes))


@dataclass(frozen=True)
class ProbeResult:
    """One active-probe round: measured seconds-per-sync per level (keys as
    in `annotated_level_costs`), a per-level value checksum (the
    determinism witness — under ``deterministic_reduce`` two probes of the
    same mesh produce identical checksums), and the probe payload size."""
    costs: Dict[str, float]
    checksums: Dict[str, float]
    rounds: int
    param_bytes: float


def active_probe(spec: TopologySpec, *, n_values: int = 1 << 12,
                 rounds: int = 3, deterministic: bool = True,
                 mask=None) -> ProbeResult:
    """Time one real `level_group_mean` per replica level on the live mesh.

    Builds a deterministic dummy arena of ``n_values`` floats per replica,
    jits the exact group mean each level's schedule runs (same group
    sizes, same membership mask, same reduce order), and times it
    ``rounds`` times after a compile warm-up, keeping the per-level
    minimum (the least-noise estimate of the true cost). The returned
    costs feed `HierDasoController.retune` against
    `annotated_level_costs(spec, result.param_bytes)`; the checksums are
    the probe's own numerics regression handle."""
    import jax
    import jax.numpy as jnp

    from repro.core.daso import level_group_mean

    r = spec.n_replicas
    arena = (jnp.arange(r * n_values, dtype=jnp.float32)
             .reshape(r, n_values) / float(r * n_values))
    tree = {"probe": arena}
    targets = [(lvl.name, spec.group_size(lvl.name))
               for lvl in spec.levels[1:-1]
               if spec.group_size(lvl.name) > 1]
    targets.append((OUTER_KEY, r))

    costs: Dict[str, float] = {}
    checksums: Dict[str, float] = {}
    for name, g in targets:
        fn = jax.jit(lambda t, g=g: level_group_mean(
            t, g, mask=mask, deterministic=deterministic))
        out = jax.block_until_ready(fn(tree))  # compile outside the timing
        checksums[name] = float(jnp.sum(out["probe"]))
        best = float("inf")
        for _ in range(max(1, rounds)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(tree))
            best = min(best, time.perf_counter() - t0)
        costs[name] = best
    return ProbeResult(costs=costs, checksums=checksums,
                       rounds=max(1, rounds),
                       param_bytes=float(arena.size * 4))


def fit_level_costs(samples: Iterable[Tuple[str, float]]
                    ) -> Dict[str, float]:
    """Passive probe: per-level cost from sync-span samples the tracer
    already collects during normal training (``(level_name, seconds)``
    pairs — `obs.meters.LevelMeter.measured_sync_s` or the trace's
    per-level comm spans). The per-level *median* is the estimate: robust
    to the one-off spikes (compile, checkpoint stall) that pollute a mean.

    >>> fit_level_costs([("host", 2.0), ("host", 100.0), ("host", 2.5),
    ...                  ("_outer", 4.0)])
    {'host': 2.5, '_outer': 4.0}
    """
    by_level: Dict[str, list] = {}
    for name, s in samples:
        by_level.setdefault(name, []).append(float(s))
    out: Dict[str, float] = {}
    for name, xs in by_level.items():
        xs = sorted(xs)
        n = len(xs)
        out[name] = (xs[n // 2] if n % 2
                     else 0.5 * (xs[n // 2 - 1] + xs[n // 2]))
    return out


def skew_permutation(slowdowns: Sequence[float], *,
                     rel_tol: float = 0.1) -> Optional[Tuple[int, ...]]:
    """Straggler-aware regrouping permutation: slot order = replicas sorted
    by slowdown (stable, so equal-speed replicas keep their relative
    order). Consecutive slots share an inner group
    (`DasoStrategy.set_group_permutation`), so similar-speed replicas are
    packed together and a straggler's inner barrier delays only its own
    group — the recoverable part of the wait (`wasted_wait_s`).

    Skew below `rel_tol` (max/min - 1) returns None: the identity keeps
    the unpermuted fast-path HLO, and a near-uniform fleet should not pay
    a recompile for noise.

    >>> skew_permutation([1.0, 3.0, 1.0, 3.0])
    (0, 2, 1, 3)
    >>> skew_permutation([1.0, 1.02, 0.99, 1.0]) is None
    True
    """
    xs = [float(s) for s in slowdowns]
    if not xs or min(xs) <= 0:
        return None
    if max(xs) / min(xs) - 1.0 <= rel_tol:
        return None
    return tuple(sorted(range(len(xs)), key=lambda i: (xs[i], i)))


def wasted_wait_s(slowdowns: Sequence[float], mask, group_size: int,
                  perm: Optional[Tuple[int, ...]],
                  t_compute_s: float) -> float:
    """Per-step straggler wait an inner-group barrier wastes: every active
    replica waits for its group's slowest member, so the waste is
    ``sum_r (group_max_slowdown - own_slowdown) * t_compute``. The global
    makespan is gated by the worst straggler regardless — this is the
    *recoverable* slack reshuffling targets, and the honest metric
    BENCH_tuning.json gates (`reshuffle_wait_ratio`).

    >>> wasted_wait_s([1.0, 3.0, 1.0, 3.0], None, 2, None, 1.0)
    4.0
    >>> wasted_wait_s([1.0, 3.0, 1.0, 3.0], None, 2, (0, 2, 1, 3), 1.0)
    0.0
    """
    n = len(slowdowns)
    order = list(perm) if perm is not None else list(range(n))
    total = 0.0
    for g0 in range(0, n, max(1, group_size)):
        members = order[g0:g0 + max(1, group_size)]
        active = [r for r in members if mask is None or mask[r]]
        if not active:
            continue
        worst = max(slowdowns[r] for r in active)
        total += sum(worst - slowdowns[r] for r in active)
    return total * t_compute_s
