"""Lowering a `TopologySpec` onto the DASO control plane.

A spec lowers to three artifacts (docs/topologies.md walks through the
model):

  (a) a JAX mesh with one axis per level (`launch/mesh.py::
      make_topology_mesh`), outermost level first, so a sync at level l
      produces collectives spanning exactly that level's axes;
  (b) a `DasoConfig` whose replica axis is the product of the replica-level
      fanouts and whose Eq. (1) world size P is the full topology world;
  (c) a per-level sync schedule: fixed periods B_l for the intermediate
      levels (`derive_inner_periods`) driven by a `HierDasoController`,
      with the paper's plateau-adaptive B/W schedule driving the outermost
      level.

The 2-level special case lowers to the unmodified legacy objects
(`DasoController`, `DasoStrategy`) — bit-exact with the pre-topology code
by construction, and asserted by tests/test_topology.py.

>>> from repro.topo.spec import TopologySpec
>>> spec = TopologySpec.parse("chip:4 x host:2@50e9 x pod:2@25e9")
>>> derive_inner_periods(spec, b_max=4)
{'host': 2}
>>> daso_config_from(spec).n_replicas, daso_config_from(spec).global_world
(4, 16)
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

from repro.core.daso import DasoConfig
from repro.core.schedule import DasoController, HierDasoController
from repro.topo.spec import TopologySpec


def derive_inner_periods(spec: TopologySpec, *, b_max: int = 4,
                         bandwidths: Optional[Dict[str, float]] = None
                         ) -> Dict[str, int]:
    """Per-level sync periods B_l for the intermediate replica levels,
    innermost first. An explicit ``%period`` on the level wins; otherwise
    B_l scales the outermost b_max by the bandwidth ratio — a level as fast
    as the outermost syncs as rarely (B_l = b_max), a level k× faster
    syncs k× more often (min 1):

        B_l = clamp(round(b_max * bw_outer / bw_l), 1, b_max)

    which is the match-the-schedule-to-the-topology rule DS-Sync argues
    for: bytes flow where the links can afford them.

    `bandwidths` overrides the spec's *annotations* with *measurements*
    (level name -> bytes/s, outermost included), which is how the runtime
    probe (`repro.topo.probe`) feeds what it observed on the live mesh
    back into the same lowering rule — levels it did not measure keep
    their annotated value:

    >>> from repro.topo.spec import TopologySpec
    >>> s = TopologySpec.parse("chip:4 x host:2@50e9 x pod:2@25e9")
    >>> derive_inner_periods(s, b_max=4)
    {'host': 2}
    >>> derive_inner_periods(s, b_max=4, bandwidths={"host": 12.5e9})
    {'host': 4}
    """
    if b_max < 1:
        raise ValueError(f"b_max must be >= 1, got {b_max}")
    bw = bandwidths or {}
    bw_outer = bw.get(spec.outer.name, spec.outer.bandwidth)
    periods: Dict[str, int] = {}
    for lvl in spec.levels[1:-1]:
        if spec.group_size(lvl.name) == 1:
            # a degenerate level (all fanouts up to it are 1) has
            # single-replica groups — its sync is a no-op, so it is
            # elided from the schedule rather than compiled into steps
            continue
        if lvl.period is not None:
            periods[lvl.name] = lvl.period
        else:
            bw_l = bw.get(lvl.name, lvl.bandwidth)
            periods[lvl.name] = max(
                1, min(b_max, round(b_max * bw_outer / bw_l)))
    return periods


def daso_config_from(spec: TopologySpec, *, b_max: int = 4,
                     **overrides) -> DasoConfig:
    """`DasoConfig` for a topology: R from the replica-level fanouts, P
    (Eq. (1) world) = the full topology world, b_max from the outermost
    level's ``%period`` if pinned. Remaining DasoConfig fields pass through
    `overrides`."""
    if spec.outer.period is not None:
        b_max = spec.outer.period
    return DasoConfig(n_replicas=spec.n_replicas,
                      global_world=spec.world,
                      b_max=b_max, **overrides)


def make_controller(spec: TopologySpec, cfg: DasoConfig, *,
                    loss_window: int = 50):
    """The schedule layer of the lowering: the plain `DasoController` for a
    2-level spec (byte-identical histories to the legacy build), a
    `HierDasoController` carrying the derived per-level periods
    otherwise."""
    if cfg.n_replicas != spec.n_replicas:
        raise ValueError(f"DasoConfig.n_replicas={cfg.n_replicas} does not "
                         f"match the topology's {spec.n_replicas}")
    if spec.n_levels == 2:
        return DasoController(cfg, loss_window=loss_window)
    return HierDasoController(cfg, loss_window=loss_window,
                              inner_periods=derive_inner_periods(
                                  spec, b_max=cfg.b_max),
                              pinned_periods=tuple(
                                  spec.inner_periods_explicit()))


def build_topology_strategy(loss_fn: Callable, optimizer, spec: TopologySpec,
                            cfg: Optional[DasoConfig] = None, *,
                            loss_window: int = 50, b_max: int = 4,
                            n_micro: int = 1, membership=None,
                            **cfg_overrides):
    """Lower a spec all the way to a registered Strategy instance.

    2-level specs return the stock `DasoStrategy` (the legacy code path —
    bit-exact reproduction of pre-topology training); deeper specs return
    a `HierDasoStrategy` whose step variants carry the per-level phase
    vector. `cfg` may be passed pre-built (it must agree with the spec);
    otherwise it is derived via `daso_config_from(spec, b_max=b_max,
    **cfg_overrides)`."""
    from repro.core.executor import DasoStrategy
    from repro.topo.strategy import HierDasoStrategy

    cfg = cfg or daso_config_from(spec, b_max=b_max, **cfg_overrides)
    controller = make_controller(spec, cfg, loss_window=loss_window)
    if spec.n_levels == 2:
        strategy = DasoStrategy(loss_fn, optimizer, cfg,
                                controller=controller, n_micro=n_micro,
                                membership=membership)
        # stamp the spec on the stock strategy too, so topology-aware
        # consumers (the resilience supervisor's node-addressed fault
        # resolution) work uniformly across lowered strategies
        strategy.topo = spec
        return strategy
    return HierDasoStrategy(loss_fn, optimizer, cfg, topo=spec,
                            controller=controller, n_micro=n_micro,
                            membership=membership)
