"""`hier_daso`: the N-level topology strategy.

Registered in the same strategy registry as `daso`/`sync`/`local_sgd`
(core/executor.py), so both executors, the train loop, the launcher, and
the resilience supervisor drive it through the common plan -> program
interface with zero special-casing. The only deltas vs `DasoStrategy`:

  * the controller is a `HierDasoController`, so cycle shapes carry the
    per-level phase vector (mode tokens like ``"send+host"`` — still plain
    strings, so the executor's shape-keyed compile cache, the history
    records, and the checkpoint format are unchanged);
  * `_inner_syncs_of` resolves the token's inner-level names against the
    topology, baking the syncing levels' `level_group_mean` calls into
    every step variant (`inner_syncs` on `daso_train_step` and its overlap
    counterparts), each one collective per arena over exactly that level's
    replica groups.

With a 2-level topology there are no intermediate levels, every token is a
legacy mode string, and this class builds byte-identical step functions to
`DasoStrategy` — but `repro.topo.lower.build_topology_strategy` returns the
stock `DasoStrategy` for that case anyway.
"""
from __future__ import annotations

from repro.core.executor import DasoStrategy, register_strategy
from repro.core.schedule import HierDasoController
from repro.topo.spec import TopologySpec


@register_strategy("hier_daso")
class HierDasoStrategy(DasoStrategy):
    """Paper strategy generalized to an explicit N-level topology: the
    outermost level keeps the plateau-driven asynchronous send/receive
    exchange, intermediate levels get synchronous group syncs every B_l
    steps, level 0 stays the per-step gradient all-reduce."""

    def __init__(self, loss_fn, optimizer, cfg, *, topo: TopologySpec,
                 controller=None, **kw):
        if cfg is not None and cfg.n_replicas != topo.n_replicas:
            raise ValueError(
                f"DasoConfig.n_replicas={cfg.n_replicas} does not match "
                f"the topology's {topo.n_replicas}")
        if controller is None:
            from repro.topo.lower import make_controller
            controller = make_controller(topo, cfg)
        if not isinstance(controller, HierDasoController) \
                and topo.n_levels > 2:
            raise ValueError("a >2-level topology needs a "
                             "HierDasoController (repro.topo.lower."
                             "make_controller builds one)")
        super().__init__(loss_fn, optimizer, cfg, controller=controller,
                         **kw)
        self.topo = topo

    def _inner_syncs_of(self, inner):
        # the one topology-aware hook: every step-build path in the base
        # class (plain, overlap, overlap-compute) routes through it
        return tuple((name, self.topo.group_size(name)) for name in inner)
