"""Low-overhead span/counter tracing: one JSONL stream per process.

Every event is already shaped like a Chrome trace-event (the `ph`/`ts`/
`dur`/`pid`/`tid` vocabulary of the trace-event format), so merging the
per-process streams of a multi-process run is pure line concatenation +
sort, and exporting to a Perfetto/chrome://tracing-loadable file is just
wrapping the lines in ``{"traceEvents": [...]}`` (tools/trace_report.py).

Design constraints, in order:

  * **cheap when off** — callers hold a tracer unconditionally; the shared
    `NULL_TRACER` makes every call a no-op (its `span` returns a reusable
    do-nothing context manager, no allocation per call).
  * **cheap when on** — events are appended to an in-memory list under a
    lock (the resilience heartbeat thread and the training thread both
    write) and flushed to disk every `flush_every` events; the tracer
    accounts its own cumulative cost in `overhead_s` so the tracing-
    overhead claim in BENCH_obs.json is self-measured, not inferred.
  * **merge-aligned timestamps** — `ts` is wall-clock microseconds
    (`time.time_ns() // 1000`): processes of one run share the host clock,
    so merged streams interleave correctly; `dur` comes from
    `perf_counter` so span lengths are monotonic-clock accurate.

Span taxonomy (the `cat` field; docs/observability.md has the full table):

  executor    compiled-cycle dispatch, compiles, overlap exchange legs,
              tail-fallback steps (core/executor.py)
  schedule    controller decision events: plateau-driven B/W changes,
              membership/DCN notifications, each with a `reason`
              (core/schedule.py)
  resilience  health-plane phase changes, fault events, regroup replay
              (resilience/runtime.py, resilience/supervisor.py)
  checkpoint  TrainState saves (train/loop.py)
  meter       comm-accounting counter snapshots (obs/meters.py readings)
  meta        the run_metadata event: topology, wire format, parameter
              bytes — what tools/trace_report.py needs to price the model
              side of its drift table
"""
from __future__ import annotations

import glob as _glob
import json
import os
import threading
import time
from typing import Callable, Dict, Iterable, List, Optional

# event phases we emit/accept: X = complete span (ts + dur), i = instant,
# C = counter, M = metadata (process_name etc.)
PHASES = ("X", "i", "C", "M")

#: the one metadata event every stream opens with — trace_report reads the
#: run configuration (topology, param bytes, wire format) out of its args
RUN_METADATA = "run_metadata"


def stream_path(base: str, proc_id: int, epoch: int = 0) -> str:
    """Per-process JSONL stream path for a run whose merged trace is
    `base`: ``{base}.e{epoch}p{proc}.jsonl`` — epoch-tagged so a supervised
    regroup (fresh coordinator epoch, same run dir) never overwrites the
    pre-crash epoch's stream."""
    return f"{base}.e{epoch}p{proc_id}.jsonl"


class _NullSpan:
    """Reusable no-op context manager (one shared instance, no per-call
    allocation)."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """API-complete no-op tracer; the default everywhere a tracer can be
    threaded so call sites never branch."""
    enabled = False
    overhead_s = 0.0
    n_events = 0

    def span(self, name: str, cat: str = "executor", **args):
        return _NULL_SPAN

    def instant(self, name: str, cat: str = "executor", **args) -> None:
        pass

    def counter(self, name: str, values: Dict[str, float],
                cat: str = "meter") -> None:
        pass

    def metadata(self, **args) -> None:
        pass

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


NULL_TRACER = NullTracer()


class _Span:
    """Context manager recording one complete ("X") event on exit."""
    __slots__ = ("tracer", "name", "cat", "args", "_ts_us", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self):
        self._ts_us = time.time_ns() // 1000
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur_s = time.perf_counter() - self._t0
        self.tracer._emit({"name": self.name, "cat": self.cat, "ph": "X",
                           "ts": self._ts_us,
                           "dur": int(dur_s * 1e6),
                           "pid": self.tracer.proc_id, "tid": _tid(),
                           "args": self.args})
        return False


def _tid() -> int:
    return threading.get_ident() & 0xFFFF


class Tracer:
    """Buffered JSONL trace writer for ONE process of a run.

    `path` is this process's stream file (use `stream_path` in
    multi-process runs so the launcher can merge). Events accumulate in
    memory and hit the disk every `flush_every` events and on `close()`.
    The tracer measures its own cost: `overhead_s` is the cumulative wall
    time spent inside tracer calls (span bookkeeping + serialization +
    writes), emitted as a final `tracer_self` counter so the overhead
    claim in BENCH_obs.json is carried inside the trace itself."""
    enabled = True

    def __init__(self, path: str, *, proc_id: int = 0,
                 flush_every: int = 256):
        self.path = path
        self.proc_id = proc_id
        self.flush_every = flush_every
        self.overhead_s = 0.0
        self.n_events = 0
        self._buf: List[dict] = []
        self._lock = threading.Lock()
        self._closed = False
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        # truncate: one stream per (run, epoch, proc)
        with open(self.path, "w"):
            pass
        self._emit({"name": "process_name", "cat": "meta", "ph": "M",
                    "ts": time.time_ns() // 1000,
                    "pid": proc_id, "tid": _tid(),
                    "args": {"name": f"proc {proc_id}"}})

    # -- event API ---------------------------------------------------------
    def span(self, name: str, cat: str = "executor", **args) -> _Span:
        """Context manager: one complete event spanning the with-block."""
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "executor", **args) -> None:
        t0 = time.perf_counter()
        self._emit({"name": name, "cat": cat, "ph": "i", "s": "p",
                    "ts": time.time_ns() // 1000,
                    "pid": self.proc_id, "tid": _tid(), "args": args},
                   t0=t0)

    def counter(self, name: str, values: Dict[str, float],
                cat: str = "meter") -> None:
        t0 = time.perf_counter()
        self._emit({"name": name, "cat": cat, "ph": "C",
                    "ts": time.time_ns() // 1000,
                    "pid": self.proc_id, "tid": _tid(), "args": values},
                   t0=t0)

    def metadata(self, **args) -> None:
        """The run_metadata instant: emitted once per stream by the entry
        point (launch/train.py) with everything trace_report needs to
        reconstruct the run's model-side costs."""
        self.instant(RUN_METADATA, cat="meta", **args)

    # -- internals ---------------------------------------------------------
    def _emit(self, ev: dict, *, t0: Optional[float] = None) -> None:
        if t0 is None:
            t0 = time.perf_counter()
        with self._lock:
            if self._closed:
                return
            self._buf.append(ev)
            self.n_events += 1
            buf = None
            if len(self._buf) >= self.flush_every:
                buf, self._buf = self._buf, []
        if buf is not None:
            self._write(buf)
        self.overhead_s += time.perf_counter() - t0

    def _write(self, events: List[dict]) -> None:
        with open(self.path, "a") as f:
            for ev in events:
                f.write(json.dumps(ev, separators=(",", ":")))
                f.write("\n")

    def flush(self) -> None:
        with self._lock:
            buf, self._buf = self._buf, []
        if buf:
            self._write(buf)

    def close(self) -> None:
        """Final flush; appends the tracer's self-accounting counter so
        the overhead is auditable from the trace alone."""
        if self._closed:
            return
        self.counter("tracer_self",
                     {"events": self.n_events,
                      "overhead_us": self.overhead_s * 1e6},
                     cat="meta")
        with self._lock:
            self._closed = True
            buf, self._buf = self._buf, []
        self._write(buf)


# -- schema + merge (launcher/report side) ------------------------------------

def validate_event(ev) -> Optional[str]:
    """One trace event's schema check; returns an error string or None.

    The contract the CI trace-smoke lane enforces on merged run traces:
    required keys, known phase, numeric non-negative timestamps, complete
    events carry a numeric non-negative `dur`, args (when present) is an
    object. Extra keys are tolerated — the stream may grow fields without
    breaking old readers (same stance as the heartbeat wire format,
    resilience/runtime.py)."""
    if not isinstance(ev, dict):
        return f"event is {type(ev).__name__}, not an object"
    for key in ("name", "ph", "ts", "pid"):
        if key not in ev:
            return f"missing required key {key!r}"
    if not isinstance(ev["name"], str) or not ev["name"]:
        return f"name must be a non-empty string, got {ev['name']!r}"
    if ev["ph"] not in PHASES:
        return f"unknown phase {ev['ph']!r} (expected one of {PHASES})"
    if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
        return f"ts must be a non-negative number, got {ev['ts']!r}"
    if ev["ph"] == "X":
        if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
            return (f"complete event {ev['name']!r} needs a non-negative "
                    f"dur, got {ev.get('dur')!r}")
    if "args" in ev and not isinstance(ev["args"], dict):
        return f"args must be an object, got {type(ev['args']).__name__}"
    return None


def _read_stream(path: str) -> List[dict]:
    events = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i}: not JSON: {e}") from None
    return events


def merge_streams(base: str, *, keep_streams: bool = True,
                  log: Optional[Callable] = None) -> Optional[str]:
    """Merge every per-process stream of `base` (``{base}.e*p*.jsonl``)
    into the single run trace at `base`, sorted by timestamp. Returns the
    merged path, or None when no streams exist (run was not traced).
    Called by tools/launch_procs.py after the group exits — the only
    race-free merge point — and by single-process runs on themselves."""
    paths = sorted(_glob.glob(f"{_glob.escape(base)}.e*p*.jsonl"))
    if not paths:
        return None
    events: List[dict] = []
    for p in paths:
        events.extend(_read_stream(p))
    events.sort(key=lambda ev: ev.get("ts", 0))
    with open(base, "w") as f:
        for ev in events:
            f.write(json.dumps(ev, separators=(",", ":")))
            f.write("\n")
    if not keep_streams:
        for p in paths:
            os.remove(p)
    if log is not None:
        log(f"[trace] merged {len(paths)} stream(s), {len(events)} events "
            f"-> {base}")
    return base


def load_events(path: str) -> List[dict]:
    """Events of a merged run trace (or a single stream). When `path` does
    not exist but per-process streams do, they are merged in memory —
    tools/trace_report.py works on an un-merged run directory too."""
    if os.path.exists(path):
        return _read_stream(path)
    paths = sorted(_glob.glob(f"{_glob.escape(path)}.e*p*.jsonl"))
    if not paths:
        raise FileNotFoundError(f"no trace at {path} (and no "
                                f"{path}.e*p*.jsonl streams)")
    events: List[dict] = []
    for p in paths:
        events.extend(_read_stream(p))
    events.sort(key=lambda ev: ev.get("ts", 0))
    return events


def to_chrome(events: Iterable[dict]) -> dict:
    """Wrap merged events as a chrome://tracing / Perfetto-loadable
    trace-event JSON document."""
    return {"traceEvents": list(events), "displayTimeUnit": "ms"}
