"""Unified telemetry plane (PR 8).

`trace.py` is the span/counter API every layer writes to — the executor
wraps each dispatched program, the schedule/controller records decision
events with reasons, the resilience runtime/supervisor records health and
fault events — producing one JSONL trace stream per process (Chrome
trace-event shaped, mergeable by tools/launch_procs.py and exportable by
tools/trace_report.py).

`meters.py` is the per-level communication accounting: bytes-on-the-wire
per sync level derived from the flat-buffer arena sizes, wire formats, and
the controller's `level_sync_counts`, cross-checkable against the HLO
collective stats (launch/hlo_stats.py). The self-tuning-topology work
(ROADMAP) consumes these readings directly.
"""
from repro.obs.trace import (NULL_TRACER, Tracer, load_events, merge_streams,
                             stream_path, validate_event)
from repro.obs.meters import (LevelMeter, crosscheck_hlo, level_bytes_report,
                              outer_sync_split)

__all__ = [
    "NULL_TRACER", "Tracer", "load_events", "merge_streams", "stream_path",
    "validate_event", "LevelMeter", "crosscheck_hlo", "level_bytes_report",
    "outer_sync_split",
]
