"""Per-level communication accounting: bytes-on-the-wire per sync level.

The controller already tallies *how many* steps synced each level
(`DasoController.level_sync_counts`); the wire-format accounting already
prices *one* exchange of a parameter tree (`compression.transfer_bytes`,
arena-consistent with the fused flat-buffer codecs). This module joins the
two into per-level `LevelMeter` readings — level name, sync count, group
size, wire tier, bytes per sync, total bytes — which is exactly the shape
the ROADMAP's self-tuning-topology controller needs to re-derive sync
periods online (bytes/sync ÷ measured sync seconds = achieved bandwidth
per level).

Two honesty checks keep the meters from drifting from reality:

  * `crosscheck_hlo` compares the priced bytes-per-sync against the
    all-reduce operand bytes the compiled program actually contains
    (launch/hlo_stats.collective_stats) — the meter is a *model* of the
    wire; the HLO is the wire.
  * `outer_sync_split` separates blocking-phase from cycling-phase outer
    syncs, because the two cross at different wire tiers when
    `DasoConfig.wire_format` is unset (compress_blocking=bf16 default vs
    f32 non-blocking sends).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core import compression
from repro.core.schedule import Mode, split_mode, split_ov

#: outer-mode tokens that cross the wire while training blocks on them
#: (warm-up/cool-down full averages + the local-SGD hard average)
_BLOCKING_OUTER = (Mode.BLOCKING, Mode.HARD_AVG)
#: outer-mode tokens whose exchange crosses at the non-blocking wire tier
#: (paper send family, the overlap merge, and the baseline-family
#: exchanges of core/baselines.py — gossip partner copies, the EASGD
#: center pull, DOWNPOUR delta pushes — which all price their payload at
#: `wire_format_for(blocking=False)`)
_ASYNC_OUTER = (Mode.SEND, Mode.SEND_RECEIVE, Mode.OV_SYNC,
                Mode.GOSSIP, Mode.ELASTIC, Mode.PUSH)


@dataclass
class LevelMeter:
    """One sync level's communication reading over a run (or a window).

    `bytes_per_sync` is the payload one replica contributes to one group
    exchange at this level — the quantity a ring/tree all-reduce moves
    ~2x of per member, and the number the HLO cross-check compares
    against operand bytes. `measured_sync_s` is filled in from the trace
    by tools/trace_report.py, or live by the self-tuning loop — filled
    rows are exactly the passive-probe samples
    `repro.topo.probe.fit_level_costs` fits a retune from
    (`level_cost_samples` below does the conversion); unfilled it is None
    and `implied_gbps` has nothing to divide."""
    level: str                     # "_outer" or an inner level name
    syncs: int                     # exchanges at this level in the window
    wire_format: str               # tier the payload crossed at
    group_size: int                # replicas averaged per exchange
    bytes_per_sync: int            # per-replica payload of one exchange
    variant: str = ""              # "" | "blocking" | "nonblocking"
    measured_sync_s: Optional[float] = field(default=None, compare=False)

    @property
    def total_bytes(self) -> int:
        return self.syncs * self.bytes_per_sync

    def implied_gbps(self) -> Optional[float]:
        """Achieved per-replica wire bandwidth in GB/s, once a measured
        sync time exists. None until trace_report (or the controller)
        fills `measured_sync_s`."""
        if not self.measured_sync_s or self.measured_sync_s <= 0:
            return None
        return self.bytes_per_sync / self.measured_sync_s / 1e9


def outer_sync_split(history: Sequence) -> Dict[str, int]:
    """Split the outer-level syncs of a controller `history` (entries
    ``(step, mode, b, w)``) into blocking vs non-blocking counts — the two
    families cross at different wire tiers under the default per-phase
    compression flags."""
    out = {"blocking": 0, "nonblocking": 0}
    for (_, mode, _, _) in history:
        base, _ = split_ov(split_mode(mode)[0])
        if base in _BLOCKING_OUTER:
            out["blocking"] += 1
        elif base in _ASYNC_OUTER:
            out["nonblocking"] += 1
    return out


def level_bytes_report(params, counts: Dict[str, int], cfg, *,
                       topo=None,
                       outer_split: Optional[Dict[str, int]] = None,
                       inner_wire: str = "f32") -> List[LevelMeter]:
    """Per-level meters for a run.

    `params` is the UNREPLICATED parameter template (one replica's tree —
    what one exchange actually ships); `counts` is
    `controller.level_sync_counts()`; `cfg` is the `DasoConfig` (wire
    tiers + int8 block); `topo` the `TopologySpec` when hierarchical
    (group sizes, and levels with zero syncs so the report always covers
    every sync level); `outer_split` from `outer_sync_split(history)`
    splits the outer row by wire tier when the two phases differ.

    Inner levels cross at `inner_wire` — `daso.level_group_mean` supports
    f32/bf16 and the hierarchy lowers to f32 by default."""
    int8_block = getattr(cfg, "int8_block", 256)

    def payload(wire: str) -> int:
        return compression.transfer_bytes(params, wire_format=wire,
                                          int8_block=int8_block)

    rows: List[LevelMeter] = []
    n_replicas = topo.n_replicas if topo is not None else 2

    # outer level: one row per wire tier actually used
    outer_total = counts.get("_outer", 0)
    wf_block = cfg.wire_format_for(blocking=True)
    wf_async = cfg.wire_format_for(blocking=False)
    if outer_split is not None and wf_block != wf_async:
        n_b = min(outer_split.get("blocking", 0), outer_total)
        n_a = outer_total - n_b
        rows.append(LevelMeter("_outer", n_b, wf_block, n_replicas,
                               payload(wf_block), variant="blocking"))
        rows.append(LevelMeter("_outer", n_a, wf_async, n_replicas,
                               payload(wf_async), variant="nonblocking"))
    else:
        # a forced cfg.wire_format (or no history to split) prices every
        # outer sync at the async tier == blocking tier
        rows.append(LevelMeter("_outer", outer_total, wf_async, n_replicas,
                               payload(wf_async)))

    inner_names = tuple(topo.inner_names()) if topo is not None else ()
    for name in inner_names:
        rows.append(LevelMeter(name, counts.get(name, 0), inner_wire,
                               topo.group_size(name), payload(inner_wire)))
    # inner levels the history saw but the spec no longer names (regroup
    # shrank the topology mid-run): still account them
    for name, n in counts.items():
        if name != "_outer" and name not in inner_names:
            rows.append(LevelMeter(name, n, inner_wire, 0,
                                   payload(inner_wire)))
    return rows


def level_cost_samples(rows: Sequence[LevelMeter]) -> List[tuple]:
    """Convert meter rows with a measured sync time into the
    ``(level, seconds)`` sample pairs `repro.topo.probe.fit_level_costs`
    consumes — the passive-probe path: trace_report fills
    `measured_sync_s` from tracer sync spans, this turns the filled rows
    into retune input. Rows without a measurement are skipped.

    >>> rows = [LevelMeter("host", 4, "f32", 2, 100, measured_sync_s=2e-3),
    ...         LevelMeter("_outer", 1, "bf16", 4, 50)]
    >>> level_cost_samples(rows)
    [('host', 0.002)]
    """
    return [(r.level, float(r.measured_sync_s)) for r in rows
            if r.measured_sync_s is not None and r.measured_sync_s > 0]


def rows_as_counter(rows: Sequence[LevelMeter]) -> Dict[str, float]:
    """Flatten meters into the numeric dict a trace counter event carries
    (`Tracer.counter("comm_meters", ...)`)."""
    out: Dict[str, float] = {}
    for r in rows:
        key = r.level + (f".{r.variant}" if r.variant else "")
        out[f"{key}.syncs"] = float(r.syncs)
        out[f"{key}.bytes_per_sync"] = float(r.bytes_per_sync)
        out[f"{key}.total_bytes"] = float(r.total_bytes)
    return out


def crosscheck_hlo(rows: Sequence[LevelMeter], hlo_stats: Dict[str, dict],
                   axis_for_level: Optional[Dict[str, str]] = None, *,
                   tol: float = 0.05) -> List[dict]:
    """Compare meter payloads against the compiled program's collective
    operand bytes (`launch.hlo_stats.collective_stats` output, keys like
    ``"all-reduce@pod"``).

    `axis_for_level` maps a meter's level name to the mesh axis its
    exchange reduces over (``{"_outer": "pod", "host": "host"}``); when
    omitted, inner levels map to their own name and "_outer" to whichever
    collective axis no inner level claims. Returns one verdict per
    matched (level, axis): meter bytes-per-sync vs HLO bytes-per-op and
    whether they agree within `tol` relative error. Levels with no
    matching collective in the HLO (zero syncs this program, or fused
    away) are reported with ``hlo_bytes=None, ok=None`` rather than
    silently dropped."""
    per_axis: Dict[str, dict] = {}
    for key, st in hlo_stats.items():
        if key.startswith("_") or "@" not in key:
            continue
        axis = key.split("@", 1)[1]
        agg = per_axis.setdefault(axis, {"bytes": 0, "count": 0})
        agg["bytes"] += st.get("bytes", 0)
        agg["count"] += st.get("count", 0)

    if axis_for_level is None:
        inner = {r.level for r in rows if r.level != "_outer"}
        axis_for_level = {name: name for name in inner}
        unclaimed = [a for a in per_axis if a not in inner]
        if len(unclaimed) == 1:
            axis_for_level["_outer"] = unclaimed[0]

    # group variant rows: one compiled program carries one wire tier per
    # level, so a level with blocking+nonblocking meter rows is checked
    # against whichever variant the extracted program actually uses (the
    # best-matching one)
    by_level: Dict[str, List[LevelMeter]] = {}
    for r in rows:
        by_level.setdefault(r.level, []).append(r)

    verdicts: List[dict] = []
    for level, variants in by_level.items():
        axis = axis_for_level.get(level)
        st = per_axis.get(axis) if axis else None
        if not st or not st["count"]:
            verdicts.append({"level": level, "axis": axis, "variant": "",
                             "meter_bytes": variants[0].bytes_per_sync,
                             "hlo_bytes": None, "rel_err": None,
                             "ok": None})
            continue
        hlo_per_op = st["bytes"] / st["count"]
        best = min(variants,
                   key=lambda r: abs(hlo_per_op - r.bytes_per_sync))
        rel = (abs(hlo_per_op - best.bytes_per_sync)
               / max(best.bytes_per_sync, 1))
        verdicts.append({"level": level, "axis": axis,
                         "variant": best.variant,
                         "meter_bytes": best.bytes_per_sync,
                         "hlo_bytes": int(hlo_per_op),
                         "rel_err": rel, "ok": rel <= tol})
    return verdicts
