"""Paper-figure benchmarks.

fig6: ResNet-50/ImageNet training-time scaling, DASO vs Horovod (paper Fig 6)
fig7: ResNet top-1 accuracy parity + large-batch degradation (paper Fig 7)
fig8: second workload (transformer LM stands in for HRNet/CityScapes) time
      scaling (paper Fig 8)
fig9: quality parity on the second workload (paper Fig 9)

Wall-clock scaling figures use the analytic cluster model (we have no A100
cluster); accuracy figures run REAL training on reduced models via the DASO
virtual-node simulator — same core step code as the production mesh path.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.comm_model import (ClusterModel, daso_step_s, horovod_step_s,
                                   reduction_pct)

RESNET50_PARAM_BYTES = 25.6e6 * 4
HRNET_PARAM_BYTES = 72e6 * 4        # hierarchical multi-scale attention net
LLAMA1B_PARAM_BYTES = 1.24e9 * 4
NODE_COUNTS = (4, 8, 16, 32, 64)


def fig6_imagenet_scaling(emit):
    c = ClusterModel(t_compute_s=0.120)
    for n in NODE_COUNTS:
        h = horovod_step_s(RESNET50_PARAM_BYTES, n, c)
        d = daso_step_s(RESNET50_PARAM_BYTES, n, c)
        emit(f"fig6_resnet50_n{n}_horovod", h * 1e6, f"gpus={n * 4}")
        emit(f"fig6_resnet50_n{n}_daso", d * 1e6,
             f"reduction={100 * (1 - d / h):.1f}%")


def fig8_second_workload_scaling(emit):
    c = ClusterModel(t_compute_s=0.350)  # heavier segmentation network
    for n in NODE_COUNTS:
        h = horovod_step_s(HRNET_PARAM_BYTES, n, c)
        d = daso_step_s(HRNET_PARAM_BYTES, n, c)
        emit(f"fig8_hrnet_n{n}_horovod", h * 1e6, f"gpus={n * 4}")
        emit(f"fig8_hrnet_n{n}_daso", d * 1e6,
             f"reduction={100 * (1 - d / h):.1f}%")
    # beyond-paper: the same model applied to an assigned-arch LM
    for n in (2, 4, 8):
        r = reduction_pct(LLAMA1B_PARAM_BYTES, n, ClusterModel(
            t_compute_s=0.450))
        emit(f"fig8x_llama1b_n{n}_daso_vs_sync", 0.0, f"reduction={r:.1f}%")


def _resnet_problem(n_nodes, per_node_batch=8, image_size=16, n_classes=4,
                    noniid=False, seed=0):
    from repro.configs.resnet50 import ResNetConfig
    from repro.data.synthetic import SyntheticImages, \
        make_noniid_class_partition
    from repro.models.cnn import init_resnet
    from repro.train.step import make_resnet_loss

    cfg = ResNetConfig(name="resnet-bench", stage_sizes=(1, 1), width=8,
                       bottleneck=False, n_classes=n_classes,
                       image_size=image_size)
    src = SyntheticImages(n_classes=n_classes, image_size=image_size,
                          seed=seed)
    params, state = init_resnet(cfg, jax.random.PRNGKey(seed))
    loss_fn = make_resnet_loss(cfg)
    weights = (make_noniid_class_partition(n_classes, n_nodes, seed=seed)
               if noniid else None)

    def daso_data(step):
        outs = []
        for r in range(n_nodes):
            w = None if weights is None else weights[r]
            b = src.batch(per_node_batch, step * n_nodes + r,
                          class_weights=w)
            outs.append(b)
        batch = {k: jnp.stack([o[k] for o in outs]) for k in outs[0]}
        batch["bn_state"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_nodes,) + x.shape), state)
        return batch

    def sync_data(step):
        b = src.batch(per_node_batch * n_nodes, step)
        b["bn_state"] = state
        return b

    return {"net": params}, loss_fn, daso_data, sync_data


def fig7_accuracy_parity(emit, n_steps=120):
    from repro.train.loop import TrainLoopConfig, run_training
    for n_nodes in (2, 4, 8):
        params0, loss_fn, daso_data, sync_data = _resnet_problem(n_nodes)
        t0 = time.time()
        sync = run_training(loss_fn, params0, sync_data, TrainLoopConfig(
            strategy="sync", n_steps=n_steps, lr=0.05), log=None)
        daso = run_training(loss_fn, params0, daso_data, TrainLoopConfig(
            strategy="daso", n_steps=n_steps, n_replicas=n_nodes,
            local_world=4, b_max=4, lr=0.05, loss_window=10), log=None)
        us = (time.time() - t0) * 1e6 / (2 * n_steps)
        acc_s = np.mean([m.get("acc", 0.0) for m in sync.metrics[-12:]])
        acc_d = np.mean([m.get("acc", 0.0) for m in daso.metrics[-12:]])
        emit(f"fig7_resnet_acc_n{n_nodes}", us,
             f"sync={acc_s:.3f};daso={acc_d:.3f};"
             f"sync_frac={daso.sync_fraction:.2f}")


def fig9_quality_parity(emit, n_steps=150):
    from repro.configs import get_reduced
    from repro.data.synthetic import SyntheticLM
    from repro.models.lm import init_params
    from repro.train.loop import TrainLoopConfig, run_training
    from repro.train.step import make_lm_loss

    cfg = get_reduced("llama3.2-1b").replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=256)
    params0 = init_params(cfg, jax.random.PRNGKey(0))
    loss_fn = make_lm_loss(cfg)
    src = SyntheticLM(vocab_size=256, seq_len=64, seed=0)
    R, per = 4, 8

    def daso_data(step):
        b = src.batch(R * per, step)
        return {k: v.reshape((R, per) + v.shape[1:]) for k, v in b.items()}

    def sync_data(step):
        return src.batch(R * per, step)

    t0 = time.time()
    sync = run_training(loss_fn, params0, sync_data, TrainLoopConfig(
        strategy="sync", n_steps=n_steps, lr=0.05), log=None)
    daso = run_training(loss_fn, params0, daso_data, TrainLoopConfig(
        strategy="daso", n_steps=n_steps, n_replicas=R, local_world=4,
        b_max=4, lr=0.05, loss_window=15), log=None)
    lsgd = run_training(loss_fn, params0, daso_data, TrainLoopConfig(
        strategy="local_sgd", n_steps=n_steps, n_replicas=R, b_max=4,
        lr=0.05), log=None)
    us = (time.time() - t0) * 1e6 / (3 * n_steps)
    emit("fig9_lm_quality", us,
         f"sync={sync.final_loss:.4f};daso={daso.final_loss:.4f};"
         f"local_sgd={lsgd.final_loss:.4f};"
         f"daso_sync_frac={daso.sync_fraction:.2f}")
    gap = abs(daso.final_loss - sync.final_loss) / sync.final_loss
    emit("fig9_daso_vs_sync_gap", 0.0, f"rel_gap={gap:.4f}")
