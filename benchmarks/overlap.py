"""Overlap microbenchmark: how much of the blocking global exchange the
double-buffered overlap executor actually hides, measured on the REAL
2-process gloo runtime (tools/launch_procs.py), not the analytic model.

Three legs of the same tiny-LM run (identical seed/schedule/topology):

  * overlap — ``--overlap one_cycle --dispatch overlap``: the exchange is
    dispatched un-awaited and runs concurrently with the next B local
    steps; the executor times how much exchange latency is still VISIBLE
    after compute finishes (`ExecutorStats.overlap_exchange_visible_s`).
  * serial  — ``--overlap one_cycle --overlap-serial-exchange``: same
    numerics (bit-exact, gated), but each exchange is blocked BEFORE the
    compute program runs (`overlap_exchange_blocking_s`) — the measured
    cost of NOT overlapping.
  * off     — ``--overlap off``: the pre-overlap blocking schedule, for
    the convergence-delta row (overlap merges one cycle stale, so its
    losses differ; the gate bounds the gap, it does not zero it).

Headline derived metric, gated by tools/check_bench.py:

    overlap_hidden_fraction = 1 - visible_s / blocking_s   (>= 0.3)

Writes BENCH_overlap.json (override with $BENCH_OVERLAP_OUT)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
LAUNCHER = os.path.join(REPO, "tools", "launch_procs.py")

# 2 replicas across 2 processes, 1 CPU device each: the smallest topology
# where the outer exchange is a real cross-process gloo collective
TOPOLOGY = "chip:1 x host:2"
PROCS = 2

LEGS = {
    "overlap": ["--overlap", "one_cycle", "--dispatch", "overlap"],
    "serial": ["--overlap", "one_cycle", "--overlap-serial-exchange"],
    "off": ["--overlap", "off"],
}


def _run_leg(name: str, extra, metrics_path: str, *, steps: int,
             timeout: float = 900.0) -> dict:
    cmd = [sys.executable, LAUNCHER, "--procs", str(PROCS), "--quiet",
           "--timeout", str(int(timeout) - 60), "--",
           "--tiny", "--topology", TOPOLOGY, "--steps", str(steps),
           "--per-node-batch", "2", "--seq-len", "16", "--seed", "0",
           "--metrics-out", metrics_path] + list(extra)
    t0 = time.perf_counter()
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout)
    wall = time.perf_counter() - t0
    if r.returncode != 0:
        raise RuntimeError(
            f"overlap bench leg {name!r} exited {r.returncode}:\n"
            f"{(r.stderr or r.stdout)[-2000:]}")
    with open(metrics_path) as f:
        m = json.load(f)
    m["wall_s"] = wall
    return m


def emit_rows(emit, *, quick: bool = False) -> None:
    """Run the three 2-process legs and write the perf record to
    $BENCH_OVERLAP_OUT (default ./BENCH_overlap.json)."""
    steps = 24 if quick else 48
    out = os.environ.get("BENCH_OVERLAP_OUT", "BENCH_overlap.json")
    tmp = tempfile.mkdtemp(prefix="bench_overlap_")
    legs = {}
    try:
        for name, extra in LEGS.items():
            legs[name] = _run_leg(name, extra,
                                  os.path.join(tmp, f"{name}.json"),
                                  steps=steps)
    except (RuntimeError, subprocess.TimeoutExpired) as e:
        emit("overlap_bench_FAILED", 0.0, str(e).replace("\n", " ")[-200:])
        return

    s_ov = legs["overlap"]["executor_stats"]
    s_se = legs["serial"]["executor_stats"]
    visible = s_ov["overlap_exchange_visible_s"]
    blocking = s_se["overlap_exchange_blocking_s"]
    hidden = 1.0 - visible / blocking if blocking > 0 else 0.0
    # serial_exchange changes only WHEN the host waits, never the math:
    # the two one_cycle legs must be bit-identical step for step
    loss_delta_serial = max(
        abs(a - b) for a, b in zip(legs["overlap"]["losses"],
                                   legs["serial"]["losses"]))
    loss_delta_off = (legs["overlap"]["final_loss"]
                      - legs["off"]["final_loss"])

    # analytic cross-check (comm_model.overlap_step_s): at paper scale the
    # dispatch-structure model must never price overlap above blocking
    from benchmarks.comm_model import ClusterModel, daso_step_s, \
        overlap_step_s
    cm = ClusterModel()
    pb = 25e6 * 4.0  # ResNet-50-scale f32 parameter bytes
    model_ratio = (overlap_step_s(pb, 16, cm)
                   / daso_step_s(pb, 16, cm, nonblocking_hidden=0.0))

    results = []
    for name, m in legs.items():
        s = m["executor_stats"]
        results.append({
            "name": name, "final_loss": m["final_loss"],
            "sync_fraction": m["sync_fraction"], "wall_s": m["wall_s"],
            "overlap_cycles": s["overlap_cycles"],
            "overlap_compute_s": s["overlap_compute_s"],
            "overlap_exchange_visible_s": s["overlap_exchange_visible_s"],
            "overlap_exchange_blocking_s": s["overlap_exchange_blocking_s"],
        })
        emit(f"overlap_{name}", m["wall_s"] * 1e6,
             f"final_loss={m['final_loss']:.4f} "
             f"cycles={s['overlap_cycles']}")

    derived = {
        "overlap_cycles": s_ov["overlap_cycles"],
        "overlap_hidden_fraction": hidden,
        "overlap_exchange_visible_s": visible,
        "overlap_exchange_blocking_s": blocking,
        "loss_delta_overlap_vs_serial": loss_delta_serial,
        "loss_delta_overlap_vs_off": loss_delta_off,
        "model_step_ratio_overlap_vs_blocking": model_ratio,
    }
    record = {"benchmark": "overlap",
              "config": {"topology": TOPOLOGY, "procs": PROCS,
                         "steps": steps, "per_node_batch": 2,
                         "seq_len": 16, "arch": "tiny", "quick": quick},
              "results": results, "derived": derived}
    with open(out, "w") as f:
        json.dump(record, f, indent=2)
    emit("overlap_hidden_fraction", blocking * 1e6,
         f"hidden={hidden:.3f} visible={visible * 1e3:.2f}ms "
         f"loss_delta_serial={loss_delta_serial:.2e} json={out}")
