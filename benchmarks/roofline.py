"""Roofline analysis (deliverable g).

Sources, per (arch x input-shape) on the single-pod production mesh:
  * full-program dry-run JSON  -> memory fit, collective schedule (scan-bound)
  * unrolled 1-group / 2-group dry-run JSONs -> per-layer-group FLOPs/bytes/
    collective bytes by 2-point extrapolation (XLA cost_analysis counts scan
    bodies once — see EXPERIMENTS.md methodology), scaled to the full depth.

Terms (TPU v5e):
  compute_s    = HLO_FLOPs_per_device / 197e12
  memory_s     = HLO_bytes_per_device / 819e9
  collective_s = sum_axis bytes_axis * ring_factor / link_bw
                 (ICI 50 GB/s for data/model axes, DCN 25 GB/s for pod)
MODEL_FLOPS = 6*N_active*T (train) or 2*N_active*T (inference) per device.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

PEAK = 197e12
HBM = 819e9
ICI = 50e9
DCN = 25e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

SHAPES = {"train_4k": (4096, 256, "train"),
          "prefill_32k": (32768, 32, "prefill"),
          "decode_32k": (32768, 128, "decode"),
          "long_500k": (524288, 1, "decode")}


def _load(arch, shape, mesh="16x16", suffix=""):
    p = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__{mesh}{suffix}.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        rec = json.load(f)
    return rec if rec.get("ok") else None


def collective_seconds(coll: Dict, n_devices=256) -> Dict[str, float]:
    """Split collective result-bytes into ICI vs DCN seconds with ring
    factors (all-reduce moves ~2x its buffer per device; gather/scatter ~1x)."""
    out = {"ici_bytes": 0.0, "dcn_bytes": 0.0}
    for key, v in coll.items():
        if key.startswith("_") or not isinstance(v, dict):
            continue
        kind, axis = key.split("@")
        factor = 2.0 if kind == "all-reduce" else 1.0
        link = "dcn_bytes" if "pod" in axis else "ici_bytes"
        out[link] += factor * v["bytes"]
    out["ici_s"] = out["ici_bytes"] / ICI
    out["dcn_s"] = out["dcn_bytes"] / DCN
    return out


def active_param_count(cfg) -> float:
    """Parameters touched per token (MoE: top_k/n_experts of expert weights)."""
    import jax
    from repro.launch.specs import params_struct
    params = params_struct(cfg)
    total = active = 0.0
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        keys = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if "we1" in keys or "we2" in keys or "we3" in keys:
            active += n * cfg.moe.top_k / cfg.moe.n_experts
        elif "embed/tok" in keys or "unembed" in keys:
            active += 0  # lookup/head counted separately; exclude embeds
        else:
            active += n
    return active


def analyze_pair(arch: str, shape: str) -> Optional[Dict]:
    from repro.configs import get_config
    cfg = get_config(arch)
    full = _load(arch, shape)
    u1 = _load(arch, shape, suffix="__u1")
    u2 = _load(arch, shape, suffix="__u2")
    if full is None:
        return None
    plen = len(cfg.layer_pattern)
    n_groups = cfg.n_layers / plen

    rec = {"arch": arch, "shape": shape,
           "fits_hbm": full["memory"]["peak_estimate_per_device"] < 16e9,
           "peak_bytes": full["memory"]["peak_estimate_per_device"],
           "param_bytes": full["param_bytes"]}

    if u1 and u2:
        def extrap2(c1, c2):
            per = max(c2 - c1, 0.0)   # tiny decode lowerings can be noisy
            return max(max(c1 - per, 0.0) + per * n_groups, c1)

        def extrap(field):
            return extrap2(u1["cost"][field], u2["cost"][field])

        flops = extrap("flops")
        bytes_ = extrap("bytes_accessed")
        cs1 = collective_seconds(u1["collectives"])
        cs2 = collective_seconds(u2["collectives"])
        ici_b = extrap2(cs1["ici_bytes"], cs2["ici_bytes"])
        dcn_b = extrap2(cs1["dcn_bytes"], cs2["dcn_bytes"])
        extrapolated = True
    else:  # fall back to the scan-bound full program (underestimates)
        flops = full["cost"]["flops"]
        bytes_ = full["cost"]["bytes_accessed"]
        cs = collective_seconds(full["collectives"])
        ici_b, dcn_b = cs["ici_bytes"], cs["dcn_bytes"]
        extrapolated = False

    compute_s = flops / PEAK
    memory_s = bytes_ / HBM
    coll_s = ici_b / ICI + dcn_b / DCN
    seq, gb, kind = SHAPES[shape]
    n_active = active_param_count(cfg)
    tokens = (seq * gb) if kind != "decode" else gb
    per_dev_tokens = tokens / 256
    model_flops = (6.0 if kind == "train" else 2.0) * n_active * \
        per_dev_tokens * 256 / 256  # per-device share of global useful flops
    dominant = max((compute_s, "compute"), (memory_s, "memory"),
                   (coll_s, "collective"))[1]
    rec.update({
        "flops_per_dev": flops, "bytes_per_dev": bytes_,
        "ici_bytes": ici_b, "dcn_bytes": dcn_b,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops_per_dev": model_flops,
        "useful_flops_ratio": (model_flops / flops) if flops else 0.0,
        "extrapolated": extrapolated,
        "step_s_bound": max(compute_s, memory_s, coll_s),
    })
    return rec


def build_table() -> list:
    from repro.configs import ARCH_IDS
    rows = []
    for arch in [a for a in ARCH_IDS if a != "resnet50"]:
        for shape in SHAPES:
            r = analyze_pair(arch, shape)
            if r:
                rows.append(r)
    return rows


def write_report(rows, path):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)


def emit_rows(emit):
    rows = build_table()
    for r in rows:
        emit(f"roofline_{r['arch']}_{r['shape']}",
             r["step_s_bound"] * 1e6,
             f"dom={r['dominant']};comp={r['compute_s'] * 1e3:.2f}ms;"
             f"mem={r['memory_s'] * 1e3:.2f}ms;"
             f"coll={r['collective_s'] * 1e3:.2f}ms;"
             f"useful={r['useful_flops_ratio']:.2f};"
             f"fits={r['fits_hbm']}")
    write_report(rows, os.path.join(DRYRUN_DIR, "..", "roofline.json"))
    return rows
