"""Analytic communication/step-time model used by the fig6/fig8 scaling
benchmarks (the paper's wall-clock numbers come from A100 nodes we don't
have; the model reproduces the *relative* training-time reduction claim).

Cluster model = the paper's JUWELS Booster: nodes of 4 GPUs, NVLink3
intra-node, HDR InfiniBand inter-node. Ring all-reduce cost:
2 * bytes * (M-1)/M / bw for M members.

DASO per-step cost:
  local grad all-reduce (4 GPUs, NVLink)                 every step
  + global param all-reduce (N nodes, IB) / B            amortized
  + Eq.(1) merge (negligible)
Horovod per-step cost:
  flat all-reduce over 4N GPUs; inter-node links carry the full ring
  (tensor-fusion assumed perfect), fp16 compressed.

The fixed NVLink/IB pair above is the 2-level special case; the N-level
generalization (`topology_level_costs` / `topology_step_s`, bottom of this
file) prices one bandwidth/latency term per level of a
`repro.topo.TopologySpec`, each paid at that level's sync period — the
numbers behind docs/topologies.md's "which level pays which bytes" table.
"""
from __future__ import annotations

import os
import sys
from dataclasses import dataclass

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.compression import wire_itemsize  # noqa: E402


def model_wire_bytes(param_bytes_fp32: float, wire_format: str, *,
                     int8_block: int = 256) -> float:
    """Wire bytes of one parameter transfer at `wire_format`, via the same
    byte accounting the code path uses (repro.core.compression) instead of
    ad-hoc /2 factors. Parameters are modelled as all-f32."""
    n_params = param_bytes_fp32 / 4.0
    return n_params * wire_itemsize(wire_format, int8_block=int8_block)


@dataclass(frozen=True)
class ClusterModel:
    gpus_per_node: int = 4
    nvlink_bw: float = 600e9        # bytes/s effective per GPU
    ib_bw: float = 25e9             # bytes/s per node (HDR200 ~ 25GB/s)
    t_compute_s: float = 0.120      # fwd+bwd per step (ResNet-50/A100-ish)
    # CALIBRATION (documented in EXPERIMENTS.md): effective MPI all-reduce
    # efficiency on JUWELS with ParaStationMPI-mt (not NCCL across nodes) and
    # per-ring-step launch latency. Chosen so the model reproduces the
    # paper's measured reductions (25% fig6 / ~35% fig8); everything else is
    # first-principles.
    ib_eff: float = 0.10
    step_latency_s: float = 15e-6


def ring_allreduce_s(nbytes: float, members: int, bw: float,
                     latency: float = 0.0) -> float:
    if members <= 1:
        return 0.0
    return (2.0 * nbytes * (members - 1) / members / bw
            + 2.0 * (members - 1) * latency)


def degraded_exchange_s(param_bytes_fp32: float, n_members: int,
                        c: ClusterModel, *, wire_format: str = "bf16",
                        dcn_scale: float = 1.0,
                        int8_block: int = 256) -> float:
    """Cost of ONE global parameter exchange over `n_members` nodes whose
    inter-node (DCN) bandwidth runs at `dcn_scale`× nominal — the fault
    plan's `degrade_dcn` factor. This is the exchange_cost_fn the
    resilience supervisor charges to its simulated clock
    (benchmarks/resilience.py wires the two together)."""
    if not 0.0 < dcn_scale:
        raise ValueError(f"dcn_scale must be positive, got {dcn_scale}")
    nbytes = model_wire_bytes(param_bytes_fp32, wire_format,
                              int8_block=int8_block)
    return ring_allreduce_s(nbytes, n_members, c.ib_bw * c.ib_eff * dcn_scale,
                            latency=c.step_latency_s)


def horovod_step_s(param_bytes_fp32: float, n_nodes: int,
                   c: ClusterModel, *, wire_format: str = "f16") -> float:
    w = n_nodes * c.gpus_per_node
    nbytes = model_wire_bytes(param_bytes_fp32, wire_format)
    # flat MPI ring over all W ranks: the node's IB link carries the ring
    # traffic of its 4 local members; W-rank latency term
    t_comm = ring_allreduce_s(nbytes * c.gpus_per_node, n_nodes,
                              c.ib_bw * c.ib_eff,
                              latency=0.0)
    t_comm += 2.0 * (w - 1) * c.step_latency_s
    # Horovod overlaps grad comm with backward; assume 50% hidden
    return c.t_compute_s + 0.5 * t_comm


def daso_step_s(param_bytes_fp32: float, n_nodes: int, c: ClusterModel,
                *, b: int = 4, blocking_frac: float = 0.2,
                nonblocking_hidden: float = 0.8,
                wire_format: str = "bf16",
                dcn_scale: float = 1.0) -> float:
    # every step: node-local gradient all-reduce over NVLink (NCCL)
    t_local = ring_allreduce_s(param_bytes_fp32, c.gpus_per_node,
                               c.nvlink_bw, latency=3e-6)
    # global: the fused parameter arena at `wire_format` over the group
    # (ONE GPU per node -> 1/4 traffic), every B steps, non-blocking
    # (mostly hidden behind compute); `dcn_scale` models a degraded
    # inter-node network (fault-plan degrade_dcn)
    t_global = degraded_exchange_s(param_bytes_fp32, n_nodes, c,
                                   wire_format=wire_format,
                                   dcn_scale=dcn_scale)
    # warm-up/cool-down fraction runs blocking (no overlap), cycling overlaps
    t_cycling = c.t_compute_s + t_local + (1 - nonblocking_hidden) * t_global / b
    t_blocking = c.t_compute_s + t_local + t_global
    return blocking_frac * t_blocking + (1 - blocking_frac) * t_cycling


def reduction_pct(param_bytes_fp32: float, n_nodes: int,
                  c: ClusterModel, **daso_kw) -> float:
    h = horovod_step_s(param_bytes_fp32, n_nodes, c)
    d = daso_step_s(param_bytes_fp32, n_nodes, c, **daso_kw)
    return 100.0 * (1.0 - d / h)


def overlap_step_s(param_bytes_fp32: float, n_nodes: int, c: ClusterModel,
                   *, b: int = 4, blocking_frac: float = 0.2,
                   wire_format: str = "bf16",
                   dcn_scale: float = 1.0) -> float:
    """Per-step wall-clock under the MEASURED overlap executor
    (core/executor.py `_run_overlap`), replacing `daso_step_s`'s assumed
    `nonblocking_hidden` fraction with the dispatch structure itself: per
    cycling macro-cycle of B steps, the exchange runs concurrently with
    the B local steps and the cycle costs whichever finishes last —

        max(B * (t_compute + t_local), t_exchange) / B   per step

    Degenerate regimes (pinned by tests/test_overlap.py):
      * zero-cost exchange  -> t_compute + t_local exactly (overlap free);
      * compute-dominated   -> t_compute + t_local (exchange fully hidden);
      * exchange-dominated  -> t_exchange / B (compute fully hidden — the
        DCN is the bottleneck and local work rides under it).

    Warm-up/cool-down steps (`blocking_frac`) still pay the blocking sum,
    same as `daso_step_s`."""
    if b < 1:
        raise ValueError(f"cycle length b must be >= 1, got {b}")
    t_local = ring_allreduce_s(param_bytes_fp32, c.gpus_per_node,
                               c.nvlink_bw, latency=3e-6)
    t_exchange = degraded_exchange_s(param_bytes_fp32, n_nodes, c,
                                     wire_format=wire_format,
                                     dcn_scale=dcn_scale)
    t_cycling = max(b * (c.t_compute_s + t_local), t_exchange) / b
    t_blocking = c.t_compute_s + t_local + t_exchange
    return blocking_frac * t_blocking + (1 - blocking_frac) * t_cycling


# -- N-level topology model ----------------------------------------------------
# Generalizes the fixed ICI/DCN pair above: each level of a
# repro.topo.TopologySpec contributes its own bandwidth/latency term, paid
# at that level's sync period. docs/topologies.md's "which level pays which
# bytes" table is generated from these functions (benchmarks/topology.py).

def topology_level_costs(spec, param_bytes_fp32: float, *, b_max: int = 4,
                         wire_format: str = "bf16",
                         inner_wire: str = "f32",
                         int8_block: int = 256,
                         ib_eff: float = 1.0,
                         dcn_scale: float = 1.0) -> list:
    """Per-level cost decomposition of one training step under the
    per-level sync schedule (repro.topo.lower.derive_inner_periods).

    Returns one dict per level, innermost first:

      * level 0 — the gradient all-reduce over its `fanout` members at its
        link bandwidth, every step (period 1); payload = f32 gradients.
      * intermediate levels — a synchronous parameter group average over
        `fanout` members at `inner_wire`, amortized over the level's
        period B_l.
      * outermost level — the fused arena exchange at `wire_format` over
        its `fanout` members, amortized over b_max, with `ib_eff` (the
        calibrated MPI/DCN efficiency of `ClusterModel`) and `dcn_scale`
        (fault-plan degradation) applied to its bandwidth only — the slow
        tier is where those effects live.

    Keys: name, members, period, wire, bytes_per_sync, bytes_per_step
    (amortized), sync_s (one exchange), step_s (amortized)."""
    from repro.topo.lower import derive_inner_periods

    if spec.outer.period is not None:
        b_max = spec.outer.period  # mirror daso_config_from's override
    periods = derive_inner_periods(spec, b_max=b_max)
    rows = []
    for i, lvl in enumerate(spec.levels):
        if i == 0:
            wire, period, bw = "f32", 1, lvl.bandwidth
        elif i == len(spec.levels) - 1:
            wire = wire_format
            period = lvl.period if lvl.period is not None else b_max
            bw = lvl.bandwidth * ib_eff * dcn_scale
        else:
            period = periods.get(lvl.name)
            if period is None:
                # degenerate (group-size-1) level: elided from the
                # schedule, never syncs, contributes no cost row
                continue
            wire, bw = inner_wire, lvl.bandwidth
        nbytes = model_wire_bytes(param_bytes_fp32, wire,
                                  int8_block=int8_block)
        sync_s = ring_allreduce_s(nbytes, lvl.fanout, bw,
                                  latency=lvl.latency)
        rows.append({"name": lvl.name, "members": lvl.fanout,
                     "period": period, "wire": wire,
                     "bytes_per_sync": nbytes,
                     "bytes_per_step": nbytes / period,
                     "sync_s": sync_s, "step_s": sync_s / period})
    return rows


def topology_step_s(spec, param_bytes_fp32: float, *,
                    t_compute_s: float = 0.120,
                    nonblocking_hidden: float = 0.8,
                    blocking_frac: float = 0.2,
                    **level_kw) -> float:
    """Analytic per-step wall-clock of the N-level schedule: compute +
    every level's amortized sync term. The outermost level's exchange is
    non-blocking in the cycling phase (`nonblocking_hidden` of it overlaps
    compute, like `daso_step_s`); warm-up/cool-down (`blocking_frac` of
    steps) pay it in full. Inner levels are synchronous — never hidden."""
    rows = topology_level_costs(spec, param_bytes_fp32, **level_kw)
    inner_s = sum(r["step_s"] for r in rows[:-1])
    outer = rows[-1]
    t_cycling = (t_compute_s + inner_s
                 + (1 - nonblocking_hidden) * outer["step_s"])
    t_blocking = t_compute_s + inner_s + outer["sync_s"]
    return blocking_frac * t_blocking + (1 - blocking_frac) * t_cycling


# -- strategy-family terms -----------------------------------------------------
# One cost/bytes term per registered strategy (core/baselines.py expansion):
# the numbers behind BENCH_strategies.json's loss-vs-simulated-time and
# loss-vs-bytes curves, and docs/strategies.md's which-strategy-pays-which-
# bytes table. All share the ClusterModel's NVLink/IB pair; differences are
# purely in WHAT crosses the slow tier and HOW OFTEN:
#
#   sync      — full ring all-reduce over all nodes, EVERY step, blocking.
#   daso      — ring all-reduce every B steps, non-blocking (mostly hidden).
#   local_sgd — ring all-reduce every B steps, blocking (hard average).
#   easgd     — ring all-reduce of the params every B steps, blocking
#               (center update); same wire shape as local_sgd.
#   downpour  — ring all-reduce of the deltas every B steps, blocking
#               (masked delta-sum push); same wire shape as local_sgd.
#   gossip    — ONE partner copy per node every B steps (point-to-point,
#               no reduction): nbytes over the wire instead of the ring's
#               2*nbytes*(M-1)/M, and no (M-1) latency chain.

def pairwise_exchange_s(nbytes: float, bw: float,
                        latency: float = 0.0) -> float:
    """One gossip partner copy: each node ships its payload to exactly one
    peer (and receives one) — a single traversal of the slow link, no ring
    factor, one hop of latency."""
    return nbytes / bw + latency


def gossip_step_s(param_bytes_fp32: float, n_nodes: int, c: ClusterModel,
                  *, b: int = 4, blocking_frac: float = 0.2,
                  wire_format: str = "bf16",
                  dcn_scale: float = 1.0,
                  int8_block: int = 256) -> float:
    """Per-step wall-clock of the gossip baseline: local NVLink gradient
    all-reduce every step; one pairwise partner copy every B cycling
    steps; warm-up/cool-down steps still pay the FULL ring all-reduce
    (blocking mode is a true global average for every strategy)."""
    t_local = ring_allreduce_s(param_bytes_fp32, c.gpus_per_node,
                               c.nvlink_bw, latency=3e-6)
    nbytes = model_wire_bytes(param_bytes_fp32, wire_format,
                              int8_block=int8_block)
    t_pair = pairwise_exchange_s(nbytes, c.ib_bw * c.ib_eff * dcn_scale,
                                 latency=c.step_latency_s)
    t_ring = degraded_exchange_s(param_bytes_fp32, n_nodes, c,
                                 wire_format=wire_format,
                                 dcn_scale=dcn_scale,
                                 int8_block=int8_block)
    t_cycling = c.t_compute_s + t_local + t_pair / b
    t_blocking = c.t_compute_s + t_local + t_ring
    return blocking_frac * t_blocking + (1 - blocking_frac) * t_cycling


def periodic_blocking_step_s(param_bytes_fp32: float, n_nodes: int,
                             c: ClusterModel, *, b: int = 4,
                             blocking_frac: float = 0.2,
                             wire_format: str = "bf16",
                             dcn_scale: float = 1.0,
                             int8_block: int = 256) -> float:
    """Shared cost shape of local_sgd / easgd / downpour: one BLOCKING
    ring all-reduce over the group every B cycling steps (hard average /
    center update / delta push — identical wire traffic), the full
    exchange during warm-up/cool-down."""
    t_local = ring_allreduce_s(param_bytes_fp32, c.gpus_per_node,
                               c.nvlink_bw, latency=3e-6)
    t_ring = degraded_exchange_s(param_bytes_fp32, n_nodes, c,
                                 wire_format=wire_format,
                                 dcn_scale=dcn_scale,
                                 int8_block=int8_block)
    t_cycling = c.t_compute_s + t_local + t_ring / b
    t_blocking = c.t_compute_s + t_local + t_ring
    return blocking_frac * t_blocking + (1 - blocking_frac) * t_cycling


def sync_step_s(param_bytes_fp32: float, n_nodes: int,
                c: ClusterModel, *, wire_format: str = "f32",
                dcn_scale: float = 1.0) -> float:
    """The synchronous baseline: a blocking global parameter all-reduce
    EVERY step (b=1, no cycling phase)."""
    t_local = ring_allreduce_s(param_bytes_fp32, c.gpus_per_node,
                               c.nvlink_bw, latency=3e-6)
    t_ring = degraded_exchange_s(param_bytes_fp32, n_nodes, c,
                                 wire_format=wire_format,
                                 dcn_scale=dcn_scale)
    return c.t_compute_s + t_local + t_ring


def strategy_step_s(name: str, param_bytes_fp32: float, n_nodes: int,
                    c: ClusterModel, *, b: int = 4,
                    blocking_frac: float = 0.2,
                    wire_format: str = "bf16",
                    dcn_scale: float = 1.0) -> float:
    """Analytic per-step wall-clock for any registered strategy name —
    the single dispatch point BENCH_strategies.json prices every curve
    through."""
    if name == "sync":
        return sync_step_s(param_bytes_fp32, n_nodes, c,
                           wire_format="f32", dcn_scale=dcn_scale)
    if name == "daso":
        return daso_step_s(param_bytes_fp32, n_nodes, c, b=b,
                           blocking_frac=blocking_frac,
                           wire_format=wire_format, dcn_scale=dcn_scale)
    if name == "gossip":
        return gossip_step_s(param_bytes_fp32, n_nodes, c, b=b,
                             blocking_frac=blocking_frac,
                             wire_format=wire_format, dcn_scale=dcn_scale)
    if name in ("local_sgd", "easgd", "downpour"):
        return periodic_blocking_step_s(param_bytes_fp32, n_nodes, c, b=b,
                                        blocking_frac=blocking_frac,
                                        wire_format=wire_format,
                                        dcn_scale=dcn_scale)
    raise ValueError(f"no cost model for strategy {name!r}")


def strategy_bytes_per_step(name: str, param_bytes_fp32: float,
                            n_nodes: int, *, b: int = 4,
                            wire_format: str = "bf16",
                            int8_block: int = 256) -> float:
    """Slow-tier (inter-node) wire bytes ONE node pays per cycling-phase
    step — the x-axis of the loss-vs-bytes curves. Ring members each move
    ~2*nbytes*(M-1)/M per exchange; a gossip node moves exactly nbytes
    (its one outgoing partner copy). The sync baseline ships f32 every
    step; the periodic family amortizes its exchange over B. Warm-up/
    cool-down is excluded: every strategy pays the identical blocking
    average there, so steady-state cycling traffic is the comparison."""
    if name == "sync":
        nbytes = model_wire_bytes(param_bytes_fp32, "f32")
        return 2.0 * nbytes * (n_nodes - 1) / n_nodes
    nbytes = model_wire_bytes(param_bytes_fp32, wire_format,
                              int8_block=int8_block)
    if name == "gossip":
        return nbytes / b
    if name in ("daso", "local_sgd", "easgd", "downpour"):
        return 2.0 * nbytes * (n_nodes - 1) / n_nodes / b
    raise ValueError(f"no bytes model for strategy {name!r}")
