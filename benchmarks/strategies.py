"""Strategy-family ablation benchmark: the six registered strategies on
one shared problem (BENCH_strategies.json).

Every registered strategy (sync / daso / local_sgd + the baseline
expansion gossip / easgd / downpour from core/baselines.py) trains the
shared tiny MLP from the same seed and data stream, through the same
macro-cycle executor. Three views land in one record:

  * **quality** — full loss curves, final loss, sync fraction; every
    strategy must actually train (trains_all gate) and stay finite;
  * **numerics** — macro executor vs the per-step reference path, max
    loss delta across all six (the conformance suite's equivalence
    check, re-asserted as a regression number);
  * **cost curves** — `comm_model.strategy_step_s` /
    `strategy_bytes_per_step` price each strategy's slow-tier traffic at
    paper scale (ResNet-50-ish bytes, the ClusterModel's NVLink/IB
    pair), giving the loss-vs-simulated-time and loss-vs-bytes axes:
    gossip's single partner copy must beat the sync ring strictly
    (bytes_per_step_*_vs_sync / model_step_ratio gates).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = """
import json
import os
import time
import jax, jax.numpy as jnp
import numpy as np

from repro.core.daso import DasoConfig
from repro.core.executor import (get_strategy, list_strategies,
                                 make_strategy, run_compiled_training)
from repro.core.simulator import run_per_step_training
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant_lr

from benchmarks.comm_model import (ClusterModel, strategy_bytes_per_step,
                                   strategy_step_s)

QUICK = os.environ.get("BENCH_QUICK") == "1"
OUT = os.environ.get("BENCH_STRATEGIES_OUT", "BENCH_strategies.json")

R, per, d = 4, 8, 8
n_steps = 60 if QUICK else 120
key = jax.random.PRNGKey(0)
w1 = jax.random.normal(key, (d, 16)) * 0.5
k1, k2 = jax.random.split(jax.random.fold_in(key, 7))
params0 = {"w1": jax.random.normal(k1, (d, 16)) * 0.3,
           "w2": jax.random.normal(k2, (16, 1)) * 0.3}

def loss_fn(params, batch):
    h = jnp.tanh(batch["x"] @ params["w1"])
    pred = h @ params["w2"]
    return jnp.mean((pred - batch["y"]) ** 2), {}

def data_fn(step):
    k = jax.random.fold_in(key, step)
    x = jax.random.normal(k, (R, per, d))
    return {"x": x, "y": jnp.tanh(x @ w1).sum(-1, keepdims=True) * 0.3}

def sync_data_fn(step):
    b = data_fn(step)
    return {k: v.reshape((-1,) + v.shape[2:]) for k, v in b.items()}

STRATEGIES = ("sync", "daso", "local_sgd", "gossip", "easgd", "downpour")
# delta-sum semantics scale downpour's effective push by n_active; 1/R
# recovers the mean-delta push so all six train at the shared lr
EXTRA = {"downpour": {"push_scale": 1.0 / R}}

def build(name):
    opt = sgd(momentum=0.9, weight_decay=1e-4)
    if name == "sync":
        return make_strategy("sync", loss_fn, opt), sync_data_fn
    cfg = DasoConfig(n_replicas=R, global_world=4 * R, b_max=4,
                     warmup_steps=n_steps // 10,
                     cooldown_steps=n_steps // 10, total_steps=n_steps)
    cls = get_strategy(name)
    strat = make_strategy(name, loss_fn, opt, cfg,
                          controller=cls.make_controller(cfg,
                                                         loss_window=20),
                          **EXTRA.get(name, {}))
    return strat, data_fn

# paper-scale pricing: ResNet-50-ish f32 payload over the JUWELS pair
PB = 97.5e6 * 4
cluster = ClusterModel()

per_strategy = {}
for name in STRATEGIES:
    strat, df = build(name)
    ref, _ = build(name)
    t0 = time.perf_counter()
    res = run_compiled_training(strat, params0, df, constant_lr(0.1),
                                n_steps)
    wall = time.perf_counter() - t0
    rp = run_per_step_training(ref, params0, df, constant_lr(0.1), n_steps)
    delta = max(abs(a - b) for a, b in zip(res.losses, rp.losses))
    sim_s = strategy_step_s(name, PB, R, cluster, b=4, blocking_frac=0.2)
    bps = strategy_bytes_per_step(name, PB, R, b=4)
    per_strategy[name] = {
        "losses": [round(x, 6) for x in res.losses],
        "first_loss": res.losses[0],
        "final_loss": res.final_loss,
        "sync_fraction": res.sync_fraction,
        "macro_vs_per_step_delta": delta,
        "us_per_step": wall / n_steps * 1e6,
        "model_step_s": sim_s,
        "model_bytes_per_step": bps,
        "sim_time_to_final_s": sim_s * n_steps,
        "bytes_to_final": bps * n_steps,
    }
    print(f"CSV strategies_{name} {wall / n_steps * 1e6:.1f} "
          f"final={res.final_loss:.4f} sync_frac={res.sync_fraction:.3f} "
          f"model_step_s={sim_s:.4f} bytes_per_step={bps:.3e}")

sync_row = per_strategy["sync"]
derived = {
    "n_strategies": float(len(per_strategy)),
    "registry_covers_all": float(
        set(STRATEGIES) <= set(list_strategies())),
    "all_finite": float(all(np.all(np.isfinite(v["losses"]))
                            for v in per_strategy.values())),
    "trains_all": float(all(v["final_loss"] < v["first_loss"]
                            for v in per_strategy.values())),
    "macro_vs_per_step_max_delta": max(
        v["macro_vs_per_step_delta"] for v in per_strategy.values()),
    "max_final_loss": max(v["final_loss"] for v in per_strategy.values()),
    "bytes_per_step_gossip_vs_sync": (
        per_strategy["gossip"]["model_bytes_per_step"]
        / sync_row["model_bytes_per_step"]),
    "bytes_per_step_easgd_vs_sync": (
        per_strategy["easgd"]["model_bytes_per_step"]
        / sync_row["model_bytes_per_step"]),
    "bytes_per_step_downpour_vs_sync": (
        per_strategy["downpour"]["model_bytes_per_step"]
        / sync_row["model_bytes_per_step"]),
    "model_step_ratio_gossip_vs_sync": (
        per_strategy["gossip"]["model_step_s"]
        / sync_row["model_step_s"]),
    "model_step_ratio_daso_vs_sync": (
        per_strategy["daso"]["model_step_s"]
        / sync_row["model_step_s"]),
}
record = {"benchmark": "strategies",
          "config": {"n_replicas": R, "n_steps": n_steps, "quick": QUICK,
                     "b_max": 4, "lr": 0.1, "param_bytes_model": PB,
                     "push_scale_downpour": 1.0 / R,
                     "strategies": list(STRATEGIES)},
          "per_strategy": per_strategy,
          "derived": derived}
with open(OUT, "w") as f:
    json.dump(record, f, indent=2)
print(f"CSV strategies_summary 0.0 "
      f"max_delta={derived['macro_vs_per_step_max_delta']:.2e} "
      f"gossip_bytes_vs_sync={derived['bytes_per_step_gossip_vs_sync']:.4f} "
      f"trains_all={derived['trains_all']:.0f} json={OUT}")
"""


def emit_rows(emit, *, quick=False):
    """All six registered strategies on the shared tiny MLP (same seed and
    data): loss curves + macro-vs-per-step deltas + analytic cost axes.
    Writes the record to $BENCH_STRATEGIES_OUT
    (default ./BENCH_strategies.json)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (SRC + os.pathsep
                         + os.path.join(os.path.dirname(__file__), "..")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env["BENCH_QUICK"] = "1" if quick else "0"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(_SCRIPT)],
                       capture_output=True, text=True, timeout=900, env=env)
    if r.returncode != 0:
        emit("strategies_sweep_FAILED", 0.0, r.stderr[-200:])
        return
    for line in r.stdout.splitlines():
        if line.startswith("CSV "):
            _, name, us, derived = line.split(" ", 3)
            emit(name, float(us), derived)
