"""Benchmark harness entry: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  python -m benchmarks.run [--only fig6,fig9,...] [--quick]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig6,fig7,fig8,fig9,micro,exchange,"
                         "resilience,topology,overlap,obs,roofline,"
                         "strategies,tuning")
    ap.add_argument("--quick", action="store_true",
                    help="shorter convergence runs")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(tag):
        return only is None or tag in only

    from benchmarks import (figures, microbench, obs, overlap, resilience,
                            roofline, strategies, topology, tuning)

    print("name,us_per_call,derived")
    if want("fig6"):
        figures.fig6_imagenet_scaling(emit)
    if want("fig8"):
        figures.fig8_second_workload_scaling(emit)
    if want("fig7"):
        figures.fig7_accuracy_parity(emit, n_steps=40 if args.quick else 120)
    if want("fig9"):
        figures.fig9_quality_parity(emit, n_steps=60 if args.quick else 150)
    if want("micro"):
        microbench.emit_rows(emit)
    if want("exchange"):
        microbench.emit_exchange_rows(emit, quick=args.quick)
    if want("resilience"):
        resilience.emit_rows(emit, quick=args.quick)
    if want("topology"):
        topology.emit_rows(emit, quick=args.quick)
    if want("overlap"):
        overlap.emit_rows(emit, quick=args.quick)
    if want("obs"):
        obs.emit_rows(emit, quick=args.quick)
    if want("roofline"):
        roofline.emit_rows(emit)
    if want("strategies"):
        strategies.emit_rows(emit, quick=args.quick)
    if want("tuning"):
        tuning.emit_rows(emit, quick=args.quick)


if __name__ == "__main__":
    main()
