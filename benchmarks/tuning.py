"""Self-tuning benchmarks: does the probe->retune->reshuffle loop actually
pay? Three head-to-head legs on the simulated cluster clock (the same
analytic exchange model as BENCH_resilience.json), all real supervisor
runs of the 3-level hierarchical strategy on a tiny MLP:

  * static vs tuned under a DCN degradation the static leg never learns
    about (oracle_notify=False) — the tuned leg must discover it by
    probing and finish cheaper on simulated time;
  * autotune on a healthy cluster — bit-exact no-op (losses AND params);
  * straggler skew with vs without group reshuffling — the skew-sorted
    grouping must waste strictly less inner-barrier wait.

Writes BENCH_tuning.json (gated by tools/check_bench.py; consumed by
EXPERIMENTS.md and docs/tuning.md)."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = """
import json
import os
import time
import jax, jax.numpy as jnp
import numpy as np

from repro.core.executor import MacroCycleExecutor
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant_lr
from repro.resilience.faults import FaultPlan
from repro.resilience.supervisor import run_with_faults
from repro.topo import (TopologySpec, build_topology_strategy,
                        daso_config_from)
from repro.topo import probe

from benchmarks.comm_model import ClusterModel, degraded_exchange_s

QUICK = os.environ.get("BENCH_QUICK") == "1"
OUT = os.environ.get("BENCH_TUNING_OUT", "BENCH_tuning.json")

TOPO = "chip:4 x host:2@50e9 x pod:2@25e9"   # R = 4, inner host groups of 2
spec = TopologySpec.parse(TOPO)
R = spec.n_replicas
per, d, h = 8, 64, 64
n_steps = 60 if QUICK else 140
t_compute_s = 0.120
key = jax.random.PRNGKey(0)
params0 = {"w1": jax.random.normal(key, (d, h)) * 0.05,
           "w2": jax.random.normal(jax.random.fold_in(key, 1), (h, d)) * 0.05}
wtrue = jax.random.normal(jax.random.fold_in(key, 2), (d, d))

def loss_fn(params, batch):
    hh = jnp.tanh(batch["x"] @ params["w1"])
    return jnp.mean((hh @ params["w2"] - batch["y"]) ** 2), {}

def data_fn(step):
    k = jax.random.fold_in(key, step)
    x = jax.random.normal(k, (R, per, d))
    return {"x": x, "y": jnp.tanh(x @ wtrue) * 0.5}

param_bytes = sum(x.size for x in jax.tree.leaves(params0)) * 4.0
# the simulated clock prices the wire at a representative 100M-param fp32
# payload: the tiny MLP drives the numerics, the analytic model the cost
# (pricing the MLP's 32KB would make every exchange microsecond noise
# next to t_compute_s and the schedule couldn't matter either way)
priced_bytes = 4e8
cm = ClusterModel()
exchange_fn = lambda n, s: degraded_exchange_s(priced_bytes, n, cm,
                                               dcn_scale=s)

def strategy():
    cfg = daso_config_from(spec, warmup_steps=n_steps // 10,
                           cooldown_steps=n_steps // 10,
                           total_steps=n_steps)
    return build_topology_strategy(loss_fn, sgd(momentum=0.9), spec, cfg,
                                   loss_window=20)

def run(name, events, *, autotune_every, oracle_notify=None,
        reshuffle=True):
    plan = FaultPlan.from_dicts(events)
    plan.validate(R)
    strat = strategy()
    ex = MacroCycleExecutor(strat)
    t0 = time.perf_counter()
    rep = run_with_faults(strat, params0, data_fn, constant_lr(0.1),
                          n_steps, plan, executor=ex,
                          t_compute_s=t_compute_s,
                          exchange_cost_fn=exchange_fn,
                          autotune_every=autotune_every,
                          oracle_notify=oracle_notify,
                          reshuffle=reshuffle)
    wall = time.perf_counter() - t0
    rec = {"name": name, "autotune_every": autotune_every,
           "final_loss": rep.result.final_loss,
           "losses": [float(x) for x in rep.result.losses],
           "simulated_time_s": rep.simulated_time_s,
           "wasted_wait_s": rep.wasted_wait_s,
           "retunes": rep.retunes, "reshuffles": rep.reshuffles,
           "invalidations": ex.stats.invalidations,
           "final_b": strat.controller.b,
           "inner_periods": dict(strat.controller.inner_periods),
           "wall_s": wall}
    results.append(rec)
    print(f"CSV tuning_{name} {wall * 1e6:.1f} "
          f"sim_time={rep.simulated_time_s:.1f}s "
          f"final_loss={rep.result.final_loss:.4f} "
          f"retunes={len(rep.retunes)} reshuffles={rep.reshuffles}")
    return rec, rep.result

results = []

# -- leg 1: DCN degrades mid-run; static never learns, tuned probes -----
degrade_step = n_steps // 4
dcn_events = [{"step": degrade_step, "kind": "degrade_dcn", "factor": 0.25}]
static, _ = run("static_degraded", dcn_events, autotune_every=0,
                oracle_notify=False)
tuned, _ = run("tuned_degraded", dcn_events, autotune_every=2)

sched = [r for r in tuned["retunes"] if r["schedule_changed"]]
assert sched, "tuned leg never retuned"
# adapt latency in cycles: probes run every cycle, so the gap between the
# first post-degrade cycle index and the first schedule-changing one
post = [r["cycle"] for r in tuned["retunes"] if r["step"] >= degrade_step]
adapt_cycles = sched[0]["cycle"] - min(post) if post else 99

# -- leg 2: healthy cluster; autotune must be a bit-exact no-op ---------
off, res_off = run("noop_autotune_off", [], autotune_every=0)
on, res_on = run("noop_autotune_on", [], autotune_every=1)
noop_param_delta = max(
    float(np.max(np.abs(np.asarray(a, np.float32)
                        - np.asarray(b, np.float32))))
    for a, b in zip(jax.tree.leaves(res_off.params),
                    jax.tree.leaves(res_on.params)))
noop_loss_delta = float(np.max(np.abs(
    np.asarray(off["losses"], np.float32)
    - np.asarray(on["losses"], np.float32))))

# -- leg 3: straggler skew; reshuffle on vs off -------------------------
straggle_events = [
    {"step": n_steps // 8, "kind": "straggle", "replica": 1, "factor": 3.0},
    {"step": n_steps // 8, "kind": "straggle", "replica": 3, "factor": 3.0},
]
no_shuf, _ = run("straggler_static_groups", straggle_events,
                 autotune_every=1, reshuffle=False)
shuf, _ = run("straggler_reshuffled", straggle_events, autotune_every=1)

# -- probe microbench: one active probe round on this host --------------
t0 = time.perf_counter()
pr = probe.active_probe(spec, rounds=3)
probe_wall = time.perf_counter() - t0
retuned = probe.derive_retuned_periods(spec, pr.costs,
                                       param_bytes=pr.param_bytes)
print(f"CSV tuning_active_probe {probe_wall * 1e6:.1f} "
      f"levels={len(pr.costs)} retuned={retuned}")
results.append({"name": "active_probe", "wall_s": probe_wall,
                "costs_us": {k: v * 1e6 for k, v in pr.costs.items()},
                "retuned_periods": retuned})

derived = {
    # the headline: discovering the degradation beats never learning of it
    "tuned_vs_static_sim_time_ratio":
        tuned["simulated_time_s"] / static["simulated_time_s"],
    "adapt_cycles": float(adapt_cycles),
    "retune_events": float(len(sched)),
    "tuned_final_b": float(tuned["final_b"]),
    "static_final_b": float(static["final_b"]),
    "loss_delta_tuned_vs_static":
        tuned["final_loss"] - static["final_loss"],
    # autotune on a healthy cluster changes NOTHING
    "noop_retune_param_delta": noop_param_delta,
    "noop_retune_loss_delta": noop_loss_delta,
    # skew-sorted groups waste less inner-barrier wait
    "reshuffle_wait_ratio":
        shuf["wasted_wait_s"] / max(no_shuf["wasted_wait_s"], 1e-12),
    "reshuffles": float(shuf["reshuffles"]),
}
for r in results:
    r.pop("losses", None)   # keep the record small
record = {"benchmark": "tuning",
          "config": {"topology": TOPO, "n_replicas": R, "n_steps": n_steps,
                     "n_params": int(param_bytes // 4), "quick": QUICK,
                     "t_compute_s": t_compute_s,
                     "degrade_step": degrade_step, "dcn_factor": 0.25},
          "results": results, "derived": derived}
with open(OUT, "w") as f:
    json.dump(record, f, indent=2)
print(f"CSV tuning_headline {0.0:.1f} "
      f"sim_ratio={derived['tuned_vs_static_sim_time_ratio']:.3f} "
      f"adapt_cycles={adapt_cycles} "
      f"wait_ratio={derived['reshuffle_wait_ratio']:.3f} json={OUT}")
"""


def emit_rows(emit, *, quick=False):
    """Static-vs-tuned DCN degradation, bit-exact no-op check, and
    reshuffle wait accounting on a single device (the supervisor's
    simulated clock is device-count independent). Writes the perf record
    to $BENCH_TUNING_OUT (default ./BENCH_tuning.json)."""
    repo = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = (SRC + os.pathsep + repo
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env["BENCH_QUICK"] = "1" if quick else "0"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(_SCRIPT)],
                       capture_output=True, text=True, timeout=1200,
                       env=env)
    if r.returncode != 0:
        emit("tuning_microbench_FAILED", 0.0, r.stderr[-200:])
        return
    for line in r.stdout.splitlines():
        if line.startswith("CSV "):
            _, name, us, derived = line.split(" ", 3)
            emit(name, float(us), derived)
