"""Measured (wall-clock) microbenchmarks of the DASO step variants on an
8-virtual-device (2 pods x 2 data x 2 model) CPU mesh, via subprocess so the
main process keeps one device. Times are real; they validate the *relative*
cost ordering (local < send < blocking), not TPU magnitudes.

Also benchmarks the compiled macro-cycle executor (core/executor.py) against
the per-step path on a cycling-phase schedule: same numerics, host dispatches
per B=4 cycle reduced from B+1 step launches to 1 compiled program."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = """
import time
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.daso import DasoConfig, daso_train_step, replicate_params
from repro.optim.optimizers import sgd

def loss_fn(params, batch):
    h = jnp.tanh(batch["x"] @ params["w1"])
    pred = h @ params["w2"]
    return jnp.mean((pred - batch["y"]) ** 2), {}

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
R, per, d, h = 2, 32, 256, 512
key = jax.random.PRNGKey(0)
params0 = {"w1": jax.random.normal(key, (d, h)) * 0.05,
           "w2": jax.random.normal(key, (h, d)) * 0.05}
opt = sgd(momentum=0.9)
cfg = DasoConfig(n_replicas=R, global_world=8)
shp = NamedSharding(mesh, P("pod"))
shb = NamedSharding(mesh, P("pod", "data"))
p = jax.tree.map(lambda x: jax.device_put(x, shp), replicate_params(params0, R))
o = jax.tree.map(lambda x: jax.device_put(x, shp),
                 replicate_params(opt.init(params0), R))
infl = jax.tree.map(lambda x: x, p)
batch = {"x": jax.device_put(jax.random.normal(key, (R, per, d)), shb),
         "y": jax.device_put(jax.random.normal(key, (R, per, d)), shb)}
for mode in ("local", "send", "receive", "blocking"):
    step = jax.jit(daso_train_step(loss_fn, opt, cfg, mode=mode, staleness=1))
    out = step(p, o, infl, batch, 0.01)
    jax.block_until_ready(out)
    n = 30
    t0 = time.perf_counter()
    for _ in range(n):
        p2, o2, infl2, m = step(p, o, infl, batch, 0.01)
    jax.block_until_ready((p2, o2, infl2))
    dt = (time.perf_counter() - t0) / n * 1e6
    print(f"CSV daso_step_{mode} {dt:.1f} mesh=2x2x2")
"""


_CYCLE_SCRIPT = """
import time
import jax, jax.numpy as jnp
from repro.core.daso import DasoConfig
from repro.core.executor import MacroCycleExecutor, make_strategy
from repro.core.schedule import DasoController
from repro.optim.optimizers import sgd

def loss_fn(params, batch):
    h = jnp.tanh(batch["x"] @ params["w1"])
    pred = h @ params["w2"]
    return jnp.mean((pred - batch["y"]) ** 2), {}

# deliberately tiny: this benchmark isolates the host-dispatch overhead the
# macro-cycle executor removes (small step times = controller-dominated
# wall-clock, the regime the tentpole targets)
R, per, d, h, B = 2, 8, 64, 64, 4
key = jax.random.PRNGKey(0)
params0 = {"w1": jax.random.normal(key, (d, h)) * 0.05,
           "w2": jax.random.normal(key, (h, d)) * 0.05}
def data_fn(step):
    k = jax.random.fold_in(key, step)
    return {"x": jax.random.normal(k, (R, per, d)),
            "y": jax.random.normal(k, (R, per, d))}
# pure cycling phase (no warm-up/cool-down), frozen B/W: every cycle is the
# same (send, receive, local, local) shape
cfg = DasoConfig(n_replicas=R, global_world=8, b_max=B)
strat = make_strategy("daso", loss_fn, sgd(momentum=0.9), cfg,
                      controller=DasoController(cfg, loss_window=10**9))
ex = MacroCycleExecutor(strat)
plan = strat.plan_cycle(0, 32)
assert len(plan) == B, plan.shape
steps = list(range(B))
batches = jax.tree.map(lambda *xs: jnp.stack(xs),
                       *[data_fn(t) for t in steps])
lrs = jnp.asarray([0.01] * B, jnp.float32)
stepwise = [jax.jit(strat.step_fn(m, s)) for m, s in plan.shape]

# warm both paths (compile), threading the carry (run_cycle donates it)
carry = strat.init_carry(params0)
carry, _ = ex.run_cycle(carry, plan, batches, lrs)
for i, fn in enumerate(stepwise):
    carry, _ = fn(carry, jax.tree.map(lambda x, j=i: x[j], batches), lrs[i])
jax.block_until_ready(carry)

# Both timed loops reproduce what the host loop really does per step/cycle:
# dispatch + blocking metrics readback (the controller consumes the loss).
n = 30
ex.stats.dispatches = 0
t0 = time.perf_counter()
for _ in range(n):
    carry, m = ex.run_cycle(carry, plan, batches, lrs)
    _ = float(m["loss"][0])        # one readback per cycle
jax.block_until_ready(carry)
t_macro = (time.perf_counter() - t0) / n * 1e6
d_macro = ex.stats.dispatches / n  # = 1: one fused program per cycle

t0 = time.perf_counter()
for _ in range(n):
    for i, fn in enumerate(stepwise):
        carry, m = fn(carry, jax.tree.map(lambda x, j=i: x[j], batches),
                      lrs[i])
        _ = float(m["loss"])       # one readback per step
jax.block_until_ready(carry)
t_step = (time.perf_counter() - t0) / n * 1e6
# per cycle the old loop pays B step launches plus the blocking metrics
# round-trip that separates cycles: the issue's "B+1" host dispatches
d_step = len(stepwise) + 1

print(f"CSV daso_macro_cycle_compiled {t_macro:.1f} "
      f"host_dispatches_per_cycle={d_macro:.0f} (B={B})")
print(f"CSV daso_macro_cycle_stepwise {t_step:.1f} "
      f"host_dispatches_per_cycle=B+1={d_step} "
      f"({len(stepwise)} step launches + blocking metrics round-trip)")
print(f"CSV daso_macro_cycle_speedup {t_step / max(t_macro, 1e-9):.3f} "
      f"host_dispatches_per_cycling_cycle: B+1={d_step} -> {d_macro:.0f}")
"""


def _run_sub(emit, script, fail_tag, *, devices=8):
    env = dict(os.environ)
    if devices > 1:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=600, env=env)
    if r.returncode != 0:
        emit(fail_tag, 0.0, r.stderr[-200:])
        return
    for line in r.stdout.splitlines():
        if line.startswith("CSV "):
            _, name, us, derived = line.split(" ", 3)
            emit(name, float(us), derived)


def emit_rows(emit):
    _run_sub(emit, _SCRIPT, "daso_step_microbench_FAILED")
    # single device: the virtual-node replica axis needs no mesh, and the
    # host-dispatch overhead being measured is device-count independent
    _run_sub(emit, _CYCLE_SCRIPT, "daso_macro_cycle_FAILED", devices=1)
