"""Measured (wall-clock) microbenchmarks of the DASO step variants on an
8-virtual-device (2 pods x 2 data x 2 model) CPU mesh, via subprocess so the
main process keeps one device. Times are real; they validate the *relative*
cost ordering (local < send < blocking), not TPU magnitudes.

Also benchmarks the compiled macro-cycle executor (core/executor.py) against
the per-step path on a cycling-phase schedule: same numerics, host dispatches
per B=4 cycle reduced from B+1 step launches to 1 compiled program."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = """
import time
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.daso import DasoConfig, daso_train_step, replicate_params
from repro.optim.optimizers import sgd

def loss_fn(params, batch):
    h = jnp.tanh(batch["x"] @ params["w1"])
    pred = h @ params["w2"]
    return jnp.mean((pred - batch["y"]) ** 2), {}

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
R, per, d, h = 2, 32, 256, 512
key = jax.random.PRNGKey(0)
params0 = {"w1": jax.random.normal(key, (d, h)) * 0.05,
           "w2": jax.random.normal(key, (h, d)) * 0.05}
opt = sgd(momentum=0.9)
cfg = DasoConfig(n_replicas=R, global_world=8)
shp = NamedSharding(mesh, P("pod"))
shb = NamedSharding(mesh, P("pod", "data"))
p = jax.tree.map(lambda x: jax.device_put(x, shp), replicate_params(params0, R))
o = jax.tree.map(lambda x: jax.device_put(x, shp),
                 replicate_params(opt.init(params0), R))
infl = jax.tree.map(lambda x: x, p)
batch = {"x": jax.device_put(jax.random.normal(key, (R, per, d)), shb),
         "y": jax.device_put(jax.random.normal(key, (R, per, d)), shb)}
for mode in ("local", "send", "receive", "blocking"):
    step = jax.jit(daso_train_step(loss_fn, opt, cfg, mode=mode, staleness=1))
    out = step(p, o, infl, batch, 0.01)
    jax.block_until_ready(out)
    n = 30
    t0 = time.perf_counter()
    for _ in range(n):
        p2, o2, infl2, m = step(p, o, infl, batch, 0.01)
    jax.block_until_ready((p2, o2, infl2))
    dt = (time.perf_counter() - t0) / n * 1e6
    print(f"CSV daso_step_{mode} {dt:.1f} mesh=2x2x2")
"""


_CYCLE_SCRIPT = """
import time
import jax, jax.numpy as jnp
from repro.core.daso import DasoConfig
from repro.core.executor import MacroCycleExecutor, make_strategy
from repro.core.schedule import DasoController
from repro.optim.optimizers import sgd

def loss_fn(params, batch):
    h = jnp.tanh(batch["x"] @ params["w1"])
    pred = h @ params["w2"]
    return jnp.mean((pred - batch["y"]) ** 2), {}

# deliberately tiny: this benchmark isolates the host-dispatch overhead the
# macro-cycle executor removes (small step times = controller-dominated
# wall-clock, the regime the tentpole targets)
R, per, d, h, B = 2, 8, 64, 64, 4
key = jax.random.PRNGKey(0)
params0 = {"w1": jax.random.normal(key, (d, h)) * 0.05,
           "w2": jax.random.normal(key, (h, d)) * 0.05}
def data_fn(step):
    k = jax.random.fold_in(key, step)
    return {"x": jax.random.normal(k, (R, per, d)),
            "y": jax.random.normal(k, (R, per, d))}
# pure cycling phase (no warm-up/cool-down), frozen B/W: every cycle is the
# same (send, receive, local, local) shape
cfg = DasoConfig(n_replicas=R, global_world=8, b_max=B)
strat = make_strategy("daso", loss_fn, sgd(momentum=0.9), cfg,
                      controller=DasoController(cfg, loss_window=10**9))
ex = MacroCycleExecutor(strat)
plan = strat.plan_cycle(0, 32)
assert len(plan) == B, plan.shape
steps = list(range(B))
batches = jax.tree.map(lambda *xs: jnp.stack(xs),
                       *[data_fn(t) for t in steps])
lrs = jnp.asarray([0.01] * B, jnp.float32)
stepwise = [jax.jit(strat.step_fn(m, s)) for m, s in plan.shape]

# warm both paths (compile), threading the carry (run_cycle donates it)
carry = strat.init_carry(params0)
carry, _ = ex.run_cycle(carry, plan, batches, lrs)
for i, fn in enumerate(stepwise):
    carry, _ = fn(carry, jax.tree.map(lambda x, j=i: x[j], batches), lrs[i])
jax.block_until_ready(carry)

# Both timed loops reproduce what the host loop really does per step/cycle:
# dispatch + blocking metrics readback (the controller consumes the loss).
n = 30
ex.stats.dispatches = 0
t0 = time.perf_counter()
for _ in range(n):
    carry, m = ex.run_cycle(carry, plan, batches, lrs)
    _ = float(m["loss"][0])        # one readback per cycle
jax.block_until_ready(carry)
t_macro = (time.perf_counter() - t0) / n * 1e6
d_macro = ex.stats.dispatches / n  # = 1: one fused program per cycle

t0 = time.perf_counter()
for _ in range(n):
    for i, fn in enumerate(stepwise):
        carry, m = fn(carry, jax.tree.map(lambda x, j=i: x[j], batches),
                      lrs[i])
        _ = float(m["loss"])       # one readback per step
jax.block_until_ready(carry)
t_step = (time.perf_counter() - t0) / n * 1e6
# per cycle the old loop pays B step launches plus the blocking metrics
# round-trip that separates cycles: the issue's "B+1" host dispatches
d_step = len(stepwise) + 1

print(f"CSV daso_macro_cycle_compiled {t_macro:.1f} "
      f"host_dispatches_per_cycle={d_macro:.0f} (B={B})")
print(f"CSV daso_macro_cycle_stepwise {t_step:.1f} "
      f"host_dispatches_per_cycle=B+1={d_step} "
      f"({len(stepwise)} step launches + blocking metrics round-trip)")
print(f"CSV daso_macro_cycle_speedup {t_step / max(t_macro, 1e-9):.3f} "
      f"host_dispatches_per_cycling_cycle: B+1={d_step} -> {d_macro:.0f}")
"""


_EXCHANGE_SCRIPT = """
import json
import os
import time
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.daso import (replica_mean, replica_mean_per_leaf,
                             replicate_params)
from repro.core.compression import transfer_bytes
from repro.launch.hlo_stats import collective_stats

QUICK = os.environ.get("BENCH_QUICK") == "1"
OUT = os.environ.get("BENCH_EXCHANGE_OUT", "BENCH_exchange.json")

# A transformer-ish pytree: many leaves of mixed sizes (16 blocks x 7
# leaves -> 112 leaves, ~525k params). The per-leaf path pays one
# cross-replica all-reduce + wire cast per leaf; the fused arena path
# pays exactly one, whatever this count is.
R = 2
n_blocks = 8 if QUICK else 16
dims = (32, 64) if QUICK else (64, 128)
key = jax.random.PRNGKey(0)
tree = {}
for l in range(n_blocks):
    k = jax.random.fold_in(key, l)
    d, f = dims
    tree[f"layer{l}"] = {
        "wq": jax.random.normal(jax.random.fold_in(k, 0), (d, d)),
        "wk": jax.random.normal(jax.random.fold_in(k, 1), (d, d)),
        "wv": jax.random.normal(jax.random.fold_in(k, 2), (d, d)),
        "wo": jax.random.normal(jax.random.fold_in(k, 3), (d, d)),
        "w_up": jax.random.normal(jax.random.fold_in(k, 4), (d, f)),
        "w_down": jax.random.normal(jax.random.fold_in(k, 5), (f, d)),
        "scale": jax.random.normal(jax.random.fold_in(k, 6), (d,)),
    }
n_leaves = len(jax.tree.leaves(tree))
n_params = sum(x.size for x in jax.tree.leaves(tree))

mesh = jax.make_mesh((2,), ("pod",))
mesh_shape = {"pod": 2}
sh = NamedSharding(mesh, P("pod"))
params = jax.tree.map(lambda x: jax.device_put(x, sh),
                      replicate_params(tree, R))
params = jax.tree.map(
    lambda x: x + jnp.arange(R, dtype=x.dtype).reshape(
        (R,) + (1,) * (x.ndim - 1)), params)

def bench(name, fn, *args, wire_format=None, impl=None):
    jitted = jax.jit(fn)
    out = jitted(*args)
    jax.block_until_ready(out)
    hlo = jitted.lower(*args).compile().as_text()
    stats = collective_stats(hlo, mesh_shape)
    ar = sum(v["count"] for k, v in stats.items()
             if isinstance(v, dict) and k.startswith("all-reduce"))
    n = 10 if QUICK else 30
    t0 = time.perf_counter()
    for _ in range(n):
        out = jitted(*args)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / n * 1e6
    rec = {"name": name, "impl": impl, "wire_format": wire_format,
           "us_per_exchange": us, "all_reduce_ops": ar}
    if wire_format:
        rec["transfer_bytes"] = transfer_bytes(tree,
                                               wire_format=wire_format)
    results.append(rec)
    print(f"CSV exchange_{name} {us:.1f} "
          f"all_reduce_ops={ar} wire={wire_format} impl={impl}")
    return us

results = []
for wf, wd in (("f32", None), ("bf16", jnp.bfloat16)):
    bench(f"per_leaf_{wf}",
          lambda p, wd=wd: replica_mean_per_leaf(p, wd), params,
          wire_format=wf, impl="per_leaf")
for wf in ("f32", "bf16", "int8"):
    bench(f"fused_{wf}",
          lambda p, wf=wf: replica_mean(p, wire_format=wf), params,
          wire_format=wf, impl="fused")

by = {r["name"]: r for r in results}
tb = {r["wire_format"]: r["transfer_bytes"] for r in results
      if r.get("transfer_bytes")}
derived = {
    "fused_speedup_f32": by["per_leaf_f32"]["us_per_exchange"]
    / by["fused_f32"]["us_per_exchange"],
    "fused_speedup_bf16": by["per_leaf_bf16"]["us_per_exchange"]
    / by["fused_bf16"]["us_per_exchange"],
    "all_reduce_ops_per_leaf": by["per_leaf_f32"]["all_reduce_ops"],
    "all_reduce_ops_fused": by["fused_f32"]["all_reduce_ops"],
    "int8_vs_bf16_bytes": tb["int8"] / tb["bf16"],
}
record = {"benchmark": "exchange",
          "config": {"n_replicas": R, "n_leaves": n_leaves,
                     "n_params": int(n_params), "quick": QUICK,
                     "mesh": "pod=2"},
          "results": results, "derived": derived}
with open(OUT, "w") as f:
    json.dump(record, f, indent=2)
print(f"CSV exchange_speedup_f32 {derived['fused_speedup_f32']:.3f} "
      f"all_reduce_ops {by['per_leaf_f32']['all_reduce_ops']} -> "
      f"{by['fused_f32']['all_reduce_ops']} (leaves={n_leaves})")
print(f"CSV exchange_int8_vs_bf16_bytes "
      f"{derived['int8_vs_bf16_bytes']:.3f} json={OUT}")
"""


def _run_sub(emit, script, fail_tag, *, devices=8, extra_env=None):
    env = dict(os.environ)
    if devices > 1:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.update(extra_env or {})
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=600, env=env)
    if r.returncode != 0:
        emit(fail_tag, 0.0, r.stderr[-200:])
        return
    for line in r.stdout.splitlines():
        if line.startswith("CSV "):
            _, name, us, derived = line.split(" ", 3)
            emit(name, float(us), derived)


def emit_rows(emit):
    _run_sub(emit, _SCRIPT, "daso_step_microbench_FAILED")
    # single device: the virtual-node replica axis needs no mesh, and the
    # host-dispatch overhead being measured is device-count independent
    _run_sub(emit, _CYCLE_SCRIPT, "daso_macro_cycle_FAILED", devices=1)


def emit_exchange_rows(emit, *, quick=False):
    """Fused flat-buffer exchange vs the legacy per-leaf path, across wire
    formats, on a 2-device (pod) mesh. Writes the perf record to
    $BENCH_EXCHANGE_OUT (default ./BENCH_exchange.json)."""
    _run_sub(emit, _EXCHANGE_SCRIPT, "exchange_microbench_FAILED",
             devices=2, extra_env={"BENCH_QUICK": "1" if quick else "0"})
