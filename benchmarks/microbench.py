"""Measured (wall-clock) microbenchmarks of the DASO step variants on an
8-virtual-device (2 pods x 2 data x 2 model) CPU mesh, via subprocess so the
main process keeps one device. Times are real; they validate the *relative*
cost ordering (local < send < blocking), not TPU magnitudes."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = """
import time
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.daso import DasoConfig, daso_train_step, replicate_params
from repro.optim.optimizers import sgd

def loss_fn(params, batch):
    h = jnp.tanh(batch["x"] @ params["w1"])
    pred = h @ params["w2"]
    return jnp.mean((pred - batch["y"]) ** 2), {}

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
R, per, d, h = 2, 32, 256, 512
key = jax.random.PRNGKey(0)
params0 = {"w1": jax.random.normal(key, (d, h)) * 0.05,
           "w2": jax.random.normal(key, (h, d)) * 0.05}
opt = sgd(momentum=0.9)
cfg = DasoConfig(n_replicas=R, global_world=8)
shp = NamedSharding(mesh, P("pod"))
shb = NamedSharding(mesh, P("pod", "data"))
p = jax.tree.map(lambda x: jax.device_put(x, shp), replicate_params(params0, R))
o = jax.tree.map(lambda x: jax.device_put(x, shp),
                 replicate_params(opt.init(params0), R))
infl = jax.tree.map(lambda x: x, p)
batch = {"x": jax.device_put(jax.random.normal(key, (R, per, d)), shb),
         "y": jax.device_put(jax.random.normal(key, (R, per, d)), shb)}
for mode in ("local", "send", "receive", "blocking"):
    step = jax.jit(daso_train_step(loss_fn, opt, cfg, mode=mode, staleness=1))
    out = step(p, o, infl, batch, 0.01)
    jax.block_until_ready(out)
    n = 30
    t0 = time.perf_counter()
    for _ in range(n):
        p2, o2, infl2, m = step(p, o, infl, batch, 0.01)
    jax.block_until_ready((p2, o2, infl2))
    dt = (time.perf_counter() - t0) / n * 1e6
    print(f"CSV daso_step_{mode} {dt:.1f} mesh=2x2x2")
"""


def emit_rows(emit):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(_SCRIPT)],
                       capture_output=True, text=True, timeout=600, env=env)
    if r.returncode != 0:
        emit("daso_step_microbench_FAILED", 0.0, r.stderr[-200:])
        return
    for line in r.stdout.splitlines():
        if line.startswith("CSV "):
            _, name, us, derived = line.split(" ", 3)
            emit(name, float(us), derived)
