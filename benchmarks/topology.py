"""Topology sweep benchmark: the N-level sync schedule vs the legacy
two-level one.

Three measurements, one record (BENCH_topology.json):

  * **equivalence** — lowering the 2-level spec must reproduce the legacy
    (pre-topology) training run BIT-exactly: param/loss deltas recorded,
    asserted 0.0 in CI;
  * **simulator sweep** — real training of the shared tiny MLP under the
    2-level and 3-level schedules (same seed/data): final losses, per-level
    sync counts, outermost-sync fraction, wall us/step. The 3-level run
    shows the schedule trading DCN syncs for cheap mid-tier syncs;
  * **analytic decomposition** — `comm_model.topology_level_costs` for the
    docs' worked chip/host/pod example at ResNet-50 scale: which level pays
    which bytes per step, and the predicted step-time ratio vs the 2-level
    layout (the "which level pays which bytes" table in docs/topologies.md
    is this data).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = """
import json
import os
import time
import jax, jax.numpy as jnp
import numpy as np

from repro.core.daso import DasoConfig
from repro.core.executor import make_strategy, run_compiled_training
from repro.core.schedule import DasoController
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant_lr
from repro.topo import (TopologySpec, build_topology_strategy,
                        daso_config_from, derive_inner_periods)

from benchmarks.comm_model import (topology_level_costs, topology_step_s)

QUICK = os.environ.get("BENCH_QUICK") == "1"
OUT = os.environ.get("BENCH_TOPOLOGY_OUT", "BENCH_topology.json")

R, per, d = 4, 8, 8
n_steps = 60 if QUICK else 120
key = jax.random.PRNGKey(0)
w1 = jax.random.normal(key, (d, 16)) * 0.5
k1, k2 = jax.random.split(jax.random.fold_in(key, 7))
params0 = {"w1": jax.random.normal(k1, (d, 16)) * 0.3,
           "w2": jax.random.normal(k2, (16, 1)) * 0.3}

def loss_fn(params, batch):
    h = jnp.tanh(batch["x"] @ params["w1"])
    pred = h @ params["w2"]
    return jnp.mean((pred - batch["y"]) ** 2), {}

def data_fn(step):
    k = jax.random.fold_in(key, step)
    x = jax.random.normal(k, (R, per, d))
    return {"x": x, "y": jnp.tanh(x @ w1).sum(-1, keepdims=True) * 0.3}

SPEC2 = "chip:4 x pod:4"
SPEC3 = "chip:4 x host:2 x pod:2"

def run_spec(spec_str):
    spec = TopologySpec.parse(spec_str)
    cfg = daso_config_from(spec, warmup_steps=n_steps // 10,
                           cooldown_steps=n_steps // 10,
                           total_steps=n_steps)
    strat = build_topology_strategy(loss_fn, sgd(momentum=0.9,
                                                 weight_decay=1e-4),
                                    spec, cfg, loss_window=20)
    t0 = time.perf_counter()
    res = run_compiled_training(strat, params0, data_fn, constant_lr(0.1),
                                n_steps)
    wall = time.perf_counter() - t0
    return spec, res, wall

def run_legacy():
    cfg = DasoConfig(n_replicas=R, global_world=4 * R, b_max=4,
                     warmup_steps=n_steps // 10,
                     cooldown_steps=n_steps // 10, total_steps=n_steps)
    strat = make_strategy("daso", loss_fn,
                          sgd(momentum=0.9, weight_decay=1e-4), cfg,
                          controller=DasoController(cfg, loss_window=20))
    return run_compiled_training(strat, params0, data_fn, constant_lr(0.1),
                                 n_steps)

legacy = run_legacy()
spec2, two, wall2 = run_spec(SPEC2)
spec3, three, wall3 = run_spec(SPEC3)

param_delta = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(jax.tree.leaves(two.params),
                                  jax.tree.leaves(legacy.params)))
loss_delta = max(abs(a - b) for a, b in zip(two.losses, legacy.losses))

# analytic decomposition at ResNet-50 scale (97.5M params f32)
PB = 97.5e6 * 4
spec3_model = TopologySpec.parse("chip:4 x host:4@50e9 x pod:8@25e9")
spec2_model = TopologySpec.parse("chip:4 x pod:32@25e9")
rows = topology_level_costs(spec3_model, PB, b_max=4, ib_eff=0.10)
t3 = topology_step_s(spec3_model, PB, ib_eff=0.10)
t2 = topology_step_s(spec2_model, PB, ib_eff=0.10)
# same pair under a 0.25x-degraded DCN (the fault-plan scenario): the
# hierarchy keeps only 8 members on the slow tier instead of 32, so the
# degradation hurts the 2-level layout more
t3_deg = topology_step_s(spec3_model, PB, ib_eff=0.10, dcn_scale=0.25)
t2_deg = topology_step_s(spec2_model, PB, ib_eff=0.10, dcn_scale=0.25)

derived = {
    "two_level_param_delta": param_delta,
    "two_level_loss_delta": loss_delta,
    "two_level_final_loss": two.final_loss,
    "three_level_final_loss": three.final_loss,
    "final_loss_gap_3v2": three.final_loss - two.final_loss,
    "two_level_sync_fraction": two.sync_fraction,
    "three_level_sync_fraction": three.sync_fraction,
    "three_level_sync_counts": three.controller.level_sync_counts(),
    "three_level_inner_periods": derive_inner_periods(spec3, b_max=4),
    "us_per_step_two_level": wall2 / n_steps * 1e6,
    "us_per_step_three_level": wall3 / n_steps * 1e6,
    "analytic_level_rows": rows,
    "analytic_step_s_three_level": t3,
    "analytic_step_s_two_level": t2,
    "analytic_step_ratio_3v2": t3 / t2,
    "analytic_step_ratio_3v2_degraded_dcn": t3_deg / t2_deg,
}
record = {"benchmark": "topology",
          "config": {"n_replicas": R, "n_steps": n_steps, "quick": QUICK,
                     "spec2": spec2.to_str(), "spec3": spec3.to_str(),
                     "spec2_model": spec2_model.to_str(),
                     "spec3_model": spec3_model.to_str(),
                     "param_bytes_model": PB, "b_max": 4},
          "derived": derived}
with open(OUT, "w") as f:
    json.dump(record, f, indent=2)
print(f"CSV topology_two_level_bitexact {0.0:.1f} "
      f"param_delta={param_delta} loss_delta={loss_delta}")
print(f"CSV topology_three_level_train {wall3 / n_steps * 1e6:.1f} "
      f"final={three.final_loss:.4f} "
      f"sync_frac={three.sync_fraction:.3f} "
      f"host_syncs={derived['three_level_sync_counts'].get('host', 0)}")
print(f"CSV topology_analytic_step_ratio {0.0:.1f} "
      f"3v2={t3 / t2:.3f} 3v2_degraded_dcn={t3_deg / t2_deg:.3f} "
      f"json={OUT}")
"""


def emit_rows(emit, *, quick=False):
    """2-level-vs-legacy bit-exactness + 2-vs-3-level schedule sweep on the
    single-device simulator + the analytic per-level decomposition. Writes
    the record to $BENCH_TOPOLOGY_OUT (default ./BENCH_topology.json)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (SRC + os.pathsep
                         + os.path.join(os.path.dirname(__file__), "..")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env["BENCH_QUICK"] = "1" if quick else "0"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(_SCRIPT)],
                       capture_output=True, text=True, timeout=900, env=env)
    if r.returncode != 0:
        emit("topology_sweep_FAILED", 0.0, r.stderr[-200:])
        return
    for line in r.stdout.splitlines():
        if line.startswith("CSV "):
            _, name, us, derived = line.split(" ", 3)
            emit(name, float(us), derived)
