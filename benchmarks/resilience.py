"""Resilience microbenchmarks: recovery cost and convergence impact of node
failures, plus the full-state checkpoint/resume round-trip. Real runs of the
supervisor (resilience/supervisor.py) on a tiny MLP — wall-clock recovery
numbers are real; the DCN-degradation exchange costs come from the analytic
cluster model (comm_model.degraded_exchange_s). Writes BENCH_resilience.json
(consumed by CI's resilience-smoke job and EXPERIMENTS.md)."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = """
import json
import os
import time
import jax, jax.numpy as jnp
import numpy as np

from repro.core.daso import DasoConfig
from repro.core.executor import MacroCycleExecutor, make_strategy
from repro.core.schedule import DasoController
from repro.checkpoint.io import TrainState, load_train_state, save_train_state
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant_lr
from repro.resilience.faults import FaultPlan
from repro.resilience.supervisor import run_with_faults
from repro.train.loop import TrainLoopConfig, run_training

from benchmarks.comm_model import ClusterModel, degraded_exchange_s

QUICK = os.environ.get("BENCH_QUICK") == "1"
OUT = os.environ.get("BENCH_RESILIENCE_OUT", "BENCH_resilience.json")

R, per, d, h = 4, 8, 64, 64
n_steps = 60 if QUICK else 140
key = jax.random.PRNGKey(0)
params0 = {"w1": jax.random.normal(key, (d, h)) * 0.05,
           "w2": jax.random.normal(jax.random.fold_in(key, 1), (h, d)) * 0.05}
wtrue = jax.random.normal(jax.random.fold_in(key, 2), (d, d))

def loss_fn(params, batch):
    hh = jnp.tanh(batch["x"] @ params["w1"])
    return jnp.mean((hh @ params["w2"] - batch["y"]) ** 2), {}

def data_fn(step):
    k = jax.random.fold_in(key, step)
    x = jax.random.normal(k, (R, per, d))
    return {"x": x, "y": jnp.tanh(x @ wtrue) * 0.5}

param_bytes = sum(x.size for x in jax.tree.leaves(params0)) * 4.0
cm = ClusterModel()
exchange_fn = lambda n, s: degraded_exchange_s(param_bytes, n, cm,
                                               dcn_scale=s)

def strategy():
    cfg = DasoConfig(n_replicas=R, global_world=4 * R, b_max=4,
                     warmup_steps=n_steps // 10,
                     cooldown_steps=n_steps // 10, total_steps=n_steps)
    return make_strategy("daso", loss_fn, sgd(momentum=0.9), cfg,
                         controller=DasoController(cfg, loss_window=20))

def faulty_run(name, events):
    plan = FaultPlan.from_dicts(events)
    plan.validate(R)
    t0 = time.perf_counter()
    rep = run_with_faults(strategy(), params0, data_fn, constant_lr(0.1),
                          n_steps, plan, t_compute_s=0.120,
                          exchange_cost_fn=exchange_fn)
    wall = time.perf_counter() - t0
    rec = {"name": name, "n_events": len(plan.events),
           "final_loss": rep.result.final_loss,
           "recovery_s": rep.recovery_s(),
           "handle_s": [e["handle_s"] for e in rep.applied
                        if e["kind"] in ("crash", "rejoin")],
           "invalidations": rep.invalidations,
           "simulated_time_s": rep.simulated_time_s,
           "wall_s": wall}
    results.append(rec)
    rtot = sum(rec["recovery_s"])
    print(f"CSV resilience_{name} {wall * 1e6:.1f} "
          f"final_loss={rep.result.final_loss:.4f} "
          f"recovery_total={rtot * 1e3:.1f}ms "
          f"sim_time={rep.simulated_time_s:.1f}s")
    return rec

results = []

# -- fault-free baseline vs K in-flight failures ------------------------
base = faulty_run("fault_free", [])
k1 = faulty_run("crash1_rejoin", [
    {"step": n_steps // 3, "kind": "crash", "replica": 3},
    {"step": 2 * n_steps // 3, "kind": "rejoin", "replica": 3}])
k2 = faulty_run("crash2_rejoin", [
    {"step": n_steps // 4, "kind": "crash", "replica": 3},
    {"step": n_steps // 3, "kind": "crash", "replica": 2},
    {"step": 2 * n_steps // 3, "kind": "rejoin", "replica": 3},
    {"step": 3 * n_steps // 4, "kind": "rejoin", "replica": 2}])
degraded = faulty_run("degraded_dcn", [
    {"step": n_steps // 3, "kind": "degrade_dcn", "factor": 0.25},
    {"step": 2 * n_steps // 3, "kind": "restore_dcn"}])

# -- checkpoint/resume round-trip ---------------------------------------
loop = TrainLoopConfig(strategy="daso", n_steps=n_steps, n_replicas=R,
                       loss_window=20)
fresh = run_training(loss_fn, params0, data_fn, loop, log=None)
import tempfile
ckpt_dir = tempfile.mkdtemp(prefix="bench_resilience_ckpt_")
ck = TrainLoopConfig(**{**loop.__dict__, "ckpt_every": n_steps // 2,
                        "ckpt_dir": ckpt_dir})
t0 = time.perf_counter()
run_training(loss_fn, params0, data_fn, ck, log=None)
state_dir = os.path.join(ckpt_dir, sorted(os.listdir(ckpt_dir))[0])
t_save_run = time.perf_counter() - t0
t0 = time.perf_counter()
ts = load_train_state(state_dir)
load_s = time.perf_counter() - t0
rs = TrainLoopConfig(**{**loop.__dict__, "resume_from": state_dir})
resumed = run_training(loss_fn, params0, data_fn, rs, log=None)
param_delta = max(float(np.max(np.abs(np.asarray(a, np.float32)
                                      - np.asarray(b, np.float32))))
                  for a, b in zip(jax.tree.leaves(resumed.params),
                                  jax.tree.leaves(fresh.params)))
loss_delta = float(np.max(np.abs(np.asarray(resumed.losses, np.float32)
                                 - np.asarray(fresh.losses, np.float32))))
results.append({"name": "resume", "resume_from_step": ts.step,
                "load_s": load_s, "param_delta": param_delta,
                "loss_delta": loss_delta})
print(f"CSV resilience_resume {load_s * 1e6:.1f} "
      f"from_step={ts.step} param_delta={param_delta:.2e} "
      f"loss_delta={loss_delta:.2e}")

# -- live kill e2e: real SIGKILL, supervised regroup, oracle delta ------
# the same scenario tests/test_live_faults.py asserts, measured: a 2-proc
# group loses rank 1 to SIGKILL at step 6, the launcher detects, regroups
# onto 1 proc over the full world, and the finished params are compared
# against the simulated fault-plan oracle
import glob
import subprocess
import sys
import tempfile

REPO = os.environ["BENCH_REPO_ROOT"]
LAUNCHER = os.path.join(REPO, "tools", "launch_procs.py")
WATCHDOG_S = 120.0
live_steps = 12 if QUICK else 16
tmp = tempfile.mkdtemp(prefix="bench_live_")
base_args = ["--arch", "llama3.2-1b", "--tiny",
             "--topology", "chip:1 x host:2 x pod:2",
             "--per-node-batch", "2", "--seq-len", "16", "--b-max", "4",
             "--seed", "0"]
report_path = os.path.join(tmp, "report.json")
live_ckpt = os.path.join(tmp, "ck_live")
live_metrics = os.path.join(tmp, "m_live.json")
r = subprocess.run(
    [sys.executable, LAUNCHER, "--procs", "2", "--kill", "1:6",
     "--watchdog", str(WATCHDOG_S), "--timeout", "600", "--quiet",
     "--report", report_path, "--"] + base_args +
    ["--steps", str(live_steps), "--ckpt", live_ckpt, "--ckpt-every", "1",
     "--metrics-out", live_metrics],
    capture_output=True, text=True, timeout=700, cwd=REPO)
if r.returncode != 0:
    raise SystemExit(f"live supervised run failed ({r.returncode}):\\n"
                     f"{r.stdout[-2000:]}\\n{r.stderr[-2000:]}")
with open(report_path) as f:
    live_report = json.load(f)
with open(live_metrics) as f:
    live_meta = json.load(f)["resilience"]["live"]

plan_path = os.path.join(tmp, "oracle_plan.json")
with open(plan_path, "w") as f:
    json.dump({"events": [{"step": live_meta["crash_step"],
                           "kind": "crash", "replica": rr}
                          for rr in live_meta["dead_replicas"]]}, f)
oracle_ckpt = os.path.join(tmp, "ck_oracle")
r = subprocess.run(
    [sys.executable, LAUNCHER, "--procs", "1", "--timeout", "600",
     "--quiet", "--"] + base_args +
    ["--steps", str(live_steps), "--fault-plan", plan_path,
     "--ckpt", oracle_ckpt, "--ckpt-every", "1"],
    capture_output=True, text=True, timeout=700, cwd=REPO)
if r.returncode != 0:
    raise SystemExit(f"live oracle run failed ({r.returncode}):\\n"
                     f"{r.stdout[-2000:]}\\n{r.stderr[-2000:]}")

live_delta = 0.0
pairs = list(zip(sorted(glob.glob(os.path.join(live_ckpt, "*.npz"))),
                 sorted(glob.glob(os.path.join(oracle_ckpt, "*.npz")))))
assert pairs, "no final checkpoints to compare"
for fa, fb in pairs:
    a, b = np.load(fa), np.load(fb)
    for k in a.files:
        if k == "__save_id__":
            continue
        live_delta = max(live_delta,
                         float(np.max(np.abs(a[k].astype(np.float64)
                                             - b[k].astype(np.float64)))))
timings = live_report["timings"]
results.append({"name": "live_kill", "steps": live_steps,
                "kill": live_report["kill"],
                "dead_replicas": live_report["dead_replicas"],
                "crash_step": live_meta["crash_step"],
                "epochs": live_report["epochs"], "timings": timings,
                "oracle_param_delta": live_delta})
print(f"CSV resilience_live_kill {timings['total_s'] * 1e6:.1f} "
      f"detect={timings['detect_s']:.2f}s "
      f"regroup={timings['regroup_s']:.2f}s "
      f"resume={timings['resume_s']:.2f}s "
      f"oracle_delta={live_delta:.1e}")

by = {r["name"]: r for r in results}
derived = {
    "loss_delta_k1": k1["final_loss"] - base["final_loss"],
    "loss_delta_k2": k2["final_loss"] - base["final_loss"],
    "loss_delta_degraded_dcn": degraded["final_loss"] - base["final_loss"],
    "recovery_s_mean": float(np.mean(k1["recovery_s"]
                                     + k2["recovery_s"])),
    "handle_s_mean": float(np.mean(k1["handle_s"] + k2["handle_s"])),
    "invalidations_per_membership_event": 1.0,
    "resume_param_delta": by["resume"]["param_delta"],
    "resume_loss_delta": by["resume"]["loss_delta"],
    # analytic: a 0.25x DCN makes one exchange ~4x more expensive; the
    # controller stretches B to compensate (schedule.notify_dcn_scale)
    "degraded_exchange_cost_ratio":
        exchange_fn(R, 0.25) / exchange_fn(R, 1.0),
    # live fault plane: measured on a real SIGKILL + regroup (see above)
    "live_detect_s": timings["detect_s"],
    "live_regroup_s": timings["regroup_s"],
    "live_resume_s": timings["resume_s"],
    "live_total_s": timings["total_s"],
    "live_detect_within_budget":
        1.0 if timings["detect_s"] < WATCHDOG_S else 0.0,
    "live_oracle_param_delta": live_delta,
}
record = {"benchmark": "resilience",
          "config": {"n_replicas": R, "n_steps": n_steps,
                     "n_params": int(param_bytes // 4), "quick": QUICK,
                     "b_max": 4, "t_compute_s": 0.120},
          "results": results, "derived": derived}
with open(OUT, "w") as f:
    json.dump(record, f, indent=2)
print(f"CSV resilience_loss_delta_k1 {0.0:.1f} "
      f"{derived['loss_delta_k1']:+.4f} json={OUT}")
print(f"CSV resilience_recovery_mean "
      f"{derived['recovery_s_mean'] * 1e6:.1f} "
      f"handle_mean={derived['handle_s_mean'] * 1e3:.2f}ms")
"""


def emit_rows(emit, *, quick=False):
    """Recovery/loss-delta microbench + checkpoint resume round-trip on a
    single device (the supervisor host path is device-count independent),
    plus the live-kill e2e (2-process SIGKILL + supervised regroup, timed
    and oracle-compared). Writes the perf record to $BENCH_RESILIENCE_OUT
    (default ./BENCH_resilience.json)."""
    repo = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = (SRC + os.pathsep + repo
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env["BENCH_QUICK"] = "1" if quick else "0"
    env["BENCH_REPO_ROOT"] = repo
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(_SCRIPT)],
                       capture_output=True, text=True, timeout=1500,
                       env=env)
    if r.returncode != 0:
        emit("resilience_microbench_FAILED", 0.0, r.stderr[-200:])
        return
    for line in r.stdout.splitlines():
        if line.startswith("CSV "):
            _, name, us, derived = line.split(" ", 3)
            emit(name, float(us), derived)
