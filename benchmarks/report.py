"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json.

  python -m benchmarks.report dryrun    -> §Dry-run markdown table
  python -m benchmarks.report daso      -> cross-pod traffic comparison
  python -m benchmarks.report roofline  -> §Roofline markdown table
"""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
ARCHS = ("musicgen-large", "falcon-mamba-7b", "qwen3-8b", "llama3.2-1b",
         "moonshot-v1-16b-a3b", "recurrentgemma-9b", "granite-moe-3b-a800m",
         "minitron-8b", "qwen2-vl-2b", "mixtral-8x22b")


def _load(name):
    p = os.path.join(DRYRUN, name + ".json")
    if not os.path.exists(p):
        return None
    r = json.load(open(p))
    return r if r.get("ok") else None


def _gb(x):
    return f"{x / 2**30:.2f}"


def dryrun_table():
    print("| arch | shape | mesh | variant | peak GiB/dev | HLO GFLOP/dev |"
          " coll MB/dev | compile s |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCHS:
        for shape in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                r = _load(f"{arch}__{shape}__{mesh}")
                if not r:
                    print(f"| {arch} | {shape} | {mesh} | MISSING | | | | |")
                    continue
                print(f"| {arch} | {shape} | {mesh} | {r['variant']}"
                      f"{' +fsdp' if r.get('fsdp') else ''} |"
                      f" {_gb(r['memory']['peak_estimate_per_device'])} |"
                      f" {r['cost']['flops'] / 1e9:.0f} |"
                      f" {r['collectives']['_total_bytes'] / 1e6:.0f} |"
                      f" {r['compile_s']:.0f} |")


def daso_table():
    print("| arch | sync cross-pod MB/step | daso cycle MB/step (B=4) |"
          " reduction |")
    print("|---|---|---|---|")
    for arch in ARCHS:
        sync = _load(f"{arch}__train_4k__2x16x16")
        daso = _load(f"{arch}__train_4k__2x16x16__daso")
        if not (sync and daso):
            print(f"| {arch} | ? | ? | ? |")
            continue

        def pod_bytes(r):
            return sum(v["bytes"] for k, v in r["collectives"].items()
                       if isinstance(v, dict) and "pod" in k.split("@")[1])

        s = pod_bytes(sync)
        d = pod_bytes(daso) / 4.0  # amortize the 4-step cycle
        red = 100 * (1 - d / s) if s else float("nan")
        print(f"| {arch} | {s / 1e6:.1f} | {d / 1e6:.1f} | {red:.1f}% |")


def roofline_table():
    from benchmarks.roofline import build_table
    rows = build_table()
    print("| arch | shape | compute ms | memory ms | collective ms |"
          " dominant | useful/HLO flops | fits 16G | extrap |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} |"
              f" {r['compute_s'] * 1e3:.2f} | {r['memory_s'] * 1e3:.2f} |"
              f" {r['collective_s'] * 1e3:.3f} | {r['dominant']} |"
              f" {r['useful_flops_ratio']:.2f} |"
              f" {'Y' if r['fits_hbm'] else 'N'} |"
              f" {'Y' if r['extrapolated'] else 'N'} |")


if __name__ == "__main__":
    {"dryrun": dryrun_table, "daso": daso_table,
     "roofline": roofline_table}[sys.argv[1]]()
