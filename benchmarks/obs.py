"""Observability benchmark: what does tracing cost, and does the run
trace actually carry the full story?

Two identical supervised 2-process legs of the tiny-LM live-kill
scenario (the resilience bench's e2e shape: SIGKILL proc 1 at step 6,
watchdog detection, regroup onto the survivor, finish) — one with
``--trace-out``, one without:

  * traced   — per-process JSONL streams across BOTH coordinator epochs,
    merged by the launcher into one run trace; `tools/trace_report.py`
    then validates the schema, checks category coverage (executor spans,
    schedule decision events, resilience phases/faults, checkpoint
    saves, comm meters), and prices the drift table.
  * untraced — the tracing-off wall-time denominator.

Headline derived metric, gated by tools/check_bench.py:

    trace_overhead_frac = tracer self-accounted overhead / untraced wall

The overhead is the tracer's OWN cumulative in-band cost (`tracer_self`
counters, summed over every stream of the run) — the number the trace
itself carries — not a wall-clock subtraction, which on a watchdog-paced
supervised run would be dominated by detection-timing noise. The raw
wall times of both legs are still recorded for the eyeball check.

Writes BENCH_obs.json (override with $BENCH_OBS_OUT)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
LAUNCHER = os.path.join(REPO, "tools", "launch_procs.py")
REPORTER = os.path.join(REPO, "tools", "trace_report.py")

TOPOLOGY = "chip:1 x host:2 x pod:2"
PROCS = 2
KILL = "1:6"
WATCHDOG_S = 120.0

#: categories a complete run trace must carry (docs/observability.md):
#: compiled-cycle spans, controller decision events (the regroup replays
#: the death as a membership change), health phases + the fault replay,
#: checkpoint saves, the comm-meter counter, and run_metadata
REQUIRED_CATS = ("executor", "schedule", "resilience", "checkpoint",
                 "meter", "meta")


def _run_leg(name: str, tmp: str, *, steps: int, trace: str | None,
             timeout: float = 900.0) -> dict:
    cmd = [sys.executable, LAUNCHER, "--procs", str(PROCS),
           "--kill", KILL, "--watchdog", str(WATCHDOG_S),
           "--timeout", str(int(timeout) - 60), "--quiet", "--",
           "--arch", "llama3.2-1b", "--tiny", "--topology", TOPOLOGY,
           "--steps", str(steps), "--per-node-batch", "2",
           "--seq-len", "16", "--b-max", "4", "--seed", "0",
           "--ckpt", os.path.join(tmp, f"ck_{name}"), "--ckpt-every", "1",
           "--metrics-out", os.path.join(tmp, f"m_{name}.json")]
    if trace is not None:
        cmd += ["--trace-out", trace]
    t0 = time.perf_counter()
    r = subprocess.run(cmd, capture_output=True, text=True,
                       timeout=timeout, cwd=REPO)
    wall = time.perf_counter() - t0
    if r.returncode != 0:
        raise RuntimeError(
            f"obs bench leg {name!r} exited {r.returncode}:\n"
            f"{(r.stderr or r.stdout)[-2000:]}")
    return {"name": name, "wall_s": wall}


def emit_rows(emit, *, quick: bool = False) -> None:
    """Run the traced/untraced supervised legs, validate + report the
    merged trace, and write the perf record to $BENCH_OBS_OUT (default
    ./BENCH_obs.json)."""
    steps = 12 if quick else 16
    out = os.environ.get("BENCH_OBS_OUT", "BENCH_obs.json")
    tmp = tempfile.mkdtemp(prefix="bench_obs_")
    trace_path = os.path.join(tmp, "trace.jsonl")
    try:
        traced = _run_leg("traced", tmp, steps=steps, trace=trace_path)
        untraced = _run_leg("untraced", tmp, steps=steps, trace=None)
    except (RuntimeError, subprocess.TimeoutExpired) as e:
        emit("obs_bench_FAILED", 0.0, str(e).replace("\n", " ")[-200:])
        return

    # validate + report the merged run trace (exit 1 = schema failure)
    report_json = os.path.join(tmp, "report.json")
    r = subprocess.run(
        [sys.executable, REPORTER, trace_path, "--json", report_json,
         "--validate"],
        capture_output=True, text=True, timeout=120, cwd=REPO)
    if r.returncode != 0:
        emit("obs_bench_FAILED", 0.0,
             f"trace_report exited {r.returncode}: "
             f"{(r.stderr or r.stdout)[-200:]}")
        return
    with open(report_json) as f:
        rep = json.load(f)

    summary = rep["summary"]
    overhead_s = summary.get("_tracer", {}).get("overhead_s", 0.0)
    missing = [c for c in REQUIRED_CATS if c not in summary]
    drift = rep.get("drift") or []
    model_levels = sum(1 for row in drift
                      if row.get("model_sync_s") is not None)

    results = [dict(traced, n_events=rep["n_events"],
                    tracer_overhead_s=overhead_s), untraced]
    for m in results:
        emit(f"obs_{m['name']}", m["wall_s"] * 1e6,
             f"events={m.get('n_events', 0)}")

    derived = {
        # the ISSUE gate: tracing costs <= 3% of the tracing-off wall
        "trace_overhead_frac": overhead_s / untraced["wall_s"],
        "trace_valid": 1.0 if not rep["schema_errors"] else 0.0,
        "trace_events": float(rep["n_events"]),
        "trace_has_required_cats": 1.0 if not missing else 0.0,
        "trace_missing_cats": missing,
        # drift rows priced by the model: one per sync level of the
        # 3-level topology (host + pod)
        "drift_levels_covered": float(model_levels),
        # not gated: watchdog/regroup timing noise dominates this delta
        "wall_overhead_frac": (traced["wall_s"] - untraced["wall_s"])
                              / untraced["wall_s"],
    }
    record = {"benchmark": "obs",
              "config": {"topology": TOPOLOGY, "procs": PROCS,
                         "kill": KILL, "steps": steps,
                         "per_node_batch": 2, "seq_len": 16,
                         "arch": "tiny", "quick": quick},
              "results": results, "derived": derived}
    with open(out, "w") as f:
        json.dump(record, f, indent=2)
    emit("obs_trace_overhead", overhead_s * 1e6,
         f"frac={derived['trace_overhead_frac']:.2e} "
         f"events={rep['n_events']} drift_levels={model_levels} "
         f"json={out}")
