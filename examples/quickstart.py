"""Quickstart: train a tiny assigned-architecture LM with DASO and compare
against the synchronous (Horovod-analog) baseline — the paper's core claim
(equal quality, far less global communication) in ~2 minutes on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_reduced
from repro.data.synthetic import SyntheticLM
from repro.models.lm import init_params
from repro.train.loop import TrainLoopConfig, run_training
from repro.train.step import make_lm_loss


def main():
    cfg = get_reduced("llama3.2-1b").replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=256)
    params0 = init_params(cfg, jax.random.PRNGKey(0))
    loss_fn = make_lm_loss(cfg)
    src = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, seed=0)

    R, per = 4, 8  # 4 virtual "nodes" (pods), 8 sequences each

    def daso_data(step):
        b = src.batch(R * per, step)
        return {k: v.reshape((R, per) + v.shape[1:]) for k, v in b.items()}

    def sync_data(step):
        return src.batch(R * per, step)

    steps = 200
    sync = run_training(loss_fn, params0, sync_data, TrainLoopConfig(
        strategy="sync", n_steps=steps, lr=0.05))
    daso = run_training(loss_fn, params0, daso_data, TrainLoopConfig(
        strategy="daso", n_steps=steps, n_replicas=R, local_world=4,
        b_max=4, lr=0.05))

    print(f"\nsync  final loss: {sync.final_loss:.4f} "
          f"(global sync every step)")
    print(f"DASO  final loss: {daso.final_loss:.4f} "
          f"(global network touched on {daso.sync_fraction:.0%} of steps)")
    gap = abs(daso.final_loss - sync.final_loss) / sync.final_loss
    print(f"relative quality gap: {gap:.2%}  "
          f"<- paper claim: parity with far less global traffic")


if __name__ == "__main__":
    main()
