"""End-to-end multi-pod dry-run walkthrough: lowers the DASO B=4 cycle and
the sync baseline for one architecture on the 2x16x16 production mesh and
prints the cross-pod traffic comparison — the paper's communication-reduction
claim, read directly off the compiled HLO.

  PYTHONPATH=src python examples/multipod_dryrun.py [arch]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

from repro.configs import get_config                       # noqa: E402
from repro.launch.dryrun import build_train_lowering       # noqa: E402
from repro.launch.hlo_stats import collective_stats        # noqa: E402
from repro.launch.mesh import make_production_mesh         # noqa: E402


def pod_bytes(compiled, mesh):
    stats = collective_stats(
        compiled.as_text(), dict(zip(mesh.axis_names, mesh.devices.shape)))
    return sum(v["bytes"] for k, v in stats.items()
               if isinstance(v, dict) and "pod" in k.split("@")[1]), stats


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "llama3.2-1b"
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=True)
    print(f"arch={arch}  mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    lowered, extra = build_train_lowering(cfg, mesh, daso=False)
    sync_pod, _ = pod_bytes(lowered.compile(), mesh)
    print(f"sync step      : cross-pod bytes/step          = {sync_pod:.3e}")

    lowered, extra = build_train_lowering(cfg, mesh, daso=True)
    daso_pod, stats = pod_bytes(lowered.compile(), mesh)
    per_step = daso_pod / 4
    print(f"daso B=4 cycle : cross-pod bytes/cycle          = {daso_pod:.3e}")
    print(f"daso B=4 cycle : cross-pod bytes/step (amortized)= {per_step:.3e}")
    if sync_pod:
        print(f"cross-pod traffic reduction: "
              f"{100 * (1 - per_step / sync_pod):.1f}%  <- paper's mechanism")


if __name__ == "__main__":
    main()
