"""Serving example: batched generation with prefill + one-token decode, on a
reduced config of each serving-relevant architecture family (full GQA cache,
sliding-window ring cache, SSM state, hybrid state).

  PYTHONPATH=src python examples/serve_batch.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_reduced
from repro.models.lm import init_params
from repro.serve.engine import Engine


def main():
    for arch in ["llama3.2-1b", "mixtral-8x22b", "falcon-mamba-7b",
                 "recurrentgemma-9b"]:
        cfg = get_reduced(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        eng = Engine(cfg, params, max_len=96)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                     cfg.vocab_size)
        t0 = time.time()
        out = eng.generate(prompts, max_new_tokens=32)
        jax.block_until_ready(out)
        dt = time.time() - t0
        toks = out.shape[0] * out.shape[1]
        print(f"{arch:22s} generated {out.shape} in {dt:5.1f}s "
              f"({toks / dt:6.1f} tok/s on CPU) "
              f"first row: {list(map(int, out[0][:8]))}")


if __name__ == "__main__":
    main()
