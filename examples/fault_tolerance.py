"""Fault tolerance demo: train a tiny LM with DASO while a scripted fault
plan kills a node mid-cycling, degrades the cross-pod network, and brings
the node back — then prove the checkpoint/resume path reproduces an
uninterrupted run exactly.

  PYTHONPATH=src python examples/fault_tolerance.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.executor import MacroCycleExecutor
from repro.data.synthetic import SyntheticLM
from repro.models.lm import init_params
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant_lr
from repro.resilience.faults import FaultPlan
from repro.resilience.supervisor import run_with_faults
from repro.train.loop import TrainLoopConfig, build_strategy, run_training


def main():
    cfg = get_reduced("llama3.2-1b").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=128)
    from repro.train.step import make_lm_loss
    params0 = init_params(cfg, jax.random.PRNGKey(0))
    loss_fn = make_lm_loss(cfg)
    src = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
    R, per, n_steps = 4, 4, 48

    def data_fn(step):
        b = src.batch(R * per, step)
        return {k: v.reshape((R, per) + v.shape[1:]) for k, v in b.items()}

    loop_cfg = TrainLoopConfig(strategy="daso", n_steps=n_steps,
                               n_replicas=R, b_max=4, loss_window=12)

    # -- 1. scripted failures through the supervisor ------------------------
    plan = FaultPlan.from_dicts([
        {"step": 12, "kind": "crash", "replica": 3},
        {"step": 16, "kind": "degrade_dcn", "factor": 0.25},
        {"step": 28, "kind": "restore_dcn"},
        {"step": 32, "kind": "rejoin", "replica": 3},
    ])
    strategy = build_strategy(loss_fn, loop_cfg, sgd(momentum=0.9))
    ex = MacroCycleExecutor(strategy)
    report = run_with_faults(strategy, params0, data_fn, constant_lr(0.05),
                             n_steps, plan, executor=ex,
                             t_compute_s=0.120,
                             exchange_cost_fn=lambda n, s: 0.030 / s)
    r = report.result
    print(f"[faults] {len(plan.events)} events, final_loss="
          f"{r.final_loss:.4f}, cycle-cache invalidations="
          f"{report.invalidations}, simulated_time="
          f"{report.simulated_time_s:.1f}s")
    for ev in report.applied:
        print(f"[faults]   step {ev['step']:>3} {ev['kind']:<12} "
              f"handle={ev['handle_s'] * 1e3:6.1f}ms "
              f"first_cycle={ev['first_cycle_s'] * 1e3:6.1f}ms")

    # -- 2. deterministic resume -------------------------------------------
    fresh = run_training(loss_fn, params0, data_fn, loop_cfg, log=None)
    with tempfile.TemporaryDirectory() as d:
        ck = TrainLoopConfig(**{**loop_cfg.__dict__,
                                "ckpt_every": 16, "ckpt_dir": d})
        run_training(loss_fn, params0, data_fn, ck, log=None)
        state = sorted(os.listdir(d))[0]
        rs = TrainLoopConfig(**{**loop_cfg.__dict__,
                                "resume_from": os.path.join(d, state)})
        resumed = run_training(loss_fn, params0, data_fn, rs, log=None)
    delta = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
                for a, b in zip(jax.tree.leaves(resumed.params),
                                jax.tree.leaves(fresh.params)))
    print(f"[resume] interrupted-at-{state} vs uninterrupted: "
          f"max|Δparam| = {delta:.2e} "
          f"({'EXACT' if delta == 0.0 else 'allclose'})")


if __name__ == "__main__":
    main()
