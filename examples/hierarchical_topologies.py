"""Hierarchical topologies: the same cluster described as 2 levels vs 3
levels, trained side by side on the single-device simulator.

The paper's DASO has exactly two tiers: GPUs inside a node (synced every
step) and nodes on the slow network (synced every B steps). Real clusters
have more — chips share NVLink, hosts share a rack network, pods share the
DCN. `repro.topo` makes that hierarchy declarative: a spec string lowers to
a mesh, a per-level sync schedule (B_l per level, derived from the
bandwidth ratios), and statically-specialized step variants whose
collectives hit exactly the levels that sync each step. Here both layouts
cover the same 16 workers:

  * ``chip:4 x pod:4``           — the legacy 2-level world: 4 replicas,
    consensus ONLY via the slow outermost exchange every B steps;
  * ``chip:4 x host:2 x pod:2``  — the 3-level world: the same 4 replicas,
    but host pairs also average over their fast mid-tier link every
    B_host steps (derived: 2), between the slow pod exchanges.

Same model, same seed, same data. Watch the mode tokens: the 3-level
schedule runs ``local+host`` / ``receive+host`` steps — cheap mid-tier
consensus the 2-level layout simply cannot express. docs/topologies.md
walks through the lowering model behind this.

  PYTHONPATH=src python examples/hierarchical_topologies.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.topo import TopologySpec, derive_inner_periods
from repro.train.loop import TrainLoopConfig, run_training


def main():
    key = jax.random.PRNGKey(0)
    d, R, per = 8, 4, 16
    w_true = jax.random.normal(key, (d, 16)) * 0.5
    k1, k2 = jax.random.split(jax.random.fold_in(key, 7))
    params0 = {"w1": jax.random.normal(k1, (d, 16)) * 0.3,
               "w2": jax.random.normal(k2, (16, 1)) * 0.3}

    def loss_fn(params, batch):
        h = jnp.tanh(batch["x"] @ params["w1"])
        pred = h @ params["w2"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    def data_fn(step):
        k = jax.random.fold_in(key, step)
        x = jax.random.normal(k, (R, per, d))
        return {"x": x, "y": jnp.tanh(x @ w_true).sum(-1, keepdims=True) * 0.3}

    steps = 200
    runs = {}
    for spec_str in ("chip:4 x pod:4", "chip:4 x host:2 x pod:2"):
        spec = TopologySpec.parse(spec_str)
        print(f"\n=== {spec_str} ===")
        print(f"  levels: {[f'{l.name}:{l.fanout}@{l.bandwidth:g}B/s' for l in spec.levels]}")
        print(f"  R={spec.n_replicas} world={spec.world} "
              f"inner periods: {derive_inner_periods(spec, b_max=4) or '(none)'}")
        res = run_training(loss_fn, params0, data_fn, TrainLoopConfig(
            strategy="daso", n_steps=steps, topology=spec_str,
            b_max=4, lr=0.1, loss_window=20))
        runs[spec_str] = res
        counts = res.controller.level_sync_counts()
        print(f"  final loss: {res.final_loss:.4f}")
        print(f"  outermost (DCN) syncs: {counts['_outer']} steps "
              f"({res.sync_fraction:.0%})")
        for name, n in counts.items():
            if name != "_outer":
                print(f"  {name}-level syncs: {n} steps (fast mid-tier)")
        seen = []
        for _, mode, _, _ in res.controller.history:
            if mode not in seen:
                seen.append(mode)
        print(f"  step variants compiled: {seen}")

    two, three = runs.values()
    print(f"\n3-level vs 2-level final loss: {three.final_loss:.4f} vs "
          f"{two.final_loss:.4f} at the SAME outermost sync fraction "
          f"({three.sync_fraction:.0%}) — the mid-tier consensus comes on "
          f"links the 2-level spec leaves idle.")
    print("Sweep + analytic per-level byte accounting: "
          "python -m benchmarks.run --only topology")


if __name__ == "__main__":
    main()
