"""Ablation of the paper's knobs (section 4 + supplementary):
  * B (max batches between global syncs): larger B = less global traffic but
    a larger effective batch -> quality degrades at large B (paper Fig 7's
    256-GPU effect, reproduced via virtual nodes)
  * staleness weighting (Eq. 1) vs naive overwrite (local-SGD style)
  * iid vs non-iid node data (the paper's core assumption)
  * macro-cycle executor vs per-step reference path: identical loss traces,
    far fewer host dispatches (core/executor.py)

All runs drive through the strategy registry; every registered strategy
(`repro.core.executor.list_strategies()`) is ablatable by name.

  PYTHONPATH=src python examples/daso_schedule_ablation.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.resnet50 import ResNetConfig
from repro.core.executor import list_strategies
from repro.data.synthetic import SyntheticImages, make_noniid_class_partition
from repro.models.cnn import init_resnet
from repro.train.loop import TrainLoopConfig, run_training
from repro.train.step import make_resnet_loss


def make_problem(n_nodes, noniid=False, per_node_batch=8):
    cfg = ResNetConfig(name="resnet-tiny", stage_sizes=(1, 1), width=8,
                       bottleneck=False, n_classes=4, image_size=16)
    src = SyntheticImages(n_classes=4, image_size=16, seed=0)
    params, state = init_resnet(cfg, jax.random.PRNGKey(0))
    loss_fn = make_resnet_loss(cfg)
    weights = (make_noniid_class_partition(4, n_nodes, alpha=0.2, seed=0)
               if noniid else None)

    def data(step):
        outs = []
        for r in range(n_nodes):
            w = None if weights is None else weights[r]
            outs.append(src.batch(per_node_batch, step * n_nodes + r,
                                  class_weights=w))
        batch = {k: jnp.stack([o[k] for o in outs]) for k in outs[0]}
        batch["bn_state"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_nodes,) + x.shape), state)
        return batch

    return {"net": params}, loss_fn, data


def run(tag, strategy, n_nodes, b_max, noniid=False, steps=120,
        executor="macro"):
    assert strategy in list_strategies(), (strategy, list_strategies())
    params0, loss_fn, data = make_problem(n_nodes, noniid=noniid)
    res = run_training(loss_fn, params0, data, TrainLoopConfig(
        strategy=strategy, n_steps=steps, n_replicas=n_nodes, local_world=4,
        b_max=b_max, lr=0.05, loss_window=10, executor=executor), log=None)
    import numpy as np
    acc = np.mean([m.get("acc", 0.0) for m in res.metrics[-12:]])
    stats = res.executor_stats
    disp = f" dispatches={stats.dispatches}/{steps}" if stats else ""
    print(f"{tag:40s} final_loss={res.final_loss:.4f} acc={acc:.3f} "
          f"sync_frac={res.sync_fraction:.2f}{disp}")
    return res


def run_lm(tag, b_max, n_nodes=4, steps=150):
    """B sweep on the (harder, non-saturating) LM task."""
    import jax
    from repro.configs import get_reduced
    from repro.data.synthetic import SyntheticLM
    from repro.models.lm import init_params
    from repro.train.step import make_lm_loss
    cfg = get_reduced("llama3.2-1b").replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=256)
    params0 = init_params(cfg, jax.random.PRNGKey(0))
    loss_fn = make_lm_loss(cfg)
    src = SyntheticLM(vocab_size=256, seq_len=64, seed=0)
    per = 8

    def data(step):
        b = src.batch(n_nodes * per, step)
        return {k: v.reshape((n_nodes, per) + v.shape[1:])
                for k, v in b.items()}

    res = run_training(loss_fn, params0, data, TrainLoopConfig(
        strategy="daso", n_steps=steps, n_replicas=n_nodes, local_world=4,
        b_max=b_max, lr=0.05, loss_window=15), log=None)
    print(f"{tag:40s} final_loss={res.final_loss:.4f} "
          f"sync_frac={res.sync_fraction:.2f}")
    return res


def main():
    print("== B sweep on tiny LM (larger B = bigger effective batch / more "
          "staleness, paper Fig 7 mechanism) ==")
    for b in (1, 4, 8, 16):
        run_lm(f"daso B={b}", b_max=b)
    print("\n== Eq.(1) staleness weighting vs naive periodic averaging ==")
    run("daso (Eq.1 weighted merge)", "daso", n_nodes=4, b_max=4)
    run("local_sgd (naive overwrite)", "local_sgd", n_nodes=4, b_max=4)
    print("\n== iid assumption (paper: non-iid breaks all DP schemes) ==")
    run("daso iid nodes", "daso", n_nodes=4, b_max=4, noniid=False)
    run("daso NON-iid nodes", "daso", n_nodes=4, b_max=4, noniid=True)
    print("\n== macro-cycle executor vs per-step reference (same numerics, "
          "fewer host dispatches) ==")
    a = run("daso macro-cycle executor", "daso", n_nodes=4, b_max=4)
    b = run("daso per-step reference", "daso", n_nodes=4, b_max=4,
            executor="per_step")
    import numpy as np
    drift = float(np.max(np.abs(np.asarray(a.losses) - np.asarray(b.losses))))
    print(f"{'max |loss trace drift|':40s} {drift:.2e} (expect ~f32 eps)")


if __name__ == "__main__":
    main()
